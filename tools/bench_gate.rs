//! Bench-regression gate: hold the benches' machine-readable results to
//! committed baseline bands, so CI fails when a headline serving property
//! regresses instead of silently drifting.
//!
//! Baselines live in `ci/bench_baselines/`, one JSON file per gated
//! result (same file name the bench dumps into `bench_results/`):
//!
//! ```json
//! {
//!   "metrics": {
//!     "throughput_ratio": {"min": 1.1, "max": 1.6},
//!     "slot_utilization": {"min": 0.85}
//!   }
//! }
//! ```
//!
//! Every gated metric must be present in the result and inside its
//! `[min, max]` band (either bound may be omitted). The gated metrics are
//! deliberately *virtual-time / ratio* quantities — deterministic across
//! machines — never wall-clock samples; the bands are the tolerance. To
//! tighten a band, copy the `bench-results` CI artifact's value in.
//!
//! Gated results: `BENCH_continuous.json` (iteration-level batching),
//! `BENCH_qos.json` (actuator win at overload), `BENCH_interval.json`
//! (interval/cadence SSIM gains), `BENCH_cluster.json` (replica scaling
//! ≥ 3.4× at 4 replicas, plan-cost routing p95 ≤ round-robin),
//! `BENCH_telemetry.json` (observation overhead), `BENCH_cache.json`
//! (amortization tiers), `BENCH_stream.json` (mid-flight cancel
//! reclaiming ≥ 1.15× useful throughput, no scenario class starving),
//! `BENCH_cost.json` (ms-priced routing p95 ≤ unit-slot p95 on the
//! speed-heterogeneous fleet, zero analytic fallbacks on the calibrated
//! grid) and `BENCH_planner.json` (frontier-guided admission: no SLO
//! regression, strictly higher mean SSIM where the legacy actuator
//! widened, exactly one O(1) frontier search per admission).
//!
//! Usage (from `rust/`, after `cargo bench -- --fast`):
//!
//! ```text
//! cargo run --release --bin bench-gate -- \
//!     --baselines ../ci/bench_baselines --results bench_results
//! ```

use std::path::{Path, PathBuf};

use selective_guidance::benchutil::Table;
use selective_guidance::json::{self, Value};

fn main() {
    match run() {
        Ok(()) => {}
        Err(e) => {
            eprintln!("bench-gate: {e}");
            std::process::exit(1);
        }
    }
}

fn load(path: &Path) -> Result<Value, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    json::from_str(&text).map_err(|e| format!("parsing {}: {e}", path.display()))
}

fn run() -> Result<(), String> {
    let mut baselines = PathBuf::from("../ci/bench_baselines");
    let mut results = PathBuf::from("bench_results");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--baselines" => {
                baselines = PathBuf::from(args.next().ok_or("--baselines needs a dir")?)
            }
            "--results" => results = PathBuf::from(args.next().ok_or("--results needs a dir")?),
            other => return Err(format!("unknown argument {other:?}")),
        }
    }

    let mut files: Vec<PathBuf> = std::fs::read_dir(&baselines)
        .map_err(|e| format!("reading {}: {e}", baselines.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    files.sort();
    if files.is_empty() {
        return Err(format!("no baseline files in {}", baselines.display()));
    }

    let mut table = Table::new(&["result", "metric", "value", "band", "status"]);
    let mut failures = 0usize;
    let mut checked = 0usize;
    for base_path in &files {
        let name = base_path
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| format!("bad baseline path {}", base_path.display()))?
            .to_string();
        let baseline = load(base_path)?;
        let result = load(&results.join(&name))?;
        let metrics = match baseline.get("metrics") {
            Some(Value::Obj(m)) => m,
            _ => return Err(format!("{name}: baseline has no \"metrics\" object")),
        };
        for (metric, band) in metrics {
            let min = band.get("min").and_then(Value::as_f64);
            let max = band.get("max").and_then(Value::as_f64);
            if min.is_none() && max.is_none() {
                return Err(format!("{name}/{metric}: band needs a min and/or max"));
            }
            let band_str = format!(
                "[{}, {}]",
                min.map(|v| format!("{v}")).unwrap_or_else(|| "-inf".into()),
                max.map(|v| format!("{v}")).unwrap_or_else(|| "+inf".into()),
            );
            checked += 1;
            let (value_str, ok) = match result.get(metric).and_then(Value::as_f64) {
                None => ("missing".to_string(), false),
                Some(v) => {
                    let ok = v.is_finite()
                        && min.map(|lo| v >= lo).unwrap_or(true)
                        && max.map(|hi| v <= hi).unwrap_or(true);
                    (format!("{v:.4}"), ok)
                }
            };
            if !ok {
                failures += 1;
            }
            table.row(&[
                name.clone(),
                metric.clone(),
                value_str,
                band_str,
                if ok { "ok".into() } else { "REGRESSION".into() },
            ]);
        }
    }
    println!("\nBench-regression gate ({checked} metrics, {} baselines):\n", files.len());
    table.print();
    if failures > 0 {
        return Err(format!("{failures} metric(s) outside their baseline band"));
    }
    println!("\nall gated metrics inside their bands");
    Ok(())
}
