//! Quickstart: generate one image with and without selective guidance.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the three-line API: load a [`ModelStack`], build an
//! [`Engine`], submit a [`GenerationRequest`] — and the paper's headline
//! trade-off: optimizing the last 20% of iterations cuts UNet executions
//! from 100 to 90 with an imperceptible output change. A fast
//! calibration pass then restates both plans in measured milliseconds
//! (e.g. `100D ≈ 812 ms` vs `80D 20C ≈ 731 ms`).

use std::path::Path;
use std::sync::Arc;

use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::{FallbackPolicy, GuidancePlan, GuidanceSchedule, WindowSpec};
use selective_guidance::quality::{psnr, ssim};
use selective_guidance::runtime::{calibrate, CalibrationConfig, ModelStack};

fn main() -> selective_guidance::Result<()> {
    let artifacts =
        std::env::var("SG_ARTIFACTS").unwrap_or_else(|_| "artifacts/tiny".to_string());
    eprintln!("loading artifacts from {artifacts} ...");
    let stack = Arc::new(ModelStack::load(&artifacts)?);
    let engine = Engine::new(Arc::clone(&stack), EngineConfig::default());

    let prompt = "A person holding a cat";

    // warm the executables (first PJRT execution pays one-off costs)
    engine.generate(&GenerationRequest::new(prompt).steps(4).decode(false))?;

    // -- baseline: full classifier-free guidance on every iteration -----
    let baseline = engine.generate(&GenerationRequest::new(prompt).seed(7))?;
    println!(
        "baseline : {:>7.1} ms, {} UNet evals",
        baseline.wall_ms, baseline.unet_evals
    );

    // -- the paper's recommended config: optimize the last 20% ----------
    let optimized = engine.generate(
        &GenerationRequest::new(prompt)
            .seed(7)
            .selective(WindowSpec::last(0.2)),
    )?;
    println!(
        "last 20% : {:>7.1} ms, {} UNet evals",
        optimized.wall_ms, optimized.unet_evals
    );

    let saving = 100.0 * (baseline.wall_ms - optimized.wall_ms) / baseline.wall_ms;
    println!("saving   : {saving:>6.1} %  (paper: ~8.2%)");

    // -- priced plan summaries ------------------------------------------
    // microbench the loaded runtime (fast grid) and restate both plans in
    // measured milliseconds instead of abstract UNet evals
    eprintln!("calibrating step costs (fast grid) ...");
    let manifest = calibrate(&stack, &CalibrationConfig::fast())?;
    let table = manifest.table(FallbackPolicy::Analytic)?;
    let cfg = EngineConfig::default();
    let full =
        GuidancePlan::compile(&cfg.schedule, cfg.guidance_scale, cfg.guidance_strategy, cfg.steps)?;
    let windowed = GuidancePlan::compile(
        &GuidanceSchedule::Window(WindowSpec::last(0.2)),
        cfg.guidance_scale,
        cfg.guidance_strategy,
        cfg.steps,
    )?;
    println!(
        "priced   : {} ≈ {:.0} ms  vs  {} ≈ {:.0} ms  ({} backend, checksum {})",
        full.summary(),
        full.cost_ms(&table),
        windowed.summary(),
        windowed.cost_ms(&table),
        manifest.backend,
        manifest.checksum,
    );

    let (a, b) = (baseline.image.as_ref().unwrap(), optimized.image.as_ref().unwrap());
    println!("quality  : SSIM {:.4}, PSNR {:.1} dB vs baseline", ssim(a, b), psnr(a, b));

    std::fs::create_dir_all("out").ok();
    a.save_png(Path::new("out/quickstart_baseline.png"))?;
    b.save_png(Path::new("out/quickstart_optimized.png"))?;
    println!("wrote out/quickstart_baseline.png, out/quickstart_optimized.png");
    Ok(())
}
