//! Iteration-sensitivity sweep (the paper's §2 / Figure 1 experiment).
//!
//! Slides a fixed-size optimization window (25% of iterations) across the
//! denoising loop and measures output quality vs the unoptimized
//! baseline. The paper's finding: quality improves as the window moves
//! right (later iterations are less sensitive).
//!
//! ```bash
//! cargo run --release --example sensitivity_sweep
//! ```

use std::path::Path;
use std::sync::Arc;

use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::WindowSpec;
use selective_guidance::prompts;
use selective_guidance::quality::{latent_drift, psnr, ssim};
use selective_guidance::runtime::ModelStack;

fn main() -> selective_guidance::Result<()> {
    let artifacts =
        std::env::var("SG_ARTIFACTS").unwrap_or_else(|_| "artifacts/tiny".to_string());
    let stack = Arc::new(ModelStack::load(&artifacts)?);
    let engine = Engine::new(stack, EngineConfig::default());

    let prompt = prompts::FIG1_PROMPT; // "A person holding a cat"
    let steps = 48; // divisible into quarters like Figure 1
    let seed = 11;

    let base = engine.generate(&GenerationRequest::new(prompt).steps(steps).seed(seed))?;
    let base_img = base.image.as_ref().unwrap();
    std::fs::create_dir_all("out").ok();
    base_img.save_png(Path::new("out/fig1_baseline.png"))?;

    println!("window of 25% of {steps} iterations, sliding left -> right");
    println!(
        "{:<14} | {:>10} | {:>9} | {:>9} | {:>8}",
        "window", "latent drift", "SSIM", "PSNR dB", "evals"
    );
    println!("{}", "-".repeat(62));
    let mut prev_ssim = -1.0f64;
    let mut ssims = Vec::new();
    for (label, offset) in
        [("first 25%", 0.0), ("25-50%", 0.25), ("50-75%", 0.5), ("last 25%", 0.75)]
    {
        let out = engine.generate(
            &GenerationRequest::new(prompt)
                .steps(steps)
                .seed(seed)
                .selective(WindowSpec::at_offset(offset, 0.25)),
        )?;
        let img = out.image.as_ref().unwrap();
        let s = ssim(base_img, img);
        let p = psnr(base_img, img);
        let d = latent_drift(&base.latent, &out.latent);
        println!("{label:<14} | {d:>12.4} | {s:>9.4} | {p:>9.1} | {:>8}", out.unet_evals);
        img.save_png(Path::new(&format!("out/fig1_offset{}.png", (offset * 100.0) as u32)))?;
        ssims.push(s);
        prev_ssim = prev_ssim.max(s);
    }
    // the paper's qualitative claim, quantified
    let improving = ssims.windows(2).filter(|w| w[1] >= w[0]).count();
    println!(
        "\nSSIM improves in {improving}/3 transitions as the window moves right \
         (paper: quality increases monotonically)"
    );
    Ok(())
}
