//! Guidance-scale retuning demo (the paper's §3.4 / Figure 4).
//!
//! At an aggressive 40% optimization window the trajectory receives less
//! total conditioning ("loses detail", in the paper's terms — the third
//! turkey vanishes). Raising the guidance scale compensates. This demo
//! measures the delivered conditioning as the *guidance displacement* G
//! (distance from the same-seed unguided trajectory), shows the deficit
//! at the naive scale, and uses [`GsTuner`] to pick the scale that
//! restores the baseline's G.
//!
//! It closes by sweeping the whole schedule grammar into a Pareto
//! frontier (DESIGN.md §16) and printing the table, so the hand-tuned
//! 40% window can be read against the plans `sgd-serve` would actually
//! pick under load.
//!
//! ```bash
//! cargo run --release --example gs_tuning
//! ```

use std::path::Path;
use std::sync::Arc;

use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::{retuned_scale, CostTable, GsTuner, TunerConfig, WindowSpec};
use selective_guidance::prompts;
use selective_guidance::quality::latent_drift;
use selective_guidance::runtime::{tune, ModelStack};

fn main() -> selective_guidance::Result<()> {
    let artifacts =
        std::env::var("SG_ARTIFACTS").unwrap_or_else(|_| "artifacts/tiny".to_string());
    let stack = Arc::new(ModelStack::load(&artifacts)?);
    let engine = Engine::new(Arc::clone(&stack), EngineConfig::default());

    let prompt = prompts::FIG4_PROMPT; // the wild-turkeys prompt of Fig. 4
    let steps = 50;
    let seed = 4;
    let fraction = 0.4;

    let gen = |gs: f32, f: f64| {
        engine
            .generate(
                &GenerationRequest::new(prompt)
                    .steps(steps)
                    .seed(seed)
                    .guidance_scale(gs)
                    .selective(WindowSpec::last(f)),
            )
            .expect("generate")
    };

    let unguided = gen(1.0, 0.0);
    let baseline = gen(7.5, 0.0);
    let g_base = latent_drift(&unguided.latent, &baseline.latent);
    println!("baseline (GS 7.5, no opt): guidance displacement G = {g_base:.4}");

    std::fs::create_dir_all("out").ok();
    baseline.image.as_ref().unwrap().save_png(Path::new("out/fig4_baseline.png"))?;

    // aggressive optimization at the default scale: guidance deficit
    let naive = gen(7.5, fraction);
    let g_naive = latent_drift(&unguided.latent, &naive.latent);
    println!(
        "40% window @ GS 7.5   : G = {g_naive:.4}  (deficit {:+.4} — the 'lost detail')",
        g_naive - g_base
    );
    naive.image.as_ref().unwrap().save_png(Path::new("out/fig4_naive.png"))?;

    // tune: restore the baseline's guidance displacement
    println!(
        "\nsweeping GS in [7.5, {:.2}] to close the deficit:",
        retuned_scale(7.5, fraction, 1.0)
    );
    let tuner = GsTuner::around(7.5, fraction, 8);
    let (best_scale, _) = tuner.tune(|scale| {
        let out = gen(scale, fraction);
        let g = latent_drift(&unguided.latent, &out.latent);
        println!("  GS {scale:>6.2} : G = {g:.4} ({:+.4})", g - g_base);
        -(g - g_base).abs() // maximize closeness to baseline conditioning
    });

    let tuned = gen(best_scale, fraction);
    let g_tuned = latent_drift(&unguided.latent, &tuned.latent);
    println!(
        "\nretuned GS {best_scale:.2}: G = {g_tuned:.4} ({:+.4} vs baseline; paper: 7.5 -> 9.6 \
         restored the third bird)",
        g_tuned - g_base
    );
    tuned.image.as_ref().unwrap().save_png(Path::new("out/fig4_tuned.png"))?;
    println!("wrote out/fig4_baseline.png, out/fig4_naive.png, out/fig4_tuned.png");

    // ---- where does the 40% window sit on the Pareto frontier? --------
    // Sweep the full schedule grammar (windows x cadences x intervals x
    // strategies) at these steps, engine-scored against full CFG, priced
    // on a proportional table (dual = 2u) — DESIGN.md §16. This is the
    // same sweep `sgd-serve tune` seals for the serving planner.
    let tuner = TunerConfig { steps_buckets: vec![steps], ..TunerConfig::fast() };
    println!(
        "\nsweeping {} schedule candidates into the Pareto frontier @ {steps} steps ...",
        tuner.candidates().len()
    );
    let manifest = tune(Arc::clone(&stack), &tuner, &CostTable::proportional(1.0, &[1, 2, 4]))?;
    for bucket in &manifest.buckets {
        println!(
            "frontier @ {} steps (full CFG {:.1} ms): {} non-dominated plan(s)",
            bucket.steps,
            bucket.full_cost_ms,
            bucket.points.len()
        );
        for p in &bucket.points {
            println!(
                "  {:<28} ssim {:.4}  cost {:>7.1} ms  (saving {:.0}%)",
                p.label,
                p.ssim,
                p.cost_ms,
                p.saving(bucket.full_cost_ms) * 100.0,
            );
        }
    }
    println!(
        "(every plan above dominates the rest of the grammar: under load, admission \
         degrades along these points instead of only widening the last-window)"
    );
    Ok(())
}
