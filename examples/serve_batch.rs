//! End-to-end serving validation (the DESIGN.md §6 driver).
//!
//! Boots the full stack — ModelStack → Engine → Coordinator → TCP server
//! — then plays a mixed client workload over the wire-protocol v2
//! envelope (DESIGN.md §14): baseline CFG requests interleaved with
//! selective-guidance requests at the paper's operating points, followed
//! by a streamed variations fan-out with progressive previews. Reports
//! per-config latency and aggregate throughput. Recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_batch
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::{BatchMode, Coordinator, CoordinatorConfig};
use selective_guidance::engine::Engine;
use selective_guidance::json::Value;
use selective_guidance::metrics::SampleStats;
use selective_guidance::prompts;
use selective_guidance::runtime::ModelStack;
use selective_guidance::server::{Client, Server};

fn main() -> selective_guidance::Result<()> {
    let artifacts =
        std::env::var("SG_ARTIFACTS").unwrap_or_else(|_| "artifacts/tiny".to_string());
    let steps: i64 = std::env::var("SG_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(50);
    let per_config: usize =
        std::env::var("SG_REQUESTS").ok().and_then(|s| s.parse().ok()).unwrap_or(8);

    eprintln!("loading artifacts from {artifacts} ...");
    let stack = Arc::new(ModelStack::load(&artifacts)?);
    let engine = Arc::new(Engine::new(stack, EngineConfig::default()));
    let coordinator = Coordinator::start(
        Arc::clone(&engine),
        CoordinatorConfig {
            max_batch: 4,
            workers: 2,
            batch_wait: Duration::from_millis(3),
            ..CoordinatorConfig::default()
        },
    );
    let server = Server::start(Arc::clone(&coordinator), "127.0.0.1:0")?;
    let addr = server.addr().to_string();
    println!("serving on {addr}; {steps} steps per image, {per_config} requests per config\n");

    // mixed workload: the paper's Table-1 operating points
    let configs: &[(&str, f64)] =
        &[("baseline", 0.0), ("last 20%", 0.2), ("last 30%", 0.3), ("last 50%", 0.5)];

    let t_all = Instant::now();
    let mut handles = Vec::new();
    for (ci, &(name, fraction)) in configs.iter().enumerate() {
        let addr = addr.clone();
        let name = name.to_string();
        handles.push(std::thread::spawn(move || -> (String, Vec<f64>, i64) {
            let mut client = Client::connect(&addr).expect("connect");
            let mut latencies = Vec::new();
            let mut evals = 0i64;
            for i in 0..per_config {
                let prompt = prompts::TABLE2[(ci * per_config + i) % prompts::TABLE2.len()];
                let mut req = Value::obj()
                    .with("v", 2i64)
                    .with("op", "generate")
                    .with("prompt", prompt)
                    .with("steps", steps)
                    .with("scheduler", "pndm")
                    .with("seed", (1000 * ci + i) as i64);
                if fraction > 0.0 {
                    req = req.with("window_fraction", fraction).with("window_position", "last");
                }
                let t0 = Instant::now();
                let resp = client.call(req).expect("generate");
                latencies.push(t0.elapsed().as_secs_f64() * 1e3);
                assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp}");
                evals += resp.get("unet_evals").and_then(Value::as_i64).unwrap_or(0);
            }
            (name, latencies, evals)
        }));
    }

    let mut results = Vec::new();
    for h in handles {
        results.push(h.join().expect("client thread"));
    }
    let wall_s = t_all.elapsed().as_secs_f64();

    println!("{:<10} | {:>9} | {:>9} | {:>9} | {:>11}", "config", "mean ms", "p50 ms", "max ms", "unet evals");
    println!("{}", "-".repeat(60));
    for (name, lat, evals) in &results {
        let s = SampleStats::from(lat);
        println!(
            "{:<10} | {:>9.1} | {:>9.1} | {:>9.1} | {:>11}",
            name, s.mean, s.p50, s.max, evals
        );
    }
    let total_reqs = configs.len() * per_config;
    let stats = coordinator.stats();
    println!("\ntotal: {total_reqs} images in {wall_s:.1}s = {:.2} img/s", total_reqs as f64 / wall_s);
    println!(
        "coordinator: {} batches for {} requests (avg batch {:.2}), p90 latency {:.0} ms",
        stats.batches,
        stats.batched_requests,
        stats.batched_requests as f64 / stats.batches.max(1) as f64,
        stats.latency_ms_p90
    );
    assert_eq!(stats.completed as usize, total_reqs);
    assert_eq!(stats.failed, 0);

    // ---- streaming plane (DESIGN.md §14): v2 event frames -------------
    // A continuous-mode coordinator serves a variations fan-out (two
    // seeds sharing one compiled plan) with progressive previews, all
    // multiplexed over a single connection as id-stamped event frames.
    let streamer = Coordinator::start(
        Arc::clone(&engine),
        CoordinatorConfig {
            mode: BatchMode::Continuous,
            slot_budget: 8,
            workers: 1,
            ..CoordinatorConfig::default()
        },
    );
    let stream_server = Server::start(Arc::clone(&streamer), "127.0.0.1:0")?;
    let mut sc = Client::connect(&stream_server.addr().to_string())?;
    let sid = sc.send(
        Value::obj()
            .with("v", 2i64)
            .with("op", "generate")
            .with("prompt", prompts::TABLE2[0])
            .with("steps", steps)
            .with("scheduler", "pndm")
            .with("seed", 7i64)
            .with("window_fraction", 0.5)
            .with("window_position", "last")
            .with("stream", true)
            .with("preview_every", (steps / 4).max(1))
            .with("variations", 2i64),
    )?;
    let (mut done, mut progress, mut previews) = (0usize, 0usize, 0usize);
    while done < 2 {
        let frame = sc.read_frame()?;
        assert_eq!(frame.get("id").and_then(Value::as_i64), Some(sid), "{frame}");
        match frame.get("event").and_then(Value::as_str) {
            Some("queued") => {}
            Some("progress") => progress += 1,
            Some("preview") => previews += 1,
            Some("done") => {
                assert_eq!(frame.get("ok").and_then(Value::as_bool), Some(true), "{frame}");
                done += 1;
            }
            other => panic!("unexpected stream frame {other:?}: {frame}"),
        }
    }
    println!(
        "\nstreamed 2 variations over one connection: {progress} progress frames, {previews} previews"
    );
    assert!(previews >= 1, "preview cadence produced no frames");
    let sstats = streamer.stats();
    assert_eq!(sstats.completed, 2);
    assert_eq!(sstats.failed, 0);
    println!("serve_batch OK");
    Ok(())
}
