//! Streaming-plane equivalence properties on the deterministic
//! synthetic backend (no PJRT artifacts needed — this suite always
//! runs, and the whole-suite `PROP_MASTER_SEED` CI matrix re-runs it in
//! other randomness universes).
//!
//! The invariants under test are DESIGN.md §14's contract:
//!
//! * watching is observation-only — a streamed sample's final output is
//!   **bit-exact** with its solo [`Engine::generate`] run, whatever the
//!   preview cadence, cohort mix or workload kind (text2img, img2img,
//!   variations);
//! * progress events are strictly monotone in step index and previews
//!   land exactly on the requested cadence;
//! * a mid-flight cancel frees the sample's continuous-batch slots as
//!   admission headroom, resolves the ticket with [`Error::Cancelled`],
//!   and closes the telemetry span with exactly one `cancelled`
//!   terminal;
//! * the v1 and v2 wire surfaces answer a non-streamed `generate` with
//!   the same payload, and one multiplexer thread serves hundreds of
//!   concurrent streaming connections (frames split at arbitrary byte
//!   boundaries included).

use std::io::{BufRead, BufReader, Write as IoWrite};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::{
    BatchMode, Coordinator, CoordinatorConfig, ProgressEvent, WatchOptions, Watched,
};
use selective_guidance::engine::{Engine, GenerationOutput, GenerationRequest};
use selective_guidance::error::{Error, Result};
use selective_guidance::guidance::{GuidanceStrategy, ReuseKind, WindowSpec};
use selective_guidance::json::{self, Value};
use selective_guidance::qos::QosMeta;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::server::{Client, Server};
use selective_guidance::telemetry::{Clock, CoordSink, Telemetry};
use selective_guidance::testutil::prop::{forall, Gen};

fn engine() -> Arc<Engine> {
    Arc::new(Engine::new(Arc::new(ModelStack::synthetic()), EngineConfig::default()))
}

fn continuous(e: &Arc<Engine>, slot_budget: usize) -> Arc<Coordinator> {
    Coordinator::start(
        Arc::clone(e),
        CoordinatorConfig {
            mode: BatchMode::Continuous,
            slot_budget,
            workers: 1,
            ..CoordinatorConfig::default()
        },
    )
}

fn random_request(g: &mut Gen) -> GenerationRequest {
    let kinds = [
        SchedulerKind::Ddim,
        SchedulerKind::Ddpm,
        SchedulerKind::Pndm,
        SchedulerKind::Euler,
        SchedulerKind::Heun,
    ];
    let strategy = match g.usize_in(0, 2) {
        0 => GuidanceStrategy::CondOnly,
        1 => GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: g.usize_in(0, 4) },
        _ => GuidanceStrategy::Reuse {
            kind: ReuseKind::Extrapolate,
            refresh_every: g.usize_in(0, 4),
        },
    };
    let mut req = GenerationRequest::new(format!("{} {}", g.word(8), g.word(8)))
        .steps(g.usize_in(3, 10))
        .scheduler(*g.choose(&kinds))
        .seed(g.u64())
        .guidance_scale(if g.bool() { g.f32_in(1.5, 12.0) } else { 1.0 })
        .selective(if g.bool() { WindowSpec::last(g.f64_in(0.0, 1.0)) } else { WindowSpec::none() })
        .strategy(strategy)
        .decode(false);
    if g.bool() {
        // img2img: the streamed trajectory is the strength-truncated
        // suffix; equivalence must hold over it too
        req = req.img2img(g.f64_in(0.15, 1.0));
    }
    req
}

/// Drain a watched submission: final result plus every buffered
/// progress event (the channel is unbounded, so nothing is lost).
fn drain(w: Watched) -> (Vec<ProgressEvent>, Result<GenerationOutput>) {
    let out = w.ticket.wait();
    let mut events = Vec::new();
    while let Ok(ev) = w.progress.try_recv() {
        events.push(ev);
    }
    (events, out)
}

#[test]
fn streamed_output_matches_solo_matrix() {
    let e = engine();
    forall("streamed == solo", 25, |g| {
        let coordinator = continuous(&e, g.usize_in(2, 8));
        let k = g.usize_in(1, 4);
        let reqs: Vec<GenerationRequest> = (0..k).map(|_| random_request(g)).collect();
        let cadences: Vec<usize> = (0..k).map(|_| g.usize_in(0, 4)).collect();
        let watched: Vec<Watched> = reqs
            .iter()
            .zip(&cadences)
            .map(|(r, &every)| {
                coordinator
                    .submit_watched(
                        r.clone(),
                        QosMeta::default(),
                        WatchOptions { preview_every: every },
                    )
                    .expect("submit_watched")
            })
            .collect();
        for (r, w) in reqs.iter().zip(watched) {
            let (events, out) = drain(w);
            let out = out.expect("streamed run");
            let solo = e.generate(r).expect("solo run");
            assert_eq!(solo.latent, out.latent, "watching leaked into the output");
            assert_eq!(solo.unet_evals, out.unet_evals, "eval count");
            // strictly monotone step stream, bounded by the executed
            // trajectory (img2img truncates it)
            let steps = r.executed_steps();
            for pair in events.windows(2) {
                assert!(pair[1].step > pair[0].step, "progress went backwards");
            }
            assert!(events.iter().all(|ev| ev.step <= steps && ev.steps == steps));
        }
        coordinator.shutdown();
    });
}

#[test]
fn variations_stream_bit_exact_with_shared_plan() {
    let e = engine();
    let coordinator = continuous(&e, 6);
    let base = GenerationRequest::new("a shared plan")
        .steps(7)
        .scheduler(SchedulerKind::Ddim)
        .selective(WindowSpec::last(0.5))
        .seed(40)
        .decode(false);
    let vars = base.variations(3).expect("fan-out");
    for (i, vr) in vars.iter().enumerate() {
        assert!(vr.shared_plan.is_some(), "variation {i} lost the shared plan");
        let w = coordinator
            .submit_watched(vr.clone(), QosMeta::default(), WatchOptions::off())
            .expect("submit");
        let (_, out) = drain(w);
        let out = out.expect("variation run");
        // the shared plan must not change the sample: rebuild the same
        // request without it and compare bit-for-bit
        let unshared = base.clone().seed(40 + i as u64);
        let solo = e.generate(&unshared).expect("solo");
        assert_eq!(solo.latent, out.latent, "variation {i}");
        assert_eq!(solo.unet_evals, out.unet_evals, "variation {i}");
    }
    coordinator.shutdown();
}

#[test]
fn preview_cadence_exact() {
    let e = engine();
    let coordinator = continuous(&e, 4);
    let req = GenerationRequest::new("previews")
        .steps(12)
        .scheduler(SchedulerKind::Ddim)
        .seed(9)
        .decode(false);
    let w = coordinator
        .submit_watched(req, QosMeta::default(), WatchOptions { preview_every: 3 })
        .expect("submit");
    let (events, out) = drain(w);
    out.expect("run");
    assert!(!events.is_empty(), "no progress events for a 12-step sample");
    for ev in &events {
        if ev.step % 3 == 0 {
            let img = ev.preview.as_ref().expect("preview on cadence step");
            assert!(img.width > 0 && img.height > 0);
        } else {
            assert!(ev.preview.is_none(), "preview off cadence at step {}", ev.step);
        }
    }
    assert!(events.iter().any(|ev| ev.preview.is_some()), "cadence 3 of 12 steps: previews due");
    coordinator.shutdown();
}

/// A request slow enough that a cancel issued after its first progress
/// event always lands while it is still mid-flight: Heun (2 evals per
/// iteration) × dual guidance (2 passes) at the step ceiling, with a
/// preview decode every iteration.
fn hog() -> GenerationRequest {
    GenerationRequest::new("hog")
        .steps(1000)
        .scheduler(SchedulerKind::Heun)
        .seed(1)
        .decode(false)
}

#[test]
fn cancel_mid_flight_frees_slots_and_closes_span_once() {
    let e = engine();
    let telemetry = Telemetry::with_clock(64, Clock::wall());
    let coordinator = Coordinator::start_full(
        Arc::clone(&e),
        CoordinatorConfig {
            mode: BatchMode::Continuous,
            slot_budget: 2,
            workers: 1,
            ..CoordinatorConfig::default()
        },
        None,
        Some(CoordSink::new(&telemetry, "single", true)),
    );
    // the dual hog costs 2 slots, saturating the budget: nothing else
    // can even join the cohort until it leaves
    let w = coordinator
        .submit_watched(hog(), QosMeta::default(), WatchOptions { preview_every: 1 })
        .expect("submit");
    let tid = w.ticket.trace().expect("traced submission");
    // wait until it is genuinely mid-flight (first iteration done)
    let first = w.progress.recv_timeout(Duration::from_secs(30)).expect("first progress event");
    assert!(first.step >= 1);
    w.cancel.cancel();
    assert!(w.cancel.is_cancelled());
    match w.ticket.wait() {
        Err(Error::Cancelled(_)) => {}
        Ok(_) => panic!("hog completed before the cancel landed"),
        Err(other) => panic!("expected Cancelled, got {other}"),
    }
    // the freed slots are real headroom: a follow-up dual sample (also
    // 2 slots) completes — it could never have joined alongside the hog
    let after = GenerationRequest::new("after")
        .steps(3)
        .scheduler(SchedulerKind::Ddim)
        .seed(2)
        .decode(false);
    let solo = e.generate(&after).expect("solo");
    let w2 = coordinator
        .submit_watched(after, QosMeta::default(), WatchOptions::off())
        .expect("submit");
    let out = w2.ticket.wait().expect("post-cancel sample");
    assert_eq!(solo.latent, out.latent);
    let stats = coordinator.stats();
    assert_eq!(stats.cancelled, 1);
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.failed, 0, "a cancel is not a failure");
    // the span closed exactly once, with the cancelled terminal
    let span = telemetry.traces().span(tid).expect("span retained");
    assert_eq!(span.terminal_events(), 1, "span must close exactly once");
    assert!(span.has("cancelled"), "terminal must be `cancelled`");
    assert!(!span.has("retired") && !span.has("shed"));
    coordinator.shutdown();
}

// ---------------------------------------------------------------------
// Wire-level properties (multiplexer + protocol v2)
// ---------------------------------------------------------------------

fn start_server(slot_budget: usize) -> (Server, String, Arc<Coordinator>) {
    let coordinator = continuous(&engine(), slot_budget);
    let server = Server::start(Arc::clone(&coordinator), "127.0.0.1:0").expect("bind");
    let addr = server.addr().to_string();
    (server, addr, coordinator)
}

/// Zero the measured wall-clock fields: everything else in a generate
/// response is deterministic on the synthetic backend.
fn zero_timings(v: &Value) -> Value {
    v.clone()
        .with("wall_ms", 0.0)
        .with("unet_cond_ms", 0.0)
        .with("unet_uncond_ms", 0.0)
        .with("combine_ms", 0.0)
        .with("scheduler_ms", 0.0)
}

#[test]
fn v1_and_v2_generate_answers_are_payload_identical() {
    let (_server, addr, _c) = start_server(4);
    let mut stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let body = "\"op\":\"generate\",\"id\":7,\"prompt\":\"wire\",\"steps\":4,\
                \"scheduler\":\"ddim\",\"seed\":3,\"window_fraction\":0.5";
    let mut read_one = |line: String| -> Value {
        stream.write_all(line.as_bytes()).unwrap();
        stream.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        json::from_str(&resp).unwrap()
    };
    let v1 = read_one(format!("{{{body}}}\n"));
    let v2 = read_one(format!("{{\"v\":2,{body}}}\n"));
    assert_eq!(v1.get("ok").and_then(Value::as_bool), Some(true), "{v1}");
    assert_eq!(v2.get("ok").and_then(Value::as_bool), Some(true), "{v2}");
    // identical key sets and identical canonical serialization once the
    // measured timings are zeroed — the v2 envelope adds nothing to a
    // non-streamed generate response
    let (Value::Obj(m1), Value::Obj(m2)) = (&v1, &v2) else { panic!("objects") };
    let k1: Vec<&String> = m1.keys().collect();
    let k2: Vec<&String> = m2.keys().collect();
    assert_eq!(k1, k2, "v1/v2 response key sets diverged");
    assert_eq!(zero_timings(&v1).to_string(), zero_timings(&v2).to_string());
}

#[test]
fn byte_at_a_time_client_still_parses() {
    // satellite regression: a frame trickling in one byte per write must
    // buffer until its newline, not be parsed as broken fragments
    let (_server, addr, _c) = start_server(4);
    let mut stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let frame = b"{\"op\":\"ping\",\"id\":1}\n";
    for &b in frame.iter() {
        stream.write_all(&[b]).unwrap();
        stream.flush().unwrap();
        std::thread::sleep(Duration::from_micros(200));
    }
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let v = json::from_str(&resp).unwrap();
    assert_eq!(v.get("pong").and_then(Value::as_bool), Some(true), "{v}");
    // and a second frame split mid-key across two writes
    stream.write_all(b"{\"op\":\"st").unwrap();
    stream.flush().unwrap();
    std::thread::sleep(Duration::from_millis(2));
    stream.write_all(b"ats\",\"id\":2}\n").unwrap();
    stream.flush().unwrap();
    resp.clear();
    reader.read_line(&mut resp).unwrap();
    let v = json::from_str(&resp).unwrap();
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true), "{v}");
    assert_eq!(v.get("id").and_then(Value::as_i64), Some(2));
}

#[test]
fn streamed_generate_over_wire_matches_solo() {
    let (_server, addr, _c) = start_server(4);
    let e = engine();
    let mut client = Client::connect(&addr).unwrap();
    let id = client
        .send(
            Value::obj()
                .with("v", 2i64)
                .with("op", "generate")
                .with("prompt", "a person holding a cat")
                .with("steps", 8i64)
                .with("scheduler", "ddim")
                .with("seed", 5i64)
                .with("stream", true)
                .with("preview_every", 4i64)
                .with("return_latent", true),
        )
        .unwrap();
    let mut steps_seen = Vec::new();
    let mut previews = 0usize;
    let done = loop {
        let v = client.read_frame().unwrap();
        assert_eq!(v.get("id").and_then(Value::as_i64), Some(id));
        assert_eq!(v.get("v").and_then(Value::as_i64), Some(2));
        match v.get("event").and_then(Value::as_str) {
            Some("queued") => {}
            Some("progress") => {
                steps_seen.push(v.get("step").and_then(Value::as_i64).unwrap());
            }
            Some("preview") => {
                previews += 1;
                assert!(v.get("png_b64").and_then(Value::as_str).is_some());
            }
            Some("done") => break v,
            other => panic!("unexpected event {other:?}: {v}"),
        }
    };
    assert!(steps_seen.windows(2).all(|w| w[1] > w[0]), "monotone: {steps_seen:?}");
    assert!(previews >= 1, "preview_every=4 over 8 steps: at least one preview");
    // the streamed final latent is bit-exact with the solo run
    let solo = e
        .generate(
            &GenerationRequest::new("a person holding a cat")
                .steps(8)
                .scheduler(SchedulerKind::Ddim)
                .seed(5)
                .decode(false),
        )
        .unwrap();
    let wire: Vec<f32> = match done.get("latent") {
        Some(Value::Arr(a)) => a.iter().map(|x| x.as_f64().unwrap() as f32).collect(),
        other => panic!("latent missing: {other:?}"),
    };
    // f32 -> json f64 -> f32 round-trips exactly
    assert_eq!(solo.latent, wire, "wire latent differs from solo");
}

#[test]
fn wire_cancel_aborts_stream_and_frees_admission() {
    let (_server, addr, coordinator) = start_server(2);
    let mut client = Client::connect(&addr).unwrap();
    let stream_id = client
        .send(
            Value::obj()
                .with("v", 2i64)
                .with("op", "generate")
                .with("prompt", "hog")
                .with("steps", 1000i64)
                .with("scheduler", "heun")
                .with("seed", 1i64)
                .with("stream", true)
                .with("preview_every", 1i64),
        )
        .unwrap();
    // wait until mid-flight: queued, then at least one progress event
    loop {
        let v = client.read_frame().unwrap();
        if v.get("event").and_then(Value::as_str) == Some("progress") {
            break;
        }
    }
    // the cancel ack interleaves with still-buffered event frames, so
    // match frames by id instead of assuming the next one is the ack
    let cancel_id = client
        .send(Value::obj().with("v", 2i64).with("op", "cancel").with("target", stream_id))
        .unwrap();
    let mut ack = None;
    let mut terminal = None;
    while ack.is_none() || terminal.is_none() {
        let v = client.read_frame().unwrap();
        match v.get("id").and_then(Value::as_i64) {
            Some(i) if i == cancel_id => ack = Some(v),
            Some(i) if i == stream_id => {
                if v.get("event").and_then(Value::as_str) == Some("error") {
                    terminal = Some(v);
                }
            }
            other => panic!("frame for unknown id {other:?}: {v}"),
        }
    }
    let ack = ack.unwrap();
    assert_eq!(ack.get("ok").and_then(Value::as_bool), Some(true), "{ack}");
    assert_eq!(ack.get("cancelled").and_then(Value::as_i64), Some(1));
    let err = terminal.unwrap();
    assert_eq!(err.get("code").and_then(Value::as_i64), Some(499), "{err}");
    // the freed slots admit new work: a plain generate completes
    let resp = client
        .call(
            Value::obj()
                .with("op", "generate")
                .with("prompt", "after")
                .with("steps", 3i64)
                .with("scheduler", "ddim")
                .with("seed", 2i64),
        )
        .unwrap();
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp}");
    assert_eq!(coordinator.stats().cancelled, 1);
    // cancelling a target with no live stream is a structured error
    let nak = client
        .call(Value::obj().with("v", 2i64).with("op", "cancel").with("target", 9999i64))
        .unwrap();
    assert_eq!(nak.get("ok").and_then(Value::as_bool), Some(false), "{nak}");
}

#[test]
fn one_multiplexer_thread_serves_256_streaming_connections() {
    let (_server, addr, coordinator) = start_server(16);
    let n = 256usize;
    let mut handles = Vec::new();
    for i in 0..n {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let id = client
                .send(
                    Value::obj()
                        .with("v", 2i64)
                        .with("op", "generate")
                        .with("prompt", format!("conn {i}"))
                        .with("steps", 2i64)
                        .with("scheduler", "ddim")
                        .with("seed", i as i64)
                        .with("stream", true),
                )
                .expect("send");
            loop {
                let v = client.read_frame().expect("frame");
                assert_eq!(v.get("id").and_then(Value::as_i64), Some(id));
                match v.get("event").and_then(Value::as_str) {
                    Some("done") => break,
                    Some("error") => panic!("stream errored: {v}"),
                    _ => {}
                }
            }
        }));
    }
    for h in handles {
        h.join().expect("streaming client");
    }
    let stats = coordinator.stats();
    assert_eq!(stats.completed as usize, n);
    assert_eq!(stats.failed, 0);
}
