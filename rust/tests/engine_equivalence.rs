//! Engine equivalence properties on the deterministic synthetic backend
//! (no PJRT artifacts needed — this suite always runs).
//!
//! For random seeded requests, `generate(r)` must equal the per-sample
//! output of `generate_batch([r, other])` **bit-for-bit**, under both
//! [`DualStrategy`] variants and across the whole guidance-strategy
//! lattice; and the executed `unet_evals` must match the policy's
//! analytic `total_unet_evals` (the engine itself hard-asserts this on
//! every run — these tests drive it through randomized configurations).

use std::sync::Arc;

use selective_guidance::config::{DualStrategy, EngineConfig};
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::{GuidanceStrategy, ReuseKind, WindowSpec};
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::testutil::prop::{forall, Gen};

fn engine(dual: DualStrategy) -> Engine {
    let cfg = EngineConfig { dual_strategy: dual, ..EngineConfig::default() };
    Engine::new(Arc::new(ModelStack::synthetic()), cfg)
}

fn random_strategy(g: &mut Gen) -> GuidanceStrategy {
    match g.usize_in(0, 2) {
        0 => GuidanceStrategy::CondOnly,
        1 => GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: g.usize_in(0, 5) },
        _ => GuidanceStrategy::Reuse {
            kind: ReuseKind::Extrapolate,
            refresh_every: g.usize_in(0, 5),
        },
    }
}

fn random_window(g: &mut Gen) -> WindowSpec {
    let f = g.f64_in(0.0, 1.0);
    match g.usize_in(0, 3) {
        0 => WindowSpec::last(f),
        1 => WindowSpec::first(f),
        2 => WindowSpec::middle(f),
        _ => WindowSpec::none(),
    }
}

/// A random request on shared (steps, scheduler) so it can batch.
fn random_request(g: &mut Gen, steps: usize, sched: SchedulerKind) -> GenerationRequest {
    let scale = if g.bool() { g.f32_in(1.5, 12.0) } else { 1.0 };
    GenerationRequest::new(format!("{} {}", g.word(8), g.word(8)))
        .steps(steps)
        .scheduler(sched)
        .seed(g.u64())
        .guidance_scale(scale)
        .selective(random_window(g))
        .strategy(random_strategy(g))
        .decode(false)
}

fn solo_matches_batch(dual: DualStrategy) {
    let e = engine(dual);
    let kinds = [
        SchedulerKind::Ddim,
        SchedulerKind::Ddpm,
        SchedulerKind::Pndm,
        SchedulerKind::Euler,
        SchedulerKind::EulerAncestral,
        SchedulerKind::DpmSolverPP,
        SchedulerKind::Heun,
    ];
    forall(&format!("solo == batch member ({dual:?})"), 60, |g| {
        let steps = g.usize_in(2, 10);
        let sched = *g.choose(&kinds);
        let r = random_request(g, steps, sched);
        let other = random_request(g, steps, sched);

        let solo = e.generate(&r).expect("solo");
        let batch = e.generate_batch(&[r.clone(), other.clone()]).expect("batch");

        // bit-for-bit: the synthetic backend computes each sample
        // independently, so bucketing must not change anything
        assert_eq!(
            solo.latent, batch[0].latent,
            "batched member diverged from solo run ({dual:?})"
        );
        assert_eq!(solo.unet_evals, batch[0].unet_evals);

        // executed evals == the analytic policy cost model, hard
        let policy = r.policy().unwrap();
        assert_eq!(
            solo.unet_evals,
            policy.total_unet_evals(steps),
            "evals diverge from cost model for {:?}",
            r.strategy
        );

        // the second member must also match its own solo run
        let solo_other = e.generate(&other).expect("solo other");
        assert_eq!(solo_other.latent, batch[1].latent);
        assert_eq!(solo_other.unet_evals, batch[1].unet_evals);
    });
}

#[test]
fn solo_matches_batch_two_b1() {
    solo_matches_batch(DualStrategy::TwoB1);
}

#[test]
fn solo_matches_batch_fused_b2() {
    solo_matches_batch(DualStrategy::FusedB2);
}

#[test]
fn dual_strategies_agree_bitwise_on_synthetic() {
    // both execution strategies run the same per-sample math on the
    // synthetic backend, so they must agree exactly
    let split = engine(DualStrategy::TwoB1);
    let fused = engine(DualStrategy::FusedB2);
    forall("two-b1 == fused-b2", 40, |g| {
        let steps = g.usize_in(2, 10);
        let r = random_request(g, steps, SchedulerKind::Ddim);
        let a = split.generate(&r).expect("two-b1");
        let b = fused.generate(&r).expect("fused-b2");
        assert_eq!(a.latent, b.latent);
        assert_eq!(a.unet_evals, b.unet_evals);
    });
}

#[test]
fn batch_of_four_buckets_match_solo() {
    // a batch of 4 exercises the larger compiled bucket sizes
    let e = engine(DualStrategy::TwoB1);
    forall("batch of four", 25, |g| {
        let steps = g.usize_in(2, 8);
        let sched = *g.choose(&[SchedulerKind::Ddim, SchedulerKind::Pndm]);
        let reqs: Vec<GenerationRequest> =
            (0..4).map(|_| random_request(g, steps, sched)).collect();
        let outs = e.generate_batch(&reqs).expect("batch");
        for (r, out) in reqs.iter().zip(&outs) {
            let solo = e.generate(r).expect("solo");
            assert_eq!(solo.latent, out.latent);
            assert_eq!(solo.unet_evals, out.unet_evals);
        }
    });
}

#[test]
fn per_sample_breakdown_not_multiplied_by_batch() {
    // regression: the whole-batch breakdown used to be cloned into every
    // output, so N outputs over-reported component times N×
    let e = engine(DualStrategy::TwoB1);
    let reqs: Vec<GenerationRequest> = (0..4)
        .map(|i| {
            GenerationRequest::new("breakdown probe")
                .steps(6)
                .scheduler(SchedulerKind::Ddim)
                .seed(i)
                .decode(false)
        })
        .collect();
    let outs = e.generate_batch(&reqs).expect("batch");
    let wall = outs[0].wall_ms;
    let summed: f64 = outs.iter().map(|o| o.breakdown.total_ms()).sum();
    assert!(
        summed <= wall * 1.05,
        "per-sample breakdowns sum to {summed:.3} ms, exceeding the batch wall {wall:.3} ms"
    );
}
