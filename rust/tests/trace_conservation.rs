//! Trace-span conservation (DESIGN.md §12): every request the system
//! admits must close its span with **exactly one** terminal event —
//! `retired`, `shed`, `expired`, or `rejected` — no double closes, no
//! spans left dangling. The suite drives the full serving stack on the
//! deterministic synthetic backend (always runs, no artifacts):
//!
//! * a mixed-QoS replay against a 3-replica continuous cluster with a
//!   mid-replay replica kill — requeued failover legs must keep
//!   appending to the *same* span and still close it exactly once;
//! * the same replay against a standalone QoS coordinator;
//! * deterministic single-request paths for the synchronous-reject and
//!   queue-expiry terminals.
//!
//! The assertions need no sleeps: the replay drivers resolve every
//! ticket before returning, and every layer records the terminal span
//! event *before* resolving the ticket, so the ledger must already
//! balance when a replay returns.

use std::sync::Arc;
use std::time::Duration;

use selective_guidance::cluster::{ClusterConfig, ReplicaSet, ReplicaSpec};
use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::{BatchMode, Coordinator, CoordinatorConfig};
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::error::Error;
use selective_guidance::qos::{DeadlineQos, Priority, QosConfig, QosMeta, QosPolicy};
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::telemetry::{CoordSink, Span, Telemetry};
use selective_guidance::workload::{
    replay_qos, replay_qos_cluster, ArrivalProcess, KillSpec, QosReplayReport, RequestOutcome,
    TraceEntry, WorkloadSpec,
};

fn engine() -> Arc<Engine> {
    Arc::new(Engine::new(
        Arc::new(ModelStack::synthetic()),
        EngineConfig::default(),
    ))
}

fn qos_policy(max_queue_depth: usize) -> Option<Arc<dyn QosPolicy>> {
    let cfg = QosConfig { enabled: true, max_queue_depth, ..QosConfig::default() };
    let policy = DeadlineQos::new(cfg).expect("valid qos config");
    Some(Arc::new(policy))
}

/// A bursty open-loop trace with per-entry QoS diversity: priorities
/// cycle through all three classes and every fourth request carries a
/// deadline far below the backlog's drain time, so replays exercise the
/// retired/expired/rejected terminals side by side.
fn mixed_trace(num_requests: usize, seed: u64) -> Vec<TraceEntry> {
    let spec = WorkloadSpec {
        arrivals: ArrivalProcess::Uniform { rate_per_s: 8000.0 },
        num_requests,
        steps: 20,
        scheduler: SchedulerKind::Ddim,
        decode: false,
        seed,
        ..WorkloadSpec::default()
    };
    let mut trace = spec.synthesize();
    let classes = [Priority::Interactive, Priority::Standard, Priority::Batch];
    for (i, entry) in trace.iter_mut().enumerate() {
        let deadline = if i % 4 == 3 { QosMeta::with_deadline_ms(1.5).deadline } else { None };
        entry.meta = QosMeta { deadline, priority: classes[i % classes.len()], ..entry.meta };
    }
    trace
}

/// The conservation invariant itself, checked two ways: globally (every
/// span in the store closed exactly once) and per replay entry (each
/// outcome's span carries the matching terminal).
fn assert_conserved(t: &Telemetry, report: &QosReplayReport) {
    let spans = t.traces().spans();
    assert!(!spans.is_empty(), "replay produced no spans");
    assert_eq!(t.traces().evicted(), 0, "ring eviction would hide spans");
    for span in &spans {
        assert_eq!(span.terminal_events(), 1, "span {} must close exactly once", span.id);
        if span.has("admitted") {
            assert!(!span.has("rejected"), "span {} admitted and rejected", span.id);
        }
    }
    assert_eq!(report.trace_ids.len(), report.outcomes.len());
    for (i, (outcome, tid)) in report.outcomes.iter().zip(&report.trace_ids).enumerate() {
        match outcome {
            // a synchronous admission rejection never yields a ticket;
            // its span closed before submit returned and is covered by
            // the global sweep above
            RequestOutcome::Rejected => {
                assert!(tid.is_none(), "request {i}: rejected entries carry no ticket")
            }
            RequestOutcome::Completed { .. } => {
                let span = span_of(t, *tid, i);
                assert!(span.has("retired"), "request {i}: completed without a retired event");
            }
            RequestOutcome::DeadlineMissed => {
                let span = span_of(t, *tid, i);
                assert!(span.has("expired"), "request {i}: missed deadline, no expired event");
            }
            RequestOutcome::Failed => {
                let span = span_of(t, *tid, i);
                assert!(span.has("shed"), "request {i}: failed without a shed event");
            }
        }
    }
    let rejected_spans = spans.iter().filter(|s| s.has("rejected")).count();
    assert_eq!(rejected_spans, report.rejected(), "rejected spans != replay report");
}

fn span_of(t: &Telemetry, tid: Option<u64>, i: usize) -> Span {
    let id = tid.unwrap_or_else(|| panic!("request {i}: ticketed request has no trace id"));
    let span = t.traces().span(id);
    span.unwrap_or_else(|| panic!("request {i}: span {id} missing"))
}

/// Mixed QoS + mid-replay replica kill on a 3-replica continuous
/// cluster: failover legs append to the original span (`requeued` is a
/// hop, not a terminal) and the requeue ledger matches the span record.
#[test]
fn cluster_replay_with_kill_conserves_spans() {
    let telemetry = Telemetry::on();
    let spec = ReplicaSpec {
        mode: BatchMode::Continuous,
        slot_budget: 4,
        ..ReplicaSpec::default()
    };
    let set = ReplicaSet::start_full(
        engine(),
        ClusterConfig {
            replicas: vec![spec.clone(), spec.clone(), spec],
            ..ClusterConfig::default()
        },
        qos_policy(24),
        Some(Arc::clone(&telemetry)),
    )
    .expect("cluster");
    let trace = mixed_trace(30, 7);
    let kills = vec![KillSpec { at_ms: 2.0, replica: 0 }];
    let report = replay_qos_cluster(&set, &trace, &kills).expect("replay");
    let stats = set.stats();
    set.shutdown();

    assert_eq!(report.outcomes.len(), trace.len());
    assert!(report.completed() >= 1, "replay must complete some work");
    assert_eq!(stats.ejected, 1);
    assert_conserved(&telemetry, &report);

    let spans = telemetry.traces().spans();
    // every admission in the report maps onto exactly one span (requeues
    // reuse the original — they never fork a second one)
    assert_eq!(spans.len(), trace.len());
    let requeue_events: usize = spans
        .iter()
        .map(|s| s.events.iter().filter(|e| e.event.name() == "requeued").count())
        .sum();
    assert_eq!(requeue_events as u64, stats.requeued, "requeue ledger out of sync");
    for span in &spans {
        if span.has("admitted") {
            assert!(span.has("routed"), "span {} admitted but never placed", span.id);
        }
    }
}

/// Same mixed replay against the standalone QoS coordinator: the
/// single-node sink owns every terminal, including synchronous 429s.
#[test]
fn coordinator_replay_conserves_spans() {
    let telemetry = Telemetry::on();
    let coordinator = Coordinator::start_full(
        engine(),
        CoordinatorConfig {
            mode: BatchMode::Continuous,
            slot_budget: 4,
            workers: 1,
            ..CoordinatorConfig::default()
        },
        qos_policy(6),
        Some(CoordSink::new(&telemetry, "single", true)),
    );
    let trace = mixed_trace(24, 11);
    let report = replay_qos(&coordinator, &trace).expect("replay");
    coordinator.shutdown();

    assert_eq!(report.outcomes.len(), trace.len());
    assert!(report.completed() >= 1, "replay must complete some work");
    assert_conserved(&telemetry, &report);
    // every valid submission opened a span, admitted or not
    assert_eq!(telemetry.traces().spans().len(), trace.len());
}

/// Deterministic synchronous-reject terminal: with a queue bound of 1,
/// a request submitted behind an 800-step occupant must be refused with
/// a 429 — and its span still closes (rejection is a complete span, not
/// a missing one).
#[test]
fn synchronous_rejection_closes_span() {
    let telemetry = Telemetry::on();
    let coordinator = Coordinator::start_full(
        engine(),
        CoordinatorConfig { max_batch: 1, workers: 1, ..CoordinatorConfig::default() },
        qos_policy(1),
        Some(CoordSink::new(&telemetry, "single", true)),
    );
    let long = GenerationRequest::new("occupant")
        .steps(800)
        .scheduler(SchedulerKind::Ddim)
        .decode(false);
    let ticket = coordinator.submit_qos(long, QosMeta::default()).expect("admitted");
    let quick = GenerationRequest::new("refused").steps(2).decode(false);
    match coordinator.submit_qos(quick, QosMeta::default()) {
        Err(Error::Rejected { code, .. }) => assert_eq!(code, 429),
        other => panic!("expected a 429 behind a full queue, got {other:?}"),
    }
    ticket.wait().expect("occupant completes");
    coordinator.shutdown();

    let spans = telemetry.traces().spans();
    assert_eq!(spans.len(), 2);
    assert!(spans.iter().all(|s| s.terminal_events() == 1));
    assert_eq!(spans.iter().filter(|s| s.has("retired")).count(), 1);
    assert_eq!(spans.iter().filter(|s| s.has("rejected")).count(), 1);
}

/// Deterministic queue-expiry terminal: a zero-deadline request queued
/// behind real work always expires before execution (no QoS policy —
/// deadline enforcement is the worker's, so the expired terminal must
/// appear even on a bare coordinator).
#[test]
fn queue_expiry_closes_span() {
    let telemetry = Telemetry::on();
    let coordinator = Coordinator::start_full(
        engine(),
        CoordinatorConfig {
            max_batch: 2,
            workers: 1,
            batch_wait: Duration::from_millis(1),
            ..CoordinatorConfig::default()
        },
        None,
        Some(CoordSink::new(&telemetry, "single", true)),
    );
    let long = GenerationRequest::new("occupant")
        .steps(400)
        .scheduler(SchedulerKind::Ddim)
        .decode(false);
    let t1 = coordinator.submit_qos(long, QosMeta::default()).expect("occupant");
    let stale = GenerationRequest::new("stale").steps(2).decode(false);
    let t2 = coordinator
        .submit_qos(stale, QosMeta::with_deadline_ms(0.0))
        .expect("zero-deadline request is admitted, then expires");
    t1.wait().expect("occupant completes");
    match t2.wait() {
        Err(Error::DeadlineExceeded(_)) => {}
        other => panic!("expected queue expiry, got {other:?}"),
    }
    coordinator.shutdown();

    let spans = telemetry.traces().spans();
    assert_eq!(spans.len(), 2);
    assert!(spans.iter().all(|s| s.terminal_events() == 1));
    assert_eq!(spans.iter().filter(|s| s.has("retired")).count(), 1);
    assert_eq!(spans.iter().filter(|s| s.has("expired")).count(), 1);
}
