//! Continuous-batching equivalence properties on the deterministic
//! synthetic backend (no PJRT artifacts needed — this suite always runs,
//! and the whole-suite `PROP_MASTER_SEED` CI matrix re-runs it in other
//! randomness universes).
//!
//! The invariant under test is DESIGN.md §9's contract: a sample's
//! output is a pure function of its own request. Whatever the admission
//! order, slot budget, cohort mix (step counts, schedulers, windows,
//! strategies) or admission stagger, every sample must match its solo
//! [`Engine::generate`] run **bit-for-bit** — and the per-iteration slot
//! usage must never overshoot the budget.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use selective_guidance::config::{DualStrategy, EngineConfig};
use selective_guidance::coordinator::{
    BatchMode, ContinuousBatcher, Coordinator, CoordinatorConfig,
};
use selective_guidance::engine::{Engine, GenerationOutput, GenerationRequest};
use selective_guidance::error::Error;
use selective_guidance::guidance::{GuidanceSchedule, GuidanceStrategy, ReuseKind, WindowSpec};
use selective_guidance::qos::{DeadlineQos, QosConfig, QosMeta};
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::testutil::prop::{forall, Gen};

fn engine(dual: DualStrategy) -> Arc<Engine> {
    let cfg = EngineConfig { dual_strategy: dual, ..EngineConfig::default() };
    Arc::new(Engine::new(Arc::new(ModelStack::synthetic()), cfg))
}

fn random_strategy(g: &mut Gen) -> GuidanceStrategy {
    match g.usize_in(0, 2) {
        0 => GuidanceStrategy::CondOnly,
        1 => GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: g.usize_in(0, 5) },
        _ => GuidanceStrategy::Reuse {
            kind: ReuseKind::Extrapolate,
            refresh_every: g.usize_in(0, 5),
        },
    }
}

fn random_window(g: &mut Gen) -> WindowSpec {
    let f = g.f64_in(0.0, 1.0);
    match g.usize_in(0, 3) {
        0 => WindowSpec::last(f),
        1 => WindowSpec::first(f),
        2 => WindowSpec::middle(f),
        _ => WindowSpec::none(),
    }
}

/// A fully random request — unlike the lock-step batcher, the continuous
/// cohort imposes *no* compatibility class, so steps and scheduler
/// randomize per request too.
fn random_request(g: &mut Gen) -> GenerationRequest {
    let kinds = [
        SchedulerKind::Ddim,
        SchedulerKind::Ddpm,
        SchedulerKind::Pndm,
        SchedulerKind::Euler,
        SchedulerKind::EulerAncestral,
        SchedulerKind::DpmSolverPP,
        SchedulerKind::Heun,
    ];
    let scale = if g.bool() { g.f32_in(1.5, 12.0) } else { 1.0 };
    GenerationRequest::new(format!("{} {}", g.word(8), g.word(8)))
        .steps(g.usize_in(2, 10))
        .scheduler(*g.choose(&kinds))
        .seed(g.u64())
        .guidance_scale(scale)
        .selective(random_window(g))
        .strategy(random_strategy(g))
        .decode(false)
}

/// Drive a [`ContinuousBatcher`] to completion over `reqs`, admitting in
/// `order` with `g`-driven stagger, asserting the slot invariant; returns
/// the outputs in request order.
fn run_cohort(
    e: &Arc<Engine>,
    reqs: &[GenerationRequest],
    order: &[usize],
    budget: usize,
    g: &mut Gen,
) -> Vec<GenerationOutput> {
    let mut cb = ContinuousBatcher::new(Arc::clone(e), budget).expect("batcher");
    let mut queue: VecDeque<usize> = order.iter().copied().collect();
    let mut id2idx: BTreeMap<u64, usize> = BTreeMap::new();
    let mut outs: Vec<Option<GenerationOutput>> = vec![None; reqs.len()];
    let mut spins = 0usize;
    while outs.iter().any(|o| o.is_none()) {
        // staggered arrivals: sometimes an iteration boundary passes with
        // no admission attempt at all (forced when the cohort is empty so
        // the loop always progresses)
        if g.bool() || cb.in_flight() == 0 {
            while let Some(&i) = queue.front() {
                match cb.try_admit(&reqs[i]).expect("admit") {
                    Some(id) => {
                        queue.pop_front();
                        id2idx.insert(id, i);
                    }
                    None => break,
                }
            }
        }
        if cb.in_flight() == 0 {
            spins += 1;
            assert!(spins < 100_000);
            continue;
        }
        let outcome = cb.step().expect("step");
        assert!(
            outcome.slots_used <= budget,
            "iteration used {} slots over budget {budget}",
            outcome.slots_used
        );
        assert!(outcome.slots_used >= 1, "a non-empty cohort always runs work");
        for (id, out) in outcome.retired {
            outs[id2idx[&id]] = Some(out);
        }
        spins += 1;
        assert!(spins < 100_000, "cohort failed to drain");
    }
    outs.into_iter().map(Option::unwrap).collect()
}

fn staggered_admission_matches_solo(dual: DualStrategy) {
    let e = engine(dual);
    forall(&format!("continuous == solo ({dual:?})"), 30, |g| {
        let budget = g.usize_in(2, 10);
        let k = g.usize_in(1, 6);
        let reqs: Vec<GenerationRequest> = (0..k).map(|_| random_request(g)).collect();
        let solo: Vec<GenerationOutput> =
            reqs.iter().map(|r| e.generate(r).expect("solo")).collect();
        // random admission order (Fisher-Yates over the index vec)
        let mut order: Vec<usize> = (0..k).collect();
        for i in (1..k).rev() {
            let j = g.usize_in(0, i);
            order.swap(i, j);
        }
        let outs = run_cohort(&e, &reqs, &order, budget, g);
        for (i, out) in outs.iter().enumerate() {
            assert_eq!(
                solo[i].latent, out.latent,
                "sample {i} (budget {budget}): cohort composition leaked into the output"
            );
            assert_eq!(solo[i].unet_evals, out.unet_evals, "sample {i}: eval count");
        }
    });
}

#[test]
fn staggered_admission_matches_solo_two_b1() {
    staggered_admission_matches_solo(DualStrategy::TwoB1);
}

#[test]
fn staggered_admission_matches_solo_fused_b2() {
    staggered_admission_matches_solo(DualStrategy::FusedB2);
}

#[test]
fn mixed_classes_cohort_where_fixed_batching_cannot() {
    // four requests no lock-step batch could ever fuse: different step
    // counts AND schedulers — plus a reuse strategy and an unguided one
    let e = engine(DualStrategy::TwoB1);
    let reqs = vec![
        GenerationRequest::new("a cat")
            .steps(6)
            .scheduler(SchedulerKind::Ddim)
            .selective(WindowSpec::last(0.5))
            .seed(1)
            .decode(false),
        GenerationRequest::new("a dog")
            .steps(9)
            .scheduler(SchedulerKind::Pndm)
            .seed(2)
            .decode(false),
        GenerationRequest::new("a fish")
            .steps(4)
            .scheduler(SchedulerKind::Euler)
            .guidance_scale(1.0)
            .seed(3)
            .decode(false),
        GenerationRequest::new("a bird")
            .steps(7)
            .scheduler(SchedulerKind::Heun)
            .selective(WindowSpec::last(0.6))
            .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 2 })
            .seed(4)
            .decode(false),
    ];
    // the fixed batcher refuses this mix outright...
    assert!(e.generate_batch(&reqs).is_err());
    // ...the continuous cohort serves it, each sample matching its solo
    let solo: Vec<GenerationOutput> = reqs.iter().map(|r| e.generate(r).unwrap()).collect();
    let mut g = Gen::new(0xC0117);
    let order: Vec<usize> = (0..reqs.len()).collect();
    let outs = run_cohort(&e, &reqs, &order, 8, &mut g);
    for (i, out) in outs.iter().enumerate() {
        assert_eq!(solo[i].latent, out.latent, "sample {i}");
        assert_eq!(solo[i].unet_evals, out.unet_evals, "sample {i}");
    }
}

#[test]
fn continuous_coordinator_end_to_end_matches_solo() {
    // the threaded driver: real submission path, worker cohort, stats
    let e = engine(DualStrategy::TwoB1);
    let coordinator = Coordinator::start(
        Arc::clone(&e),
        CoordinatorConfig {
            mode: BatchMode::Continuous,
            slot_budget: 6,
            workers: 1,
            ..CoordinatorConfig::default()
        },
    );
    let reqs: Vec<GenerationRequest> = (0..8)
        .map(|i| {
            GenerationRequest::new(format!("prompt {i}"))
                .steps(6 + (i % 3))
                .scheduler(SchedulerKind::Ddim)
                .selective(WindowSpec::last(if i % 2 == 0 { 0.5 } else { 0.0 }))
                .seed(i as u64)
                .decode(false)
        })
        .collect();
    let tickets: Vec<_> = reqs
        .iter()
        .map(|r| coordinator.submit(r.clone()).expect("submit"))
        .collect();
    let outs: Vec<GenerationOutput> =
        tickets.into_iter().map(|t| t.wait().expect("wait")).collect();
    for (i, (r, out)) in reqs.iter().zip(&outs).enumerate() {
        let solo = e.generate(r).unwrap();
        assert_eq!(solo.latent, out.latent, "sample {i}");
        assert_eq!(solo.unet_evals, out.unet_evals, "sample {i}");
    }
    let stats = coordinator.stats();
    assert_eq!(stats.mode, BatchMode::Continuous);
    assert_eq!(stats.slot_budget, 6);
    assert_eq!(stats.submitted, 8);
    assert_eq!(stats.completed, 8);
    assert_eq!(stats.failed, 0);
    // the continuous counters replace the fixed batcher's batch counters
    assert_eq!(stats.joins, 8);
    assert_eq!(stats.retires, 8);
    assert_eq!(stats.batches, 0);
    // mixed 6/7/8-step cohort: at least the longest trajectory's worth
    // of iterations, every one within budget
    assert!(stats.iterations >= 8, "iterations {}", stats.iterations);
    assert!(stats.cohort_max >= 1 && stats.cohort_max <= 6);
    assert!(
        stats.slot_utilization > 0.0 && stats.slot_utilization <= 1.0,
        "slot_utilization {}",
        stats.slot_utilization
    );
    // the outstanding gauge tracked the continuous admission queue
    assert!(stats.queue_depth_max >= 1);
    assert_eq!(stats.queue_depth, 0, "everything drained");
    coordinator.shutdown();
}

#[test]
fn continuous_coordinator_expires_queued_deadlines() {
    let e = engine(DualStrategy::TwoB1);
    let coordinator = Coordinator::start(
        Arc::clone(&e),
        CoordinatorConfig {
            mode: BatchMode::Continuous,
            slot_budget: 2,
            workers: 1,
            ..CoordinatorConfig::default()
        },
    );
    let req = GenerationRequest::new("long job").steps(10).decode(false);
    let ok = coordinator.submit(req.clone()).expect("submit");
    // an already-expired deadline must come back as 504, not burn slots
    let dead = coordinator
        .submit_qos(req, QosMeta::with_deadline_ms(0.0))
        .expect("submit");
    assert!(matches!(dead.wait(), Err(Error::DeadlineExceeded(_))));
    assert!(ok.wait().is_ok());
    let stats = coordinator.stats();
    assert_eq!(stats.deadline_missed, 1);
    assert_eq!(stats.completed, 1);
    coordinator.shutdown();
}

#[test]
fn continuous_mode_feeds_qos_slot_occupancy() {
    // end-to-end wiring of the new load signal: worker iterations must
    // reach the policy's occupancy EWMA (and service feedback must flow)
    let e = engine(DualStrategy::TwoB1);
    let qos = Arc::new(
        DeadlineQos::new(QosConfig { enabled: true, ..QosConfig::default() }).unwrap(),
    );
    let coordinator = Coordinator::start_qos(
        Arc::clone(&e),
        CoordinatorConfig {
            mode: BatchMode::Continuous,
            slot_budget: 4,
            workers: 1,
            ..CoordinatorConfig::default()
        },
        qos.clone(),
    );
    let tickets: Vec<_> = (0..4)
        .map(|i| {
            let r = GenerationRequest::new(format!("p{i}"))
                .steps(6)
                .scheduler(SchedulerKind::Ddim)
                .seed(i as u64)
                .decode(false);
            coordinator.submit(r).expect("submit")
        })
        .collect();
    for t in tickets {
        t.wait().expect("complete");
    }
    let load = qos.load(0);
    assert!(
        load.slot_occupancy > 0.0 && load.slot_occupancy <= 1.0,
        "occupancy EWMA not fed: {}",
        load.slot_occupancy
    );
    assert!(load.service_ms > 0.0, "service feedback not fed");
    coordinator.shutdown();
}

#[test]
fn continuous_coordinator_multiple_worker_cohorts() {
    // two worker cohorts share the admission queue; outputs still match
    let e = engine(DualStrategy::TwoB1);
    let coordinator = Coordinator::start(
        Arc::clone(&e),
        CoordinatorConfig {
            mode: BatchMode::Continuous,
            slot_budget: 4,
            workers: 2,
            ..CoordinatorConfig::default()
        },
    );
    let reqs: Vec<GenerationRequest> = (0..10)
        .map(|i| {
            GenerationRequest::new(format!("w{i}"))
                .steps(5)
                .scheduler(SchedulerKind::Ddim)
                .selective(WindowSpec::last(0.4))
                .seed(100 + i as u64)
                .decode(false)
        })
        .collect();
    let tickets: Vec<_> =
        reqs.iter().map(|r| coordinator.submit(r.clone()).expect("submit")).collect();
    for (r, t) in reqs.iter().zip(tickets) {
        let out = t.wait().expect("wait");
        let solo = e.generate(r).unwrap();
        assert_eq!(solo.latent, out.latent);
    }
    assert_eq!(coordinator.stats().completed, 10);
    coordinator.shutdown();
}

#[test]
fn replay_mixed_step_trace_through_continuous_coordinator() {
    // the workload layer end-to-end: a mixed-class trace (impossible to
    // fuse in one fixed batch) replays through a continuous coordinator
    use selective_guidance::workload::{replay, ArrivalProcess, WorkloadSpec};
    let e = engine(DualStrategy::TwoB1);
    let coordinator = Coordinator::start(
        Arc::clone(&e),
        CoordinatorConfig {
            mode: BatchMode::Continuous,
            slot_budget: 6,
            workers: 1,
            ..CoordinatorConfig::default()
        },
    );
    let spec = WorkloadSpec {
        arrivals: ArrivalProcess::Uniform { rate_per_s: 2000.0 },
        num_requests: 9,
        steps_choices: vec![4, 6, 8],
        scheduler: SchedulerKind::Ddim,
        schedule: GuidanceSchedule::Window(WindowSpec::last(0.5)),
        decode: false,
        ..WorkloadSpec::default()
    };
    let trace = spec.synthesize();
    let report = replay(&coordinator, &trace).expect("replay");
    assert_eq!(report.failures, 0);
    assert_eq!(report.latencies_ms.len(), 9);
    let stats = coordinator.stats();
    assert_eq!(stats.completed, 9);
    assert_eq!(stats.retires, 9);
    coordinator.shutdown();
}
