//! Integration: coordinator routing/batching over a real engine.

mod common;

use std::sync::Arc;
use std::time::Duration;

use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::{Coordinator, CoordinatorConfig};
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::WindowSpec;
use selective_guidance::scheduler::SchedulerKind;

fn coordinator(max_batch: usize, workers: usize) -> Option<Arc<Coordinator>> {
    let stack = common::shared_stack()?;
    let engine = Arc::new(Engine::new(stack, EngineConfig::default()));
    Some(Coordinator::start(
        engine,
        CoordinatorConfig {
            max_batch,
            workers,
            batch_wait: Duration::from_millis(20),
            ..CoordinatorConfig::default()
        },
    ))
}

macro_rules! require_coordinator {
    ($mb:expr, $w:expr) => {
        match coordinator($mb, $w) {
            Some(c) => c,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn quick(prompt: &str, seed: u64) -> GenerationRequest {
    GenerationRequest::new(prompt)
        .steps(6)
        .scheduler(SchedulerKind::Ddim)
        .decode(false)
        .seed(seed)
}

#[test]
fn single_request_round_trip() {
    let c = require_coordinator!(4, 1);
    let out = c.generate(quick("A cat", 1)).unwrap();
    assert_eq!(out.steps, 6);
    assert!(out.latent.iter().all(|v| v.is_finite()));
    let stats = c.stats();
    assert_eq!(stats.submitted, 1);
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.failed, 0);
    c.shutdown();
}

#[test]
fn no_request_lost_under_burst() {
    let c = require_coordinator!(4, 2);
    let n = 12;
    let tickets: Vec<_> = (0..n)
        .map(|i| c.submit(quick("burst prompt", i as u64)).unwrap())
        .collect();
    let mut ok = 0;
    for t in tickets {
        let out = t.wait().unwrap();
        assert!(out.latent.iter().all(|v| v.is_finite()));
        ok += 1;
    }
    assert_eq!(ok, n);
    let stats = c.stats();
    assert_eq!(stats.submitted, n as u64);
    assert_eq!(stats.completed, n as u64);
    assert_eq!(stats.failed, 0);
    // batching actually happened (not all singleton batches)
    assert!(
        stats.batches < n as u64,
        "expected batching, got {} batches for {} requests",
        stats.batches,
        n
    );
    c.shutdown();
}

#[test]
fn results_match_request_identity() {
    // responses must be routed back to the right submitter even when
    // batched together — distinguish via deterministic per-seed outputs
    let c = require_coordinator!(4, 1);
    let stack = common::shared_stack().unwrap();
    let engine = Engine::new(stack, EngineConfig::default());
    let solo1 = engine.generate(&quick("alpha", 101)).unwrap();
    let solo2 = engine.generate(&quick("beta", 202)).unwrap();

    let t1 = c.submit(quick("alpha", 101)).unwrap();
    let t2 = c.submit(quick("beta", 202)).unwrap();
    let r1 = t1.wait().unwrap();
    let r2 = t2.wait().unwrap();
    let close = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max) < 1e-3
    };
    assert!(close(&r1.latent, &solo1.latent), "ticket 1 got wrong result");
    assert!(close(&r2.latent, &solo2.latent), "ticket 2 got wrong result");
    c.shutdown();
}

#[test]
fn incompatible_classes_not_fused() {
    let c = require_coordinator!(8, 1);
    // different step counts -> different batch classes
    let t1 = c.submit(quick("a", 1)).unwrap();
    let t2 = c.submit(quick("b", 2).steps(8)).unwrap();
    let r1 = t1.wait().unwrap();
    let r2 = t2.wait().unwrap();
    assert_eq!(r1.steps, 6);
    assert_eq!(r2.steps, 8);
    c.shutdown();
}

#[test]
fn invalid_request_rejected_at_submit() {
    let c = require_coordinator!(4, 1);
    assert!(c.submit(GenerationRequest::new("")).is_err());
    assert!(c
        .submit(quick("x", 0).selective(WindowSpec::last(2.0)))
        .is_err());
    assert_eq!(c.stats().submitted, 0);
    c.shutdown();
}

#[test]
fn shutdown_then_submit_fails() {
    let c = require_coordinator!(4, 1);
    c.shutdown();
    assert!(c.submit(quick("x", 1)).is_err());
}

#[test]
fn mixed_policies_fuse_into_one_batch() {
    // baseline + optimized traffic in the same batch — the selling point
    // of per-sample guidance decisions
    let c = require_coordinator!(4, 1);
    let t1 = c.submit(quick("p", 1)).unwrap();
    let t2 = c
        .submit(quick("p", 1).selective(WindowSpec::last(0.5)))
        .unwrap();
    let r1 = t1.wait().unwrap();
    let r2 = t2.wait().unwrap();
    // 6 steps: baseline 12 evals vs optimized 9 evals — proof the uncond
    // pass was actually skipped inside the shared batch
    // (unet_evals counts the whole batch's evals, shared across outputs)
    assert!(r2.unet_evals <= r1.unet_evals);
    let stats = c.stats();
    assert!(stats.batches <= 2);
    c.shutdown();
}

#[test]
fn latency_stats_populated() {
    let c = require_coordinator!(2, 1);
    for i in 0..3 {
        c.generate(quick("p", i)).unwrap();
    }
    let s = c.stats();
    assert!(s.latency_ms_mean > 0.0);
    assert!(s.latency_ms_p50 > 0.0);
    assert!(s.latency_ms_max >= s.latency_ms_p50 * 0.9);
    c.shutdown();
}
