//! Integration: TCP JSON-lines server end-to-end over localhost.

mod common;

use std::sync::Arc;
use std::time::Duration;

use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::{Coordinator, CoordinatorConfig};
use selective_guidance::engine::Engine;
use selective_guidance::json::Value;
use selective_guidance::server::{b64decode, Client, Server};

fn start_server() -> Option<(Server, String)> {
    let stack = common::shared_stack()?;
    let engine = Arc::new(Engine::new(stack, EngineConfig::default()));
    let coordinator = Coordinator::start(
        engine,
        CoordinatorConfig {
            max_batch: 4,
            workers: 1,
            batch_wait: Duration::from_millis(2),
            ..CoordinatorConfig::default()
        },
    );
    let server = Server::start(coordinator, "127.0.0.1:0").expect("bind");
    let addr = server.addr().to_string();
    Some((server, addr))
}

macro_rules! require_server {
    () => {
        match start_server() {
            Some(s) => s,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

#[test]
fn ping_and_stats() {
    let (_server, addr) = require_server!();
    let mut client = Client::connect(&addr).unwrap();
    assert!(client.ping().unwrap());
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(stats.get("submitted").unwrap().as_i64(), Some(0));
}

#[test]
fn generate_over_wire() {
    let (_server, addr) = require_server!();
    let mut client = Client::connect(&addr).unwrap();
    let resp = client
        .call(
            Value::obj()
                .with("op", "generate")
                .with("prompt", "A person holding a cat")
                .with("steps", 6i64)
                .with("scheduler", "ddim")
                .with("seed", 5i64)
                .with("window_fraction", 0.5)
                .with("return_image", true),
        )
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    // 6 steps, half optimized: 3*2 + 3*1 = 9 evals
    assert_eq!(resp.get("unet_evals").unwrap().as_i64(), Some(9));
    assert!(resp.get("wall_ms").unwrap().as_f64().unwrap() > 0.0);
    // PNG round-trips through base64 and carries the PNG signature
    let png_b64 = resp.get("png_b64").unwrap().as_str().unwrap();
    let png = b64decode(png_b64).expect("valid base64");
    assert_eq!(&png[..4], &[0x89, b'P', b'N', b'G']);
}

#[test]
fn error_responses_for_bad_requests() {
    let (_server, addr) = require_server!();
    let mut client = Client::connect(&addr).unwrap();
    let resp = client.call(Value::obj().with("op", "generate")).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("prompt"));

    let resp = client.call(Value::obj().with("op", "definitely-not-an-op")).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
}

#[test]
fn multiple_sequential_requests_one_connection() {
    let (_server, addr) = require_server!();
    let mut client = Client::connect(&addr).unwrap();
    for seed in 0..3i64 {
        let resp = client
            .call(
                Value::obj()
                    .with("op", "generate")
                    .with("prompt", "x")
                    .with("steps", 4i64)
                    .with("scheduler", "ddim")
                    .with("seed", seed),
            )
            .unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    }
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("completed").unwrap().as_i64(), Some(3));
}

#[test]
fn concurrent_clients() {
    let (_server, addr) = require_server!();
    let mut handles = Vec::new();
    for seed in 0..4i64 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let resp = client
                .call(
                    Value::obj()
                        .with("op", "generate")
                        .with("prompt", "concurrent")
                        .with("steps", 4i64)
                        .with("scheduler", "ddim")
                        .with("seed", seed),
                )
                .unwrap();
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn malformed_json_reported() {
    let (_server, addr) = require_server!();
    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.write_all(b"this is not json\n").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = selective_guidance::json::from_str(&line).unwrap();
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
    assert!(v.get("error").unwrap().as_str().unwrap().contains("bad json"));
}
