//! Integration: the denoising engine over real artifacts.

mod common;

use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::WindowSpec;
use selective_guidance::quality::latent_drift;
use selective_guidance::scheduler::SchedulerKind;

fn engine() -> Option<Engine> {
    common::shared_stack().map(|s| Engine::new(s, EngineConfig::default()))
}

macro_rules! require_engine {
    () => {
        match engine() {
            Some(e) => e,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn quick(prompt: &str) -> GenerationRequest {
    GenerationRequest::new(prompt)
        .steps(10)
        .scheduler(SchedulerKind::Ddim)
        .decode(false)
        .seed(42)
}

#[test]
fn generate_deterministic() {
    let e = require_engine!();
    let a = e.generate(&quick("A person holding a cat")).unwrap();
    let b = e.generate(&quick("A person holding a cat")).unwrap();
    assert_eq!(a.latent, b.latent, "same seed must be bit-identical");
    assert!(a.latent.iter().all(|v| v.is_finite()));
}

#[test]
fn seeds_change_output() {
    let e = require_engine!();
    let a = e.generate(&quick("x y z")).unwrap();
    let b = e.generate(&quick("x y z").seed(43)).unwrap();
    assert_ne!(a.latent, b.latent);
}

#[test]
fn prompts_change_output() {
    let e = require_engine!();
    let a = e.generate(&quick("A red ball")).unwrap();
    let b = e.generate(&quick("A blue pyramid")).unwrap();
    assert_ne!(a.latent, b.latent);
}

#[test]
fn unet_eval_counts_match_policy() {
    let e = require_engine!();
    // baseline: 2 evals per step
    let base = e.generate(&quick("p")).unwrap();
    assert_eq!(base.unet_evals, 20);
    // last 50% optimized: 10 steps -> 5 dual + 5 single = 15
    let opt = e
        .generate(&quick("p").selective(WindowSpec::last(0.5)))
        .unwrap();
    assert_eq!(opt.unet_evals, 15);
    // unguided (s=1): 1 eval per step
    let ung = e.generate(&quick("p").guidance_scale(1.0)).unwrap();
    assert_eq!(ung.unet_evals, 10);
}

#[test]
fn scale_one_equals_full_window_optimization() {
    // With s=1, Dual and CondOnly produce identical eps_hat, so a fully
    // optimized window must give the exact same trajectory.
    let e = require_engine!();
    let a = e.generate(&quick("p").guidance_scale(1.0)).unwrap();
    let b = e
        .generate(&quick("p").guidance_scale(1.0).selective(WindowSpec::last(1.0)))
        .unwrap();
    assert_eq!(a.latent, b.latent);
}

#[test]
fn optimized_window_changes_latent_but_not_wildly() {
    let e = require_engine!();
    let base = e.generate(&quick("A silver dragon head")).unwrap();
    let opt = e
        .generate(&quick("A silver dragon head").selective(WindowSpec::last(0.2)))
        .unwrap();
    let drift = latent_drift(&base.latent, &opt.latent);
    assert!(drift > 0.0, "optimization must alter the trajectory");
    assert!(drift < 2.0, "20% window should not explode the latent (drift {drift})");
}

#[test]
fn later_windows_drift_less_than_earlier() {
    // the paper's §2 claim, at latent level: optimizing the FIRST 25%
    // hurts (drifts) more than optimizing the LAST 25%
    let e = require_engine!();
    let req = |w| quick("A person holding a cat").steps(16).selective(w);
    let base = e.generate(&quick("A person holding a cat").steps(16)).unwrap();
    let first = e.generate(&req(WindowSpec::first(0.25))).unwrap();
    let last = e.generate(&req(WindowSpec::last(0.25))).unwrap();
    let d_first = latent_drift(&base.latent, &first.latent);
    let d_last = latent_drift(&base.latent, &last.latent);
    assert!(
        d_last < d_first,
        "last-window drift {d_last} should be below first-window drift {d_first}"
    );
}

#[test]
fn batch_matches_individual_runs() {
    let e = require_engine!();
    let reqs = vec![
        quick("A red ball").seed(1),
        quick("A blue pyramid").seed(2).selective(WindowSpec::last(0.5)),
        quick("A cat").seed(3).guidance_scale(9.6),
    ];
    let batch = e.generate_batch(&reqs).unwrap();
    for (req, out) in reqs.iter().zip(&batch) {
        let solo = e.generate(req).unwrap();
        assert_eq!(out.latent.len(), solo.latent.len());
        let max_diff = out
            .latent
            .iter()
            .zip(&solo.latent)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_diff < 1e-3,
            "batched result differs from solo run by {max_diff} for {:?}",
            req.prompt
        );
    }
}

#[test]
fn decode_produces_image() {
    let e = require_engine!();
    let out = e.generate(&quick("p").decode(true)).unwrap();
    let img = out.image.expect("image requested");
    let m = e.stack().model();
    assert_eq!((img.width, img.height), (m.image_size, m.image_size));
    // non-degenerate image
    let luma = img.luma();
    let mean = luma.iter().sum::<f32>() / luma.len() as f32;
    assert!(luma.iter().any(|v| (v - mean).abs() > 1.0));
}

#[test]
fn stochastic_scheduler_reproducible_by_seed() {
    let e = require_engine!();
    let req = quick("p").scheduler(SchedulerKind::EulerAncestral);
    let a = e.generate(&req).unwrap();
    let b = e.generate(&req).unwrap();
    assert_eq!(a.latent, b.latent);
}

#[test]
fn all_schedulers_run_end_to_end() {
    let e = require_engine!();
    for kind in [
        SchedulerKind::Ddim,
        SchedulerKind::Ddpm,
        SchedulerKind::Pndm,
        SchedulerKind::Euler,
        SchedulerKind::EulerAncestral,
    ] {
        let out = e.generate(&quick("p").scheduler(kind)).unwrap();
        assert!(
            out.latent.iter().all(|v| v.is_finite()),
            "{kind:?} produced non-finite latent"
        );
    }
}

#[test]
fn breakdown_accounts_for_wall_time() {
    let e = require_engine!();
    let out = e.generate(&quick("p")).unwrap();
    let accounted = out.breakdown.total_ms();
    assert!(accounted > 0.0);
    assert!(
        accounted <= out.wall_ms * 1.05,
        "breakdown {accounted}ms exceeds wall {}ms",
        out.wall_ms
    );
    // UNet should dominate (the premise of the paper's cost model)
    let unet = out.breakdown.unet_cond_ms + out.breakdown.unet_uncond_ms;
    assert!(unet > 0.5 * out.wall_ms, "unet {unet}ms of wall {}ms", out.wall_ms);
}

#[test]
fn fused_b2_strategy_matches_two_b1() {
    // ablation A's two execution strategies must be numerically
    // equivalent — they run the same HLO math, just batched differently
    let stack = match common::shared_stack() {
        Some(s) => s,
        None => return,
    };
    let mut cfg = EngineConfig::default();
    cfg.dual_strategy = selective_guidance::config::DualStrategy::FusedB2;
    let fused = Engine::new(std::sync::Arc::clone(&stack), cfg);
    let split = Engine::new(stack, EngineConfig::default());
    let req = quick("A cat on a mat").selective(WindowSpec::last(0.3));
    let a = split.generate(&req).unwrap();
    let b = fused.generate(&req).unwrap();
    assert_eq!(a.unet_evals, b.unet_evals);
    let max_diff = a
        .latent
        .iter()
        .zip(&b.latent)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "strategies diverge by {max_diff}");
}

#[test]
fn adaptive_controller_skips_and_stays_sane() {
    let e = require_engine!();
    let base = e.generate(&quick("A foggy sunrise over a valley").steps(20)).unwrap();
    let adaptive = e
        .generate(
            &quick("A foggy sunrise over a valley").steps(20).adaptive(
                selective_guidance::guidance::AdaptiveConfig {
                    threshold: 10.0, // huge: skip as soon as allowed
                    patience: 1,
                    min_dual_fraction: 0.3,
                    probe_every: 0,
                },
            ),
        )
        .unwrap();
    // 20 steps, 30% protected: the first 6 iterations stay dual (the
    // controller may arm during them but decide() protects the prefix),
    // the remaining 14 run cond-only => exactly 6*2 + 14 = 26 evals
    assert!(adaptive.unet_evals < base.unet_evals);
    assert_eq!(adaptive.unet_evals, 26, "protected prefix must stay dual");
    assert!(adaptive.latent.iter().all(|v| v.is_finite()));
    let drift = latent_drift(&base.latent, &adaptive.latent);
    assert!(drift < 2.0, "adaptive skipping exploded the latent: {drift}");
}

#[test]
fn adaptive_zero_threshold_never_skips() {
    let e = require_engine!();
    let out = e
        .generate(&quick("p").steps(10).adaptive(
            selective_guidance::guidance::AdaptiveConfig {
                threshold: 0.0,
                patience: 1,
                min_dual_fraction: 0.0,
                probe_every: 0,
            },
        ))
        .unwrap();
    assert_eq!(out.unet_evals, 20, "threshold 0 must behave like the baseline");
}

#[test]
fn mixed_steps_rejected_in_batch() {
    let e = require_engine!();
    let err = e
        .generate_batch(&[quick("a").steps(10), quick("b").steps(20)])
        .unwrap_err();
    assert!(err.to_string().contains("share steps"));
}
