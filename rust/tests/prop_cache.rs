//! Fleet-wide amortization properties (DESIGN.md §13): the cache layer
//! must be *invisible* except when it saves work. Four invariants, all
//! on the deterministic synthetic backend (always runs, no artifacts):
//!
//! * **miss transparency** — a request that misses every tier produces
//!   output bit-exact with a cache-disabled coordinator;
//! * **hit fidelity** — an exact-match replay is byte-identical to the
//!   generation that populated the entry, and the hit/miss counters
//!   account for every lookup;
//! * **shared-tier quality** — a full-window reuse consumer fed by the
//!   shared uncond cache lands at least as close (SSIM) to the full-CFG
//!   reference as the cond-only floor it would otherwise degrade to;
//! * **dedup conservation** — N identical concurrent requests run ONE
//!   physical generation, deliver N bit-equal results, and close N
//!   trace spans exactly once each (stats: retired per logical request,
//!   batches/UNet work per physical generation).
//!
//! Cases run under the seeded prop harness; override `PROP_MASTER_SEED`
//! to explore other universes.

use std::sync::Arc;

use selective_guidance::cache::{CacheConfig, CacheOutcome, SharedUncondCache};
use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::{BatchMode, Coordinator, CoordinatorConfig};
use selective_guidance::engine::{Engine, GenerationOutput, GenerationRequest};
use selective_guidance::error::Error;
use selective_guidance::guidance::{GuidanceStrategy, ReuseKind, WindowSpec};
use selective_guidance::qos::QosMeta;
use selective_guidance::quality::ssim;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::telemetry::{CoordSink, Telemetry};
use selective_guidance::testutil::prop::{forall, Gen};

fn engine() -> Arc<Engine> {
    Arc::new(Engine::new(
        Arc::new(ModelStack::synthetic()),
        EngineConfig::default(),
    ))
}

fn coordinator(cache: CacheConfig) -> Arc<Coordinator> {
    Coordinator::start(
        engine(),
        CoordinatorConfig { cache, ..CoordinatorConfig::default() },
    )
}

/// A small random request: enough surface diversity (prompt, steps,
/// seed, scale) that canonical keys genuinely differ across cases.
fn random_request(g: &mut Gen) -> GenerationRequest {
    GenerationRequest::new(format!("prop {}", g.word(8)))
        .steps(g.usize_in(2, 6))
        .seed(g.u64())
        .guidance_scale(g.f32_in(1.0, 9.0))
        .scheduler(SchedulerKind::Ddim)
        .decode(false)
}

fn assert_bit_equal(a: &GenerationOutput, b: &GenerationOutput, what: &str) {
    assert_eq!(a.latent.len(), b.latent.len(), "{what}: latent length");
    for (i, (x, y)) in a.latent.iter().zip(&b.latent).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: latent[{i}] differs ({x} vs {y})"
        );
    }
    assert_eq!(a.unet_evals, b.unet_evals, "{what}: unet_evals");
    assert_eq!(a.steps, b.steps, "{what}: steps");
    assert_eq!(a.plan_summary, b.plan_summary, "{what}: plan_summary");
    match (&a.image, &b.image) {
        (None, None) => {}
        (Some(x), Some(y)) => assert_eq!(x.data, y.data, "{what}: image bytes"),
        _ => panic!("{what}: one output decoded, the other did not"),
    }
}

/// Miss transparency: a cache-on coordinator serving a cold key is
/// bit-exact with a cache-disabled one — the amortization layer buys
/// nothing on a miss, and costs nothing either.
#[test]
fn prop_cache_miss_is_bit_exact() {
    forall("cache miss bit-exact", 6, |g| {
        let req = random_request(g);
        let off = coordinator(CacheConfig::default());
        let on = coordinator(CacheConfig {
            request_cache: true,
            dedup: true,
            ..CacheConfig::default()
        });
        let t_off = off.submit(req.clone()).expect("submit off");
        let t_on = on.submit(req).expect("submit on");
        assert_eq!(t_off.cache_outcome(), None, "cache layer off: no outcome");
        assert_eq!(t_on.cache_outcome(), Some(CacheOutcome::Miss));
        let out_off = t_off.wait().expect("off completes");
        let out_on = t_on.wait().expect("on completes");
        assert_bit_equal(&out_off, &out_on, "miss vs disabled");
        assert_eq!(on.stats().cache_hits, 0);
        off.shutdown();
        on.shutdown();
    });
}

/// Hit fidelity: resubmitting an identical request replays the stored
/// output byte-for-byte, and every counter accounts for it — one miss
/// to populate, one hit to replay, a different key misses again.
#[test]
fn prop_cache_hit_is_byte_identical() {
    forall("cache hit byte-identical", 6, |g| {
        let seed = g.u64();
        let req = random_request(g).seed(seed);
        let c = coordinator(CacheConfig { request_cache: true, ..CacheConfig::default() });

        let t1 = c.submit(req.clone()).expect("first submit");
        assert_eq!(t1.cache_outcome(), Some(CacheOutcome::Miss));
        let first = t1.wait().expect("first completes");

        let t2 = c.submit(req.clone()).expect("second submit");
        assert_eq!(t2.cache_outcome(), Some(CacheOutcome::Hit));
        let second = t2.wait().expect("hit resolves");
        assert_bit_equal(&first, &second, "hit vs generation");

        // a perturbed key must not false-hit
        let t3 = c.submit(req.seed(seed.wrapping_add(1))).expect("third submit");
        assert_eq!(t3.cache_outcome(), Some(CacheOutcome::Miss));
        t3.wait().expect("third completes");

        let stats = c.stats();
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(stats.completed, 3);
        let rc = c.request_cache_stats();
        assert_eq!(rc.hits, 1, "request-cache hit counter");
        assert_eq!(rc.misses, 2, "request-cache miss counter");
        assert_eq!(rc.entries, 2, "both generations stored");
        assert!(rc.bytes > 0, "size accounting tracks payloads");
        c.shutdown();
    });
}

/// Shared-tier quality: a full-window reuse consumer riding a
/// publisher's uncond eps must land at least as close to the full-CFG
/// reference (SSIM on decoded images) as the cond-only floor — the
/// shared tier restores guidance, it never costs quality.
#[test]
fn prop_shared_uncond_ssim_dominates_cond_only() {
    forall("shared uncond SSIM >= cond-only", 4, |g| {
        let e = engine();
        let seed = g.u64();
        let prompt = format!("shared {}", g.word(6));
        let steps = 8;
        let full = GenerationRequest::new(prompt.clone())
            .steps(steps)
            .seed(seed)
            .scheduler(SchedulerKind::Ddim)
            .decode(true);
        let cond_only = full
            .clone()
            .selective(WindowSpec::last(1.0))
            .strategy(GuidanceStrategy::CondOnly);
        let consumer = full
            .clone()
            .selective(WindowSpec::last(1.0))
            .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 });

        let full_out = e.generate(&full).expect("full CFG");
        let cond_out = e.generate(&cond_only).expect("cond-only");

        // publisher (full CFG, same trajectory) steps ahead; the
        // consumer's anchor-free shared plan eats its published eps
        let shared = SharedUncondCache::new(0.5);
        let mut states = vec![e.begin_shared(&full).expect("publisher")];
        for _ in 0..3 {
            e.step_batch_shared(&mut states, Some(&shared)).expect("publisher steps");
        }
        states.push(e.begin_shared(&consumer).expect("consumer"));
        while states.iter().any(|s| !s.is_done()) {
            e.step_batch_shared(&mut states, Some(&shared)).expect("cohort steps");
        }
        let consumer_state = states.pop().expect("consumer state");
        assert!(consumer_state.failed_reason().is_none(), "warm cache never cold-fails");
        let shared_out = e.finish(consumer_state).expect("consumer finishes");
        assert!(shared.stats().hits >= steps as u64, "every consumer step hit the tier");

        let reference = full_out.image.as_ref().expect("decoded");
        let ssim_shared = ssim(shared_out.image.as_ref().expect("decoded"), reference);
        let ssim_cond = ssim(cond_out.image.as_ref().expect("decoded"), reference);
        assert!(
            ssim_shared >= ssim_cond - 1e-9,
            "shared reuse ({ssim_shared:.4}) must not trail cond-only ({ssim_cond:.4})"
        );
    });
}

/// Dedup conservation: N identical requests behind a busy worker
/// coalesce into ONE physical generation with N deliveries — every
/// logical request is retired (stats + its own span, closed exactly
/// once), while batch/UNet work is charged once.
#[test]
fn prop_dedup_coalesces_to_one_generation() {
    forall("dedup: 1 generation, N deliveries", 3, |g| {
        let waiters = g.usize_in(2, 4);
        let telemetry = Telemetry::on();
        let c = Coordinator::start_full(
            engine(),
            CoordinatorConfig {
                max_batch: 1,
                workers: 1,
                cache: CacheConfig {
                    request_cache: true,
                    dedup: true,
                    ..CacheConfig::default()
                },
                ..CoordinatorConfig::default()
            },
            None,
            Some(CoordSink::new(&telemetry, "single", true)),
        );
        // hold the only worker so the identical burst queues behind it
        let occupant = GenerationRequest::new("occupant")
            .steps(800)
            .scheduler(SchedulerKind::Ddim)
            .decode(false);
        let t_occ = c.submit_qos(occupant, QosMeta::default()).expect("occupant");

        let req = random_request(g);
        let primary = c.submit_qos(req.clone(), QosMeta::default()).expect("primary");
        assert_eq!(primary.cache_outcome(), Some(CacheOutcome::Miss));
        let joined: Vec<_> = (0..waiters)
            .map(|i| {
                let t = c
                    .submit_qos(req.clone(), QosMeta::default())
                    .unwrap_or_else(|e| panic!("waiter {i}: {e}"));
                assert_eq!(t.cache_outcome(), Some(CacheOutcome::Dedup), "waiter {i}");
                t
            })
            .collect();

        t_occ.wait().expect("occupant completes");
        let first = primary.wait().expect("primary completes");
        for (i, t) in joined.into_iter().enumerate() {
            let out = t.wait().unwrap_or_else(|e| panic!("waiter {i} delivery: {e}"));
            assert_bit_equal(&first, &out, "coalesced delivery");
        }

        let stats = c.stats();
        let logical = 2 + waiters as u64; // occupant + primary + joiners
        assert_eq!(stats.dedup_coalesced, waiters as u64);
        assert_eq!(stats.completed, logical, "every logical request retired");
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.cache_hits, 0, "joins are not replays");
        // physical work: occupant's batch + ONE coalesced generation
        assert_eq!(stats.batches, 2, "one physical generation for the burst");
        assert_eq!(stats.batched_requests, 2);

        // a late identical submit replays from the request cache instead
        let late = c.submit_qos(req, QosMeta::default()).expect("late");
        assert_eq!(late.cache_outcome(), Some(CacheOutcome::Hit));
        assert_bit_equal(&first, &late.wait().expect("hit resolves"), "late hit");
        c.shutdown();

        let spans = telemetry.traces().spans();
        assert_eq!(spans.len(), logical as usize + 1, "one span per logical request");
        for span in &spans {
            assert_eq!(span.terminal_events(), 1, "span {} closes exactly once", span.id);
            assert!(span.has("retired"), "span {} retired", span.id);
        }
        let joins: usize = spans
            .iter()
            .map(|s| s.events.iter().filter(|e| e.event.name() == "dedup_join").count())
            .sum();
        assert_eq!(joins, waiters, "every coalesced waiter logged its join");
        let hits: usize = spans
            .iter()
            .map(|s| s.events.iter().filter(|e| e.event.name() == "cache_hit").count())
            .sum();
        assert_eq!(hits, 1, "the late replay logged its hit");
    });
}

/// Cold-shared-reuse regression at the serving layer: a planned-reuse
/// sample whose shared tier has nothing to offer fails alone, with a
/// typed engine error — the coordinator (and any cohort mates) survive.
#[test]
fn cold_shared_reuse_fails_one_sample_not_the_coordinator() {
    let c = Coordinator::start(
        engine(),
        CoordinatorConfig {
            mode: BatchMode::Continuous,
            slot_budget: 4,
            workers: 1,
            cache: CacheConfig { shared_uncond: true, ..CacheConfig::default() },
            ..CoordinatorConfig::default()
        },
    );
    let doomed = GenerationRequest::new("cold consumer")
        .steps(4)
        .selective(WindowSpec::last(1.0))
        .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 })
        .decode(false);
    match c.generate(doomed) {
        Err(Error::Engine(msg)) => {
            assert!(msg.contains("cold"), "typed cold-cache error, got {msg:?}")
        }
        other => panic!("expected Error::Engine on a cold shared tier, got {other:?}"),
    }
    // the coordinator is not poisoned: ordinary work still completes
    let out = c
        .generate(GenerationRequest::new("survivor").steps(3).decode(false))
        .expect("coordinator survives a failed sample");
    assert_eq!(out.steps, 3);
    let stats = c.stats();
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.completed, 1);
    c.shutdown();
}
