//! Plan-IR property suite (synthetic backend — always runs).
//!
//! Sweeps strategy × schedule (window / segments / interval / cadence) ×
//! step count and asserts the three ISSUE-4 properties:
//!
//! (a) plan compilation is deterministic — same (schedule, scale,
//!     strategy, steps) always yields the same plan;
//! (b) the engine's executed UNet evals equal `plan.total_unet_evals()`
//!     (the engine hard-asserts this in `finish`; here we drive it
//!     through randomized configurations and check the output too);
//! (c) plan-equivalent configs — e.g. `Last(f)` vs the equivalent
//!     `Segments` / `Interval` — produce bit-identical images under both
//!     fixed (lock-step `generate`) and continuous (slot-budgeted
//!     cohort) execution.

use std::sync::Arc;

use selective_guidance::config::{DualStrategy, EngineConfig};
use selective_guidance::coordinator::ContinuousBatcher;
use selective_guidance::engine::{Engine, GenerationOutput, GenerationRequest};
use selective_guidance::guidance::{
    GuidancePlan, GuidanceSchedule, GuidanceStrategy, ReuseKind, Segment, WindowSpec,
};
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::testutil::prop::{forall, Gen};

fn engine(dual: DualStrategy) -> Arc<Engine> {
    let cfg = EngineConfig { dual_strategy: dual, ..EngineConfig::default() };
    Arc::new(Engine::new(Arc::new(ModelStack::synthetic()), cfg))
}

fn random_strategy(g: &mut Gen) -> GuidanceStrategy {
    match g.usize_in(0, 2) {
        0 => GuidanceStrategy::CondOnly,
        1 => GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: g.usize_in(0, 5) },
        _ => GuidanceStrategy::Reuse {
            kind: ReuseKind::Extrapolate,
            refresh_every: g.usize_in(0, 5),
        },
    }
}

fn random_schedule(g: &mut Gen) -> GuidanceSchedule {
    match g.usize_in(0, 4) {
        0 => GuidanceSchedule::Window(WindowSpec::last(g.f64_in(0.0, 1.0))),
        1 => GuidanceSchedule::Window(WindowSpec::at_offset(
            g.f64_in(0.0, 1.0),
            g.f64_in(0.0, 1.0),
        )),
        2 => {
            let lo = g.f64_in(0.0, 1.0);
            GuidanceSchedule::Interval { lo, hi: g.f64_in(lo, 1.0) }
        }
        3 => GuidanceSchedule::Cadence { every: g.usize_in(1, 8) },
        _ => {
            let mut segs = Vec::new();
            for _ in 0..g.usize_in(1, 3) {
                let lo = g.f64_in(0.0, 1.0);
                let hi = g.f64_in(lo, 1.0);
                segs.push(if g.bool() {
                    Segment::optimized(lo, hi)
                } else {
                    Segment::dual(lo, hi)
                });
            }
            GuidanceSchedule::Segments(segs)
        }
    }
}

#[test]
fn plan_compilation_is_deterministic() {
    forall("plan determinism", 300, |g| {
        let n = g.usize_in(0, 150);
        let schedule = random_schedule(g);
        let strategy = random_strategy(g);
        let scale = if g.bool() { g.f32_in(1.5, 12.0) } else { 1.0 };
        let a = GuidancePlan::compile(&schedule, scale, strategy, n).unwrap();
        let b = GuidancePlan::compile(&schedule, scale, strategy, n).unwrap();
        assert_eq!(a, b, "{schedule:?} {strategy:?} n={n}");
        assert_eq!(a.len(), n);
        // internal consistency of the cost queries
        assert_eq!(a.total_unet_evals(), a.remaining_cost(0));
        assert!(a.total_unet_evals() >= n.min(a.len()));
        assert!(a.total_unet_evals() <= 2 * n);
        assert_eq!(
            a.single_pass_steps() + a.total_unet_evals(),
            2 * n,
            "single + total must be 2n (each single-pass step saves one eval)"
        );
        for from in [0, n / 2, n] {
            assert!(a.peak_remaining_cost(from) <= 2);
            assert!(a.remaining_cost(from) >= a.peak_remaining_cost(from).min(1));
        }
    });
}

#[test]
fn engine_executed_evals_match_plan() {
    let engines = [engine(DualStrategy::TwoB1), engine(DualStrategy::FusedB2)];
    forall("executed evals == plan total", 40, |g| {
        let steps = g.usize_in(1, 10);
        let req = GenerationRequest::new(format!("{} {}", g.word(8), g.word(8)))
            .steps(steps)
            .scheduler(*g.choose(&[SchedulerKind::Ddim, SchedulerKind::Euler]))
            .seed(g.u64())
            .guidance_scale(if g.bool() { g.f32_in(1.5, 12.0) } else { 1.0 })
            .with_schedule(random_schedule(g))
            .strategy(random_strategy(g))
            .decode(false);
        let plan = req.plan().unwrap();
        let e = &engines[g.usize_in(0, 1)];
        // finish() hard-asserts the invariant; the output must agree too
        let out = e.generate(&req).expect("generate");
        assert_eq!(
            out.unet_evals,
            plan.total_unet_evals(),
            "{:?} {:?}",
            req.schedule,
            req.strategy
        );
        assert!((req.effective_shed() - plan.effective_fraction()).abs() < 1e-12);
    });
}

/// Build three schedules with *identical* optimized step sets: the
/// paper's `Last` window over the last `k` of `n` steps, the equivalent
/// single-segment schedule, and the equivalent guided interval.
fn equivalent_trio(k: usize, n: usize) -> [GuidanceSchedule; 3] {
    // fraction with floor(f·n) == k, robust to fp rounding
    let f = if k == n { 1.0 } else { (k as f64 + 0.5) / n as f64 };
    let split = (n - k) as f64 / n as f64;
    [
        GuidanceSchedule::Window(WindowSpec::last(f)),
        GuidanceSchedule::Segments(vec![Segment::optimized(split, 1.0)]),
        GuidanceSchedule::Interval { lo: 0.0, hi: split },
    ]
}

#[test]
fn equivalent_schedules_compile_to_the_same_plan() {
    forall("schedule equivalence (plans)", 200, |g| {
        let n = g.usize_in(1, 100);
        let k = g.usize_in(0, n);
        let strategy = random_strategy(g);
        let scale = g.f32_in(1.5, 12.0);
        let plans: Vec<GuidancePlan> = equivalent_trio(k, n)
            .iter()
            .map(|s| GuidancePlan::compile(s, scale, strategy, n).unwrap())
            .collect();
        assert_eq!(plans[0], plans[1], "window vs segments, k={k} n={n}");
        assert_eq!(plans[0], plans[2], "window vs interval, k={k} n={n}");
    });
}

#[test]
fn equivalent_schedules_bit_identical_fixed_and_continuous() {
    for dual in [DualStrategy::TwoB1, DualStrategy::FusedB2] {
        let e = engine(dual);
        forall(&format!("schedule equivalence e2e ({dual:?})"), 12, |g| {
            let n = g.usize_in(2, 8);
            let k = g.usize_in(0, n);
            let strategy = random_strategy(g);
            let seed = g.u64();
            let reqs: Vec<GenerationRequest> = equivalent_trio(k, n)
                .into_iter()
                .map(|s| {
                    GenerationRequest::new("equivalence probe")
                        .steps(n)
                        .scheduler(SchedulerKind::Ddim)
                        .seed(seed)
                        .with_schedule(s)
                        .strategy(strategy)
                        .decode(true)
                })
                .collect();
            // fixed (lock-step) execution
            let fixed: Vec<GenerationOutput> =
                reqs.iter().map(|r| e.generate(r).expect("generate")).collect();
            for out in &fixed[1..] {
                assert_eq!(fixed[0].latent, out.latent, "fixed-mode latents diverged");
                assert_eq!(fixed[0].unet_evals, out.unet_evals);
                assert_eq!(
                    fixed[0].image.as_ref().unwrap().data,
                    out.image.as_ref().unwrap().data,
                    "fixed-mode images diverged"
                );
                assert_eq!(fixed[0].plan_summary, out.plan_summary);
            }
            // continuous (slot-budgeted cohort) execution: all three in
            // one cohort — composition must not leak into any output
            let mut cb = ContinuousBatcher::new(Arc::clone(&e), 6).expect("batcher");
            let mut ids = Vec::new();
            for r in &reqs {
                ids.push(cb.try_admit(r).expect("admit").expect("headroom for all three"));
            }
            let mut outs: Vec<Option<GenerationOutput>> = vec![None, None, None];
            let mut guard = 0;
            while outs.iter().any(|o| o.is_none()) {
                for (id, out) in cb.step().expect("step").retired {
                    let idx = ids.iter().position(|&i| i == id).unwrap();
                    outs[idx] = Some(out);
                }
                guard += 1;
                assert!(guard < 100, "cohort failed to drain");
            }
            for out in outs.iter().map(|o| o.as_ref().unwrap()) {
                assert_eq!(
                    fixed[0].latent, out.latent,
                    "continuous-mode latent diverged from fixed"
                );
                assert_eq!(fixed[0].unet_evals, out.unet_evals);
                assert_eq!(
                    fixed[0].image.as_ref().unwrap().data,
                    out.image.as_ref().unwrap().data,
                    "continuous-mode image diverged"
                );
            }
        });
    }
}
