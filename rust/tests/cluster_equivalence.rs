//! Replica-cluster equivalence + lifecycle properties on the
//! deterministic synthetic backend (no PJRT artifacts needed — this
//! suite always runs, and the whole-suite `PROP_MASTER_SEED` CI matrix
//! re-runs it in other randomness universes).
//!
//! The invariants under test are DESIGN.md §11's contract:
//!
//! * a **1-replica cluster is bit-identical** to the plain coordinator —
//!   the cluster layer adds routing and lifecycle, never arithmetic —
//!   under both batch modes and both dual strategies;
//! * **placement is deterministic**: same trace + seed + route policy ⇒
//!   same per-request placement and outputs;
//! * **killing a replica mid-trace loses no requests**: queued work
//!   requeues onto survivors (503 drain sheds are a replica's failure,
//!   not the request's) and `/stats` carries the ejection audit trail;
//! * **graceful shutdown sheds queued jobs with an explicit 503** —
//!   every outstanding ticket resolves, none hang, none silently execute.

use std::sync::Arc;

use selective_guidance::cluster::{ClusterConfig, ReplicaSet, ReplicaSpec, RoutePolicy};
use selective_guidance::config::{DualStrategy, EngineConfig};
use selective_guidance::coordinator::{BatchMode, Coordinator, CoordinatorConfig};
use selective_guidance::engine::{Engine, GenerationOutput, GenerationRequest};
use selective_guidance::error::Error;
use selective_guidance::guidance::{GuidanceStrategy, ReuseKind, WindowSpec};
use selective_guidance::qos::QosMeta;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::testutil::prop::{forall, Gen};
use selective_guidance::workload::{
    replay_qos_cluster, ArrivalProcess, KillSpec, RequestOutcome, WorkloadSpec,
};

fn engine(dual: DualStrategy) -> Arc<Engine> {
    let cfg = EngineConfig { dual_strategy: dual, ..EngineConfig::default() };
    Arc::new(Engine::new(Arc::new(ModelStack::synthetic()), cfg))
}

fn continuous_spec(slot_budget: usize) -> ReplicaSpec {
    ReplicaSpec { mode: BatchMode::Continuous, slot_budget, ..ReplicaSpec::default() }
}

fn random_request(g: &mut Gen) -> GenerationRequest {
    let kinds = [
        SchedulerKind::Ddim,
        SchedulerKind::Ddpm,
        SchedulerKind::Pndm,
        SchedulerKind::Euler,
        SchedulerKind::Heun,
    ];
    let scale = if g.bool() { g.f32_in(1.5, 12.0) } else { 1.0 };
    let strategy = match g.usize_in(0, 2) {
        0 => GuidanceStrategy::CondOnly,
        1 => GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: g.usize_in(0, 4) },
        _ => GuidanceStrategy::Reuse {
            kind: ReuseKind::Extrapolate,
            refresh_every: g.usize_in(0, 4),
        },
    };
    GenerationRequest::new(format!("{} {}", g.word(8), g.word(8)))
        .steps(g.usize_in(2, 9))
        .scheduler(*g.choose(&kinds))
        .seed(g.u64())
        .guidance_scale(scale)
        .selective(WindowSpec::last(g.f64_in(0.0, 1.0)))
        .strategy(strategy)
        .decode(false)
}

/// The satellite's core claim: wrapping ONE coordinator in the cluster
/// layer changes nothing about the outputs — latents and eval counts are
/// bit-identical to the plain coordinator path (and both match solo).
fn one_replica_matches_plain(mode: BatchMode, dual: DualStrategy) {
    let e = engine(dual);
    let spec = match mode {
        BatchMode::Continuous => continuous_spec(6),
        BatchMode::Fixed => ReplicaSpec::default(),
    };
    forall(&format!("1-replica cluster == coordinator ({mode:?}/{dual:?})"), 12, |g| {
        let k = g.usize_in(1, 5);
        let reqs: Vec<GenerationRequest> = (0..k).map(|_| random_request(g)).collect();

        let plain = Coordinator::start(Arc::clone(&e), spec.coordinator_config());
        let plain_outs: Vec<GenerationOutput> = reqs
            .iter()
            .map(|r| plain.submit(r.clone()).expect("submit"))
            .collect::<Vec<_>>()
            .into_iter()
            .map(|t| t.wait().expect("plain wait"))
            .collect();
        plain.shutdown();

        let set = ReplicaSet::start(
            Arc::clone(&e),
            ClusterConfig { replicas: vec![spec.clone()], ..ClusterConfig::default() },
        )
        .expect("cluster");
        let cluster_outs: Vec<GenerationOutput> = reqs
            .iter()
            .map(|r| set.submit(r.clone()).expect("submit"))
            .collect::<Vec<_>>()
            .into_iter()
            .map(|t| t.wait().expect("cluster wait"))
            .collect();
        let stats = set.stats();
        set.shutdown();

        for (i, (p, c)) in plain_outs.iter().zip(&cluster_outs).enumerate() {
            let solo = e.generate(&reqs[i]).expect("solo");
            assert_eq!(p.latent, c.latent, "sample {i}: cluster layer leaked into the output");
            assert_eq!(p.unet_evals, c.unet_evals, "sample {i}: eval count diverged");
            assert_eq!(solo.latent, c.latent, "sample {i}: diverged from solo");
        }
        assert_eq!(stats.completed, k as u64);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.requeued, 0);
        assert_eq!(stats.replicas[0].routed, k as u64);
    });
}

#[test]
fn one_replica_cluster_matches_plain_continuous_two_b1() {
    one_replica_matches_plain(BatchMode::Continuous, DualStrategy::TwoB1);
}

#[test]
fn one_replica_cluster_matches_plain_continuous_fused_b2() {
    one_replica_matches_plain(BatchMode::Continuous, DualStrategy::FusedB2);
}

#[test]
fn one_replica_cluster_matches_plain_fixed_two_b1() {
    one_replica_matches_plain(BatchMode::Fixed, DualStrategy::TwoB1);
}

#[test]
fn one_replica_cluster_matches_plain_fixed_fused_b2() {
    one_replica_matches_plain(BatchMode::Fixed, DualStrategy::FusedB2);
}

/// Same trace + same route seed + same policy ⇒ same per-request
/// placements and the same outputs, run to run.
#[test]
fn multi_replica_placement_is_deterministic() {
    let e = engine(DualStrategy::TwoB1);
    // 30-step jobs on slot-budget-2 replicas: the submission burst (µs)
    // is orders of magnitude shorter than the first completion, so the
    // router sees a pure increment sequence — placement is a function of
    // the trace alone
    let reqs: Vec<GenerationRequest> = (0..12)
        .map(|i| {
            GenerationRequest::new(format!("det{i}"))
                .steps(30)
                .scheduler(SchedulerKind::Ddim)
                .selective(WindowSpec::last([0.0, 0.5, 1.0][i % 3]))
                .seed(i as u64)
                .decode(false)
        })
        .collect();
    let run = |route: RoutePolicy| -> (Vec<usize>, Vec<GenerationOutput>) {
        let set = ReplicaSet::start(
            Arc::clone(&e),
            ClusterConfig {
                replicas: vec![continuous_spec(2), continuous_spec(2), continuous_spec(2)],
                route,
                route_seed: 7,
            },
        )
        .expect("cluster");
        let submitted: Vec<_> = reqs
            .iter()
            .map(|r| set.submit_traced(r.clone(), QosMeta::default()).expect("submit"))
            .collect();
        let mut placements = Vec::new();
        let mut outs = Vec::new();
        for (t, trace) in submitted {
            outs.push(t.wait().expect("wait"));
            let h = trace.history();
            assert_eq!(h.len(), 1, "no requeues in a healthy cluster");
            placements.push(h[0]);
        }
        set.shutdown();
        (placements, outs)
    };
    for route in [RoutePolicy::PlanCost, RoutePolicy::RoundRobin] {
        let (p1, o1) = run(route);
        let (p2, o2) = run(route);
        assert_eq!(p1, p2, "{route:?}: placements diverged across identical runs");
        for (i, (a, b)) in o1.iter().zip(&o2).enumerate() {
            assert_eq!(a.latent, b.latent, "{route:?}: sample {i} output diverged");
        }
        // the placement stream actually spreads over the fleet:
        // round-robin by construction touches every replica; the
        // load-seeking two-choice policy is guaranteed to leave no
        // single replica hoarding everything
        match route {
            RoutePolicy::RoundRobin => assert!(
                (0..3).all(|r| p1.contains(&r)),
                "round-robin must touch every replica: {p1:?}"
            ),
            RoutePolicy::PlanCost => {
                let distinct =
                    (0..3).filter(|r| p1.contains(r)).count();
                assert!(distinct >= 2, "plan-cost hoarded one replica: {p1:?}");
            }
        }
    }
}

/// Killing a replica while it still holds queued work must lose nothing:
/// its queued jobs requeue onto the survivor and complete bit-exactly.
#[test]
fn kill_requeues_queued_work_bit_exactly() {
    let e = engine(DualStrategy::TwoB1);
    let set = ReplicaSet::start(
        Arc::clone(&e),
        ClusterConfig {
            replicas: vec![continuous_spec(2), continuous_spec(2)],
            ..ClusterConfig::default()
        },
    )
    .expect("cluster");
    let reqs: Vec<GenerationRequest> = (0..12)
        .map(|i| {
            GenerationRequest::new(format!("kill{i}"))
                .steps(25)
                .scheduler(SchedulerKind::Ddim)
                .seed(100 + i as u64)
                .decode(false)
        })
        .collect();
    let tickets: Vec<_> = reqs
        .iter()
        .map(|r| set.submit_traced(r.clone(), QosMeta::default()).expect("submit"))
        .collect();
    // kill immediately: replica 0's worker cannot have executed its whole
    // share of 25-step trajectories yet, so its queue is non-empty
    set.kill(0).expect("kill");
    for (i, ((t, trace), r)) in tickets.into_iter().zip(&reqs).enumerate() {
        let out = t.wait().unwrap_or_else(|err| panic!("request {i} lost: {err}"));
        let solo = e.generate(r).expect("solo");
        assert_eq!(solo.latent, out.latent, "request {i}: requeue corrupted the output");
        assert_eq!(solo.unet_evals, out.unet_evals, "request {i}: eval count diverged");
        // every placement hop is a real replica, and after the kill the
        // final home must be the survivor
        let h = trace.history();
        assert!(!h.is_empty() && h.iter().all(|&p| p < 2));
        if h.len() > 1 {
            assert_eq!(*h.last().unwrap(), 1, "requeued request must land on the survivor");
        }
    }
    let stats = set.stats();
    assert_eq!(stats.completed, 12, "no request may be lost");
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.ejected, 1);
    assert_eq!(stats.healthy_replicas, 1);
    // conservation: everything routed to the dead replica either
    // completed there before the kill or was requeued off it
    let r0 = &stats.replicas[0];
    assert_eq!(r0.routed, r0.coordinator.completed + stats.requeued);
    assert!(stats.requeued >= 1, "a 25-step backlog cannot drain in microseconds");
    set.shutdown();
}

/// The workload surface end-to-end: a `kill_at`-style spec entry fires
/// mid-replay and the per-request outcomes show zero loss.
#[test]
fn workload_kill_injection_replays_without_loss() {
    let e = engine(DualStrategy::TwoB1);
    let set = ReplicaSet::start(
        Arc::clone(&e),
        ClusterConfig {
            replicas: vec![continuous_spec(2), continuous_spec(2)],
            ..ClusterConfig::default()
        },
    )
    .expect("cluster");
    let spec = WorkloadSpec {
        arrivals: ArrivalProcess::Uniform { rate_per_s: 4000.0 },
        num_requests: 24,
        steps: 20,
        scheduler: SchedulerKind::Ddim,
        decode: false,
        kills: vec![KillSpec { at_ms: 3.0, replica: 0 }],
        ..WorkloadSpec::default()
    };
    let trace = spec.synthesize();
    let report = replay_qos_cluster(&set, &trace, &spec.kills).expect("replay");
    assert_eq!(report.completed(), 24, "kill mid-replay must lose no requests");
    assert!(report
        .outcomes
        .iter()
        .all(|o| matches!(o, RequestOutcome::Completed { .. })));
    let stats = set.stats();
    assert_eq!(stats.ejected, 1);
    assert_eq!(stats.completed, 24);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.healthy_replicas, 1);
    // ejection audit: the dead replica's ledger balances (served + moved)
    let r0 = &stats.replicas[0];
    assert_eq!(r0.routed, r0.coordinator.completed + stats.requeued);
    set.shutdown();
}

/// The graceful-shutdown bugfix: queued-but-unadmitted jobs must fail
/// with an explicit 503 shed — no ticket hangs, none silently executes
/// after the drain began.
fn shutdown_sheds_queued(mode: BatchMode) {
    let e = engine(DualStrategy::TwoB1);
    let config = match mode {
        BatchMode::Continuous => CoordinatorConfig {
            mode,
            slot_budget: 2,
            workers: 1,
            ..CoordinatorConfig::default()
        },
        BatchMode::Fixed => CoordinatorConfig {
            mode,
            max_batch: 1,
            workers: 1,
            batch_wait: std::time::Duration::from_millis(0),
            ..CoordinatorConfig::default()
        },
    };
    let c = Coordinator::start(Arc::clone(&e), config);
    let tickets: Vec<_> = (0..8)
        .map(|i| {
            let r = GenerationRequest::new(format!("q{i}"))
                .steps(25)
                .scheduler(SchedulerKind::Ddim)
                .seed(i as u64)
                .decode(false);
            c.submit(r).expect("submit")
        })
        .collect();
    // shutdown with most of the queue unexecuted (8 × 25 steps cannot
    // finish in the microseconds since submission)
    c.shutdown();
    let mut completed = 0u64;
    let mut shed = 0u64;
    for (i, t) in tickets.into_iter().enumerate() {
        // post-join every response has been sent: this never blocks
        match t.wait() {
            Ok(out) => {
                assert!(out.latent.iter().all(|v| v.is_finite()));
                completed += 1;
            }
            Err(Error::Rejected { code, .. }) => {
                assert_eq!(code, 503, "request {i}: drain shed must be a 503");
                shed += 1;
            }
            Err(other) => panic!("request {i}: expected completion or 503 shed, got {other}"),
        }
    }
    assert_eq!(completed + shed, 8, "every ticket resolves");
    assert!(shed >= 1, "a 25-step backlog cannot fully execute before the drain flag lands");
    let stats = c.stats();
    assert_eq!(stats.drain_shed, shed);
    assert_eq!(stats.completed, completed);
    assert_eq!(stats.failed, 0);
}

#[test]
fn shutdown_sheds_queued_jobs_continuous() {
    shutdown_sheds_queued(BatchMode::Continuous);
}

#[test]
fn shutdown_sheds_queued_jobs_fixed() {
    shutdown_sheds_queued(BatchMode::Fixed);
}

/// The server front-end over a cluster backend: `/stats` reports the
/// aggregate plus the per-replica breakdown.
#[test]
fn server_cluster_stats_surface() {
    use selective_guidance::json::Value;
    use selective_guidance::server::{Client, GuidanceDefaults, Server};
    let e = engine(DualStrategy::TwoB1);
    let set = ReplicaSet::start(
        Arc::clone(&e),
        ClusterConfig {
            replicas: vec![continuous_spec(4), continuous_spec(2)],
            ..ClusterConfig::default()
        },
    )
    .expect("cluster");
    let mut server =
        Server::start_cluster(Arc::clone(&set), "127.0.0.1:0", GuidanceDefaults::default())
            .expect("server");
    let addr = server.addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");
    // run one request through the wire path
    let resp = client
        .call(
            Value::obj()
                .with("op", "generate")
                .with("prompt", "a cluster smoke test")
                .with("steps", 4i64)
                .with("return_image", false),
        )
        .expect("generate");
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp}");
    let stats = client.stats().expect("stats");
    assert_eq!(stats.get("cluster").and_then(Value::as_bool), Some(true));
    assert_eq!(stats.get("route").and_then(Value::as_str), Some("plan-cost"));
    assert_eq!(stats.get("completed").and_then(Value::as_i64), Some(1));
    assert_eq!(stats.get("healthy_replicas").and_then(Value::as_i64), Some(2));
    let replicas = stats.get("replicas").and_then(Value::as_arr).expect("replicas array");
    assert_eq!(replicas.len(), 2);
    assert_eq!(replicas[0].get("id").and_then(Value::as_i64), Some(0));
    assert_eq!(
        replicas[0].get("capacity_weight").and_then(Value::as_f64),
        Some(4.0)
    );
    assert_eq!(replicas[1].get("capacity_weight").and_then(Value::as_f64), Some(2.0));
    server.stop();
    set.shutdown();
}
