//! Shared helpers for integration tests (need built artifacts).

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use selective_guidance::runtime::ModelStack;

/// Locate the tiny-preset artifacts, or None when not built.
pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("SG_ARTIFACTS").unwrap_or_else(|_| "artifacts/tiny".into());
    let p = PathBuf::from(dir);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        None
    }
}

/// Process-wide shared stack (PJRT compile is expensive; share it).
pub fn shared_stack() -> Option<Arc<ModelStack>> {
    static STACK: OnceLock<Option<Arc<ModelStack>>> = OnceLock::new();
    STACK
        .get_or_init(|| artifacts_dir().map(|d| Arc::new(ModelStack::load(d).expect("load stack"))))
        .clone()
}

/// Skip (return early) when artifacts aren't built. Prints a notice so
/// skipped coverage is visible in CI output.
#[macro_export]
macro_rules! require_artifacts {
    () => {
        match $crate::common::shared_stack() {
            Some(stack) => stack,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}
