//! Deadline-optimal plan-search property suite (engine-free analytic
//! scorers — always runs; seeded by `PROP_MASTER_SEED` like every prop
//! suite).
//!
//! The ISSUE-10 properties:
//!
//! (a) [`tune_frontier`] is deterministic — the same sweep over the same
//!     table seals byte-identical manifests — and every sealed bucket is
//!     *strictly* non-dominated (cost and SSIM both strictly increase
//!     along the frontier) with the full-CFG baseline as its anchor;
//! (b) [`PlanSearch::select`] is monotone in budget: lowering the
//!     demanded saving never loses SSIM, and within the frontier's
//!     reach (and under the floor) the selected point actually covers
//!     the demand;
//! (c) any post-seal tamper — one byte in a string field, one nudged
//!     score or price — fails the checksum with a typed
//!     [`Error::Artifact`];
//! (d) the planner is strictly opt-in: a policy without a frontier, and
//!     a planner-attached policy serving an opted-out request, make
//!     decisions bit-identical to the legacy analytic actuator;
//! (e) the O(1)-admission ledger balances: every select is exactly one
//!     search, every search is a frontier hit or a fallback, and the
//!     sealed `candidates_swept` never moves at admission time.

use std::sync::Arc;
use std::time::Duration;

use selective_guidance::engine::GenerationRequest;
use selective_guidance::error::Error;
use selective_guidance::guidance::{
    tune_frontier, CostTable, FrontierManifest, GuidancePlan, GuidanceSchedule,
    GuidanceStrategy, PlanSearch, TuneProvenance, TunerConfig, WindowSpec,
};
use selective_guidance::json;
use selective_guidance::qos::{DeadlineQos, QosConfig, QosMeta, QosPolicy};
use selective_guidance::testutil::prop::{forall, Gen};

/// The fig5/fig6 analytic quality shape: SSIM falls with effective shed,
/// reuse strategies degrade slower than cond-only. Deterministic and
/// engine-free, so the properties run on any machine.
fn analytic_score(
    schedule: &GuidanceSchedule,
    strategy: GuidanceStrategy,
    steps: usize,
) -> selective_guidance::error::Result<f64> {
    let plan = GuidancePlan::compile(schedule, 7.5, strategy, steps)?;
    let f = plan.effective_fraction();
    let penalty = match strategy {
        GuidanceStrategy::CondOnly => 0.30,
        GuidanceStrategy::Reuse { .. } => 0.12,
    };
    Ok((1.0 - penalty * f * f).clamp(0.0, 1.0))
}

fn prov() -> TuneProvenance {
    TuneProvenance {
        tool_version: "prop".into(),
        backend: "synthetic".into(),
        preset: "synthetic".into(),
        model_fingerprint: "00000000deadbeef".into(),
        resolution: 8,
    }
}

/// A random but valid sweep shape: buckets large enough that the
/// grammar's fractions round to real shed, fractions/cadences/intervals
/// drawn inside their domains.
fn random_tuner(g: &mut Gen) -> TunerConfig {
    let mut fractions = Vec::new();
    for _ in 0..g.usize_in(1, 4) {
        fractions.push(g.f64_in(0.1, 0.9));
    }
    let mut cadences = Vec::new();
    for _ in 0..g.usize_in(1, 3) {
        cadences.push(g.usize_in(2, 6));
    }
    let mut intervals = Vec::new();
    for _ in 0..g.usize_in(0, 2) {
        let lo = g.f64_in(0.0, 0.5);
        intervals.push((lo, g.f64_in(lo + 0.2, 1.0)));
    }
    let mut steps_buckets = Vec::new();
    let mut s = g.usize_in(10, 24);
    for _ in 0..g.usize_in(1, 3) {
        steps_buckets.push(s);
        s = s * 2 + g.usize_in(1, 10);
    }
    TunerConfig {
        steps_buckets,
        fractions,
        cadences,
        intervals,
        refresh_every: g.usize_in(0, 6),
        guidance_scale: 7.5,
    }
}

fn tuned(g: &mut Gen) -> (FrontierManifest, TunerConfig) {
    let cfg = random_tuner(g);
    let unit = *g.choose(&[0.25, 0.5, 1.0, 2.0]);
    let table = CostTable::proportional(unit, &[1, 2, 4]);
    let m = tune_frontier(&cfg, &table, &prov(), analytic_score).unwrap();
    (m, cfg)
}

#[test]
fn tuning_is_deterministic_and_strictly_non_dominated() {
    forall("frontier determinism + dominance", 60, |g| {
        let cfg = random_tuner(g);
        let table = CostTable::proportional(*g.choose(&[0.5, 1.0, 2.0]), &[1, 2, 4]);
        let a = tune_frontier(&cfg, &table, &prov(), analytic_score).unwrap();
        let b = tune_frontier(&cfg, &table, &prov(), analytic_score).unwrap();
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "same sweep must seal byte-identical manifests"
        );
        assert_eq!(a.candidates_swept, cfg.candidates().len());
        assert_eq!(a.buckets.len(), cfg.steps_buckets.len());
        for bucket in &a.buckets {
            bucket.validate().unwrap();
            // strict non-domination: both axes strictly increase
            for w in bucket.points.windows(2) {
                assert!(w[1].cost_ms > w[0].cost_ms, "{:?}", bucket.steps);
                assert!(w[1].ssim > w[0].ssim, "{:?}", bucket.steps);
            }
            // the full-CFG baseline anchors the expensive end
            let anchor = bucket.points.last().unwrap();
            assert_eq!(anchor.ssim, 1.0);
            assert!((anchor.cost_ms - bucket.full_cost_ms).abs() < 1e-9);
            // every point re-prices to its sealed cost under the same
            // table (the frontier is ordinary compiled plans, not magic)
            for p in &bucket.points {
                let plan = GuidancePlan::compile(&p.schedule, 7.5, p.strategy, bucket.steps)
                    .unwrap();
                assert!((plan.cost_ms(&table) - p.cost_ms).abs() < 1e-9, "{}", p.label);
            }
        }
    });
}

#[test]
fn select_is_monotone_in_budget_and_covers_the_demand() {
    forall("select budget monotonicity", 60, |g| {
        let (m, cfg) = tuned(g);
        let ps = PlanSearch::new(m).unwrap();
        let floor = g.f64_in(0.2, 1.0);
        let steps = *g.choose(&cfg.steps_buckets);
        let max_saving = ps.select(steps, 1.0, 1.0).unwrap().saving;
        let mut prev_ssim = f64::NEG_INFINITY;
        for i in (0..=20).rev() {
            let needed = i as f64 * 0.05;
            let sel = ps.select(steps, needed, floor).expect("tuned bucket must hit");
            assert!(
                sel.ssim >= prev_ssim,
                "more budget lost SSIM: needed {needed}, {} < {prev_ssim}",
                sel.ssim
            );
            prev_ssim = sel.ssim;
            if needed <= floor && needed <= max_saving {
                assert!(
                    sel.saving + 1e-9 >= needed,
                    "demand {needed} uncovered: got {}",
                    sel.saving
                );
            }
        }
        // zero demand always answers with the full-CFG anchor
        let idle = ps.select(steps, 0.0, floor).unwrap();
        assert_eq!(idle.ssim, 1.0);
        assert_eq!(idle.saving, 0.0);
    });
}

#[test]
fn any_post_seal_tamper_fails_the_checksum() {
    forall("frontier tamper", 60, |g| {
        let (m, _) = tuned(g);
        let mut bad = m.clone();
        match g.usize_in(0, 4) {
            0 => bad.backend.push('x'), // one extra byte in a string field
            1 => bad.preset.push('y'),
            2 => bad.resolution += 1,
            3 => {
                let b = g.usize_in(0, bad.buckets.len() - 1);
                bad.buckets[b].full_cost_ms += 0.5;
            }
            _ => {
                // make one frontier point promise more quality than the
                // sweep measured
                let b = g.usize_in(0, bad.buckets.len() - 1);
                let p = g.usize_in(0, bad.buckets[b].points.len() - 1);
                bad.buckets[b].points[p].ssim = (bad.buckets[b].points[p].ssim - 0.1).max(0.0);
            }
        }
        let text = bad.to_json().to_string();
        assert_ne!(text, m.to_json().to_string(), "the tamper must change the payload");
        let err = FrontierManifest::from_json(&json::from_str(&text).unwrap()).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err:?}");
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
    });
}

#[test]
fn planner_off_is_bit_exact_legacy_admission() {
    forall("planner opt-out equivalence", 40, |g| {
        let cfg = QosConfig {
            enabled: true,
            ramp_low: g.usize_in(0, 2),
            ramp_high: g.usize_in(3, 12),
            floor_fraction: g.f64_in(0.1, 0.8),
            max_queue_depth: 64,
            ..QosConfig::default()
        };
        let service_ms = g.f64_in(20.0, 200.0);
        let prime = |q: &DeadlineQos| {
            for _ in 0..20 {
                q.observe_batch(1, Duration::from_secs_f64(service_ms / 1e3), 0.0);
            }
        };
        let legacy = DeadlineQos::new(cfg.clone()).unwrap();
        let planned = DeadlineQos::new(cfg).unwrap();
        prime(&legacy);
        prime(&planned);
        let (m, tuner_cfg) = tuned(g);
        let search = Arc::new(PlanSearch::new(m).unwrap());
        planned.attach_planner(Arc::clone(&search));

        // identical request streams: explicit windows, rich schedules
        // and bare defaults, at depths across the whole ramp
        for _ in 0..8 {
            let steps = if g.bool() {
                *g.choose(&tuner_cfg.steps_buckets)
            } else {
                g.usize_in(4, 80)
            };
            let base = match g.usize_in(0, 2) {
                0 => GenerationRequest::new("p").steps(steps),
                1 => GenerationRequest::new("p")
                    .steps(steps)
                    .selective(WindowSpec::last(g.f64_in(0.0, 1.0))),
                _ => GenerationRequest::new("p")
                    .steps(steps)
                    .with_schedule(GuidanceSchedule::Cadence { every: g.usize_in(2, 6) }),
            }
            .decode(false);
            let depth = g.usize_in(0, 16);

            // (d1) a planner-attached policy serving an opted-out
            // request == the legacy policy, decision for decision
            let mut a = base.clone();
            let mut a_meta = QosMeta { planner_opt_out: true, ..QosMeta::default() };
            let mut b = base.clone();
            let mut b_meta = QosMeta::default();
            let before = search.snapshot();
            let da = format!("{:?}", planned.admit(&mut a, &mut a_meta, depth));
            let db = format!("{:?}", legacy.admit(&mut b, &mut b_meta, depth));
            assert_eq!(da, db, "admission decisions diverged");
            assert_eq!(a.schedule, b.schedule, "opt-out schedule diverged");
            assert_eq!(a.strategy, b.strategy, "opt-out strategy diverged");
            assert_eq!(
                search.snapshot(),
                before,
                "an opted-out request must never touch the frontier"
            );
        }
    });
}

#[test]
fn search_ledger_balances_and_candidates_stay_sealed() {
    forall("O(1) admission ledger", 60, |g| {
        let (m, cfg) = tuned(g);
        let swept = m.candidates_swept;
        let checksum = m.checksum.clone();
        let ps = PlanSearch::new(m).unwrap();
        let n = g.usize_in(1, 40);
        for _ in 0..n {
            // mix of on-frontier and off-frontier step counts
            let steps = if g.bool() {
                *g.choose(&cfg.steps_buckets)
            } else {
                g.usize_in(1, 2000)
            };
            let _ = ps.select(steps, g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0));
        }
        let snap = ps.snapshot();
        // every select is exactly one search; every search resolves to a
        // hit or a fallback, never both, never neither
        assert_eq!(snap.searches, n as u64);
        assert_eq!(snap.frontier_hits + snap.fallbacks, snap.searches);
        assert!(snap.floor_clamps <= snap.frontier_hits);
        // admission-time work never re-opens the sweep: the sealed
        // candidate count and the manifest identity are constants
        assert_eq!(ps.manifest().candidates_swept, swept);
        assert_eq!(ps.manifest().checksum, checksum);
    });
}
