//! Integration: the QoS layer over a real engine (needs built artifacts;
//! skips otherwise — the engine-free control-law coverage lives in
//! `src/qos/` and `benches/qos_control.rs`).

mod common;

use std::sync::Arc;
use std::time::Duration;

use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::{Coordinator, CoordinatorConfig};
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::error::Error;
use selective_guidance::qos::{DeadlineQos, QosConfig, QosMeta};
use selective_guidance::scheduler::SchedulerKind;

fn qos_coordinator(cfg: QosConfig) -> Option<Arc<Coordinator>> {
    let stack = common::shared_stack()?;
    let engine = Arc::new(Engine::new(stack, EngineConfig::default()));
    Some(Coordinator::start_qos(
        engine,
        CoordinatorConfig {
            max_batch: 4,
            workers: 1,
            batch_wait: Duration::from_millis(2),
            ..CoordinatorConfig::default()
        },
        Arc::new(DeadlineQos::new(cfg).expect("valid qos config")),
    ))
}

macro_rules! require_qos_coordinator {
    ($cfg:expr) => {
        match qos_coordinator($cfg) {
            Some(c) => c,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn quick(prompt: &str, seed: u64) -> GenerationRequest {
    GenerationRequest::new(prompt)
        .steps(6)
        .scheduler(SchedulerKind::Ddim)
        .decode(false)
        .seed(seed)
}

#[test]
fn admitted_request_completes_and_counts() {
    let c = require_qos_coordinator!(QosConfig { enabled: true, ..QosConfig::default() });
    let out = c
        .submit_qos(quick("A cat", 1), QosMeta::default())
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(out.steps, 6);
    let s = c.stats();
    assert_eq!(s.completed, 1);
    assert_eq!(s.rejected, 0);
    assert!(s.queue_depth_max >= 1);
    assert_eq!(s.queue_depth, 0);
    c.shutdown();
}

#[test]
fn queue_bound_sheds_excess_load() {
    // queue bound of 1: a burst must produce explicit rejections
    let c = require_qos_coordinator!(QosConfig {
        enabled: true,
        max_queue_depth: 1,
        ..QosConfig::default()
    });
    let mut tickets = Vec::new();
    let mut rejected = 0usize;
    for i in 0..8u64 {
        match c.submit_qos(quick("burst", i), QosMeta::default()) {
            Ok(t) => tickets.push(t),
            Err(Error::Rejected { code, .. }) => {
                assert_eq!(code, 429);
                rejected += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(rejected > 0, "a burst over a 1-deep queue must shed");
    for t in tickets {
        t.wait().unwrap();
    }
    let s = c.stats();
    assert_eq!(s.rejected, rejected as u64);
    assert_eq!(s.completed + s.rejected, 8);
    c.shutdown();
}

#[test]
fn stale_requests_expire_instead_of_executing() {
    // deadline far below any real service time: queued requests behind
    // the first batch expire with a 504-style error
    let c = require_qos_coordinator!(QosConfig { enabled: true, ..QosConfig::default() });
    let meta = QosMeta::with_deadline_ms(1.0);
    let mut results = Vec::new();
    for i in 0..6u64 {
        if let Ok(t) = c.submit_qos(quick("stale", i), meta) {
            results.push(t);
        }
    }
    let mut expired = 0usize;
    for t in results {
        match t.wait() {
            Err(Error::DeadlineExceeded(_)) => expired += 1,
            Ok(_) | Err(_) => {}
        }
    }
    let s = c.stats();
    assert_eq!(s.deadline_missed, expired as u64);
    assert_eq!(s.queue_depth, 0, "every job must be accounted for");
    c.shutdown();
}
