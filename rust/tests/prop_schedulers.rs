//! Property suite over every [`SchedulerKind`], driven by the in-crate
//! deterministic prop harness (`testutil::prop::forall`; override the
//! universe with `PROP_MASTER_SEED`).
//!
//! Contracts checked, 200+ random cases per scheduler:
//! * same-seed determinism — two instances fed identical latent/eps
//!   streams produce bit-identical trajectories;
//! * finite outputs for random latents and eps at every step;
//! * `init_noise_sigma()` is strictly positive and finite;
//! * step-count consistency — `timesteps()` has exactly `num_steps`
//!   strictly-descending entries and `step()` accepts all of them.

use selective_guidance::rng::Rng;
use selective_guidance::scheduler::{NoiseSchedule, SchedulerKind};
use selective_guidance::testutil::prop::forall;

const ALL_KINDS: [SchedulerKind; 7] = [
    SchedulerKind::Ddim,
    SchedulerKind::Ddpm,
    SchedulerKind::Pndm,
    SchedulerKind::Euler,
    SchedulerKind::EulerAncestral,
    SchedulerKind::DpmSolverPP,
    SchedulerKind::Heun,
];

/// Run one full random trajectory and return the per-step latents.
fn trajectory(kind: SchedulerKind, n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut sched = kind.build(NoiseSchedule::default(), n);
    // the eps stream and the scheduler's own noise draws both come from
    // seeded rngs, so the whole trajectory is a function of (kind, n, seed)
    let mut eps_rng = Rng::for_stream(seed, 1);
    let mut step_rng = Rng::for_stream(seed, 2);
    let mut x: Vec<f32> = Rng::for_stream(seed, 0).normal_vec(dim);
    let sigma = sched.init_noise_sigma();
    for v in x.iter_mut() {
        *v *= sigma;
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let eps = eps_rng.normal_vec(dim);
        x = sched.step(i, &x, &eps, &mut step_rng);
        out.push(x.clone());
    }
    out
}

#[test]
fn same_seed_determinism() {
    for kind in ALL_KINDS {
        forall(&format!("{kind:?} same-seed determinism"), 200, |g| {
            let n = g.usize_in(1, 40);
            let dim = g.usize_in(1, 32);
            let seed = g.u64();
            let a = trajectory(kind, n, dim, seed);
            let b = trajectory(kind, n, dim, seed);
            assert_eq!(a, b, "{kind:?}: same seed must be bit-identical");
        });
    }
}

#[test]
fn finite_outputs_for_random_inputs() {
    for kind in ALL_KINDS {
        forall(&format!("{kind:?} finite outputs"), 200, |g| {
            let n = g.usize_in(1, 30);
            let dim = g.usize_in(1, 24);
            for (i, x) in trajectory(kind, n, dim, g.u64()).iter().enumerate() {
                assert_eq!(x.len(), dim);
                assert!(
                    x.iter().all(|v| v.is_finite()),
                    "{kind:?}: non-finite latent at step {i}"
                );
            }
        });
    }
}

#[test]
fn init_noise_sigma_positive() {
    for kind in ALL_KINDS {
        forall(&format!("{kind:?} init sigma"), 200, |g| {
            let n = g.usize_in(1, 200);
            let sched = kind.build(NoiseSchedule::default(), n);
            let sigma = sched.init_noise_sigma();
            assert!(
                sigma > 0.0 && sigma.is_finite(),
                "{kind:?}: init_noise_sigma {sigma} must be finite and > 0"
            );
        });
    }
}

#[test]
fn step_count_consistency() {
    for kind in ALL_KINDS {
        forall(&format!("{kind:?} step counts"), 200, |g| {
            let n = g.usize_in(1, 120);
            let sched = kind.build(NoiseSchedule::default(), n);
            let ts = sched.timesteps();
            assert_eq!(ts.len(), n, "{kind:?}: timesteps() length != num_steps");
            assert!(
                ts.windows(2).all(|w| w[0] > w[1]),
                "{kind:?}: timesteps must be strictly descending"
            );
            assert!(*ts.last().unwrap() < 1000 && ts[0] < 1000);
            // model_timestep is defined (and finite) for every index
            for i in 0..n {
                assert!(sched.model_timestep(i).is_finite());
            }
        });
    }
}

#[test]
fn scale_model_input_preserves_shape_and_finiteness() {
    for kind in ALL_KINDS {
        forall(&format!("{kind:?} scale_model_input"), 200, |g| {
            let n = g.usize_in(1, 40);
            let dim = g.usize_in(1, 16);
            let sched = kind.build(NoiseSchedule::default(), n);
            let x = g.normal_vec(dim);
            let i = g.usize_in(0, n - 1);
            let scaled = sched.scale_model_input(&x, i);
            assert_eq!(scaled.len(), dim);
            assert!(scaled.iter().all(|v| v.is_finite()), "{kind:?} step {i}");
        });
    }
}
