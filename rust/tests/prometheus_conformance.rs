//! Prometheus text-exposition conformance (DESIGN.md §12): the
//! telemetry registry's render must be parseable by a strict reader of
//! the 0.0.4 text format. The suite implements that reader from scratch
//! and checks, against a *served* stack (engine + coordinator + QoS all
//! reporting into one registry):
//!
//! * every family carries exactly one `# HELP` and one `# TYPE` line,
//!   both preceding the family's samples, with a known metric kind;
//! * histogram buckets are cumulative over increasing `le` bounds and
//!   end at `le="+Inf"` equal to the family's `_count`, with `_sum`
//!   present per series;
//! * label values round-trip through `\\` / `\"` / `\n` escaping;
//! * counters are monotone across consecutive scrapes;
//! * the wire `{"op":"metrics"}` response and the plain-HTTP scrape
//!   endpoint serve the same conformant text with the right
//!   content-type (and the endpoint refuses non-GET methods).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::{BatchMode, Coordinator, CoordinatorConfig};
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::json::Value;
use selective_guidance::qos::{DeadlineQos, QosConfig, QosPolicy};
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::server::{Client, MetricsScrape, Server};
use selective_guidance::telemetry::{CoordSink, Telemetry, PROMETHEUS_CONTENT_TYPE};

// ---------------------------------------------------------------------------
// a strict 0.0.4 text-format reader
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct Sample {
    name: String,
    labels: Vec<(String, String)>,
    value: f64,
}

#[derive(Debug, Default)]
struct Exposition {
    help: BTreeMap<String, String>,
    kind: BTreeMap<String, String>,
    samples: Vec<Sample>,
}

/// Resolve a sample name to its declaring family: an exact match for
/// counters/gauges, or a `_bucket`/`_sum`/`_count` suffix of a declared
/// histogram. A bare histogram name with no suffix is NOT a valid sample.
fn family_of(sample: &str, kinds: &BTreeMap<String, String>) -> Option<String> {
    if let Some(kind) = kinds.get(sample) {
        if kind != "histogram" {
            return Some(sample.to_string());
        }
        return None;
    }
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = sample.strip_suffix(suffix) {
            if kinds.get(base).map(String::as_str) == Some("histogram") {
                return Some(base.to_string());
            }
        }
    }
    None
}

fn parse_value(v: &str, lineno: usize) -> f64 {
    v.parse::<f64>().unwrap_or_else(|_| panic!("line {lineno}: unparseable value {v:?}"))
}

fn parse_sample(line: &str, lineno: usize) -> Sample {
    let brace = match line.find('{') {
        None => {
            let (name, value) = line
                .rsplit_once(' ')
                .unwrap_or_else(|| panic!("line {lineno}: sample without value: {line:?}"));
            return Sample {
                name: name.to_string(),
                labels: Vec::new(),
                value: parse_value(value, lineno),
            };
        }
        Some(i) => i,
    };
    let name = line[..brace].to_string();
    let chars: Vec<char> = line[brace..].chars().collect();
    let mut labels = Vec::new();
    let mut i = 1; // past '{'
    loop {
        if chars[i] == '}' {
            i += 1;
            break;
        }
        let mut key = String::new();
        while chars[i] != '=' {
            key.push(chars[i]);
            i += 1;
        }
        i += 1; // '='
        assert_eq!(chars[i], '"', "line {lineno}: label value must be quoted");
        i += 1;
        let mut val = String::new();
        loop {
            match chars[i] {
                '"' => {
                    i += 1;
                    break;
                }
                '\\' => {
                    i += 1;
                    match chars[i] {
                        'n' => val.push('\n'),
                        '\\' => val.push('\\'),
                        '"' => val.push('"'),
                        bad => panic!("line {lineno}: invalid escape \\{bad}"),
                    }
                    i += 1;
                }
                c => {
                    val.push(c);
                    i += 1;
                }
            }
        }
        labels.push((key, val));
        if chars[i] == ',' {
            i += 1;
        }
    }
    assert_eq!(chars[i], ' ', "line {lineno}: expected a space before the value");
    let value: String = chars[i + 1..].iter().collect();
    Sample { name, labels, value: parse_value(&value, lineno) }
}

/// Parse and structurally validate one exposition. Panics (failing the
/// test) on any conformance violation.
fn parse(text: &str) -> Exposition {
    let mut exp = Exposition::default();
    for (n, line) in text.lines().enumerate() {
        let lineno = n + 1;
        assert!(!line.is_empty(), "line {lineno}: blank line in exposition");
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) = rest
                .split_once(' ')
                .unwrap_or_else(|| panic!("line {lineno}: HELP without text: {line:?}"));
            let dup = exp.help.insert(name.to_string(), help.to_string());
            assert!(dup.is_none(), "line {lineno}: duplicate # HELP for {name}");
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = rest
                .split_once(' ')
                .unwrap_or_else(|| panic!("line {lineno}: TYPE without kind: {line:?}"));
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "line {lineno}: unknown metric kind {kind:?}"
            );
            assert!(
                exp.help.contains_key(name),
                "line {lineno}: # TYPE {name} must follow its # HELP line"
            );
            let dup = exp.kind.insert(name.to_string(), kind.to_string());
            assert!(dup.is_none(), "line {lineno}: duplicate # TYPE for {name}");
        } else if line.starts_with('#') {
            panic!("line {lineno}: unexpected comment {line:?}");
        } else {
            let sample = parse_sample(line, lineno);
            assert!(
                family_of(&sample.name, &exp.kind).is_some(),
                "line {lineno}: sample {} precedes its # TYPE declaration",
                sample.name
            );
            exp.samples.push(sample);
        }
    }
    check_histograms(&exp);
    exp
}

fn find_sample(exp: &Exposition, name: &str, labels: &[(String, String)]) -> f64 {
    let mut want = labels.to_vec();
    want.sort();
    exp.samples
        .iter()
        .find(|s| {
            let mut have = s.labels.clone();
            have.sort();
            s.name == name && have == want
        })
        .unwrap_or_else(|| panic!("missing sample {name}{labels:?}"))
        .value
}

fn check_histograms(exp: &Exposition) {
    for (fam, kind) in &exp.kind {
        if kind != "histogram" {
            continue;
        }
        let bucket_name = format!("{fam}_bucket");
        let mut groups: BTreeMap<Vec<(String, String)>, Vec<(f64, f64)>> = BTreeMap::new();
        for s in exp.samples.iter().filter(|s| s.name == bucket_name) {
            let mut base: Vec<(String, String)> =
                s.labels.iter().filter(|(k, _)| k != "le").cloned().collect();
            base.sort();
            let le = s
                .labels
                .iter()
                .find(|(k, _)| k == "le")
                .map(|(_, v)| v.as_str())
                .unwrap_or_else(|| panic!("{fam}: bucket sample without an le label"));
            let le = if le == "+Inf" {
                f64::INFINITY
            } else {
                le.parse().unwrap_or_else(|_| panic!("{fam}: unparseable le bound {le:?}"))
            };
            groups.entry(base).or_default().push((le, s.value));
        }
        assert!(!groups.is_empty(), "{fam}: histogram family with no bucket samples");
        for (base, buckets) in groups {
            for w in buckets.windows(2) {
                assert!(w[0].0 < w[1].0, "{fam}{base:?}: le bounds not increasing");
                assert!(w[0].1 <= w[1].1, "{fam}{base:?}: bucket counts not cumulative");
            }
            let &(last_le, inf_count) = buckets.last().unwrap();
            assert!(last_le.is_infinite(), "{fam}{base:?}: buckets must end at le=\"+Inf\"");
            let count = find_sample(exp, &format!("{fam}_count"), &base);
            assert_eq!(inf_count, count, "{fam}{base:?}: +Inf bucket must equal _count");
            // _sum must exist for the same series (value itself is free)
            find_sample(exp, &format!("{fam}_sum"), &base);
        }
    }
}

/// Every counter series, keyed by (name, sorted labels).
fn counters(exp: &Exposition) -> BTreeMap<(String, Vec<(String, String)>), f64> {
    let mut out = BTreeMap::new();
    for s in &exp.samples {
        if exp.kind.get(&s.name).map(String::as_str) == Some("counter") {
            let mut labels = s.labels.clone();
            labels.sort();
            out.insert((s.name.clone(), labels), s.value);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// the stack under observation
// ---------------------------------------------------------------------------

fn telemetry_coordinator(mode: BatchMode) -> (Arc<Telemetry>, Arc<Coordinator>) {
    let telemetry = Telemetry::on();
    let engine = Arc::new(Engine::new(
        Arc::new(ModelStack::synthetic()),
        EngineConfig::default(),
    ));
    let qos = DeadlineQos::new(QosConfig { enabled: true, ..QosConfig::default() })
        .expect("valid qos config");
    let coordinator = Coordinator::start_full(
        engine,
        CoordinatorConfig { mode, slot_budget: 4, workers: 1, ..CoordinatorConfig::default() },
        Some(Arc::new(qos) as Arc<dyn QosPolicy>),
        Some(CoordSink::new(&telemetry, "single", true)),
    );
    (telemetry, coordinator)
}

fn run_work(coordinator: &Arc<Coordinator>, n: u64) {
    let tickets: Vec<_> = (0..n)
        .map(|seed| {
            let req = GenerationRequest::new("conformance probe")
                .steps(6)
                .scheduler(SchedulerKind::Ddim)
                .seed(seed)
                .decode(false);
            coordinator.submit(req).expect("submit")
        })
        .collect();
    for t in tickets {
        t.wait().expect("wait");
    }
}

#[test]
fn exposition_is_conformant_and_counters_monotone() {
    let (telemetry, coordinator) = telemetry_coordinator(BatchMode::Continuous);
    // a hostile label value: the render must escape it, the reader must
    // recover it verbatim
    let hostile = "a\\b \"quoted\"\nnewline";
    telemetry
        .registry()
        .counter("sg_test_escape_total", "escaping probe", &[("note", hostile)])
        .inc();
    run_work(&coordinator, 3);

    let text1 = telemetry.render_prometheus();
    let exp1 = parse(&text1);
    // the whole stack reports into one registry
    for family in
        ["sg_engine_unet_evals_total", "sg_coord_retired_total", "sg_qos_admitted_total"]
    {
        assert_eq!(exp1.kind.get(family).map(String::as_str), Some("counter"), "{family}");
    }
    assert_eq!(exp1.kind.get("sg_request_latency_ms").map(String::as_str), Some("histogram"));
    assert_eq!(
        find_sample(&exp1, "sg_coord_retired_total", &[("scope".into(), "single".into())]),
        3.0
    );
    // escaping round-trip: raw escapes on the wire, original through the reader
    assert!(
        text1.contains(r#"note="a\\b \"quoted\"\nnewline""#),
        "hostile label not escaped: {text1}"
    );
    assert_eq!(
        find_sample(&exp1, "sg_test_escape_total", &[("note".into(), hostile.into())]),
        1.0
    );

    run_work(&coordinator, 2);
    let exp2 = parse(&telemetry.render_prometheus());
    let (c1, c2) = (counters(&exp1), counters(&exp2));
    assert!(!c1.is_empty(), "first scrape exposed no counters");
    for (key, v1) in &c1 {
        let v2 = c2
            .get(key)
            .unwrap_or_else(|| panic!("counter series {key:?} disappeared between scrapes"));
        assert!(v2 >= v1, "counter {key:?} went backwards: {v1} -> {v2}");
    }
    assert!(
        find_sample(&exp2, "sg_coord_retired_total", &[("scope".into(), "single".into())]) >= 5.0
    );
    coordinator.shutdown();
}

#[test]
fn wire_metrics_op_and_http_scrape_agree() {
    let (telemetry, coordinator) = telemetry_coordinator(BatchMode::Fixed);
    let server = Server::start(Arc::clone(&coordinator), "127.0.0.1:0").expect("bind");
    let addr = server.addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");
    let resp = client
        .call(
            Value::obj()
                .with("op", "generate")
                .with("prompt", "over the wire")
                .with("steps", 4i64)
                .with("scheduler", "ddim")
                .with("seed", 1i64),
        )
        .expect("generate");
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp}");

    // the JSON-wrapped scrape
    let resp = client.call(Value::obj().with("op", "metrics")).expect("metrics op");
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp}");
    assert_eq!(
        resp.get("content_type").and_then(Value::as_str),
        Some(PROMETHEUS_CONTENT_TYPE)
    );
    let body = resp.get("body").and_then(Value::as_str).expect("body");
    let exp = parse(body);
    assert!(exp.kind.contains_key("sg_request_latency_ms"));
    assert!(
        find_sample(&exp, "sg_coord_retired_total", &[("scope".into(), "single".into())]) >= 1.0
    );

    // the plain-HTTP scrape serves the same registry
    let mut scrape =
        MetricsScrape::start(Arc::clone(&telemetry), "127.0.0.1:0").expect("scrape bind");
    let (head, http_body) = http_get(&scrape.addr().to_string(), "GET /metrics HTTP/1.1");
    assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
    assert!(
        head.contains(&format!("Content-Type: {PROMETHEUS_CONTENT_TYPE}")),
        "missing content type: {head}"
    );
    assert!(
        head.contains(&format!("Content-Length: {}", http_body.len())),
        "content length mismatch: {head}"
    );
    let exp = parse(&http_body);
    assert!(exp.kind.contains_key("sg_coord_retired_total"));
    // non-GET methods are refused, not served
    let (head, _) = http_get(&scrape.addr().to_string(), "POST /metrics HTTP/1.1");
    assert!(head.starts_with("HTTP/1.1 405"), "{head}");
    scrape.stop();

    // the trace op rides the same backend: recent ids, then one span
    let resp = client.call(Value::obj().with("op", "trace")).expect("trace op");
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp}");
    let recent = match resp.get("recent") {
        Some(Value::Arr(ids)) => ids.clone(),
        other => panic!("expected recent id list, got {other:?}"),
    };
    assert!(!recent.is_empty(), "served work must leave a span behind");
    let id = recent[0].as_i64().expect("trace id");
    let resp =
        client.call(Value::obj().with("op", "trace").with("trace", id)).expect("span fetch");
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp}");
    let span = resp.get("span").expect("span object");
    assert_eq!(span.get("terminated").and_then(Value::as_bool), Some(true), "{span}");
    coordinator.shutdown();
}

/// Minimal HTTP/1.1 exchange: send one request line (plus Host and
/// Connection: close), return (header block, body).
fn http_get(addr: &str, request_line: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect scrape");
    stream
        .write_all(format!("{request_line}\r\nHost: test\r\nConnection: close\r\n\r\n").as_bytes())
        .expect("send request");
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8(raw).expect("utf8 response");
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
    (head.to_string(), body.to_string())
}
