//! Measured-cost plan-model property suite (synthetic backend — always
//! runs; seeded by `PROP_MASTER_SEED` like every prop suite).
//!
//! The ISSUE-9 properties:
//!
//! (a) [`CostTable`] interpolation is bounded by its bracketing
//!     calibrated buckets and monotone in batch size when the table is;
//! (b) a sealed [`CostManifest`] serialize→load round-trips bit-exact,
//!     and any post-seal tamper — one byte in a string field, one
//!     nudged price — fails the checksum with a typed
//!     [`Error::Artifact`];
//! (c) uncovered (batch, mode) lookups price analytically and are
//!     *counted*, never silent; `fallback = reject` refuses the gap up
//!     front;
//! (d) pricing with a proportional table is a pure relabeling of unit
//!     cost: every priced plan view equals its unit counterpart × the
//!     unit price, and a continuous batcher with the equivalent
//!     millisecond budget makes bit-identical admission/retire/output
//!     decisions to the slot-budget batcher on the same stream.

use std::sync::Arc;

use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::ContinuousBatcher;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::error::Error;
use selective_guidance::guidance::{
    CostManifest, CostRow, CostTable, FallbackPolicy, GuidancePlan, GuidanceSchedule,
    GuidanceStrategy, ReuseKind, Segment, StepMode, WindowSpec,
};
use selective_guidance::json;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::testutil::prop::{forall, Gen};

fn random_strategy(g: &mut Gen) -> GuidanceStrategy {
    match g.usize_in(0, 2) {
        0 => GuidanceStrategy::CondOnly,
        1 => GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: g.usize_in(0, 5) },
        _ => GuidanceStrategy::Reuse {
            kind: ReuseKind::Extrapolate,
            refresh_every: g.usize_in(0, 5),
        },
    }
}

fn random_schedule(g: &mut Gen) -> GuidanceSchedule {
    match g.usize_in(0, 3) {
        0 => GuidanceSchedule::Window(WindowSpec::last(g.f64_in(0.0, 1.0))),
        1 => {
            let lo = g.f64_in(0.0, 1.0);
            GuidanceSchedule::Interval { lo, hi: g.f64_in(lo, 1.0) }
        }
        2 => GuidanceSchedule::Cadence { every: g.usize_in(1, 8) },
        _ => {
            let lo = g.f64_in(0.0, 1.0);
            let hi = g.f64_in(lo, 1.0);
            GuidanceSchedule::Segments(vec![if g.bool() {
                Segment::optimized(lo, hi)
            } else {
                Segment::dual(lo, hi)
            }])
        }
    }
}

/// A table whose per-mode prices strictly increase with the batch
/// bucket (how real calibrations come out), plus its bucket list.
fn random_monotone_table(g: &mut Gen) -> (CostTable, Vec<usize>) {
    let mut buckets = Vec::new();
    let mut b = g.usize_in(1, 4);
    for _ in 0..g.usize_in(2, 5) {
        buckets.push(b);
        b += g.usize_in(1, 8);
    }
    let mut t = CostTable::new(
        "synthetic",
        "prop",
        8,
        g.f64_in(0.1, 2.0),
        FallbackPolicy::Analytic,
    )
    .unwrap();
    let mut dual = g.f64_in(0.5, 2.0);
    let mut single = dual * g.f64_in(0.4, 0.9);
    for &bk in &buckets {
        t.insert(bk, StepMode::Dual, dual).unwrap();
        t.insert(bk, StepMode::Single, single).unwrap();
        dual += g.f64_in(0.01, 3.0);
        single += g.f64_in(0.01, 3.0);
    }
    (t, buckets)
}

fn random_manifest(g: &mut Gen) -> CostManifest {
    let mut rows = Vec::new();
    let mut b = g.usize_in(1, 3);
    for _ in 0..g.usize_in(1, 4) {
        rows.push(CostRow {
            batch: b,
            dual_ms: g.f64_in(0.05, 40.0),
            single_ms: g.f64_in(0.05, 40.0),
        });
        b += g.usize_in(1, 6);
    }
    CostManifest::seal(
        g.word(6),
        g.word(6),
        g.word(6),
        g.word(16),
        g.usize_in(1, 128),
        g.usize_in(1, 9),
        g.usize_in(0, 4),
        g.f64_in(0.05, 5.0),
        rows,
    )
}

#[test]
fn interpolation_bounded_by_brackets_and_monotone() {
    forall("interpolation bounds", 300, |g| {
        let (t, buckets) = random_monotone_table(g);
        for mode in [StepMode::Dual, StepMode::Single] {
            // bounded: a batch between two calibrated buckets prices
            // inside [lower bucket, upper bucket]
            for w in buckets.windows(2) {
                let (lo_b, hi_b) = (w[0], w[1]);
                let (lo_ms, hi_ms) = (t.step_ms(lo_b, mode), t.step_ms(hi_b, mode));
                let probe = g.usize_in(lo_b, hi_b);
                let v = t.step_ms(probe, mode);
                assert!(
                    v >= lo_ms - 1e-12 && v <= hi_ms + 1e-12,
                    "{mode:?} batch {probe} priced {v} outside [{lo_ms}, {hi_ms}]"
                );
            }
            // monotone in batch across the whole calibrated range
            let (first, last) = (buckets[0], *buckets.last().unwrap());
            let mut prev = t.step_ms(first, mode);
            for b in first..=last {
                let v = t.step_ms(b, mode);
                assert!(v + 1e-12 >= prev, "{mode:?} not monotone at batch {b}: {v} < {prev}");
                prev = v;
            }
        }
        assert_eq!(t.fallback_count(), 0, "in-range lookups must never fall back");
    });
}

#[test]
fn manifest_round_trips_bit_exact() {
    forall("manifest round trip", 200, |g| {
        let m = random_manifest(g);
        let text = m.to_json().to_string();
        let back = CostManifest::from_json(&json::from_str(&text).unwrap()).unwrap();
        assert_eq!(m, back);
        assert_eq!(back.to_json().to_string(), text, "canonical serialization");
        // the rebuilt table reproduces every sealed price exactly
        let t = back.table(FallbackPolicy::Analytic).unwrap();
        for r in &m.rows {
            assert_eq!(t.step_ms(r.batch, StepMode::Dual), r.dual_ms);
            assert_eq!(t.step_ms(r.batch, StepMode::Single), r.single_ms);
        }
        assert_eq!(t.fallback_count(), 0);
    });
}

#[test]
fn any_post_seal_tamper_fails_the_checksum() {
    forall("manifest tamper", 200, |g| {
        let m = random_manifest(g);
        let mut bad = m.clone();
        match g.usize_in(0, 4) {
            0 => bad.backend.push('x'), // one extra byte in a string field
            1 => bad.preset.push('y'),
            2 => bad.resolution += 1,
            3 => bad.analytic_unit_ms += 0.5,
            _ => {
                let i = g.usize_in(0, bad.rows.len() - 1);
                bad.rows[i].dual_ms += 0.25; // make a dual step look cheaper/dearer
            }
        }
        let text = bad.to_json().to_string();
        assert_ne!(text, m.to_json().to_string(), "the tamper must change the payload");
        let err = CostManifest::from_json(&json::from_str(&text).unwrap()).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err:?}");
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
    });
}

#[test]
fn uncovered_keys_fall_back_analytically_and_count() {
    forall("fallback counting", 200, |g| {
        let unit = g.f64_in(0.1, 2.0);
        let lo = g.usize_in(3, 6);
        let hi = lo + g.usize_in(1, 6);
        let mut t = CostTable::new("synthetic", "prop", 8, unit, FallbackPolicy::Analytic).unwrap();
        for &b in &[lo, hi] {
            t.insert(b, StepMode::Dual, g.f64_in(0.5, 5.0)).unwrap();
            t.insert(b, StepMode::Single, g.f64_in(0.5, 5.0)).unwrap();
        }
        // below the calibrated range: analytic price, counted, per lookup
        let below = g.usize_in(1, lo - 1);
        assert!(!t.covers(below, StepMode::Dual));
        assert_eq!(t.step_ms(below, StepMode::Dual), 2.0 * unit);
        assert_eq!(t.step_ms(below, StepMode::Single), unit);
        assert_eq!(t.fallback_count(), 2);
        // above it too
        let above = hi + g.usize_in(1, 10);
        assert!(!t.covers(above, StepMode::Single));
        assert_eq!(t.step_ms(above, StepMode::Single), unit);
        assert_eq!(t.fallback_count(), 3);
        // inside, nothing counts
        t.step_ms(lo, StepMode::Dual);
        t.step_ms(g.usize_in(lo, hi), StepMode::Single);
        assert_eq!(t.fallback_count(), 3);
        // a reject-policy table refuses the same gap before attach
        let mut r = CostTable::new("synthetic", "prop", 8, unit, FallbackPolicy::Reject).unwrap();
        r.insert(lo, StepMode::Dual, 1.0).unwrap();
        r.insert(lo, StepMode::Single, 0.5).unwrap();
        assert!(r.validate_covers(&[below]).is_err());
        assert!(r.validate_covers(&[lo]).is_ok());
    });
}

#[test]
fn proportional_pricing_relabels_every_plan_view() {
    forall("priced views relabel unit cost", 300, |g| {
        // dyadic unit prices make every f64 sum exact, so the equalities
        // below are bit-exact, not approximate
        let unit = *g.choose(&[0.25, 0.5, 1.0, 2.0]);
        let table = CostTable::proportional(unit, &[1, 2, 4]);
        let n = g.usize_in(0, 60);
        let scale = if g.bool() { g.f32_in(1.5, 12.0) } else { 1.0 };
        let plan =
            GuidancePlan::compile(&random_schedule(g), scale, random_strategy(g), n).unwrap();
        assert_eq!(plan.cost_ms(&table), plan.total_unet_evals() as f64 * unit);
        for from in [0, n / 3, n] {
            assert_eq!(
                plan.remaining_cost_ms(from, &table),
                plan.remaining_cost(from) as f64 * unit
            );
            assert_eq!(
                plan.peak_remaining_cost_ms(from, &table),
                plan.peak_remaining_cost(from) as f64 * unit
            );
        }
        let per_step: f64 = (0..n).map(|i| plan.next_cost_ms(i, &table)).sum();
        assert_eq!(per_step, plan.cost_ms(&table), "per-step prices must sum to the whole");
        assert_eq!(table.fallback_count(), 0);
    });
}

#[test]
fn ms_budget_preserves_batcher_decisions_bit_exact() {
    let engine = Arc::new(Engine::new(
        Arc::new(ModelStack::synthetic()),
        EngineConfig::default(),
    ));
    forall("ms admission == slot admission", 12, |g| {
        let budget = g.usize_in(2, 6);
        let unit = *g.choose(&[0.25, 0.5, 1.0, 2.0]);
        let table = Arc::new(CostTable::proportional(unit, &[1, 2, 4]));
        let mut slots = ContinuousBatcher::new(Arc::clone(&engine), budget).unwrap();
        let mut priced = ContinuousBatcher::new(Arc::clone(&engine), budget)
            .unwrap()
            .with_ms_budget(budget as f64 * unit, Arc::clone(&table))
            .unwrap();
        let reqs: Vec<GenerationRequest> = (0..g.usize_in(3, 8))
            .map(|i| {
                GenerationRequest::new(format!("cost probe {i} {}", g.word(6)))
                    .steps(g.usize_in(2, 6))
                    .scheduler(SchedulerKind::Ddim)
                    .seed(g.u64())
                    .with_schedule(random_schedule(g))
                    .strategy(random_strategy(g))
                    .decode(false)
            })
            .collect();

        // drive both batchers in lockstep over the identical stream: the
        // ms tier must never flip an admission the slot budget made
        let (mut next_a, mut next_b) = (0usize, 0usize);
        let mut retired_a = Vec::new();
        let mut retired_b = Vec::new();
        let mut guard = 0;
        while retired_a.len() < reqs.len() {
            while next_a < reqs.len() {
                match slots.try_admit(&reqs[next_a]).unwrap() {
                    Some(_) => next_a += 1,
                    None => break,
                }
            }
            while next_b < reqs.len() {
                match priced.try_admit(&reqs[next_b]).unwrap() {
                    Some(_) => next_b += 1,
                    None => break,
                }
            }
            assert_eq!(next_a, next_b, "admission decisions diverged");
            // the measured headroom is the slot headroom relabeled
            assert_eq!(
                priced.headroom_ms(),
                Some(priced.headroom() as f64 * unit),
                "headroom_ms must relabel headroom exactly"
            );
            let oa = slots.step().unwrap();
            let ob = priced.step().unwrap();
            assert_eq!(oa.slots_used, ob.slots_used);
            assert_eq!(oa.cohort, ob.cohort);
            retired_a.extend(oa.retired);
            retired_b.extend(ob.retired);
            guard += 1;
            assert!(guard < 500, "lockstep run failed to drain");
        }
        assert_eq!(retired_a.len(), retired_b.len());
        for ((ia, oa), (ib, ob)) in retired_a.iter().zip(&retired_b) {
            assert_eq!(ia, ib, "retire order diverged");
            assert_eq!(oa.latent, ob.latent, "ms-priced run not bit-exact");
            assert_eq!(oa.unet_evals, ob.unet_evals);
            assert_eq!(oa.plan_summary, ob.plan_summary);
        }
        assert_eq!(table.fallback_count(), 0, "proportional grid must cover every lookup");
    });
}
