//! Integration: artifact loading + PJRT execution of every executable.

mod common;

use selective_guidance::rng::Rng;
use selective_guidance::tokenizer::Tokenizer;

#[test]
fn manifest_and_stack_load() {
    let stack = require_artifacts!();
    let m = stack.model();
    assert_eq!(m.preset, "tiny");
    assert_eq!(m.latent_channels, 4);
    assert!(m.batch_sizes.contains(&1));
    assert_eq!(m.image_size, m.latent_size * 4); // two upsample stages
}

#[test]
fn text_encoder_runs_and_discriminates() {
    let stack = require_artifacts!();
    let m = stack.model();
    let tok = Tokenizer::new(m.vocab_size, m.seq_len);
    let a = stack.encode_text(&tok.encode("A person holding a cat")).unwrap();
    let b = stack.encode_text(&tok.encode("A silver dragon head")).unwrap();
    assert_eq!(a.len(), m.ctx_elems());
    assert!(a.iter().all(|v| v.is_finite()));
    let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
    assert!(diff > 1e-3, "different prompts must encode differently");
    // determinism
    let a2 = stack.encode_text(&tok.encode("A person holding a cat")).unwrap();
    assert_eq!(a, a2);
}

#[test]
fn uncond_ctx_cached_and_stable() {
    let stack = require_artifacts!();
    let u1 = stack.uncond_ctx().unwrap();
    let u2 = stack.uncond_ctx().unwrap();
    assert_eq!(u1, u2);
    assert_eq!(u1.len(), stack.model().ctx_elems());
}

#[test]
fn unet_executes_all_batch_sizes() {
    let stack = require_artifacts!();
    let m = stack.model();
    let mut rng = Rng::new(0);
    for &b in &m.batch_sizes.clone() {
        let latents = rng.normal_vec(b * m.latent_elems());
        let ts = vec![500.0f32; b];
        let ctx = rng.normal_vec(b * m.ctx_elems());
        let eps = stack.unet_eps(b, &latents, &ts, &ctx).unwrap();
        assert_eq!(eps.len(), b * m.latent_elems(), "batch {b}");
        assert!(eps.iter().all(|v| v.is_finite()), "batch {b}");
        // output must not be trivially zero
        let norm: f32 = eps.iter().map(|v| v * v).sum();
        assert!(norm > 1e-6, "batch {b}: zero eps");
    }
}

#[test]
fn unet_batch_consistency() {
    // running [a, b] as batch-2 equals running a and b separately
    let stack = require_artifacts!();
    let m = stack.model();
    if !m.batch_sizes.contains(&2) {
        return;
    }
    let mut rng = Rng::new(1);
    let la = rng.normal_vec(m.latent_elems());
    let lb = rng.normal_vec(m.latent_elems());
    let ca = rng.normal_vec(m.ctx_elems());
    let cb = rng.normal_vec(m.ctx_elems());
    let ea = stack.unet_eps(1, &la, &[300.0], &ca).unwrap();
    let eb = stack.unet_eps(1, &lb, &[700.0], &cb).unwrap();
    let mut lat2 = la.clone();
    lat2.extend_from_slice(&lb);
    let mut ctx2 = ca.clone();
    ctx2.extend_from_slice(&cb);
    let e2 = stack.unet_eps(2, &lat2, &[300.0, 700.0], &ctx2).unwrap();
    for (i, (x, y)) in e2[..m.latent_elems()].iter().zip(&ea).enumerate() {
        assert!((x - y).abs() < 1e-4, "sample 0 elem {i}: {x} vs {y}");
    }
    for (i, (x, y)) in e2[m.latent_elems()..].iter().zip(&eb).enumerate() {
        assert!((x - y).abs() < 1e-4, "sample 1 elem {i}: {x} vs {y}");
    }
}

#[test]
fn cfg_combine_matches_host_math() {
    let stack = require_artifacts!();
    let m = stack.model();
    let mut rng = Rng::new(2);
    let u = rng.normal_vec(m.latent_elems());
    let c = rng.normal_vec(m.latent_elems());
    for scale in [0.0f32, 1.0, 7.5, 9.6] {
        let dev = stack.cfg_combine(1, &u, &c, scale).unwrap();
        for i in 0..u.len() {
            let host = u[i] + scale * (c[i] - u[i]);
            assert!(
                (dev[i] - host).abs() < 1e-5,
                "scale {scale} elem {i}: {} vs {host}",
                dev[i]
            );
        }
    }
}

#[test]
fn cfg_combine_scale_one_is_conditional() {
    // the identity underpinning the paper's optimization
    let stack = require_artifacts!();
    let m = stack.model();
    let mut rng = Rng::new(3);
    let u = rng.normal_vec(m.latent_elems());
    let c = rng.normal_vec(m.latent_elems());
    let out = stack.cfg_combine(1, &u, &c, 1.0).unwrap();
    for (a, b) in out.iter().zip(&c) {
        assert!((a - b).abs() < 1e-6);
    }
}

#[test]
fn vae_decodes_to_image_range() {
    let stack = require_artifacts!();
    let m = stack.model();
    let mut rng = Rng::new(4);
    let latent = rng.normal_vec(m.latent_elems());
    let img = stack.decode(&latent).unwrap();
    assert_eq!(img.len(), m.image_elems());
    // tanh output in [-1, 1]
    assert!(img.iter().all(|v| (-1.0..=1.0).contains(v) && v.is_finite()));
    // and not constant
    let mean: f32 = img.iter().sum::<f32>() / img.len() as f32;
    assert!(img.iter().any(|v| (v - mean).abs() > 1e-4));
}

#[test]
fn unet_timestep_sensitivity() {
    // the UNet must respond to t — otherwise selective windows are
    // indistinguishable from global optimization
    let stack = require_artifacts!();
    let m = stack.model();
    let mut rng = Rng::new(5);
    let latent = rng.normal_vec(m.latent_elems());
    let ctx = rng.normal_vec(m.ctx_elems());
    let e1 = stack.unet_eps(1, &latent, &[10.0], &ctx).unwrap();
    let e2 = stack.unet_eps(1, &latent, &[900.0], &ctx).unwrap();
    let diff: f32 = e1.iter().zip(&e2).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1e-3);
}

#[test]
fn bucketize_covers_any_count() {
    let stack = require_artifacts!();
    for n in 1..=9 {
        let buckets = stack.bucketize(n);
        assert_eq!(buckets.iter().sum::<usize>(), n);
        for b in buckets {
            assert!(stack.model().batch_sizes.contains(&b));
        }
    }
}
