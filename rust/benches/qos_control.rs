//! QoS control-loop evaluation: arrival rate vs. SLO attainment with the
//! selective-guidance actuator on and off.
//!
//! Replays identical Poisson traces through the *real* [`DeadlineQos`]
//! policy (admission + window actuation + EWMA feedback) inside the
//! deterministic virtual-time serving model of [`qos::sim`] — no PJRT
//! artifacts needed, so this bench runs everywhere, including CI. The
//! engine-in-the-loop counterpart is `slo_serving` (artifacts required).
//!
//! The sweep offers λ = m × capacity for m in ~[0.6, 2.0]:
//!
//! * below capacity both modes attain the SLO and the actuator idles
//!   (full dual-pass CFG for everyone — no quality given up for free);
//! * past capacity the baseline's unbounded queue sends latency to
//!   infinity and attainment toward zero, while the control loop widens
//!   the cond-only window (raising capacity by up to u·floor/2, §3.3)
//!   and sheds the provably-late remainder early.
//!
//! Run: `cargo bench --bench qos_control` (`--fast` for a smoke run)

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::json::Value;
use selective_guidance::qos::{simulate, DeadlineQos, QosConfig, SimSpec};
use selective_guidance::workload::ArrivalProcess;

fn main() {
    let args = BenchArgs::parse();
    let n_requests = if args.fast { 400 } else { 4000 };
    let multipliers: &[f64] = if args.fast {
        &[0.6, 1.2, 1.6]
    } else {
        &[0.6, 0.9, 1.1, 1.2, 1.4, 1.6, 2.0]
    };

    let spec = SimSpec {
        base_service_ms: 100.0, // virtual full-CFG service time
        unet_share: 0.95,
        deadline_ms: 300.0, // SLO = 3x the unloaded service time
        workers: 1,
        steps: 50,
    };
    let capacity_per_s = 1e3 / spec.base_service_ms * spec.workers as f64;
    let qos_cfg = QosConfig {
        enabled: true,
        max_queue_depth: 64,
        floor_fraction: 0.5, // the paper's "last 50%" quality floor
        ramp_low: 1,
        ramp_high: 3,
        default_deadline_ms: 0.0, // the trace carries explicit deadlines
        ewma_alpha: 0.2,
        unet_share: spec.unet_share,
        // escalation split: moderate sheds keep guidance via reuse
        // (DESIGN.md §8), heavy sheds drop it
        ..QosConfig::default()
    };

    eprintln!(
        "[qos] capacity {capacity_per_s:.1} img/s at full CFG, SLO {:.0} ms, \
         {n_requests} requests per point",
        spec.deadline_ms
    );

    let mut table = Table::new(&[
        "offered",
        "SLO off",
        "SLO on",
        "shed",
        "expired",
        "mean window",
        "p90 off ms",
        "p90 on ms",
    ]);
    let mut rows = Vec::new();
    let mut overloaded_checked = false;
    // gate metrics (tools/bench_gate.rs): worst-case overload win and
    // light-load regression across the sweep — virtual-time, so exact
    let mut slo_gain_overload = f64::INFINITY;
    let mut slo_on_overload_min = f64::INFINITY;
    let mut light_regression_max = f64::NEG_INFINITY;

    for &m in multipliers {
        let rate = m * capacity_per_s;
        let arrivals = ArrivalProcess::Poisson { rate_per_s: rate }.arrivals(n_requests, 42);

        let off = simulate(&arrivals, &spec, None);
        // fresh policy per operating point: the EWMA carries state
        let policy = DeadlineQos::new(qos_cfg.clone()).expect("valid qos config");
        let on = simulate(&arrivals, &spec, Some(&policy));

        eprintln!(
            "[qos] {m:.1}x: off {:.0}% -> on {:.0}% (shed {}, expired {}, window {:.2})",
            off.slo_attainment() * 100.0,
            on.slo_attainment() * 100.0,
            on.rejected,
            on.expired,
            on.mean_fraction
        );
        table.row(&[
            format!("{m:.1}x"),
            format!("{:.1}%", off.slo_attainment() * 100.0),
            format!("{:.1}%", on.slo_attainment() * 100.0),
            format!("{}", on.rejected),
            format!("{}", on.expired),
            format!("{:.2}", on.mean_fraction),
            format!("{:.0}", off.p90_latency_ms),
            format!("{:.0}", on.p90_latency_ms),
        ]);
        rows.push(
            Value::obj()
                .with("multiplier", m)
                .with("offered_per_s", rate)
                .with("slo_off", off.slo_attainment())
                .with("slo_on", on.slo_attainment())
                .with("rejected", on.rejected as i64)
                .with("expired", on.expired as i64)
                .with("mean_fraction", on.mean_fraction)
                .with("p90_off_ms", off.p90_latency_ms)
                .with("p90_on_ms", on.p90_latency_ms),
        );

        // ---- the headline claims, enforced -----------------------------
        assert!(
            on.mean_fraction <= qos_cfg.floor_fraction + 1e-12,
            "{m:.1}x: quality floor violated ({})",
            on.mean_fraction
        );
        if m >= 1.4 {
            slo_gain_overload = slo_gain_overload.min(on.slo_attainment() - off.slo_attainment());
            slo_on_overload_min = slo_on_overload_min.min(on.slo_attainment());
        }
        if m <= 0.9 {
            light_regression_max =
                light_regression_max.max(off.slo_attainment() - on.slo_attainment());
            // light load: the control loop must not regress attainment.
            // (It may still shed a little during Poisson bursts — but
            // only requests the feasibility model proves would have been
            // late anyway, so attainment stays at the baseline's level.)
            assert!(
                on.slo_attainment() >= off.slo_attainment() - 0.02,
                "{m:.1}x: light-load SLO regressed (on {:.3} vs off {:.3})",
                on.slo_attainment(),
                off.slo_attainment()
            );
        }
        if m >= 1.4 {
            // overload: the control loop must beat the unbounded queue
            overloaded_checked = true;
            assert!(on.rejected > 0, "{m:.1}x: overload must shed explicitly");
            assert!(
                on.slo_attainment() > off.slo_attainment(),
                "{m:.1}x: actuator lost at overload (on {:.3} vs off {:.3})",
                on.slo_attainment(),
                off.slo_attainment()
            );
        }
    }
    assert!(overloaded_checked, "sweep must include an overloaded point");

    println!(
        "\nQoS control — Poisson open-loop, virtual time, capacity \
         {capacity_per_s:.0} img/s, SLO {:.0} ms, floor {:.0}%:\n",
        spec.deadline_ms,
        qos_cfg.floor_fraction * 100.0
    );
    table.print();
    println!(
        "\n(past capacity the baseline queue grows without bound; the QoS loop \
         widens the paper's cond-only window — raising capacity by ~u*f/2 — \
         and sheds the provably-late rest at admission)"
    );

    write_result_json(
        "qos_control",
        &Value::obj()
            .with("capacity_per_s", capacity_per_s)
            .with("slo_ms", spec.deadline_ms)
            .with("requests", n_requests as i64)
            .with("floor_fraction", qos_cfg.floor_fraction)
            .with("rows", Value::Arr(rows)),
    );
    // the regression-gate view, compared against
    // ci/bench_baselines/BENCH_qos.json by tools/bench_gate.rs
    write_result_json(
        "BENCH_qos",
        &Value::obj()
            .with("slo_gain_overload", slo_gain_overload)
            .with("slo_on_overload_min", slo_on_overload_min)
            .with("light_regression_max", light_regression_max),
    );
}
