//! Figure 6 (repo extension): generalized guidance schedules at **equal
//! UNet-eval budgets** — last-window vs limited-interval vs cadence.
//!
//! The plan IR (DESIGN.md §10) makes schedules first-class, so the
//! serving question becomes concrete: *given a fixed eval budget, which
//! schedule should a request run?* Three candidates, all compiled to the
//! exact same budget on the deterministic synthetic backend:
//!
//! * **last-window** — the paper's `Last(0.5)` drop-guidance window;
//! * **limited interval** — guidance only inside a centered `[lo, hi)`
//!   (Kynkäänniemi et al.), with the *reuse* strategy keeping Eq.-1
//!   guidance alive (cached uncond eps) outside the interval;
//! * **cadence** — guidance every 2nd step (Dinh et al., "Compress
//!   Guidance"), reusing the cached uncond eps in between.
//!
//! Asserted (hard, per prompt × seed):
//!
//! (a) all three plans execute the **same** number of UNet evals — the
//!     comparison is at equal budget by construction, enforced via
//!     `plan.total_unet_evals()`;
//! (b) SSIM(interval, full CFG) >= SSIM(last-window, full CFG) and
//!     SSIM(cadence, full CFG) >= SSIM(last-window, full CFG): keeping
//!     guidance alive everywhere at the same cost beats dropping it on
//!     the tail.
//!
//! A drop-guidance (cond-only) middle interval rides along as an
//! informational row: it *loses* badly — early steps are the most
//! guidance-sensitive (the paper's Figure-1 insight) — which is exactly
//! why the winning interval/cadence schedules pair with reuse.
//!
//! Run: `cargo bench --bench fig6_interval_guidance [-- --fast]`

use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::{GuidanceSchedule, GuidanceStrategy, ReuseKind, WindowSpec};
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::quality::{latent_drift, ssim};
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;

fn main() {
    let args = BenchArgs::parse();
    let steps = if args.fast { 16 } else { 24 };
    let prompts: &[&str] = if args.fast {
        &["A person holding a cat"]
    } else {
        &[
            prompts::FIG2_PROMPT,
            "A watercolor of a silver dragon head with colorful flowers growing out of the top",
            "A person holding a cat",
        ]
    };
    let seeds: &[u64] = &[11, 12];
    let hold = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 };

    // ---- the equal-budget trio --------------------------------------
    // last-window Last(0.5): k = n/2 optimized steps, n/2 dual.
    let n = steps;
    let k = n / 2;
    // interval: g guided steps centered; the leading reuse run opens
    // with one cold-cache dual anchor, so g + 1 == n - k duals.
    let g = n - k - 1;
    let lo = (n - g) / 2;
    let schedules: Vec<(&str, GuidanceSchedule, GuidanceStrategy)> = vec![
        (
            "last-window (cond-only)",
            GuidanceSchedule::Window(WindowSpec::last(0.5)),
            GuidanceStrategy::CondOnly,
        ),
        (
            "interval (hold reuse)",
            GuidanceSchedule::Interval {
                lo: lo as f64 / n as f64,
                hi: (lo + g) as f64 / n as f64,
            },
            hold,
        ),
        ("cadence /2 (hold reuse)", GuidanceSchedule::Cadence { every: 2 }, hold),
    ];
    // informational only: the same interval with guidance *dropped*
    // outside it — the paper's Figure-1 "early steps matter" result
    let drop_interval = (
        "interval (cond-only, info)",
        GuidanceSchedule::Interval {
            lo: lo as f64 / n as f64,
            hi: (lo + g) as f64 / n as f64,
        },
        GuidanceStrategy::CondOnly,
    );

    eprintln!("[fig6] synthetic backend, {steps} steps, equal-budget schedules");
    let engine = Engine::new(Arc::new(ModelStack::synthetic()), EngineConfig::default());

    let mut table = Table::new(&["prompt", "seed", "schedule", "evals", "SSIM", "drift"]);
    let mut rows_json = Vec::new();
    let mut interval_gain_min = f64::INFINITY;
    let mut cadence_gain_min = f64::INFINITY;
    let mut ssim_last_min = f64::INFINITY;
    let mut runs = 0usize;

    for (pi, prompt) in prompts.iter().enumerate() {
        for &seed in seeds {
            let request = |sched: GuidanceSchedule, strat: GuidanceStrategy| {
                GenerationRequest::new(*prompt)
                    .steps(steps)
                    .scheduler(SchedulerKind::Ddim)
                    .seed(seed)
                    .with_schedule(sched)
                    .strategy(strat)
                    .decode(true)
            };
            let base = engine
                .generate(&request(GuidanceSchedule::none(), GuidanceStrategy::CondOnly))
                .expect("baseline");
            let base_img = base.image.as_ref().unwrap();
            assert_eq!(base.unet_evals, 2 * steps, "baseline must be dual everywhere");

            let mut ssims = Vec::new();
            let mut budget = None;
            for (name, sched, strat) in
                schedules.iter().chain(std::iter::once(&drop_interval)).cloned()
            {
                let info = name.ends_with("info)");
                let req = request(sched, strat);
                let planned = req.plan().expect("plan").total_unet_evals();
                let out = engine.generate(&req).expect("optimized");
                assert_eq!(out.unet_evals, planned, "{name}: executed != planned");
                let s = ssim(base_img, out.image.as_ref().unwrap());
                let d = latent_drift(&base.latent, &out.latent);
                if !info {
                    // (a) equal budget, enforced through the plan IR
                    match budget {
                        None => budget = Some(planned),
                        Some(b) => assert_eq!(
                            planned, b,
                            "{name}: unequal budget ({planned} vs {b})"
                        ),
                    }
                    ssims.push(s);
                }
                let short: String = prompt.chars().take(20).collect();
                table.row(&[
                    short,
                    format!("{seed}"),
                    name.into(),
                    format!("{}", out.unet_evals),
                    format!("{s:.4}"),
                    format!("{d:.4}"),
                ]);
                rows_json.push(
                    Value::obj()
                        .with("prompt_index", pi as i64)
                        .with("seed", seed as i64)
                        .with("schedule", name)
                        .with("unet_evals", out.unet_evals as i64)
                        .with("ssim", s)
                        .with("latent_drift", d),
                );
            }
            let (s_last, s_interval, s_cadence) = (ssims[0], ssims[1], ssims[2]);
            // (b) guidance kept via reuse beats guidance dropped on the
            // tail, at the same eval budget
            assert!(
                s_interval >= s_last,
                "{prompt}/{seed}: interval SSIM {s_interval:.4} below last-window {s_last:.4}"
            );
            assert!(
                s_cadence >= s_last,
                "{prompt}/{seed}: cadence SSIM {s_cadence:.4} below last-window {s_last:.4}"
            );
            interval_gain_min = interval_gain_min.min(s_interval - s_last);
            cadence_gain_min = cadence_gain_min.min(s_cadence - s_last);
            ssim_last_min = ssim_last_min.min(s_last);
            runs += 1;
        }
    }

    println!(
        "\nFigure 6 — equal-budget guidance schedules, {steps} steps \
         (synthetic backend):\n"
    );
    table.print();
    println!(
        "\nall {runs} prompt×seed runs: equal UNet-eval budgets; interval/cadence \
         (guidance kept via cached uncond eps) >= last-window (guidance dropped) \
         on SSIM vs full CFG\nworst margins: interval {interval_gain_min:+.4}, \
         cadence {cadence_gain_min:+.4}"
    );

    write_result_json(
        "fig6_interval_guidance",
        &Value::obj()
            .with("steps", steps as i64)
            .with("runs", runs as i64)
            .with("interval_gain_min", interval_gain_min)
            .with("cadence_gain_min", cadence_gain_min)
            .with("ssim_last_min", ssim_last_min)
            .with("rows", Value::Arr(rows_json)),
    );
    // the regression-gate view (ci/bench_baselines/BENCH_interval.json,
    // checked by tools/bench_gate.rs): deterministic SSIM margins only
    write_result_json(
        "BENCH_interval",
        &Value::obj()
            .with("runs", runs as i64)
            .with("interval_gain_min", interval_gain_min)
            .with("cadence_gain_min", cadence_gain_min)
            .with("ssim_last_min", ssim_last_min),
    );
}
