//! Figure 4: recovering aggressive-optimization losses by GS retuning.
//!
//! Paper protocol (§3.4): the wild-turkeys prompt at a 40% optimization
//! window loses detail at GS 7.5 (the third bird disappears); raising GS
//! to 9.6 restores it.
//!
//! What "lost detail" means mechanically: the optimized iterations apply
//! an effective guidance scale of 1, so the trajectory receives *less
//! total conditioning* than the baseline. We quantify delivered
//! conditioning as the **guidance displacement**
//! `G = ||latent(s, f) − latent_unguided|| / ||latent_unguided||` —
//! distance from the same-seed unguided (s = 1) trajectory — and verify
//! the paper's mechanism: a 40% window leaves a G-deficit at GS 7.5, and
//! raising GS closes it (with an overshoot beyond the compensation
//! point). SSIM vs the baseline image is reported for context.
//!
//! Run: `cargo bench --bench fig4_gs_tuning`

use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::{retuned_scale, WindowSpec};
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::quality::{latent_drift, ssim};
use selective_guidance::runtime::ModelStack;

fn main() {
    let args = BenchArgs::parse();
    let steps = if args.fast { 20 } else { 50 };
    let grid: usize = if args.fast { 5 } else { 9 };
    eprintln!("[fig4] loading {} ...", args.artifacts);
    let stack = Arc::new(ModelStack::load(&args.artifacts).expect("artifacts"));
    let engine = Engine::new(stack, EngineConfig::default());

    let prompt = prompts::FIG4_PROMPT;
    let fraction = 0.4;
    let seed = 4;

    let gen = |gs: f32, f: f64| {
        engine
            .generate(
                &GenerationRequest::new(prompt)
                    .steps(steps)
                    .seed(seed)
                    .guidance_scale(gs)
                    .selective(WindowSpec::last(f)),
            )
            .expect("generate")
    };

    // references: unguided trajectory (conditioning = 0 displacement) and
    // the full-CFG baseline
    let unguided = gen(1.0, 0.0);
    let baseline = gen(7.5, 0.0);
    let g_base = latent_drift(&unguided.latent, &baseline.latent);
    let base_img = baseline.image.as_ref().unwrap();

    // sweep GS over [7.5, full mean-compensation]
    let hi = retuned_scale(7.5, fraction, 1.0);
    let scales: Vec<f32> =
        (0..grid).map(|i| 7.5 + (hi - 7.5) * i as f32 / (grid - 1) as f32).collect();

    let mut table = Table::new(&["GS", "guidance G", "G deficit", "SSIM vs base", "note"]);
    let mut rows = Vec::new();
    let mut best: Option<(f32, f64)> = None;
    let mut naive_deficit = 0.0;
    for &s in &scales {
        let out = gen(s, fraction);
        let g = latent_drift(&unguided.latent, &out.latent);
        let deficit = g - g_base;
        let q = ssim(base_img, out.image.as_ref().unwrap());
        if (s - 7.5).abs() < 1e-3 {
            naive_deficit = deficit;
        }
        if best.map(|(_, d)| deficit.abs() < d).unwrap_or(true) {
            best = Some((s, deficit.abs()));
        }
        let note = if (s - 7.5).abs() < 1e-3 { "naive (fig 4b)" } else { "" };
        table.row(&[
            format!("{s:.2}"),
            format!("{g:.4}"),
            format!("{deficit:+.4}"),
            format!("{q:.4}"),
            note.into(),
        ]);
        rows.push(
            Value::obj()
                .with("scale", s as f64)
                .with("guidance_displacement", g)
                .with("deficit", deficit)
                .with("ssim_vs_baseline", q),
        );
    }
    // the paper's hand-tuned point
    let paper = gen(9.6, fraction);
    let g_paper = latent_drift(&unguided.latent, &paper.latent);

    // bisection refinement: G is monotone in s, so the deficit crosses
    // zero between the last negative and first positive grid points
    let (mut best_scale, mut best_def) = best.unwrap();
    let deficit_at = |s: f32| {
        let out = gen(s, fraction);
        latent_drift(&unguided.latent, &out.latent) - g_base
    };
    let mut lo = scales[0];
    let mut hi_s = scales[scales.len() - 1];
    let mut d_lo = naive_deficit;
    if d_lo < 0.0 {
        for w in rows.windows(2) {
            let (d0, d1) = (
                w[0].get("deficit").unwrap().as_f64().unwrap(),
                w[1].get("deficit").unwrap().as_f64().unwrap(),
            );
            if d0 < 0.0 && d1 >= 0.0 {
                lo = w[0].get("scale").unwrap().as_f64().unwrap() as f32;
                hi_s = w[1].get("scale").unwrap().as_f64().unwrap() as f32;
                d_lo = d0;
                break;
            }
        }
        for _ in 0..6 {
            let mid = (lo + hi_s) / 2.0;
            let d = deficit_at(mid);
            if d.abs() < best_def {
                best_scale = mid;
                best_def = d.abs();
            }
            if (d < 0.0) == (d_lo < 0.0) {
                lo = mid;
                d_lo = d;
            } else {
                hi_s = mid;
            }
        }
    }
    println!(
        "\nFigure 4 — GS retuning at a 40% window, {steps} steps \
         (baseline guidance G = {g_base:.4}):\n"
    );
    table.print();
    println!(
        "\nmechanism check: naive GS 7.5 leaves a guidance deficit of {naive_deficit:+.4}; \
         retuned GS {best_scale:.2} closes it to ±{best_def:.4}"
    );
    println!(
        "paper's hand-tuned 9.6 delivers G = {g_paper:.4} ({:+.4} vs baseline) — \
         on a trained SD model the compensation point sits there; on our \
         random-weight substrate the optimized window contributes less, so \
         the crossing lands nearer the base scale (DESIGN.md section 3).",
        g_paper - g_base
    );
    let mechanism_holds = naive_deficit < 0.0 && best_def < naive_deficit.abs();
    println!("shape check: deficit-then-recovery {}", if mechanism_holds { "PASS" } else { "DIVERGES" });

    write_result_json(
        "fig4_gs_tuning",
        &Value::obj()
            .with("steps", steps)
            .with("fraction", fraction)
            .with("g_baseline", g_base)
            .with("naive_deficit", naive_deficit)
            .with("best_scale", best_scale as f64)
            .with("best_abs_deficit", best_def)
            .with("paper_scale_g", g_paper)
            .with("mechanism_holds", mechanism_holds)
            .with("rows", Value::Arr(rows)),
    );
}
