//! Figure 2: quality vs *degree* of optimization.
//!
//! Paper protocol (§3.1): per prompt, five images — baseline plus the
//! last {20, 30, 40, 50}% of iterations optimized. Finding: quality
//! degrades gradually left → right; 20% is visually indistinguishable,
//! 50% is still "visually pleasing".
//!
//! We run the sweep over the paper's figure prompts and report
//! SSIM/PSNR/drift vs baseline per (prompt, fraction), checking that
//! degradation is monotone in the fraction.
//! Run: `cargo bench --bench fig2_degradation`

use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::WindowSpec;
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::quality::{latent_drift, ssim};
use selective_guidance::runtime::ModelStack;

fn main() {
    let args = BenchArgs::parse();
    let steps = if args.fast { 20 } else { 50 };
    eprintln!("[fig2] loading {} ...", args.artifacts);
    let stack = Arc::new(ModelStack::load(&args.artifacts).expect("artifacts"));
    let engine = Engine::new(stack, EngineConfig::default());

    // the figure's prompts (2 shown in the paper's figure + 2 more from
    // Table 2 for coverage)
    let test_prompts: &[&str] = if args.fast {
        &[prompts::FIG2_PROMPT]
    } else {
        &[
            prompts::FIG2_PROMPT,
            "A watercolor of a silver dragon head with colorful flowers growing out of the top",
            "A person holding a cat",
            "3d rendering of 5 tennis balls on top of a cake",
        ]
    };
    let fractions = [0.2, 0.3, 0.4, 0.5];
    let seed = 2;

    let mut table = Table::new(&["prompt", "opt", "SSIM", "latent drift"]);
    let mut rows_json = Vec::new();
    let mut monotone_ok = 0usize;
    let mut monotone_total = 0usize;

    std::fs::create_dir_all("out/fig2").ok();
    for (pi, prompt) in test_prompts.iter().enumerate() {
        let base = engine
            .generate(&GenerationRequest::new(*prompt).steps(steps).seed(seed))
            .expect("baseline");
        let base_img = base.image.as_ref().unwrap();
        base_img
            .save_png(std::path::Path::new(&format!("out/fig2/p{pi}_a_baseline.png")))
            .ok();
        let mut drifts = Vec::new();
        for (fi, &f) in fractions.iter().enumerate() {
            let out = engine
                .generate(
                    &GenerationRequest::new(*prompt)
                        .steps(steps)
                        .seed(seed)
                        .selective(WindowSpec::last(f)),
                )
                .expect("optimized");
            let s = ssim(base_img, out.image.as_ref().unwrap());
            let d = latent_drift(&base.latent, &out.latent);
            out.image
                .as_ref()
                .unwrap()
                .save_png(std::path::Path::new(&format!(
                    "out/fig2/p{pi}_{}_last{}.png",
                    (b'b' + fi as u8) as char,
                    (f * 100.0) as u32
                )))
                .ok();
            let short: String = prompt.chars().take(28).collect();
            table.row(&[short, format!("last {:.0}%", f * 100.0), format!("{s:.4}"), format!("{d:.4}")]);
            rows_json.push(
                Value::obj()
                    .with("prompt", *prompt)
                    .with("fraction", f)
                    .with("ssim", s)
                    .with("latent_drift", d),
            );
            drifts.push(d);
        }
        // degradation should be monotone (non-decreasing drift) in f
        monotone_total += drifts.len() - 1;
        monotone_ok += drifts.windows(2).filter(|w| w[1] >= w[0] - 1e-9).count();
    }

    println!("\nFigure 2 — degradation vs optimization degree, {steps} steps:\n");
    table.print();
    println!(
        "\ndrift monotone in fraction: {monotone_ok}/{monotone_total} transitions \
         (paper: quality degrades left -> right)"
    );
    println!("images written to out/fig2/ (a=baseline, b..e = last 20..50%)");

    write_result_json(
        "fig2_degradation",
        &Value::obj()
            .with("steps", steps)
            .with("monotone_ok", monotone_ok as i64)
            .with("monotone_total", monotone_total as i64)
            .with("rows", Value::Arr(rows_json)),
    );
}
