//! Serving-level evaluation: what the paper's saving buys *under load*.
//!
//! The paper reports single-stream latency (Table 1). In a serving
//! deployment the same saving compounds through queueing: at a fixed
//! arrival rate, faster images mean shorter queues (lower p90) and a
//! higher saturation throughput. This bench replays identical Poisson
//! traces over the Table-2 corpus against the full coordinator at
//! several selective-guidance operating points and reports
//! latency percentiles, throughput and SLO attainment.
//!
//! Run: `cargo bench --bench slo_serving` (`--fast` for a smoke run)

use std::sync::Arc;
use std::time::Duration;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::{Coordinator, CoordinatorConfig};
use selective_guidance::engine::Engine;
use selective_guidance::guidance::WindowSpec;
use selective_guidance::json::Value;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::workload::{replay, ArrivalProcess, WorkloadSpec};

fn main() {
    let args = BenchArgs::parse();
    let (steps, num_requests) = if args.fast { (12, 12) } else { (50, 40) };
    eprintln!("[slo] loading {} ...", args.artifacts);
    let stack = Arc::new(ModelStack::load(&args.artifacts).expect("artifacts"));

    // calibrate the offered load to ~80% of the baseline's single-worker
    // service rate so queueing effects are visible but stable
    let engine = Engine::new(Arc::clone(&stack), EngineConfig::default());
    let probe = engine
        .generate(
            &selective_guidance::engine::GenerationRequest::new("warmup probe")
                .steps(steps)
                .decode(false)
                .scheduler(SchedulerKind::Ddim),
        )
        .expect("probe");
    let service_rate = 1e3 / probe.wall_ms; // img/s at baseline
    let offered = 0.8 * service_rate;
    let slo_ms = 3.0 * probe.wall_ms;
    eprintln!(
        "[slo] baseline service {:.1} img/s; offering {:.1} img/s; SLO {:.0} ms",
        service_rate, offered, slo_ms
    );

    let policies: &[(&str, WindowSpec)] = &[
        ("baseline", WindowSpec::none()),
        ("last 20%", WindowSpec::last(0.2)),
        ("last 30%", WindowSpec::last(0.3)),
        ("last 50%", WindowSpec::last(0.5)),
    ];

    let mut table = Table::new(&[
        "policy", "p50 ms", "p90 ms", "max ms", "img/s", "SLO att.",
    ]);
    let mut rows = Vec::new();
    for &(name, window) in policies {
        let coordinator = Coordinator::start(
            Arc::new(Engine::new(Arc::clone(&stack), EngineConfig::default())),
            CoordinatorConfig {
                max_batch: 4,
                workers: 1,
                batch_wait: Duration::from_millis(2),
                ..CoordinatorConfig::default()
            },
        );
        let spec = WorkloadSpec {
            arrivals: ArrivalProcess::Poisson { rate_per_s: offered },
            num_requests,
            steps,
            scheduler: SchedulerKind::Ddim,
            schedule: selective_guidance::guidance::GuidanceSchedule::Window(window),
            decode: false,
            ..WorkloadSpec::default()
        };
        let trace = spec.synthesize();
        let report = replay(&coordinator, &trace).expect("replay");
        coordinator.shutdown();
        let stats = report.latency_stats();
        let slo = report.slo_attainment(slo_ms);
        eprintln!(
            "[slo] {name}: p90 {:.0} ms, {:.2} img/s, SLO {:.0}%",
            stats.p90,
            report.throughput,
            slo * 100.0
        );
        table.row(&[
            name.into(),
            format!("{:.0}", stats.p50),
            format!("{:.0}", stats.p90),
            format!("{:.0}", stats.max),
            format!("{:.2}", report.throughput),
            format!("{:.0}%", slo * 100.0),
        ]);
        rows.push(
            Value::obj()
                .with("policy", name)
                .with("p50_ms", stats.p50)
                .with("p90_ms", stats.p90)
                .with("max_ms", stats.max)
                .with("throughput", report.throughput)
                .with("slo_attainment", slo)
                .with("failures", report.failures as i64),
        );
        assert_eq!(report.failures, 0, "{name}: requests failed");
    }

    println!(
        "\nSLO serving — Poisson open-loop at {offered:.1} img/s offered, \
         {num_requests} requests x {steps} steps, SLO = {slo_ms:.0} ms:\n"
    );
    table.print();
    println!(
        "\n(the paper's per-image saving compounds under load: shorter service \
         times drain the queue faster, improving tail latency and SLO attainment)"
    );

    write_result_json(
        "slo_serving",
        &Value::obj()
            .with("offered_rate", offered)
            .with("slo_ms", slo_ms)
            .with("steps", steps)
            .with("requests", num_requests)
            .with("rows", Value::Arr(rows)),
    );
}
