//! Measured-cost routing evaluation — the DESIGN.md §15 headline claim,
//! enforced in deterministic **virtual time**.
//!
//! The fleet under test is heterogeneous in a way slot budgets cannot
//! see: every replica packs the same 4 UNet slots per iteration, but the
//! replicas run at different *measured speeds* (a fast pair, a half-speed
//! replica, a quarter-speed one — one tick = one virtual millisecond, a
//! replica advances one cohort iteration every `period` ticks). On top of
//! that the per-step costs are skewed: a single (cond-only) step measures
//! 80% of a dual step, not the analytic 50%, so the unit model also
//! over-discounts optimized-window requests.
//!
//! Unit-slot routing weighs every replica by its slot budget — identical
//! here — and prices jobs in analytic evals, so it hands the slow
//! replicas the same share as the fast ones and their queues pay for it.
//! Ms-priced routing derives each replica's weight from its own
//! [`CostTable`] (slots × 2 / measured dual ms, exactly the live
//! cluster's `route_weight`) and prices jobs in measured microseconds
//! against the fleet-reference table, keeping every replica's
//! *normalized* load honest. The asserted claim: ms-priced p95 latency
//! ≤ unit-slot p95 on the identical arrival stream, with zero analytic
//! fallbacks on the calibrated grid. The regression gate
//! (`tools/bench_gate.rs`) holds both to committed bands in
//! `ci/bench_baselines/BENCH_cost.json`.
//!
//! Run: `cargo bench --bench cost_routing` (`--fast` for CI smoke)

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::cluster::{RoutePolicy, Router};
use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::ContinuousBatcher;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::{
    CostTable, FallbackPolicy, GuidanceSchedule, StepMode, WindowSpec,
};
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;

const STEPS: usize = 10;
const SLOT_BUDGET: usize = 4;
/// Ticks per cohort iteration, per replica: two full-speed replicas, a
/// half-speed one, a quarter-speed one. Aggregate 11 slots/tick.
const PERIODS: [u64; 4] = [1, 1, 2, 4];
/// Fast-replica measured batch-1 prices (ms): the skew is the point —
/// a single step costs 0.8 of a dual, not the analytic 0.5.
const DUAL_MS: f64 = 2.0;
const SINGLE_MS: f64 = 1.6;

/// Request `i` of the mixed-schedule stream: per-request analytic costs
/// span 2× (full CFG = 20 evals at 10 steps, full window = 10), but the
/// *measured* spread is only ~1.25× under the skewed single price.
fn mixed_request(i: usize) -> GenerationRequest {
    let base = GenerationRequest::new(prompts::TABLE2[i % prompts::TABLE2.len()])
        .steps(STEPS)
        .scheduler(SchedulerKind::Ddim)
        .seed(i as u64)
        .decode(false);
    match i % 4 {
        0 => base,                                                       // full CFG
        1 => base.selective(WindowSpec::last(0.5)),                      // paper's headline
        2 => base.selective(WindowSpec::last(1.0)),                      // all cond-only
        _ => base.with_schedule(GuidanceSchedule::Cadence { every: 2 }), // compressed
    }
}

/// Replica `r`'s calibrated table: every price scales with its period
/// (a quarter-speed replica measures 4× the fast replica's step times).
fn replica_table(period: u64) -> CostTable {
    let mut t = CostTable::new(
        "synthetic",
        "bench",
        8,
        SINGLE_MS * period as f64,
        FallbackPolicy::Analytic,
    )
    .expect("table");
    t.insert(1, StepMode::Dual, DUAL_MS * period as f64).expect("dual");
    t.insert(1, StepMode::Single, SINGLE_MS * period as f64).expect("single");
    t
}

struct SimReplica {
    cb: ContinuousBatcher,
    period: u64,
    queue: VecDeque<usize>,
    /// Routed-and-uncompleted job cost (evals or µs) — the router's
    /// load signal, exactly as the live ReplicaSet tracks it.
    outstanding: u64,
    /// cohort id -> request index
    inflight: BTreeMap<u64, usize>,
}

/// Drive the heterogeneous-speed fleet in virtual time (one tick = one
/// virtual ms) over a fixed arrival stream until every request retires.
/// `weights[r]` is replica `r`'s routing weight, `costs[i]` request `i`'s
/// job price — the two knobs that distinguish unit-slot from ms-priced
/// routing; everything else is identical.
fn simulate(
    engine: &Arc<Engine>,
    weights: &[f64],
    costs: &[u64],
    reqs: &[GenerationRequest],
    arrivals: &[u64],
) -> Vec<u64> {
    let mut router = Router::new(RoutePolicy::PlanCost, weights.to_vec(), 0).expect("router");
    let mut replicas: Vec<SimReplica> = PERIODS
        .iter()
        .map(|&period| SimReplica {
            cb: ContinuousBatcher::new(Arc::clone(engine), SLOT_BUDGET).expect("batcher"),
            period,
            queue: VecDeque::new(),
            outstanding: 0,
            inflight: BTreeMap::new(),
        })
        .collect();
    let mut next_arrival = 0usize;
    let mut done = 0usize;
    let mut latencies = Vec::with_capacity(reqs.len());
    let mut t: u64 = 0;
    while done < reqs.len() {
        while next_arrival < reqs.len() && arrivals[next_arrival] <= t {
            let loads: Vec<Option<u64>> = replicas.iter().map(|r| Some(r.outstanding)).collect();
            let target = router.place(&loads).expect("some replica is healthy");
            replicas[target].outstanding += costs[next_arrival];
            replicas[target].queue.push_back(next_arrival);
            next_arrival += 1;
        }
        for r in replicas.iter_mut() {
            // a slower replica only reaches an iteration boundary every
            // `period` ticks — that is the speed the slot budget hides
            if t % r.period != 0 {
                continue;
            }
            while let Some(&idx) = r.queue.front() {
                match r.cb.try_admit(&reqs[idx]).expect("admit") {
                    Some(id) => {
                        r.inflight.insert(id, idx);
                        r.queue.pop_front();
                    }
                    None => break,
                }
            }
            if r.cb.in_flight() == 0 {
                continue;
            }
            let outcome = r.cb.step().expect("step");
            assert!(outcome.slots_used <= r.cb.slot_budget(), "slot budget violated");
            for (id, _out) in outcome.retired {
                let idx = r.inflight.remove(&id).expect("retired id");
                r.outstanding -= costs[idx];
                latencies.push(t + 1 - arrivals[idx]);
                done += 1;
            }
        }
        t += 1;
        assert!(t < 1_000_000, "virtual-time run failed to finish");
    }
    latencies
}

fn quantile(sorted: &[u64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] as f64 * (1.0 - frac) + sorted[hi] as f64 * frac
}

fn main() {
    let args = BenchArgs::parse();
    let engine = Arc::new(Engine::new(
        Arc::new(ModelStack::synthetic()),
        EngineConfig::default(),
    ));

    let tables: Vec<CostTable> = PERIODS.iter().map(|&p| replica_table(p)).collect();
    let fleet_ref = &tables[0];

    // aggregate capacity ~0.73 req/tick at the 15-eval mean mix; offer
    // ~0.55 req/tick — comfortably under aggregate, but 2.2× what the
    // slot-blind router hands the quarter-speed replica
    let n = if args.fast { 240 } else { 480 };
    let reqs: Vec<GenerationRequest> = (0..n).map(mixed_request).collect();
    let arrivals: Vec<u64> = (0..n).map(|i| (i as f64 * 1.8) as u64).collect();

    // unit-slot view: identical slot budgets -> identical weights, jobs
    // priced in analytic evals
    let unit_weights = vec![SLOT_BUDGET as f64; PERIODS.len()];
    let unit_costs: Vec<u64> = reqs
        .iter()
        .map(|r| r.plan().expect("plan").total_unet_evals() as u64)
        .collect();

    // ms-priced view: the live cluster's route_weight (slots × 2 /
    // measured dual ms) from each replica's own table, jobs priced in
    // integer microseconds against the fleet-reference table
    let ms_weights: Vec<f64> = tables
        .iter()
        .map(|t| SLOT_BUDGET as f64 * 2.0 / t.sample_step_ms(StepMode::Dual))
        .collect();
    let ms_costs: Vec<u64> = reqs
        .iter()
        .map(|r| (r.plan().expect("plan").cost_ms(fleet_ref) * 1000.0).round() as u64)
        .collect();

    let mut unit_lat = simulate(&engine, &unit_weights, &unit_costs, &reqs, &arrivals);
    let mut ms_lat = simulate(&engine, &ms_weights, &ms_costs, &reqs, &arrivals);
    unit_lat.sort_unstable();
    ms_lat.sort_unstable();
    assert_eq!(unit_lat.len(), n, "unit-slot run lost requests");
    assert_eq!(ms_lat.len(), n, "ms-priced run lost requests");

    let p50_unit = quantile(&unit_lat, 0.5);
    let p95_unit = quantile(&unit_lat, 0.95);
    let p50_ms = quantile(&ms_lat, 0.5);
    let p95_ms = quantile(&ms_lat, 0.95);
    let p95_ratio = p95_ms / p95_unit;
    let fallbacks: u64 = tables.iter().map(|t| t.fallback_count()).sum();

    let mut table = Table::new(&["routing", "weights", "p50 / p95 virtual ms"]);
    table.row(&[
        "unit-slot".into(),
        format!("{unit_weights:?}"),
        format!("{p50_unit:.1} / {p95_unit:.1}"),
    ]);
    table.row(&[
        "ms-priced".into(),
        format!("{ms_weights:?}"),
        format!("{p50_ms:.1} / {p95_ms:.1}"),
    ]);
    println!(
        "\nMeasured-cost routing — virtual time, {STEPS}-step mixed stream over a \
         speed-heterogeneous fleet (periods {PERIODS:?}, single/dual skew \
         {:.2}):\n",
        SINGLE_MS / DUAL_MS
    );
    table.print();
    println!(
        "\n(the slot budgets are identical, so unit-slot routing loads the \
         quarter-speed replica like a full-speed one; the measured tables \
         price the speed difference in: p95 {p95_ms:.0} vs {p95_unit:.0} virtual ms)"
    );

    assert!(
        p95_ratio <= 1.0,
        "ms-priced routing must not lose to unit-slot on p95: {p95_ms:.1} vs {p95_unit:.1}"
    );
    assert_eq!(fallbacks, 0, "calibrated grid must never price analytically");
    // a proportional table merely relabels cost; a skewed one genuinely
    // reorders it — sanity-check the skew is visible in the pricing
    let full_cfg = reqs[0].plan().expect("plan");
    let all_cond = reqs[2].plan().expect("plan");
    assert!(
        full_cfg.cost_ms(fleet_ref) / all_cond.cost_ms(fleet_ref)
            < full_cfg.total_unet_evals() as f64 / all_cond.total_unet_evals() as f64,
        "skewed single price must compress the measured spread"
    );

    write_result_json(
        "cost_routing",
        &Value::obj()
            .with("steps", STEPS as i64)
            .with("requests", n as i64)
            .with("slot_budget", SLOT_BUDGET as i64)
            .with("single_over_dual", SINGLE_MS / DUAL_MS)
            .with("p50_unit_slot", p50_unit)
            .with("p95_unit_slot", p95_unit)
            .with("p50_ms_priced", p50_ms)
            .with("p95_ms_priced", p95_ms)
            .with("p95_ratio", p95_ratio)
            .with("fallbacks", fallbacks as i64),
    );
    // the regression-gate view, compared against
    // ci/bench_baselines/BENCH_cost.json by tools/bench_gate.rs
    write_result_json(
        "BENCH_cost",
        &Value::obj().with("p95_ratio", p95_ratio).with("fallbacks", fallbacks as i64),
    );
}
