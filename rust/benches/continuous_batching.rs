//! Continuous (iteration-level) batching evaluation — the DESIGN.md §9
//! headline claims, enforced.
//!
//! Runs entirely on the deterministic synthetic backend
//! (`ModelStack::synthetic`), so it runs everywhere including CI, and in
//! **virtual time**: one cohort iteration == one tick, the cost model of
//! a device that executes up to `slot_budget` UNet slots per iteration in
//! parallel. That makes every number below exactly reproducible — the
//! regression gate (`tools/bench_gate.rs`) holds them to committed bands.
//!
//! Asserted claims:
//!
//! 1. **Bit-exactness** — a sample admitted into a continuously
//!    re-composed cohort (staggered joins, mixed step counts, mixed
//!    windows/strategies) produces the *identical* latent and eval count
//!    as its solo `Engine::generate` run.
//! 2. **Throughput at overload** — with a 0.5 cond-only window,
//!    continuous mode converts the window's freed slots into admission
//!    headroom and beats the fixed-composition batcher serving dual-only
//!    traffic by a measured margin; the fixed batcher gains *nothing*
//!    from the same window (its cohort is frozen at dispatch), which is
//!    exactly the gap the ISSUE closes.
//!
//! Run: `cargo bench --bench continuous_batching` (`--fast` for CI smoke)

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::ContinuousBatcher;
use selective_guidance::engine::{Engine, GenerationOutput, GenerationRequest};
use selective_guidance::guidance::{GuidanceStrategy, ReuseKind, WindowSpec};
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;

fn request(i: usize, steps: usize, window: f64) -> GenerationRequest {
    GenerationRequest::new(prompts::TABLE2[i % prompts::TABLE2.len()])
        .steps(steps)
        .scheduler(SchedulerKind::Ddim)
        .selective(WindowSpec::last(window))
        .seed(i as u64)
        .decode(false)
}

/// Claim 1: cohort composition cannot affect a sample's output.
fn check_bitexact(engine: &Arc<Engine>, fast: bool) -> usize {
    let base_steps = if fast { 8 } else { 16 };
    let budget = 6usize;
    let mut reqs: Vec<GenerationRequest> = (0..10)
        .map(|i| {
            let w = [0.0, 0.5, 1.0, 0.3, 0.7][i % 5];
            // mixed step counts: only a continuous cohort can serve these
            // together at all
            request(i, base_steps + (i % 3) * 4, w)
        })
        .collect();
    // one reuse-strategy sample rides along to cover the cache path
    reqs[7] = reqs[7]
        .clone()
        .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 2 });

    let solo: Vec<GenerationOutput> =
        reqs.iter().map(|r| engine.generate(r).expect("solo run")).collect();

    let mut cb = ContinuousBatcher::new(Arc::clone(engine), budget).expect("batcher");
    let mut queue: VecDeque<usize> = (0..reqs.len()).collect();
    let mut id2idx: BTreeMap<u64, usize> = BTreeMap::new();
    let mut outs: Vec<Option<GenerationOutput>> = vec![None; reqs.len()];
    let mut guard = 0usize;
    while outs.iter().any(|o| o.is_none()) {
        while let Some(&i) = queue.front() {
            match cb.try_admit(&reqs[i]).expect("admit") {
                Some(id) => {
                    id2idx.insert(id, i);
                    queue.pop_front();
                }
                None => break,
            }
        }
        let outcome = cb.step().expect("step");
        assert!(outcome.slots_used <= budget, "slot budget violated");
        for (id, out) in outcome.retired {
            outs[id2idx[&id]] = Some(out);
        }
        guard += 1;
        assert!(guard < 10_000, "cohort failed to drain");
    }
    for (i, out) in outs.iter().enumerate() {
        let out = out.as_ref().unwrap();
        assert_eq!(
            solo[i].latent, out.latent,
            "sample {i}: cohort composition leaked into the output"
        );
        assert_eq!(solo[i].unet_evals, out.unet_evals, "sample {i}: eval count diverged");
    }
    eprintln!("[continuous] bit-exact: {} samples match their solo runs", reqs.len());
    reqs.len()
}

/// Fixed-mode virtual time: lock-step batches sized for worst-case dual
/// cost (`budget/2` samples — any sample may need 2 slots on any step),
/// `steps` ticks per batch. Windows change nothing here: the cohort is
/// frozen, so freed slots idle.
fn fixed_ticks(
    engine: &Arc<Engine>,
    n_done: usize,
    offered: usize,
    steps: usize,
    budget: usize,
    window: f64,
) -> usize {
    let group = budget / 2;
    let reqs: Vec<GenerationRequest> =
        (0..offered).map(|i| request(i, steps, window)).collect();
    let mut ticks = 0usize;
    let mut done = 0usize;
    for chunk in reqs.chunks(group) {
        let outs = engine.generate_batch(chunk).expect("fixed batch");
        std::hint::black_box(&outs);
        ticks += steps;
        done += outs.len();
        if done >= n_done {
            break;
        }
    }
    assert!(done >= n_done, "offered too few requests");
    ticks
}

fn main() {
    let args = BenchArgs::parse();
    let engine = Arc::new(Engine::new(
        Arc::new(ModelStack::synthetic()),
        EngineConfig::default(),
    ));

    // ---- claim 1: bit-exactness -----------------------------------------
    let bitexact_samples = check_bitexact(&engine, args.fast);

    // ---- claim 2: throughput at overload --------------------------------
    let steps = if args.fast { 12 } else { 20 };
    let target = if args.fast { 24 } else { 40 };
    let offered = target * 2; // stay saturated past the measured window
    let budget = 8usize;

    let ticks_fixed_dual = fixed_ticks(&engine, target, offered, steps, budget, 0.0);
    let ticks_fixed_win = fixed_ticks(&engine, target, offered, steps, budget, 0.5);

    // continuous: admit whenever slot headroom exists; count ticks until
    // the target-th completion (steady state — the arrival stream stays
    // saturated, so no drain tail distorts the rate)
    let reqs: Vec<GenerationRequest> =
        (0..offered).map(|i| request(i, steps, 0.5)).collect();
    let mut cb = ContinuousBatcher::new(Arc::clone(&engine), budget).expect("batcher");
    let mut next = 0usize;
    let mut done = 0usize;
    let mut ticks_cont = 0usize;
    let mut slots_sum = 0usize;
    while done < target {
        while next < offered {
            match cb.try_admit(&reqs[next]).expect("admit") {
                Some(_) => next += 1,
                None => break,
            }
        }
        let outcome = cb.step().expect("step");
        assert!(outcome.slots_used <= budget, "slot budget violated");
        slots_sum += outcome.slots_used;
        ticks_cont += 1;
        done += outcome.retired.len();
        assert!(ticks_cont < 100_000, "continuous run failed to reach target");
    }

    let thr_fixed_dual = target as f64 / ticks_fixed_dual as f64;
    let thr_fixed_win = target as f64 / ticks_fixed_win as f64;
    let thr_cont = target as f64 / ticks_cont as f64;
    let slot_utilization = slots_sum as f64 / (ticks_cont as f64 * budget as f64);
    let throughput_ratio = thr_cont / thr_fixed_dual;

    let mut table = Table::new(&["mode", "window", "ticks", "img/tick", "vs fixed dual"]);
    table.row(&[
        "fixed".into(),
        "none (dual CFG)".into(),
        format!("{ticks_fixed_dual}"),
        format!("{thr_fixed_dual:.4}"),
        "1.00x".into(),
    ]);
    table.row(&[
        "fixed".into(),
        "last 50% cond-only".into(),
        format!("{ticks_fixed_win}"),
        format!("{thr_fixed_win:.4}"),
        format!("{:.2}x", thr_fixed_win / thr_fixed_dual),
    ]);
    table.row(&[
        "continuous".into(),
        "last 50% cond-only".into(),
        format!("{ticks_cont}"),
        format!("{thr_cont:.4}"),
        format!("{throughput_ratio:.2}x"),
    ]);
    println!(
        "\nContinuous batching — virtual time, slot budget {budget}, {steps} steps, \
         first {target} completions of {offered} offered:\n"
    );
    table.print();
    println!(
        "\n(the fixed batcher gains nothing from the window — its cohort is frozen \
         at dispatch; continuous admission turns the same freed slots into \
         {throughput_ratio:.2}x throughput at {:.0}% slot utilization)",
        slot_utilization * 100.0
    );

    // ---- the headline claims, enforced ----------------------------------
    assert!(
        (thr_fixed_win - thr_fixed_dual).abs() < 1e-12,
        "fixed-mode throughput must be window-invariant in the slot model \
         ({thr_fixed_win} vs {thr_fixed_dual})"
    );
    assert!(
        throughput_ratio >= 1.1,
        "continuous mode must beat fixed dual-only by a measured margin, got {throughput_ratio:.3}x"
    );
    assert!(
        slot_utilization >= 0.85,
        "continuous packing left too many slots idle: {slot_utilization:.3}"
    );

    write_result_json(
        "continuous_batching",
        &Value::obj()
            .with("steps", steps as i64)
            .with("target", target as i64)
            .with("offered", offered as i64)
            .with("slot_budget", budget as i64)
            .with("ticks_fixed_dual", ticks_fixed_dual as i64)
            .with("ticks_fixed_windowed", ticks_fixed_win as i64)
            .with("ticks_continuous", ticks_cont as i64)
            .with("throughput_fixed_dual", thr_fixed_dual)
            .with("throughput_fixed_windowed", thr_fixed_win)
            .with("throughput_continuous", thr_cont)
            .with("throughput_ratio", throughput_ratio)
            .with("slot_utilization", slot_utilization)
            .with("bitexact_samples", bitexact_samples as i64),
    );
    // the regression-gate view: only the mode-invariant headline metrics
    // (virtual-time ratios, not wall clock), compared against
    // ci/bench_baselines/BENCH_continuous.json by tools/bench_gate.rs
    write_result_json(
        "BENCH_continuous",
        &Value::obj()
            .with("throughput_ratio", throughput_ratio)
            .with("slot_utilization", slot_utilization)
            .with("bitexact_samples", bitexact_samples as i64),
    );
}
