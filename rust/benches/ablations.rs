//! Ablations beyond the paper's tables (DESIGN.md §6, A–C):
//!
//! A. Execution strategy: per-step `cond + uncond as two b=1 calls`
//!    (ours, skippable) vs the HF pipeline's fused batch-2 call
//!    (unskippable). Quantifies what the batched baseline gives up.
//! B. Scheduler independence: saving vs optimized fraction across
//!    DDIM / PNDM / Euler — the paper's claim is scheduler-agnostic.
//! C. Window-position grid: quality at First/Middle/Last x fraction,
//!    refining Figure 1's four points.
//!
//! Run: `cargo bench --bench ablations`

use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, BenchRunner, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::WindowSpec;
use selective_guidance::json::Value;
use selective_guidance::quality::latent_drift;
use selective_guidance::rng::Rng;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;

fn main() {
    let args = BenchArgs::parse();
    eprintln!("[ablations] loading {} ...", args.artifacts);
    let stack = Arc::new(ModelStack::load(&args.artifacts).expect("artifacts"));
    let engine = Engine::new(Arc::clone(&stack), EngineConfig::default());
    let mut results = Value::obj();

    // ---- A: execution strategy ------------------------------------------
    {
        let m = stack.model();
        let runner = if args.fast { BenchRunner::new(2, 5) } else { BenchRunner::new(5, 20) };
        let mut rng = Rng::new(0);
        let lat1 = rng.normal_vec(m.latent_elems());
        let ctx1 = rng.normal_vec(m.ctx_elems());
        let uncond = stack.uncond_ctx().expect("uncond ctx");

        // two b=1 calls (selective-guidance-capable)
        let two_calls = runner.run(|| {
            stack.unet_eps(1, &lat1, &[500.0], &ctx1).unwrap();
            stack.unet_eps(1, &lat1, &[500.0], &uncond).unwrap();
        });
        // one b=2 call (HF-style fused CFG, cannot skip half)
        let mut lat2 = lat1.clone();
        lat2.extend_from_slice(&lat1);
        let mut ctx2 = ctx1.clone();
        ctx2.extend_from_slice(&uncond);
        let fused = runner.run(|| {
            stack.unet_eps(2, &lat2, &[500.0, 500.0], &ctx2).unwrap();
        });
        // the optimized step: a single b=1 call
        let single = runner.run(|| {
            stack.unet_eps(1, &lat1, &[500.0], &ctx1).unwrap();
        });

        let mut t = Table::new(&["strategy", "per-step ms", "vs fused b=2"]);
        let base = fused.mean * 1e3;
        for (name, s) in [("fused b=2 (HF baseline)", &fused), ("2x b=1 (ours, dual)", &two_calls), ("1x b=1 (ours, optimized)", &single)] {
            t.row(&[
                name.into(),
                format!("{:.2}", s.mean * 1e3),
                format!("{:+.1}%", 100.0 * (s.mean * 1e3 - base) / base),
            ]);
        }
        println!("\nAblation A — per-step execution strategy:\n");
        t.print();
        println!(
            "optimized step runs at {:.0}% of the fused-CFG step cost \
             (paper: ~50% — 'cutting the Unet computation time in half')",
            100.0 * single.mean / fused.mean
        );
        results = results.with(
            "ablation_a",
            Value::obj()
                .with("fused_b2_ms", fused.mean * 1e3)
                .with("two_b1_ms", two_calls.mean * 1e3)
                .with("single_b1_ms", single.mean * 1e3),
        );
    }

    // ---- B: scheduler independence ---------------------------------------
    {
        let steps = if args.fast { 16 } else { 50 };
        let samples = if args.fast { 3 } else { 10 };
        let prompt = "A silver dragon head";
        let kinds = [SchedulerKind::Ddim, SchedulerKind::Pndm, SchedulerKind::Euler];
        let fractions = [0.0, 0.2, 0.5];
        let mut t = Table::new(&["scheduler", "opt", "mean ms", "saving"]);
        let mut rows = Vec::new();
        for kind in kinds {
            let mut base_ms = 0.0;
            for &f in &fractions {
                let mut acc = 0.0;
                for s in 0..samples {
                    let out = engine
                        .generate(
                            &GenerationRequest::new(prompt)
                                .steps(steps)
                                .seed(100 + s as u64)
                                .scheduler(kind)
                                .decode(false)
                                .selective(WindowSpec::last(f)),
                        )
                        .expect("generate");
                    acc += out.wall_ms;
                }
                let mean = acc / samples as f64;
                if f == 0.0 {
                    base_ms = mean;
                }
                let saving = 100.0 * (base_ms - mean) / base_ms;
                t.row(&[
                    kind.name().into(),
                    WindowSpec::last(f).label(),
                    format!("{mean:.0}"),
                    if f == 0.0 { "-".into() } else { format!("{saving:.1}%") },
                ]);
                rows.push(
                    Value::obj()
                        .with("scheduler", kind.name())
                        .with("fraction", f)
                        .with("mean_ms", mean)
                        .with("saving_pct", saving),
                );
            }
        }
        println!("\nAblation B — saving is scheduler-independent ({steps} steps):\n");
        t.print();
        results = results.with("ablation_b", Value::Arr(rows));
    }

    // ---- C: window-position grid ------------------------------------------
    {
        let steps = if args.fast { 16 } else { 40 };
        let prompt = "A person holding a cat";
        let seed = 3;
        let base = engine
            .generate(&GenerationRequest::new(prompt).steps(steps).seed(seed).decode(false))
            .expect("baseline");
        let fractions = [0.2, 0.4, 0.6];
        let mut t = Table::new(&["position", "fraction", "latent drift"]);
        let mut rows = Vec::new();
        for (pos_name, mk) in [
            ("first", WindowSpec::first as fn(f64) -> WindowSpec),
            ("middle", WindowSpec::middle as fn(f64) -> WindowSpec),
            ("last", WindowSpec::last as fn(f64) -> WindowSpec),
        ] {
            for &f in &fractions {
                let out = engine
                    .generate(
                        &GenerationRequest::new(prompt)
                            .steps(steps)
                            .seed(seed)
                            .decode(false)
                            .selective(mk(f)),
                    )
                    .expect("generate");
                let d = latent_drift(&base.latent, &out.latent);
                t.row(&[pos_name.into(), format!("{:.0}%", f * 100.0), format!("{d:.4}")]);
                rows.push(
                    Value::obj()
                        .with("position", pos_name)
                        .with("fraction", f)
                        .with("latent_drift", d),
                );
            }
        }
        println!("\nAblation C — window-position grid ({steps} steps, drift vs baseline):\n");
        t.print();
        println!("(expect: drift(last) < drift(middle) < drift(first) at equal fractions)");
        results = results.with("ablation_c", Value::Arr(rows));
    }

    // ---- D: adaptive controller vs static windows --------------------------
    {
        let steps = if args.fast { 16 } else { 40 };
        let prompt = "A waterfall with a tree in the middle of it";
        let seed = 6;
        let base = engine
            .generate(&GenerationRequest::new(prompt).steps(steps).seed(seed).decode(false))
            .expect("baseline");
        let mut t = Table::new(&["policy", "unet evals", "latent drift"]);
        let mut rows = Vec::new();
        let mut record = |t: &mut Table, rows: &mut Vec<Value>, label: String, out: &selective_guidance::engine::GenerationOutput| {
            let d = latent_drift(&base.latent, &out.latent);
            t.row(&[label.clone(), out.unet_evals.to_string(), format!("{d:.4}")]);
            rows.push(
                Value::obj()
                    .with("policy", label)
                    .with("unet_evals", out.unet_evals as i64)
                    .with("latent_drift", d),
            );
        };
        record(&mut t, &mut rows, "baseline".into(), &base);
        for f in [0.2, 0.4, 0.6] {
            let out = engine
                .generate(
                    &GenerationRequest::new(prompt)
                        .steps(steps)
                        .seed(seed)
                        .decode(false)
                        .selective(WindowSpec::last(f)),
                )
                .expect("static");
            record(&mut t, &mut rows, format!("static last {:.0}%", f * 100.0), &out);
        }
        for threshold in [0.02, 0.05, 0.1] {
            let out = engine
                .generate(
                    &GenerationRequest::new(prompt).steps(steps).seed(seed).decode(false).adaptive(
                        selective_guidance::guidance::AdaptiveConfig {
                            threshold,
                            patience: 2,
                            min_dual_fraction: 0.3,
                            probe_every: 8,
                        },
                    ),
                )
                .expect("adaptive");
            record(&mut t, &mut rows, format!("adaptive thr={threshold}"), &out);
        }
        println!(
            "\nAblation D — adaptive controller (paper's future work) vs static \
             windows ({steps} steps; cost = UNet evals, quality = drift):\n"
        );
        t.print();
        results = results.with("ablation_d", Value::Arr(rows));
    }

    write_result_json("ablations", &results);
}
