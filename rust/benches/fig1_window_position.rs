//! Figure 1: sensitivity to the *position* of the optimization window.
//!
//! Paper protocol (§2): same prompt ("A person holding a cat"), same
//! seed/parameters, a 25%-of-iterations window optimized at four
//! positions sliding left → right. Finding: image quality increases as
//! the window moves right — later iterations are less sensitive.
//!
//! Humans judged the paper's four images; we quantify with SSIM/PSNR
//! against the unoptimized baseline plus latent drift, and check the
//! monotone trend. Run: `cargo bench --bench fig1_window_position`

use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::WindowSpec;
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::quality::{latent_drift, psnr, ssim};
use selective_guidance::runtime::ModelStack;

fn main() {
    let args = BenchArgs::parse();
    let steps = if args.fast { 16 } else { 48 };
    let seeds: &[u64] = if args.fast { &[11] } else { &[11, 23, 47] };
    eprintln!("[fig1] loading {} ...", args.artifacts);
    let stack = Arc::new(ModelStack::load(&args.artifacts).expect("artifacts"));
    let engine = Engine::new(stack, EngineConfig::default());
    let prompt = prompts::FIG1_PROMPT;

    let offsets = [("first 25%", 0.0), ("25-50%", 0.25), ("50-75%", 0.5), ("last 25%", 0.75)];
    let mut table = Table::new(&["window", "latent drift", "SSIM", "PSNR dB"]);
    let mut rows_json = Vec::new();
    let mut mean_ssims = Vec::new();

    for &(label, offset) in &offsets {
        let (mut d_acc, mut s_acc, mut p_acc) = (0.0, 0.0, 0.0);
        for &seed in seeds {
            let base = engine
                .generate(&GenerationRequest::new(prompt).steps(steps).seed(seed))
                .expect("baseline");
            let out = engine
                .generate(
                    &GenerationRequest::new(prompt)
                        .steps(steps)
                        .seed(seed)
                        .selective(WindowSpec::at_offset(offset, 0.25)),
                )
                .expect("optimized");
            d_acc += latent_drift(&base.latent, &out.latent);
            let (bi, oi) = (base.image.as_ref().unwrap(), out.image.as_ref().unwrap());
            s_acc += ssim(bi, oi);
            let p = psnr(bi, oi);
            p_acc += if p.is_finite() { p } else { 99.0 };
        }
        let n = seeds.len() as f64;
        let (d, s, p) = (d_acc / n, s_acc / n, p_acc / n);
        eprintln!("[fig1] {label}: drift {d:.4} ssim {s:.4}");
        table.row(&[label.into(), format!("{d:.4}"), format!("{s:.4}"), format!("{p:.1}")]);
        rows_json.push(
            Value::obj()
                .with("window", label)
                .with("offset", offset)
                .with("latent_drift", d)
                .with("ssim", s)
                .with("psnr_db", p),
        );
        mean_ssims.push(s);
    }

    println!("\nFigure 1 — 25% window position sweep, {steps} steps, {} seed(s):\n", seeds.len());
    table.print();
    let improving = mean_ssims.windows(2).filter(|w| w[1] >= w[0]).count();
    println!(
        "\ntrend: SSIM improves in {improving}/3 left->right transitions \
         (paper: quality increases as the window moves right)"
    );

    write_result_json(
        "fig1_window_position",
        &Value::obj()
            .with("steps", steps)
            .with("seeds", seeds.len())
            .with("improving_transitions", improving as i64)
            .with("rows", Value::Arr(rows_json)),
    );
}
