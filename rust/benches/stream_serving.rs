//! Streaming serving-plane soak — the DESIGN.md §14 headline claims,
//! enforced in virtual time on the deterministic synthetic backend.
//!
//! Two experiments, both driven through the continuous batcher (the
//! exact component the streaming multiplexer submits into, so the
//! cancel path measured here is the wire `cancel` op's path):
//!
//! 1. **Mixed-trace soak, no class starves** — a 6-scenario round-robin
//!    trace (text2img × {dual, interval, cadence}, img2img, variations,
//!    streamed-with-30%-cancel-at-half) runs under FIFO admission until
//!    a completion target. Every scenario class must retire a fair
//!    share of samples: the admission order the QoS layer feeds must
//!    not structurally favor cheap plans.
//! 2. **Cancel reclaims capacity** — cancel-heavy traffic (half the
//!    requests abandoned at half their trajectory) measured twice: once
//!    honoring cancels (slots return to admission headroom mid-cohort)
//!    and once ignoring them (abandoned samples run to completion, the
//!    pre-cancel-op behavior). Honoring cancels must lift useful
//!    completed-requests/tick by >= 1.15x.
//!
//! All quantities are virtual-time ratios (one cohort iteration == one
//! tick), reproducible bit-for-bit; `tools/bench_gate.rs` holds the
//! gated ones to `ci/bench_baselines/BENCH_stream.json`.
//!
//! Run: `cargo bench --bench stream_serving` (`--fast` for CI smoke)

use std::collections::BTreeMap;
use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::ContinuousBatcher;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::{GuidanceSchedule, GuidanceStrategy, ReuseKind, WindowSpec};
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;

const CLASSES: [&str; 6] =
    ["dual", "interval", "cadence", "img2img", "variations", "stream-cancel"];

fn base(i: usize, steps: usize) -> GenerationRequest {
    GenerationRequest::new(prompts::TABLE2[i % prompts::TABLE2.len()])
        .steps(steps)
        .scheduler(SchedulerKind::Ddim)
        .seed(i as u64)
        .decode(false)
}

/// One trace entry: a request, its scenario class, and whether the
/// client abandons it at half its trajectory.
struct Entry {
    req: GenerationRequest,
    class: usize,
    cancel_at_half: bool,
}

/// The 6-scenario round: five singles plus one variations group of 4,
/// the group sharing one compiled plan. Seeds/prompts stay distinct
/// across rounds.
fn mixed_round(round: usize, steps: usize) -> Vec<Entry> {
    let hold = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 };
    let i = round * CLASSES.len();
    let mut out = vec![
        Entry { req: base(i, steps), class: 0, cancel_at_half: false },
        Entry {
            req: base(i + 1, steps)
                .with_schedule(GuidanceSchedule::interval(0.25, 0.75))
                .strategy(hold),
            class: 1,
            cancel_at_half: false,
        },
        Entry {
            req: base(i + 2, steps)
                .with_schedule(GuidanceSchedule::cadence(2))
                .strategy(hold),
            class: 2,
            cancel_at_half: false,
        },
        Entry {
            req: base(i + 3, steps).selective(WindowSpec::last(0.5)).img2img(0.5),
            class: 3,
            cancel_at_half: false,
        },
    ];
    let vars = base(i + 4, steps)
        .selective(WindowSpec::last(0.5))
        .variations(4)
        .expect("variations fan-out");
    out.extend(vars.into_iter().map(|req| Entry { req, class: 4, cancel_at_half: false }));
    // the streamed class: 3 of every 10 rounds abandon mid-flight
    out.push(Entry { req: base(i + 5, steps), class: 5, cancel_at_half: round % 10 < 3 });
    out
}

/// Drive a trace through the batcher in virtual time until `target`
/// useful samples complete. Useful = never-abandoned: with cancels
/// honored an abandoned sample can never retire; with cancels ignored
/// it retires but its output is waste either way, so it never counts.
/// Returns (ticks, useful-completions-per-class, cancelled, waste).
fn soak(
    engine: &Arc<Engine>,
    trace: &[Entry],
    budget: usize,
    target: usize,
    honor_cancel: bool,
) -> (usize, Vec<usize>, usize, usize) {
    let mut cb = ContinuousBatcher::new(Arc::clone(engine), budget).expect("batcher");
    let mut next = 0usize;
    let mut meta: BTreeMap<u64, usize> = BTreeMap::new(); // id -> trace index
    let mut done_per_class = vec![0usize; CLASSES.len()];
    let mut done = 0usize;
    let mut cancelled = 0usize;
    let mut waste = 0usize;
    let mut ticks = 0usize;
    while done < target {
        while next < trace.len() {
            match cb.try_admit(&trace[next].req).expect("admit") {
                Some(id) => {
                    meta.insert(id, next);
                    next += 1;
                }
                None => break,
            }
        }
        assert!(cb.in_flight() > 0, "trace exhausted before reaching target");
        let outcome = cb.step().expect("step");
        assert!(outcome.slots_used <= budget, "slot budget violated");
        ticks += 1;
        for (id, _) in outcome.retired {
            let e = &trace[meta[&id]];
            if e.cancel_at_half {
                assert!(!honor_cancel, "a cancelled sample must never retire");
                waste += 1;
            } else {
                done_per_class[e.class] += 1;
                done += 1;
            }
        }
        if honor_cancel {
            // the wire cancel lands at an iteration boundary: abandon
            // any in-flight sample past half its trajectory, returning
            // its reserved slots to admission headroom immediately
            for (id, step, steps) in cb.progress() {
                if trace[meta[&id]].cancel_at_half && step >= steps / 2 && cb.cancel(id) {
                    cancelled += 1;
                }
            }
        }
        assert!(ticks < 100_000, "soak failed to reach target");
    }
    (ticks, done_per_class, cancelled, waste)
}

fn main() {
    let args = BenchArgs::parse();
    let engine = Arc::new(Engine::new(
        Arc::new(ModelStack::synthetic()),
        EngineConfig::default(),
    ));
    let steps = if args.fast { 12 } else { 20 };
    let budget = 8usize;

    // ---- experiment 1: mixed-trace soak, no class starves ---------------
    let target = if args.fast { 60 } else { 120 };
    let rounds = 4 * target / 9; // ~4x the measured window stays offered
    let trace: Vec<Entry> = (0..rounds).flat_map(|r| mixed_round(r, steps)).collect();
    let (ticks_mix, per_class, cancelled_mix, _) = soak(&engine, &trace, budget, target, true);
    let fair = target as f64 / CLASSES.len() as f64;
    let min_share = per_class
        .iter()
        .map(|&c| c as f64 / fair)
        .fold(f64::INFINITY, f64::min);

    let mut table = Table::new(&["class", "completed", "share of fair"]);
    for (name, &c) in CLASSES.iter().zip(&per_class) {
        table.row(&[(*name).into(), format!("{c}"), format!("{:.2}", c as f64 / fair)]);
    }
    println!(
        "\nStreaming soak — virtual time, slot budget {budget}, {steps} steps, \
         first {target} completions ({cancelled_mix} cancelled mid-flight, {ticks_mix} ticks):\n"
    );
    table.print();
    assert!(
        per_class.iter().all(|&c| c > 0) && min_share >= 0.3,
        "a scenario class starved: {per_class:?} (min share {min_share:.2})"
    );

    // ---- experiment 2: cancel-heavy traffic, honored vs ignored ---------
    // all-dual streamed traffic, every second request abandoned at half
    // its steps — half the offered slot-work is reclaimable
    let useful = if args.fast { 35 } else { 70 };
    let heavy: Vec<Entry> = (0..useful * 4)
        .map(|i| Entry { req: base(i, steps), class: 0, cancel_at_half: i % 2 == 0 })
        .collect();
    let (ticks_honored, _, n_cancelled, _) = soak(&engine, &heavy, budget, useful, true);
    assert!(n_cancelled > 0, "cancel-heavy trace produced no cancels");
    let (ticks_ignored, _, _, waste) = soak(&engine, &heavy, budget, useful, false);
    assert!(waste > 0, "cancel-ignored run must burn slots on abandoned samples");
    let thr_honored = useful as f64 / ticks_honored as f64;
    let thr_ignored = useful as f64 / ticks_ignored as f64;
    let cancel_speedup = thr_honored / thr_ignored;

    let mut table = Table::new(&["policy", "ticks", "useful/tick", "speedup"]);
    table.row(&[
        "cancel ignored".into(),
        format!("{ticks_ignored}"),
        format!("{thr_ignored:.4}"),
        "1.00x".into(),
    ]);
    table.row(&[
        "cancel honored".into(),
        format!("{ticks_honored}"),
        format!("{thr_honored:.4}"),
        format!("{cancel_speedup:.2}x"),
    ]);
    println!(
        "\nCancel-heavy traffic — 50% of requests abandoned at half their steps, \
         first {useful} useful completions ({n_cancelled} cancels honored; \
         {waste} abandoned samples ran to completion when ignored):\n"
    );
    table.print();
    assert!(
        cancel_speedup >= 1.15,
        "honoring cancel must reclaim >= 1.15x useful throughput, got {cancel_speedup:.3}x"
    );

    write_result_json(
        "stream_serving",
        &Value::obj()
            .with("steps", steps as i64)
            .with("slot_budget", budget as i64)
            .with("soak_target", target as i64)
            .with("soak_ticks", ticks_mix as i64)
            .with("soak_cancelled", cancelled_mix as i64)
            .with("starvation_min_share", min_share)
            .with("useful_target", useful as i64)
            .with("ticks_cancel_honored", ticks_honored as i64)
            .with("ticks_cancel_ignored", ticks_ignored as i64)
            .with("cancel_speedup", cancel_speedup),
    );
    // the regression-gate view (virtual-time ratios only), compared
    // against ci/bench_baselines/BENCH_stream.json by tools/bench_gate.rs
    write_result_json(
        "BENCH_stream",
        &Value::obj()
            .with("cancel_speedup", cancel_speedup)
            .with("starvation_min_share", min_share)
            .with("classes_served", per_class.iter().filter(|&&c| c > 0).count() as i64),
    );
}
