//! Figure 5 (repo extension): guidance-reuse strategies vs the paper's
//! drop-guidance optimization.
//!
//! Protocol: per prompt and window fraction, four trajectories with the
//! same seed — full CFG (baseline), the paper's CondOnly window, and the
//! two Reuse windows (zero-order hold / linear extrapolation, DESIGN.md
//! §8). Everything runs on the deterministic synthetic backend
//! ([`ModelStack::synthetic`]), so the run needs no artifacts, is
//! bit-reproducible in CI, and the assertions below are *hard*:
//!
//! (a) Reuse UNet evals < Dual evals for every window with fraction > 0
//!     (and >= CondOnly evals — refresh steps are paid, not free);
//! (b) SSIM(Reuse, full CFG) >= SSIM(CondOnly, full CFG) at the same
//!     window — cached guidance tracks the baseline at least as well as
//!     dropped guidance, which is the point of the strategy lattice.
//!
//! Run: `cargo bench --bench fig5_reuse_strategies [-- --fast]`

use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::{GuidanceStrategy, ReuseKind, WindowSpec};
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::quality::{latent_drift, ssim};
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;

fn main() {
    let args = BenchArgs::parse();
    let steps = if args.fast { 20 } else { 50 };
    let prompts: &[&str] = if args.fast {
        &[prompts::FIG2_PROMPT]
    } else {
        &[
            prompts::FIG2_PROMPT,
            "A watercolor of a silver dragon head with colorful flowers growing out of the top",
            "A person holding a cat",
        ]
    };
    let fractions = [0.2, 0.3, 0.4, 0.5];
    let refresh = 4usize;
    let seed = 11u64;

    eprintln!("[fig5] synthetic backend, {steps} steps, refresh cadence {refresh}");
    let engine = Engine::new(Arc::new(ModelStack::synthetic()), EngineConfig::default());

    let strategies = [
        ("cond-only", GuidanceStrategy::CondOnly),
        ("hold", GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: refresh }),
        (
            "extrapolate",
            GuidanceStrategy::Reuse { kind: ReuseKind::Extrapolate, refresh_every: refresh },
        ),
    ];

    let mut table = Table::new(&["prompt", "window", "strategy", "evals", "SSIM", "drift"]);
    let mut rows_json = Vec::new();
    let mut checked = 0usize;

    for (pi, prompt) in prompts.iter().enumerate() {
        let request = |w: WindowSpec, s: GuidanceStrategy| {
            GenerationRequest::new(*prompt)
                .steps(steps)
                .scheduler(SchedulerKind::Ddim)
                .seed(seed)
                .selective(w)
                .strategy(s)
                .decode(true)
        };
        let base = engine
            .generate(&request(WindowSpec::none(), GuidanceStrategy::CondOnly))
            .expect("baseline");
        let base_img = base.image.as_ref().unwrap();
        assert_eq!(base.unet_evals, 2 * steps, "baseline must be dual everywhere");

        for &f in &fractions {
            let mut ssim_cond = f64::NAN;
            for (name, strategy) in strategies {
                let out = engine
                    .generate(&request(WindowSpec::last(f), strategy))
                    .expect("optimized");
                let s = ssim(base_img, out.image.as_ref().unwrap());
                let d = latent_drift(&base.latent, &out.latent);

                // (a) every optimized run beats the dual baseline on cost
                assert!(
                    out.unet_evals < 2 * steps,
                    "{name} last {f}: {} evals not below dual {}",
                    out.unet_evals,
                    2 * steps
                );
                match strategy {
                    GuidanceStrategy::CondOnly => ssim_cond = s,
                    GuidanceStrategy::Reuse { .. } => {
                        // reuse pays for its refresh steps ...
                        let k = WindowSpec::last(f).optimized_count(steps);
                        assert!(
                            out.unet_evals >= 2 * steps - k,
                            "{name} last {f}: reuse cheaper than cond-only?"
                        );
                        // ... and (b) buys baseline fidelity back for it
                        assert!(
                            s >= ssim_cond,
                            "{name} last {f}: SSIM {s:.4} below cond-only {ssim_cond:.4}"
                        );
                        checked += 1;
                    }
                }

                let short: String = prompt.chars().take(24).collect();
                table.row(&[
                    short,
                    format!("last {:.0}%", f * 100.0),
                    name.into(),
                    format!("{}", out.unet_evals),
                    format!("{s:.4}"),
                    format!("{d:.4}"),
                ]);
                rows_json.push(
                    Value::obj()
                        .with("prompt_index", pi as i64)
                        .with("fraction", f)
                        .with("strategy", name)
                        .with("unet_evals", out.unet_evals as i64)
                        .with("ssim", s)
                        .with("latent_drift", d),
                );
            }
        }
    }

    println!("\nFigure 5 — guidance-reuse strategies, {steps} steps (synthetic backend):\n");
    table.print();
    println!(
        "\nall {checked} reuse runs: evals < dual baseline and \
         SSIM(reuse) >= SSIM(cond-only) at the same window"
    );

    write_result_json(
        "fig5_reuse_strategies",
        &Value::obj()
            .with("steps", steps)
            .with("refresh_every", refresh as i64)
            .with("reuse_runs_checked", checked as i64)
            .with("rows", Value::Arr(rows_json)),
    );
}
