//! Deadline-optimal plan search: frontier-guided admission vs. the
//! legacy analytic actuator, at equal SLO attainment (DESIGN.md §16).
//!
//! The legacy QoS actuator degrades along one axis — it widens the
//! paper's last-window and escalates drop/reuse by a fixed threshold.
//! The planner instead consults a sealed Pareto frontier tuned offline
//! over the whole schedule grammar (windows × cadences × intervals ×
//! strategies) and, per admission, picks the *highest-SSIM* plan whose
//! measured cost still meets the demanded saving — an O(1) lookup into
//! the compiled frontier, never a re-sweep.
//!
//! Method (everything deterministic, runs in CI):
//!
//! 1. tune a frontier on the synthetic backend with the real
//!    engine-driven scorer ([`runtime::tune`]) over a unit cost table;
//! 2. replay identical Poisson arrival traces through two freshly-built
//!    [`DeadlineQos`] policies — legacy, and the same config with the
//!    frontier attached — inside the virtual-time serving model
//!    ([`qos::sim`]), collecting the per-request applied-plan traces;
//! 3. replay every *distinct compiled plan* the two modes actually
//!    applied through the real engine and score SSIM against full CFG.
//!
//! Asserted (hard):
//!
//! (a) equal service: SLO attainment with the planner is no worse than
//!     legacy at every operating point (the frontier's selected saving
//!     covers the same demanded shed by construction);
//! (b) quality win: wherever the legacy actuator actually widened,
//!     the searched plans achieve *strictly higher* mean SSIM;
//! (c) O(1) admission ledger: searches == admissions (one lookup each,
//!     never more), zero bucket fallbacks on tuned traffic, and the
//!     sealed `candidates_swept` count never moves at admission time —
//!     the sweep happened offline, exactly once.
//!
//! Run: `cargo bench --bench plan_search [-- --fast]`

use std::collections::HashMap;
use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::{CostTable, PlanSearch, TunerConfig};
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::qos::{
    simulate_trace, AppliedPlan, DeadlineQos, QosConfig, QosPolicy, SimSpec,
};
use selective_guidance::quality::ssim;
use selective_guidance::runtime::{tune, ModelStack};
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::workload::ArrivalProcess;

fn main() {
    let args = BenchArgs::parse();
    let steps = if args.fast { 12 } else { 20 };
    let n_requests = if args.fast { 300 } else { 1500 };
    let multipliers: &[f64] = if args.fast { &[1.4] } else { &[0.8, 1.2, 1.6] };

    // ---- offline: tune the frontier once, engine-scored ---------------
    let stack = Arc::new(ModelStack::synthetic());
    let cost_table = CostTable::proportional(1.0, &[1, 2, 4]);
    let tuner = if args.fast {
        TunerConfig { steps_buckets: vec![steps], ..TunerConfig::fast() }
    } else {
        TunerConfig { steps_buckets: vec![steps], ..TunerConfig::default() }
    };
    eprintln!(
        "[planner] tuning frontier: {} candidates x 1 bucket ({steps} steps), synthetic backend",
        tuner.candidates().len()
    );
    let manifest = tune(Arc::clone(&stack), &tuner, &cost_table).expect("tune");
    let candidates_swept = manifest.candidates_swept;
    let frontier_points: usize = manifest.buckets.iter().map(|b| b.points.len()).sum();
    eprintln!(
        "[planner] sealed frontier: {frontier_points} non-dominated of {candidates_swept} \
         swept (checksum {})",
        manifest.checksum
    );
    let search = Arc::new(PlanSearch::new(manifest).expect("sealed frontier"));

    // ---- quality oracle: SSIM of a plan vs full CFG, memoized on the
    // *compiled* plan (distinct demanded fractions that floor-round to
    // the same executed plan share one engine run) -----------------------
    let engine = Engine::new(Arc::clone(&stack), EngineConfig::default());
    let request = |p: &AppliedPlan| {
        GenerationRequest::new(prompts::FIG2_PROMPT)
            .steps(p.steps)
            .scheduler(SchedulerKind::Ddim)
            .seed(42)
            .with_schedule(p.schedule.clone())
            .strategy(p.strategy)
            .decode(true)
    };
    let baseline = engine
        .generate(
            &GenerationRequest::new(prompts::FIG2_PROMPT)
                .steps(steps)
                .scheduler(SchedulerKind::Ddim)
                .seed(42)
                .decode(true),
        )
        .expect("full-CFG baseline");
    let base_img = baseline.image.as_ref().expect("decoded baseline");
    let mut memo: HashMap<String, f64> = HashMap::new();
    let mut mean_ssim = |plans: &[AppliedPlan]| -> f64 {
        let mut sum = 0.0;
        for p in plans {
            let req = request(p);
            let key = format!("{:?}", req.plan().expect("compilable plan"));
            let s = *memo.entry(key).or_insert_with(|| {
                let out = engine.generate(&req).expect("plan replay");
                ssim(base_img, out.image.as_ref().expect("decoded"))
            });
            sum += s;
        }
        sum / plans.len().max(1) as f64
    };

    // ---- the serving sweep --------------------------------------------
    let spec = SimSpec {
        base_service_ms: 100.0,
        unet_share: 0.95,
        deadline_ms: 300.0,
        workers: 1,
        steps,
    };
    let capacity_per_s = 1e3 / spec.base_service_ms * spec.workers as f64;
    let qos_cfg = QosConfig {
        enabled: true,
        max_queue_depth: 64,
        floor_fraction: 0.5,
        ramp_low: 1,
        ramp_high: 3,
        default_deadline_ms: 0.0,
        ewma_alpha: 0.2,
        unet_share: spec.unet_share,
        ..QosConfig::default()
    };

    let mut table = Table::new(&[
        "offered",
        "SLO legacy",
        "SLO planner",
        "widened",
        "SSIM legacy",
        "SSIM planner",
        "searches",
        "fallbacks",
    ]);
    let mut rows = Vec::new();
    let mut ssim_gain_min = f64::INFINITY;
    let mut slo_delta_min = f64::INFINITY;
    let mut searches_total = 0u64;
    let mut admitted_total = 0u64;
    let mut fallbacks_total = 0u64;
    let mut widened_checked = false;

    for &m in multipliers {
        let rate = m * capacity_per_s;
        let arrivals = ArrivalProcess::Poisson { rate_per_s: rate }.arrivals(n_requests, 42);

        // fresh policies per operating point (the EWMA carries state);
        // the sealed frontier is shared — it is immutable by design
        let legacy = DeadlineQos::new(qos_cfg.clone()).expect("valid qos config");
        let planned = DeadlineQos::new(qos_cfg.clone()).expect("valid qos config");
        planned.attach_planner(Arc::clone(&search));

        let before = search.snapshot();
        let (leg_report, leg_plans) = simulate_trace(&arrivals, &spec, Some(&legacy));
        let mid = search.snapshot();
        assert_eq!(mid, before, "the legacy policy must never consult the frontier");
        let (plan_report, plan_plans) = simulate_trace(&arrivals, &spec, Some(&planned));
        let after = search.snapshot();

        // (c) O(1) ledger: exactly one frontier lookup per admission —
        // rejected requests never search, admitted ones search once
        let admitted = (plan_report.offered - plan_report.rejected) as u64;
        let searches = after.searches - before.searches;
        assert_eq!(searches, admitted, "{m:.1}x: admissions and searches must reconcile");
        let fallbacks = after.fallbacks - before.fallbacks;
        assert_eq!(fallbacks, 0, "{m:.1}x: tuned-bucket traffic must never fall back");
        assert_eq!(
            search.manifest().candidates_swept,
            candidates_swept,
            "admission must never re-open the offline sweep"
        );
        searches_total += searches;
        admitted_total += admitted;
        fallbacks_total += fallbacks;

        let s_leg = mean_ssim(&leg_plans);
        let s_plan = mean_ssim(&plan_plans);
        let slo_delta = plan_report.slo_attainment() - leg_report.slo_attainment();
        slo_delta_min = slo_delta_min.min(slo_delta);

        // (a) equal service: the selected plan's measured saving covers
        // the same demanded shed, so attainment must not regress
        assert!(
            slo_delta >= -0.02,
            "{m:.1}x: planner regressed SLO attainment (planner {:.3} vs legacy {:.3})",
            plan_report.slo_attainment(),
            leg_report.slo_attainment()
        );

        let widened = leg_report.mean_fraction > 0.05;
        if widened {
            widened_checked = true;
            // (b) the quality win the frontier was tuned for, strict
            assert!(
                s_plan > s_leg,
                "{m:.1}x: searched plans must beat actuator widening on mean SSIM \
                 (planner {s_plan:.4} vs legacy {s_leg:.4})"
            );
            ssim_gain_min = ssim_gain_min.min(s_plan - s_leg);
        }

        eprintln!(
            "[planner] {m:.1}x: SLO {:.0}% -> {:.0}%, mean SSIM {s_leg:.4} -> {s_plan:.4} \
             ({searches} searches / {admitted} admissions)",
            leg_report.slo_attainment() * 100.0,
            plan_report.slo_attainment() * 100.0,
        );
        table.row(&[
            format!("{m:.1}x"),
            format!("{:.1}%", leg_report.slo_attainment() * 100.0),
            format!("{:.1}%", plan_report.slo_attainment() * 100.0),
            format!("{}", widened),
            format!("{s_leg:.4}"),
            format!("{s_plan:.4}"),
            format!("{searches}"),
            format!("{fallbacks}"),
        ]);
        rows.push(
            Value::obj()
                .with("multiplier", m)
                .with("offered_per_s", rate)
                .with("slo_legacy", leg_report.slo_attainment())
                .with("slo_planner", plan_report.slo_attainment())
                .with("mean_ssim_legacy", s_leg)
                .with("mean_ssim_planner", s_plan)
                .with("mean_fraction_legacy", leg_report.mean_fraction)
                .with("mean_fraction_planner", plan_report.mean_fraction)
                .with("admitted", admitted as i64)
                .with("searches", searches as i64)
                .with("fallbacks", fallbacks as i64),
        );
    }
    assert!(widened_checked, "sweep must include a point where legacy widens");

    println!(
        "\nPlan search — frontier-guided admission vs legacy actuator, {steps} steps, \
         {frontier_points}-point frontier from {candidates_swept} candidates \
         (synthetic backend, virtual time):\n"
    );
    table.print();
    println!(
        "\n(the planner consults the sealed Pareto frontier once per admission — \
         O(1) in the candidate count — and picks the highest-SSIM plan meeting the \
         demanded saving; the legacy actuator can only widen the last-window)"
    );

    write_result_json(
        "plan_search",
        &Value::obj()
            .with("steps", steps as i64)
            .with("requests", n_requests as i64)
            .with("candidates_swept", candidates_swept as i64)
            .with("frontier_points", frontier_points as i64)
            .with("rows", Value::Arr(rows)),
    );
    // the regression-gate view (ci/bench_baselines/BENCH_planner.json,
    // checked by tools/bench_gate.rs): deterministic ratios only
    write_result_json(
        "BENCH_planner",
        &Value::obj()
            .with("ssim_gain_min", ssim_gain_min)
            .with("slo_delta_min", slo_delta_min)
            .with(
                "searches_per_admission",
                searches_total as f64 / admitted_total.max(1) as f64,
            )
            .with("fallbacks", fallbacks_total as i64),
    );
}
