//! Replica-cluster evaluation — the DESIGN.md §11 headline claims,
//! enforced in deterministic **virtual time**.
//!
//! Runs the *real* [`Router`] and *real* per-replica
//! [`ContinuousBatcher`]s over the synthetic backend; one tick = one
//! cohort iteration on every replica in parallel (the cost model of N
//! independent accelerators). Everything below is exactly reproducible —
//! the regression gate (`tools/bench_gate.rs`) holds the headline
//! metrics to committed bands in `ci/bench_baselines/BENCH_cluster.json`.
//!
//! Asserted claims:
//!
//! 1. **Near-linear scaling** — 4 homogeneous replicas sustain ≥ 3.4×
//!    the steady-state saturated throughput of 1 replica (measured over
//!    a fixed post-warmup window, so fill/drain edges don't distort the
//!    rate).
//! 2. **Plan-cost routing beats round-robin** — under heterogeneous
//!    slot budgets (8/4/2/2) and mixed guidance schedules (full CFG,
//!    half-window, full-window, cadence — per-request costs spanning
//!    2×), weighted least-outstanding-evals routing yields a p95
//!    latency no worse than replica-blind round-robin on the identical
//!    arrival stream. Round-robin overloads the weak replicas (it sends
//!    them the same request share as the strong one); the plan-cost
//!    router keeps every replica's *normalized* load balanced.
//!
//! Run: `cargo bench --bench cluster_scaling` (`--fast` for CI smoke)

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::cluster::{RoutePolicy, Router};
use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::ContinuousBatcher;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::{GuidanceSchedule, WindowSpec};
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;

const STEPS: usize = 10;

/// Request `i` of the mixed-schedule stream: per-request plan costs span
/// 2× (full CFG = 20 evals at 10 steps, full window = 10).
fn mixed_request(i: usize) -> GenerationRequest {
    let base = GenerationRequest::new(prompts::TABLE2[i % prompts::TABLE2.len()])
        .steps(STEPS)
        .scheduler(SchedulerKind::Ddim)
        .seed(i as u64)
        .decode(false);
    match i % 4 {
        0 => base,                                                      // full CFG
        1 => base.selective(WindowSpec::last(0.5)),                     // paper's headline
        2 => base.selective(WindowSpec::last(1.0)),                     // all cond-only
        _ => base.with_schedule(GuidanceSchedule::Cadence { every: 2 }), // compressed
    }
}

struct SimReplica {
    cb: ContinuousBatcher,
    queue: VecDeque<usize>,
    /// Plan-compiled evals routed here and not yet completed — the
    /// router's load signal, exactly as the live ReplicaSet tracks it.
    outstanding: u64,
    /// cohort id -> request index
    inflight: BTreeMap<u64, usize>,
}

struct SimOutcome {
    /// latency (ticks) per completed request, completion order
    latencies: Vec<u64>,
    /// completions inside the [warmup, warmup+window) measurement window
    /// (0 when no window was requested)
    windowed_completions: usize,
}

/// Drive a replica fleet in virtual time over a fixed arrival stream.
/// `arrivals[i]` is request `i`'s arrival tick (sorted). Runs until
/// every request completes, or — when `measure` is set — until the
/// measurement window `[warmup, warmup+window)` closes.
fn simulate(
    engine: &Arc<Engine>,
    budgets: &[usize],
    route: RoutePolicy,
    reqs: &[GenerationRequest],
    arrivals: &[u64],
    measure: Option<(u64, u64)>,
) -> SimOutcome {
    let weights: Vec<f64> = budgets.iter().map(|&b| b as f64).collect();
    let mut router = Router::new(route, weights, 0).expect("router");
    let mut replicas: Vec<SimReplica> = budgets
        .iter()
        .map(|&b| SimReplica {
            cb: ContinuousBatcher::new(Arc::clone(engine), b).expect("batcher"),
            queue: VecDeque::new(),
            outstanding: 0,
            inflight: BTreeMap::new(),
        })
        .collect();
    let costs: Vec<u64> = reqs
        .iter()
        .map(|r| r.plan().expect("plan").total_unet_evals() as u64)
        .collect();
    let mut next_arrival = 0usize;
    let mut done = 0usize;
    let mut latencies = Vec::with_capacity(reqs.len());
    let mut windowed = 0usize;
    let mut t: u64 = 0;
    loop {
        // 1) route this tick's arrivals by current outstanding evals
        while next_arrival < reqs.len() && arrivals[next_arrival] <= t {
            let loads: Vec<Option<u64>> = replicas.iter().map(|r| Some(r.outstanding)).collect();
            let target = router.place(&loads).expect("some replica is healthy");
            replicas[target].outstanding += costs[next_arrival];
            replicas[target].queue.push_back(next_arrival);
            next_arrival += 1;
        }
        // 2) every replica advances one iteration in parallel
        for r in replicas.iter_mut() {
            while let Some(&idx) = r.queue.front() {
                match r.cb.try_admit(&reqs[idx]).expect("admit") {
                    Some(id) => {
                        r.inflight.insert(id, idx);
                        r.queue.pop_front();
                    }
                    None => break,
                }
            }
            if r.cb.in_flight() == 0 {
                continue;
            }
            let outcome = r.cb.step().expect("step");
            assert!(outcome.slots_used <= r.cb.slot_budget(), "slot budget violated");
            for (id, _out) in outcome.retired {
                let idx = r.inflight.remove(&id).expect("retired id");
                r.outstanding -= costs[idx];
                let latency = t + 1 - arrivals[idx];
                latencies.push(latency);
                done += 1;
                if let Some((warmup, window)) = measure {
                    if t >= warmup && t < warmup + window {
                        windowed += 1;
                    }
                }
            }
        }
        t += 1;
        match measure {
            Some((warmup, window)) => {
                if t >= warmup + window {
                    break;
                }
            }
            None => {
                if done == reqs.len() {
                    break;
                }
            }
        }
        assert!(t < 1_000_000, "virtual-time run failed to finish");
    }
    SimOutcome { latencies, windowed_completions: windowed }
}

fn quantile(sorted: &[u64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] as f64 * (1.0 - frac) + sorted[hi] as f64 * frac
}

fn main() {
    let args = BenchArgs::parse();
    let engine = Arc::new(Engine::new(
        Arc::new(ModelStack::synthetic()),
        EngineConfig::default(),
    ));

    // ---- claim 1: near-linear throughput scaling, 1 -> 4 replicas -------
    // saturated: everything arrives at t=0, far more work than the
    // measurement horizon consumes; throughput is completions inside a
    // fixed post-warmup window, so the rate is steady-state by design
    let warmup = (STEPS as u64) * 3;
    let window = if args.fast { 120u64 } else { 240 };
    let offered = if args.fast { 600 } else { 1200 };
    let reqs: Vec<GenerationRequest> = (0..offered).map(mixed_request).collect();
    let arrivals = vec![0u64; offered];

    let solo = simulate(
        &engine,
        &[8],
        RoutePolicy::PlanCost,
        &reqs,
        &arrivals,
        Some((warmup, window)),
    );
    let quad = simulate(
        &engine,
        &[8, 8, 8, 8],
        RoutePolicy::PlanCost,
        &reqs,
        &arrivals,
        Some((warmup, window)),
    );
    let thr_1 = solo.windowed_completions as f64 / window as f64;
    let thr_4 = quad.windowed_completions as f64 / window as f64;
    let scaling = thr_4 / thr_1;

    // ---- claim 2: plan-cost routing vs round-robin, heterogeneous -------
    // budgets 8/4/2/2 (aggregate 16 slots/tick), arrivals at ~80% of
    // aggregate capacity; identical stream under both policies, run to
    // full drain so every request's latency counts
    let budgets = [8usize, 4, 2, 2];
    let n = if args.fast { 240 } else { 480 };
    let het_reqs: Vec<GenerationRequest> = (0..n).map(mixed_request).collect();
    // mean cost at this mix is 15 evals -> aggregate capacity ~1.07
    // req/tick; offer ~0.79 req/tick (one arrival every 1.27 ticks),
    // ~74% of aggregate — but 160% of what round-robin hands the
    // budget-2 replicas, which is exactly the failure mode under test
    let het_arrivals: Vec<u64> = (0..n).map(|i| (i as f64 * 1.27) as u64).collect();

    let plan = simulate(&engine, &budgets, RoutePolicy::PlanCost, &het_reqs, &het_arrivals, None);
    let rr = simulate(&engine, &budgets, RoutePolicy::RoundRobin, &het_reqs, &het_arrivals, None);
    let mut plan_lat = plan.latencies.clone();
    let mut rr_lat = rr.latencies.clone();
    plan_lat.sort_unstable();
    rr_lat.sort_unstable();
    assert_eq!(plan_lat.len(), n, "plan-cost run lost requests");
    assert_eq!(rr_lat.len(), n, "round-robin run lost requests");
    let p95_plan = quantile(&plan_lat, 0.95);
    let p95_rr = quantile(&rr_lat, 0.95);
    let p50_plan = quantile(&plan_lat, 0.5);
    let p50_rr = quantile(&rr_lat, 0.5);
    let p95_ratio = p95_plan / p95_rr;

    let mut table = Table::new(&["experiment", "config", "metric", "value"]);
    table.row(&[
        "scaling".into(),
        "1 replica (budget 8)".into(),
        "img/tick".into(),
        format!("{thr_1:.4}"),
    ]);
    table.row(&[
        "scaling".into(),
        "4 replicas (budget 8 each)".into(),
        "img/tick".into(),
        format!("{thr_4:.4} ({scaling:.2}x)"),
    ]);
    table.row(&[
        "routing".into(),
        "plan-cost (8/4/2/2)".into(),
        "p50 / p95 ticks".into(),
        format!("{p50_plan:.1} / {p95_plan:.1}"),
    ]);
    table.row(&[
        "routing".into(),
        "round-robin (8/4/2/2)".into(),
        "p50 / p95 ticks".into(),
        format!("{p50_rr:.1} / {p95_rr:.1}"),
    ]);
    println!(
        "\nReplica cluster — virtual time, {STEPS}-step mixed-schedule stream \
         (costs 10..20 evals):\n"
    );
    table.print();
    println!(
        "\n(plan-cost routing keeps every replica's normalized load balanced; \
         round-robin sends the budget-2 replicas the same request share as the \
         budget-8 one and their queues pay for it: p95 {p95_plan:.0} vs {p95_rr:.0} ticks)"
    );

    // ---- the headline claims, enforced ----------------------------------
    assert!(
        scaling >= 3.4,
        "4 homogeneous replicas must scale >= 3.4x over 1, got {scaling:.3}x"
    );
    assert!(
        scaling <= 4.2,
        "scaling {scaling:.3}x above the physical 4x bound (sim bug?)"
    );
    assert!(
        p95_ratio <= 1.0,
        "plan-cost routing must not lose to round-robin on p95: {p95_plan:.1} vs {p95_rr:.1}"
    );

    write_result_json(
        "cluster_scaling",
        &Value::obj()
            .with("steps", STEPS as i64)
            .with("warmup_ticks", warmup as i64)
            .with("window_ticks", window as i64)
            .with("offered", offered as i64)
            .with("throughput_1_replica", thr_1)
            .with("throughput_4_replicas", thr_4)
            .with("scaling_ratio", scaling)
            .with("het_requests", n as i64)
            .with("p50_plan_cost", p50_plan)
            .with("p95_plan_cost", p95_plan)
            .with("p50_round_robin", p50_rr)
            .with("p95_round_robin", p95_rr)
            .with("p95_ratio", p95_ratio),
    );
    // the regression-gate view (virtual-time ratios only), compared
    // against ci/bench_baselines/BENCH_cluster.json by tools/bench_gate.rs
    write_result_json(
        "BENCH_cluster",
        &Value::obj()
            .with("scaling_ratio", scaling)
            .with("p95_ratio", p95_ratio),
    );
}
