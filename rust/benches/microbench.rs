//! Micro-benchmarks: per-operation cost inside the serving hot path.
//!
//! Feeds EXPERIMENTS.md §Perf: UNet execution per batch size, CFG
//! combine (device vs host), VAE decode, text encode, scheduler step,
//! latent init, PNG encode. The UNet share reported here grounds the
//! Table-1 analytic model.
//!
//! Run: `cargo bench --bench microbench`

use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, BenchRunner, Table};
use selective_guidance::image::RgbImage;
use selective_guidance::json::Value;
use selective_guidance::rng::Rng;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::{NoiseSchedule, Scheduler, SchedulerKind};
use selective_guidance::tokenizer::Tokenizer;

fn main() {
    let args = BenchArgs::parse();
    let runner = if args.fast { BenchRunner::new(2, 5) } else { BenchRunner::new(5, 30) };
    eprintln!("[micro] loading {} ...", args.artifacts);
    let stack = Arc::new(ModelStack::load(&args.artifacts).expect("artifacts"));
    let m = stack.model().clone();

    let mut rng = Rng::new(0);
    let mut table = Table::new(&["operation", "mean ms", "p50 ms", "max ms"]);
    let mut json = Value::obj();
    let mut record = |table: &mut Table, name: &str, stats: &selective_guidance::metrics::SampleStats| {
        table.row(&[
            name.into(),
            format!("{:.3}", stats.mean * 1e3),
            format!("{:.3}", stats.p50 * 1e3),
            format!("{:.3}", stats.max * 1e3),
        ]);
        eprintln!("[micro] {name}: {:.3} ms", stats.mean * 1e3);
    };

    // UNet per batch size
    let mut unet_b1_ms = 0.0;
    for &b in &m.batch_sizes {
        let lat = rng.normal_vec(b * m.latent_elems());
        let ts = vec![500.0f32; b];
        let ctx = rng.normal_vec(b * m.ctx_elems());
        let s = runner.run(|| stack.unet_eps(b, &lat, &ts, &ctx).unwrap());
        if b == 1 {
            unet_b1_ms = s.mean * 1e3;
        }
        record(&mut table, &format!("unet_eps b={b}"), &s);
        json = json.with(format!("unet_b{b}_ms").as_str(), s.mean * 1e3);
    }

    // CFG combine: device artifact vs host loop
    let u = rng.normal_vec(m.latent_elems());
    let c = rng.normal_vec(m.latent_elems());
    let s_dev = runner.run(|| stack.cfg_combine(1, &u, &c, 7.5).unwrap());
    record(&mut table, "cfg_combine (device)", &s_dev);
    json = json.with("cfg_combine_device_ms", s_dev.mean * 1e3);
    let s_host = runner.run(|| {
        let out: Vec<f32> = u.iter().zip(&c).map(|(&a, &b)| a + 7.5 * (b - a)).collect();
        std::hint::black_box(out)
    });
    record(&mut table, "cfg_combine (host)", &s_host);
    json = json.with("cfg_combine_host_ms", s_host.mean * 1e3);

    // text encode
    let tok = Tokenizer::new(m.vocab_size, m.seq_len);
    let ids = tok.encode("A person holding a cat");
    let s = runner.run(|| stack.encode_text(&ids).unwrap());
    record(&mut table, "text_encoder", &s);
    json = json.with("text_encoder_ms", s.mean * 1e3);

    // VAE decode
    let lat = rng.normal_vec(m.latent_elems());
    let s = runner.run(|| stack.decode(&lat).unwrap());
    record(&mut table, "vae_decoder", &s);
    json = json.with("vae_decoder_ms", s.mean * 1e3);

    // scheduler step (host math)
    let mut sched = SchedulerKind::Pndm.build(NoiseSchedule::default(), 50);
    let x = rng.normal_vec(m.latent_elems());
    let eps = rng.normal_vec(m.latent_elems());
    let mut step_rng = Rng::new(1);
    let s = runner.run(|| {
        sched.reset();
        std::hint::black_box(sched.step(0, &x, &eps, &mut step_rng))
    });
    record(&mut table, "scheduler step (pndm)", &s);
    json = json.with("scheduler_step_ms", s.mean * 1e3);

    // latent init
    let s = runner.run(|| {
        let mut r = Rng::new(7);
        std::hint::black_box(r.normal_vec(m.latent_elems()))
    });
    record(&mut table, "latent init (box-muller)", &s);
    json = json.with("latent_init_ms", s.mean * 1e3);

    // PNG encode
    let mut img = RgbImage::new(m.image_size, m.image_size);
    let mut r2 = Rng::new(9);
    for b in img.data.iter_mut() {
        *b = r2.next_below(256) as u8;
    }
    let s = runner.run(|| selective_guidance::image::encode_png(&img).unwrap());
    record(&mut table, "png encode", &s);
    json = json.with("png_encode_ms", s.mean * 1e3);

    println!("\nMicrobench — per-op cost on the serving hot path:\n");
    table.print();

    // the paper's premise: UNet dominates the per-step cost
    let step_dual = 2.0 * unet_b1_ms + s_dev.mean * 1e3;
    println!(
        "\nper-dual-step estimate: {step_dual:.2} ms, UNet share {:.0}% \
         (paper: 'the denoising Unet comprises the bulk of the computation')",
        100.0 * 2.0 * unet_b1_ms / step_dual
    );
    write_result_json("microbench", &json);
}
