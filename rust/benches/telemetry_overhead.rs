//! Telemetry overhead — the DESIGN.md §12 "observation-only" claim,
//! enforced in virtual time.
//!
//! Runs the saturated continuous-batching workload twice on the
//! deterministic synthetic backend — telemetry disabled, then enabled
//! with a manual [`Clock`] ticking 1 ms per cohort iteration — and
//! asserts that observation changes *nothing*:
//!
//! 1. **identical virtual-time throughput** — the same tick count to the
//!    same completion target (`throughput_ratio == 1.0`, gated to the
//!    acceptance band [0.98, 1.02] by `tools/bench_gate.rs`);
//! 2. **bit-identical outputs** — latents and eval counts match
//!    per sample between the two runs;
//! 3. **an exact ledger** — join/retire/iteration counters equal the
//!    driver's own counts, every retired sample's span is terminated,
//!    and span timestamps land exactly on the virtual tick that retired
//!    them (clock-abstraction, not wall-clock noise).
//!
//! Wall-clock overhead is reported for context but never gated — the
//! virtual-time ratio is the deterministic regression signal.
//!
//! Run: `cargo bench --bench telemetry_overhead` (`--fast` for CI smoke)

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::ContinuousBatcher;
use selective_guidance::engine::{Engine, GenerationOutput, GenerationRequest};
use selective_guidance::guidance::WindowSpec;
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::telemetry::{BatcherMetrics, Clock, Telemetry, TraceEvent, TraceId};

fn request(i: usize, steps: usize) -> GenerationRequest {
    GenerationRequest::new(prompts::TABLE2[i % prompts::TABLE2.len()])
        .steps(steps)
        .scheduler(SchedulerKind::Ddim)
        .selective(WindowSpec::last(0.5))
        .seed(i as u64)
        .decode(false)
}

struct RunOutcome {
    ticks: usize,
    joined: usize,
    /// (admission index, output), in retire order.
    retired: Vec<(usize, GenerationOutput)>,
    /// (trace id, 0-based retire tick) per retired sample, telemetry runs only.
    retire_ticks: Vec<(TraceId, usize)>,
    wall_ns: u64,
}

/// Drive one saturated run: admit whenever headroom exists, step until
/// `target` samples retired. With telemetry, every admission opens a
/// span (admitted/queued/cohort_join), every retirement closes it, and
/// the shared manual clock advances 1 ms per iteration.
fn run(
    engine: &Arc<Engine>,
    offered: usize,
    target: usize,
    steps: usize,
    budget: usize,
    telemetry: Option<&Arc<Telemetry>>,
) -> RunOutcome {
    let reqs: Vec<GenerationRequest> = (0..offered).map(|i| request(i, steps)).collect();
    let cb = ContinuousBatcher::new(Arc::clone(engine), budget).expect("batcher");
    let mut cb = match telemetry {
        Some(t) => cb.with_telemetry(BatcherMetrics::new(t, "bench")),
        None => cb,
    };
    let mut id2idx: BTreeMap<u64, usize> = BTreeMap::new();
    let mut id2trace: BTreeMap<u64, TraceId> = BTreeMap::new();
    let mut out = RunOutcome {
        ticks: 0,
        joined: 0,
        retired: Vec::new(),
        retire_ticks: Vec::new(),
        wall_ns: 0,
    };
    let mut next = 0usize;
    let t0 = Instant::now();
    while out.retired.len() < target {
        while next < offered {
            match cb.try_admit(&reqs[next]).expect("admit") {
                Some(id) => {
                    id2idx.insert(id, next);
                    out.joined += 1;
                    if let Some(t) = telemetry {
                        let trace = t.begin_trace();
                        t.event(trace, TraceEvent::Admitted { class: "standard" });
                        t.event(trace, TraceEvent::Queued { depth: id2idx.len() });
                        t.event(trace, TraceEvent::CohortJoin { cohort: id2idx.len() });
                        id2trace.insert(id, trace.expect("telemetry enabled"));
                    }
                    next += 1;
                }
                None => break,
            }
        }
        let outcome = cb.step().expect("step");
        assert!(outcome.slots_used <= budget, "slot budget violated");
        for (id, sample) in outcome.retired {
            if let Some(t) = telemetry {
                let trace = id2trace[&id];
                t.event(Some(trace), TraceEvent::Retired);
                out.retire_ticks.push((trace, out.ticks));
            }
            out.retired.push((id2idx[&id], sample));
        }
        if let Some(t) = telemetry {
            t.clock().advance_ms(1.0);
        }
        out.ticks += 1;
        assert!(out.ticks < 100_000, "run failed to reach target");
    }
    out.wall_ns = t0.elapsed().as_nanos() as u64;
    out
}

fn counter_value(t: &Arc<Telemetry>, name: &str, help: &str) -> u64 {
    let c = t.registry().counter(name, help, &[("scope", "bench")]);
    c.value()
}

fn main() {
    let args = BenchArgs::parse();
    let engine = Arc::new(Engine::new(
        Arc::new(ModelStack::synthetic()),
        EngineConfig::default(),
    ));
    let steps = if args.fast { 12 } else { 20 };
    let target = if args.fast { 24 } else { 40 };
    let offered = target * 2; // stay saturated past the measured window
    let budget = 8usize;

    let off = run(&engine, offered, target, steps, budget, None);
    let telemetry = Telemetry::with_clock(4096, Clock::manual());
    let on = run(&engine, offered, target, steps, budget, Some(&telemetry));

    // ---- claim 1: identical virtual-time throughput ---------------------
    assert_eq!(
        on.ticks, off.ticks,
        "telemetry must not change the virtual-time schedule"
    );
    let throughput_ratio = off.ticks as f64 / on.ticks as f64;

    // ---- claim 2: bit-identical outputs ---------------------------------
    assert_eq!(on.retired.len(), off.retired.len());
    for ((i_on, s_on), (i_off, s_off)) in on.retired.iter().zip(&off.retired) {
        assert_eq!(i_on, i_off, "retire order diverged under observation");
        assert_eq!(s_on.latent, s_off.latent, "sample {i_on}: latent diverged");
        assert_eq!(s_on.unet_evals, s_off.unet_evals, "sample {i_on}: evals diverged");
    }
    let bitexact_samples = on.retired.len();

    // ---- claim 3: exact ledger on the manual clock ----------------------
    let joins =
        counter_value(&telemetry, "sg_batcher_joins_total", "Samples admitted into cohorts");
    let retires =
        counter_value(&telemetry, "sg_batcher_retires_total", "Samples retired from cohorts");
    let iterations = counter_value(&telemetry, "sg_batcher_iterations_total", "Cohort iterations");
    assert_eq!(joins as usize, on.joined, "join counter out of sync with the driver");
    assert_eq!(retires as usize, on.retired.len(), "retire counter out of sync");
    assert_eq!(iterations as usize, on.ticks, "iteration counter out of sync");
    let terminated = telemetry
        .traces()
        .spans()
        .iter()
        .filter(|s| s.terminal_events() == 1)
        .count();
    assert_eq!(terminated, on.retired.len(), "every retired sample closes its span");
    for &(trace, tick) in &on.retire_ticks {
        let span = telemetry.traces().span(trace).expect("retired span present");
        let last = span.events.last().expect("span has events");
        assert_eq!(last.event.name(), "retired");
        assert_eq!(
            last.at_ns, tick as u64 * 1_000_000,
            "span timestamp must land exactly on its virtual retire tick"
        );
    }
    let render = telemetry.render_prometheus();
    assert!(render.contains(&format!("sg_batcher_joins_total{{scope=\"bench\"}} {joins}")));
    let ledger_exact = 1i64; // every assert above passed to get here

    // ---- report ---------------------------------------------------------
    let wall_ratio = on.wall_ns as f64 / off.wall_ns.max(1) as f64;
    let mut table = Table::new(&["telemetry", "ticks", "img/tick", "wall ms"]);
    table.row(&[
        "off".into(),
        format!("{}", off.ticks),
        format!("{:.4}", target as f64 / off.ticks as f64),
        format!("{:.2}", off.wall_ns as f64 / 1e6),
    ]);
    table.row(&[
        "on".into(),
        format!("{}", on.ticks),
        format!("{:.4}", target as f64 / on.ticks as f64),
        format!("{:.2}", on.wall_ns as f64 / 1e6),
    ]);
    println!(
        "\nTelemetry overhead — virtual time, slot budget {budget}, {steps} steps, \
         first {target} completions of {offered} offered:\n"
    );
    table.print();
    println!(
        "\n(identical {} ticks with and without observation — throughput ratio \
         {throughput_ratio:.3}; wall-clock ratio {wall_ratio:.3}, reported unguarded)",
        on.ticks
    );

    write_result_json(
        "telemetry_overhead",
        &Value::obj()
            .with("steps", steps as i64)
            .with("target", target as i64)
            .with("offered", offered as i64)
            .with("slot_budget", budget as i64)
            .with("ticks_off", off.ticks as i64)
            .with("ticks_on", on.ticks as i64)
            .with("throughput_ratio", throughput_ratio)
            .with("wall_ratio", wall_ratio)
            .with("joins", joins as i64)
            .with("retires", retires as i64)
            .with("bitexact_samples", bitexact_samples as i64),
    );
    // the regression-gate view: deterministic virtual-time metrics only
    // (never wall clock), compared against
    // ci/bench_baselines/BENCH_telemetry.json by tools/bench_gate.rs
    write_result_json(
        "BENCH_telemetry",
        &Value::obj()
            .with("throughput_ratio", throughput_ratio)
            .with("ledger_exact", ledger_exact)
            .with("bitexact_samples", bitexact_samples as i64),
    );
}
