//! Table 1: average time to generate an image vs optimized fraction.
//!
//! Paper protocol (§3.3): fixed prompt, 50 denoising iterations, 10
//! warmup generations, then the mean over 50 images with different
//! seeds, for optimization fractions {0, 20, 30, 40, 50}% of the last
//! iterations. Paper result (Tesla V100): 9.94s baseline and savings of
//! 8.2 / 12.1 / 16.2 / 20.3%.
//!
//! Our substrate is the CPU PJRT backend, so absolute times differ; the
//! reproduced quantity is the *saving* column and its agreement with the
//! analytic model saving ≈ f·u/2 (u = UNet share of image time).
//!
//! Run: `cargo bench --bench table1_timing` (add `--fast` for a smoke run)

use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::{DualStrategy, EngineConfig};
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::{CostModel, WindowSpec};
use selective_guidance::json::Value;
use selective_guidance::metrics::SampleStats;
use selective_guidance::runtime::ModelStack;

fn main() {
    let args = BenchArgs::parse();
    let (warmup, samples, steps) = if args.fast { (2, 6, 20) } else { (10, 50, 50) };
    eprintln!("[table1] loading {} ...", args.artifacts);
    let stack = Arc::new(ModelStack::load(&args.artifacts).expect("artifacts (run `make artifacts`)"));
    let engine = Engine::new(Arc::clone(&stack), EngineConfig::default());
    let mut fused_cfg = EngineConfig::default();
    fused_cfg.dual_strategy = DualStrategy::FusedB2;
    let engine_fused = Engine::new(stack, fused_cfg);

    let prompt = "A Hokusai painting of a happy dragon head with flowers growing out of the top";
    let fractions = [0.0, 0.2, 0.3, 0.4, 0.5];

    // paper protocol: warm up, then time `samples` images w/ varying seeds
    let run_one = |eng: &Engine, fraction: f64, seed: u64| -> (f64, f64) {
        let req = GenerationRequest::new(prompt)
            .steps(steps)
            .seed(seed)
            .decode(false)
            .selective(WindowSpec::last(fraction));
        let out = eng.generate(&req).expect("generate");
        (out.wall_ms, out.breakdown.unet_cond_ms + out.breakdown.unet_uncond_ms)
    };

    eprintln!("[table1] warmup x{warmup} ...");
    for w in 0..warmup {
        run_one(&engine, 0.0, w as u64);
        run_one(&engine_fused, 0.0, w as u64);
    }

    let mut means = Vec::new();
    let mut fused_means = Vec::new();
    let mut unet_share_acc = 0.0;
    for &f in &fractions {
        let mut wall = Vec::with_capacity(samples);
        let mut wall_fused = Vec::with_capacity(samples);
        for s in 0..samples {
            let (w, unet_ms) = run_one(&engine, f, 1000 + s as u64);
            wall.push(w);
            if f == 0.0 {
                unet_share_acc += unet_ms / w;
            }
            let (wf, _) = run_one(&engine_fused, f, 1000 + s as u64);
            wall_fused.push(wf);
        }
        let stats = SampleStats::from(&wall);
        eprintln!("[table1] f={f:.1}: mean {:.1} ms (std {:.1})", stats.mean, stats.std);
        means.push(stats);
        fused_means.push(SampleStats::from(&wall_fused));
    }
    let unet_share = unet_share_acc / samples as f64;

    // analytic model from the measured baseline decomposition
    let base_ms = means[0].mean;
    let model = CostModel {
        unet_eval_s: unet_share * base_ms / 1e3 / (2.0 * steps as f64),
        per_step_overhead_s: (1.0 - unet_share) * base_ms / 1e3 / steps as f64,
        fixed_s: 0.0,
    };

    let mut table = Table::new(&[
        "Iterations optimized",
        "Time(s)",
        "Saving",
        "Paper saving",
        "Model saving",
        "Fused-b2 saving",
    ]);
    let paper = [("No opt.", 0.0), ("20% of iters", 8.2), ("30% of iters", 12.1), ("40% of iters", 16.2), ("50% of iters", 20.3)];
    let fused_base = fused_means[0].mean;
    let mut rows_json = Vec::new();
    for (i, &f) in fractions.iter().enumerate() {
        let t = means[i].mean / 1e3;
        let saving = 100.0 * (base_ms - means[i].mean) / base_ms;
        let fused_saving = 100.0 * (fused_base - fused_means[i].mean) / fused_base;
        let policy = selective_guidance::guidance::SelectiveGuidancePolicy::new(
            WindowSpec::last(f),
            7.5,
        )
        .unwrap();
        let model_saving = 100.0 * model.predicted_saving(&policy, steps);
        table.row(&[
            paper[i].0.to_string(),
            format!("{t:.3}"),
            if i == 0 { "-".into() } else { format!("{saving:.1}%") },
            if i == 0 { "-".into() } else { format!("{:.1}%", paper[i].1) },
            if i == 0 { "-".into() } else { format!("{model_saving:.1}%") },
            if i == 0 { "-".into() } else { format!("{fused_saving:.1}%") },
        ]);
        rows_json.push(
            Value::obj()
                .with("fraction", f)
                .with("time_s", t)
                .with("saving_pct", saving)
                .with("paper_saving_pct", paper[i].1)
                .with("model_saving_pct", model_saving)
                .with("fused_time_s", fused_means[i].mean / 1e3)
                .with("fused_saving_pct", fused_saving),
        );
    }
    println!("\nTable 1 — mean image time, {steps} steps, {samples} samples (UNet share {:.0}%):\n", 100.0 * unet_share);
    table.print();
    println!(
        "\n'Saving' uses the paper-matching two-b1 engine (linear batching, as on a \
         compute-bound V100).\n'Fused-b2 saving' keeps the HF-style fused dual pass as the \
         baseline — CPU batch-2 is sublinear,\nso the achievable saving shrinks (ablation A \
         quantifies the per-step gap)."
    );

    write_result_json(
        "table1_timing",
        &Value::obj()
            .with("steps", steps)
            .with("samples", samples)
            .with("unet_share", unet_share)
            .with("rows", Value::Arr(rows_json)),
    );
}
