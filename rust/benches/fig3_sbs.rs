//! Figure 3 + Table 2: the side-by-side (SBS) study.
//!
//! Paper protocol (§3.2): 60 prompts (Table 2), per prompt a pair of
//! images — baseline and last-20%-optimized — judged by six raters.
//! Paper result: 68% "similar", 21% prefer baseline, 11% prefer
//! optimized.
//!
//! The human panel is simulated by [`SbsJudge`] (SSIM threshold + rater
//! jitter + sharpness preference — a documented substitution, DESIGN.md
//! §3). Reproduced quantity: the *shape* — a dominant "similar" mass and
//! a small, split preference remainder at 20% optimization.
//!
//! Run: `cargo bench --bench fig3_sbs`

use std::sync::Arc;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::config::EngineConfig;
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::guidance::WindowSpec;
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::quality::SbsJudge;
use selective_guidance::runtime::ModelStack;

fn main() {
    let args = BenchArgs::parse();
    let steps = if args.fast { 16 } else { 50 };
    let prompt_set: Vec<&str> = if args.fast {
        prompts::sbs_set().iter().take(10).copied().collect()
    } else {
        prompts::sbs_set().to_vec()
    };
    eprintln!("[fig3] loading {} ...", args.artifacts);
    let stack = Arc::new(ModelStack::load(&args.artifacts).expect("artifacts"));
    let engine = Engine::new(stack, EngineConfig::default());

    let seed = 9;
    let mut pairs = Vec::with_capacity(prompt_set.len());
    for (i, prompt) in prompt_set.iter().enumerate() {
        let base = engine
            .generate(&GenerationRequest::new(*prompt).steps(steps).seed(seed))
            .expect("baseline");
        let opt = engine
            .generate(
                &GenerationRequest::new(*prompt)
                    .steps(steps)
                    .seed(seed)
                    .selective(WindowSpec::last(0.2)),
            )
            .expect("optimized");
        pairs.push((base.image.unwrap(), opt.image.unwrap()));
        if (i + 1) % 10 == 0 {
            eprintln!("[fig3] generated {}/{} pairs", i + 1, prompt_set.len());
        }
    }

    let judge = SbsJudge::default();
    let tally = judge.run(&pairs);

    // distribution-level view: FID-lite between the two image sets
    let baselines: Vec<_> = pairs.iter().map(|(b, _)| b.clone()).collect();
    let optimized: Vec<_> = pairs.iter().map(|(_, o)| o.clone()).collect();
    let fid = selective_guidance::quality::fid_lite(&baselines, &optimized);
    // scale reference: FID-lite of the baseline set against itself with
    // fresh seeds (the sampling noise floor)
    let half = baselines.len() / 2;
    let fid_floor = if half >= 2 {
        selective_guidance::quality::fid_lite(&baselines[..half], &baselines[half..])
    } else {
        0.0
    };

    let mut table = Table::new(&["verdict", "ours", "paper"]);
    table.row(&["similar".into(), format!("{:.0}%", tally.pct_similar()), "68%".into()]);
    table.row(&[
        "prefer baseline".into(),
        format!("{:.0}%", tally.pct_baseline()),
        "21%".into(),
    ]);
    table.row(&[
        "prefer optimized".into(),
        format!("{:.0}%", tally.pct_optimized()),
        "11%".into(),
    ]);
    println!(
        "\nFigure 3 — SBS study: {} pairs x {} simulated raters, last 20% optimized, {steps} steps:\n",
        pairs.len(),
        judge.num_raters
    );
    table.print();

    println!(
        "\nFID-lite(baseline set, optimized set) = {fid:.5} \
         (sampling noise floor: {fid_floor:.5}) — a 20% window leaves the \
         image distribution within the set-to-set noise scale"
    );

    let shape_holds = tally.pct_similar() > 50.0
        && tally.pct_similar() > tally.pct_baseline() + tally.pct_optimized();
    println!(
        "\nshape check: similar dominates ({}): {}",
        format_args!("{:.0}%", tally.pct_similar()),
        if shape_holds { "PASS" } else { "DIVERGES from paper" }
    );

    write_result_json(
        "fig3_sbs",
        &Value::obj()
            .with("steps", steps)
            .with("pairs", pairs.len())
            .with("raters", judge.num_raters)
            .with("fid_lite", fid)
            .with("fid_lite_noise_floor", fid_floor)
            .with("pct_similar", tally.pct_similar())
            .with("pct_prefer_baseline", tally.pct_baseline())
            .with("pct_prefer_optimized", tally.pct_optimized())
            .with("paper_pct_similar", 68.0)
            .with("paper_pct_prefer_baseline", 21.0)
            .with("paper_pct_prefer_optimized", 11.0)
            .with("shape_holds", shape_holds),
    );
}
