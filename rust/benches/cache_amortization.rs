//! Fleet-wide guidance amortization (DESIGN.md §13) under a skewed
//! ("trending prompt") workload.
//!
//! A Zipf-distributed request mix — the standard model for prompt
//! popularity — is replayed twice per skew on the deterministic
//! synthetic backend: once against a cache-disabled coordinator and
//! once with the exact-match request cache + in-flight dedup on. The
//! gated claims, all counter-based and therefore deterministic (no
//! wall-clock in any gated metric):
//!
//! 1. **UNet-evals-per-request falls monotonically with skew** when the
//!    amortization tiers are on — the hotter the head of the prompt
//!    distribution, the less physical work per logical request;
//! 2. **≥ 25% eval reduction at skew 1.1** (the acceptance bar) versus
//!    the cache-off baseline on the identical request sequence;
//! 3. **bit-exactness** — every amortized delivery (hit, dedup join,
//!    or plain miss) is bitwise identical to the cache-off run's output
//!    for the same request index.
//!
//! Wall time is reported for context but never gated.
//!
//! Run: `cargo bench --bench cache_amortization` (`--fast` for CI smoke)

use std::sync::Arc;
use std::time::Instant;

use selective_guidance::benchutil::{write_result_json, BenchArgs, Table};
use selective_guidance::cache::{CacheConfig, CacheOutcome};
use selective_guidance::config::EngineConfig;
use selective_guidance::coordinator::{Coordinator, CoordinatorConfig};
use selective_guidance::engine::{Engine, GenerationOutput, GenerationRequest};
use selective_guidance::json::Value;
use selective_guidance::prompts;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::workload::ZipfPrompts;

const SKEWS: [f64; 4] = [0.4, 0.8, 1.1, 1.5];
const CATALOG: usize = 240;
const STEPS: usize = 8;
const RANK_SEED: u64 = 0xA3027;

/// The skew-`s` request sequence: prompt, seed and steps all derive
/// from the sampled popularity rank, so two draws of the same rank are
/// exact-key duplicates and distinct ranks never collide.
fn requests(skew: f64, n: usize) -> Vec<GenerationRequest> {
    let zipf = ZipfPrompts { skew, catalog: CATALOG };
    zipf.ranks(n, RANK_SEED)
        .into_iter()
        .map(|rank| {
            GenerationRequest::new(prompts::TABLE2[rank % prompts::TABLE2.len()])
                .steps(STEPS)
                .scheduler(SchedulerKind::Ddim)
                .seed(rank as u64)
                .decode(false)
        })
        .collect()
}

struct RunOutcome {
    /// Outputs in submission order (delivery per logical request).
    outputs: Vec<GenerationOutput>,
    /// UNet evals actually executed (hits and joins cost zero).
    physical_evals: u64,
    /// Requests served without physical work (hits + dedup joins).
    amortized: u64,
    wall_ns: u64,
}

/// Submit the whole sequence, then wait for every delivery — the
/// open-loop burst shape that gives in-flight dedup something to do.
/// One worker, singleton batches: physical work is strictly serialized,
/// so eval counts are a pure function of the key sequence.
fn run(engine: &Arc<Engine>, reqs: &[GenerationRequest], cache: CacheConfig) -> RunOutcome {
    let c = Coordinator::start(
        Arc::clone(engine),
        CoordinatorConfig { max_batch: 1, workers: 1, cache, ..CoordinatorConfig::default() },
    );
    let t0 = Instant::now();
    let tickets: Vec<_> = reqs
        .iter()
        .map(|r| c.submit(r.clone()).expect("submit"))
        .collect();
    let mut outcome = RunOutcome {
        outputs: Vec::with_capacity(tickets.len()),
        physical_evals: 0,
        amortized: 0,
        wall_ns: 0,
    };
    for t in tickets {
        let physical = matches!(t.cache_outcome(), None | Some(CacheOutcome::Miss));
        let out = t.wait().expect("delivery");
        if physical {
            outcome.physical_evals += out.unet_evals as u64;
        } else {
            outcome.amortized += 1;
        }
        outcome.outputs.push(out);
    }
    outcome.wall_ns = t0.elapsed().as_nanos() as u64;
    let stats = c.stats();
    assert_eq!(stats.failed, 0, "amortized replay must not fail requests");
    assert_eq!(stats.completed as usize, reqs.len(), "every logical request delivers");
    c.shutdown();
    outcome
}

fn main() {
    let args = BenchArgs::parse();
    let n = if args.fast { 60 } else { 120 };
    let engine = Arc::new(Engine::new(
        Arc::new(ModelStack::synthetic()),
        EngineConfig::default(),
    ));
    let amortized_cfg =
        CacheConfig { request_cache: true, dedup: true, ..CacheConfig::default() };

    let mut table = Table::new(&[
        "skew",
        "evals/req off",
        "evals/req on",
        "reduction",
        "hit rate",
        "wall ms on",
    ]);
    let mut evals_on = Vec::new();
    let mut reduction_at_s11 = 0.0;
    let mut hit_rate_s11 = 0.0;
    let mut bitexact = true;
    for &skew in &SKEWS {
        let reqs = requests(skew, n);
        let off = run(&engine, &reqs, CacheConfig::default());
        let on = run(&engine, &reqs, amortized_cfg.clone());
        assert_eq!(off.amortized, 0, "cache-off run cannot amortize");
        // bit-exactness: every delivery — replayed, coalesced, or
        // generated — matches the cache-off output for the same index
        for (i, (a, b)) in off.outputs.iter().zip(&on.outputs).enumerate() {
            let same = a.latent.len() == b.latent.len()
                && a.latent.iter().zip(&b.latent).all(|(x, y)| x.to_bits() == y.to_bits())
                && a.unet_evals == b.unet_evals
                && a.plan_summary == b.plan_summary;
            assert!(same, "skew {skew}: delivery {i} diverged from the cache-off run");
            bitexact &= same;
        }
        let per_req_off = off.physical_evals as f64 / n as f64;
        let per_req_on = on.physical_evals as f64 / n as f64;
        let reduction = 1.0 - per_req_on / per_req_off;
        let hit_rate = on.amortized as f64 / n as f64;
        if skew == 1.1 {
            reduction_at_s11 = reduction;
            hit_rate_s11 = hit_rate;
        }
        evals_on.push(per_req_on);
        table.row(&[
            format!("{skew:.1}"),
            format!("{per_req_off:.2}"),
            format!("{per_req_on:.2}"),
            format!("{:.1}%", reduction * 100.0),
            format!("{:.1}%", hit_rate * 100.0),
            format!("{:.2}", on.wall_ns as f64 / 1e6),
        ]);
    }
    let monotone = evals_on.windows(2).all(|w| w[1] <= w[0] + 1e-9);

    println!(
        "\nGuidance amortization — Zipf prompt mix, catalog {CATALOG}, {n} requests, \
         {STEPS} steps, request-cache + dedup vs cache-off:\n"
    );
    table.print();
    println!(
        "\n(skew 1.1: {:.1}% fewer UNet evals/request, {:.1}% of requests amortized; \
         evals/request monotone falling: {monotone})",
        reduction_at_s11 * 100.0,
        hit_rate_s11 * 100.0,
    );
    assert!(monotone, "evals/request must fall as the prompt mix concentrates");
    assert!(
        reduction_at_s11 >= 0.25,
        "skew 1.1 must amortize >= 25% of UNet work, got {:.1}%",
        reduction_at_s11 * 100.0
    );

    write_result_json(
        "cache_amortization",
        &Value::obj()
            .with("n", n as i64)
            .with("catalog", CATALOG as i64)
            .with("steps", STEPS as i64)
            .with("skews", SKEWS.to_vec())
            .with("evals_per_request", evals_on.clone())
            .with("reduction_at_s11", reduction_at_s11)
            .with("hit_rate_s11", hit_rate_s11),
    );
    // the regression-gate view: deterministic counter ratios only,
    // compared against ci/bench_baselines/BENCH_cache.json
    write_result_json(
        "BENCH_cache",
        &Value::obj()
            .with("reduction_at_s11", reduction_at_s11)
            .with("hit_rate_s11", hit_rate_s11)
            .with("monotone_evals", if monotone { 1i64 } else { 0i64 })
            .with("bitexact", if bitexact { 1i64 } else { 0i64 }),
    );
}
