//! Request coordinator: admission queue, dynamic batcher, worker pool.
//!
//! The serving topology mirrors a vLLM-style router scaled to this stack:
//!
//! ```text
//!   clients ──> submit() ──> [admission queue]
//!                                  │  batcher thread: group compatible
//!                                  │  requests (same steps+scheduler) up
//!                                  │  to max_batch within batch_wait
//!                                  ▼
//!                            [batch channel] ──> worker threads ──> Engine
//! ```
//!
//! Concurrency uses std threads + mpsc channels (tokio is absent from the
//! offline registry snapshot — DESIGN.md §5); the structure (admission /
//! batching / execution decoupled, graceful drain) is the same.

mod batcher;

pub use batcher::{compatible, BatchClass};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::engine::{Engine, GenerationOutput, GenerationRequest};
use crate::error::{Error, Result};
use crate::metrics::LatencyHistogram;

/// Coordinator tuning knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Maximum requests fused into one engine batch.
    pub max_batch: usize,
    /// Worker threads executing batches.
    pub workers: usize,
    /// How long the batcher waits to fill a batch before dispatching.
    pub batch_wait: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { max_batch: 4, workers: 1, batch_wait: Duration::from_millis(2) }
    }
}

/// Aggregate serving stats (snapshot via [`Coordinator::stats`]).
#[derive(Debug, Clone, Default)]
pub struct CoordinatorStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub latency_ms_mean: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p90: f64,
    pub latency_ms_max: f64,
}

struct StatsInner {
    latency: LatencyHistogram,
    batches: u64,
    batched_requests: u64,
    completed: u64,
    failed: u64,
}

struct Job {
    req: GenerationRequest,
    enqueued: Instant,
    respond: Sender<(Result<GenerationOutput>, Duration)>,
}

struct Batch {
    jobs: Vec<Job>,
}

/// Handle to one in-flight request.
pub struct Ticket {
    rx: Receiver<(Result<GenerationOutput>, Duration)>,
}

impl Ticket {
    /// Block until the result is ready.
    pub fn wait(self) -> Result<GenerationOutput> {
        Ok(self.wait_timed()?.0)
    }

    /// Block until the result is ready; also return the request's
    /// queue+service latency as measured at completion time (immune to
    /// late consumption by the caller).
    pub fn wait_timed(self) -> Result<(GenerationOutput, Duration)> {
        let (result, latency) = self
            .rx
            .recv()
            .map_err(|_| Error::Coordinator("worker dropped without responding".into()))?;
        Ok((result?, latency))
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<GenerationOutput>> {
        self.rx.try_recv().ok().map(|(r, _)| r)
    }
}

/// The running coordinator.
pub struct Coordinator {
    submit_tx: Mutex<Option<Sender<Job>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    stats: Arc<Mutex<StatsInner>>,
    submitted: Arc<AtomicU64>,
    draining: Arc<AtomicBool>,
}

impl Coordinator {
    /// Start the batcher + worker threads over an engine.
    pub fn start(engine: Arc<Engine>, config: CoordinatorConfig) -> Arc<Coordinator> {
        assert!(config.max_batch >= 1 && config.workers >= 1);
        let (submit_tx, submit_rx) = mpsc::channel::<Job>();
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let stats = Arc::new(Mutex::new(StatsInner {
            latency: LatencyHistogram::new(),
            batches: 0,
            batched_requests: 0,
            completed: 0,
            failed: 0,
        }));
        let mut handles = Vec::new();

        // ---- batcher thread ----------------------------------------------
        {
            let stats = Arc::clone(&stats);
            let max_batch = config.max_batch;
            let wait = config.batch_wait;
            handles.push(std::thread::spawn(move || {
                batcher_loop(submit_rx, batch_tx, max_batch, wait, stats);
            }));
        }

        // ---- worker threads ----------------------------------------------
        for worker_id in 0..config.workers {
            let engine = Arc::clone(&engine);
            let batch_rx = Arc::clone(&batch_rx);
            let stats = Arc::clone(&stats);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("sgd-worker-{worker_id}"))
                    .spawn(move || worker_loop(engine, batch_rx, stats))
                    .expect("spawn worker"),
            );
        }

        Arc::new(Coordinator {
            submit_tx: Mutex::new(Some(submit_tx)),
            handles: Mutex::new(handles),
            stats,
            submitted: Arc::new(AtomicU64::new(0)),
            draining: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Enqueue a request; returns a [`Ticket`] for the result.
    pub fn submit(&self, req: GenerationRequest) -> Result<Ticket> {
        req.validate()?;
        if self.draining.load(Ordering::SeqCst) {
            return Err(Error::Coordinator("coordinator is draining".into()));
        }
        let (tx, rx) = mpsc::channel();
        let job = Job { req, enqueued: Instant::now(), respond: tx };
        let guard = self.submit_tx.lock().unwrap();
        match guard.as_ref() {
            Some(sender) => sender
                .send(job)
                .map_err(|_| Error::Coordinator("queue closed".into()))?,
            None => return Err(Error::Coordinator("coordinator stopped".into())),
        }
        self.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(Ticket { rx })
    }

    /// Submit + wait.
    pub fn generate(&self, req: GenerationRequest) -> Result<GenerationOutput> {
        self.submit(req)?.wait()
    }

    /// Snapshot aggregate stats.
    pub fn stats(&self) -> CoordinatorStats {
        let inner = self.stats.lock().unwrap();
        CoordinatorStats {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: inner.completed,
            failed: inner.failed,
            batches: inner.batches,
            batched_requests: inner.batched_requests,
            latency_ms_mean: inner.latency.mean_ms(),
            latency_ms_p50: inner.latency.quantile_ms(0.5),
            latency_ms_p90: inner.latency.quantile_ms(0.9),
            latency_ms_max: inner.latency.max_ms(),
        }
    }

    /// Graceful drain: stop accepting, finish in-flight work, join threads.
    pub fn shutdown(&self) {
        self.draining.store(true, Ordering::SeqCst);
        // closing the submit channel ends the batcher, which ends workers
        *self.submit_tx.lock().unwrap() = None;
        let mut handles = self.handles.lock().unwrap();
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn batcher_loop(
    submit_rx: Receiver<Job>,
    batch_tx: Sender<Batch>,
    max_batch: usize,
    wait: Duration,
    stats: Arc<Mutex<StatsInner>>,
) {
    loop {
        // block for the first job
        let first = match submit_rx.recv() {
            Ok(j) => j,
            Err(_) => return, // queue closed -> drain done
        };
        let class = BatchClass::of(&first.req);
        let mut jobs = vec![first];
        let deadline = Instant::now() + wait;
        let mut deferred: Vec<Job> = Vec::new();
        while jobs.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match submit_rx.recv_timeout(deadline - now) {
                Ok(job) => {
                    if compatible(&class, &job.req) {
                        jobs.push(job);
                    } else {
                        // incompatible: defer to its own future batch
                        deferred.push(job);
                        // one deferred class at a time is enough; dispatch
                        // current batch promptly to avoid head-of-line
                        // blocking across classes
                        break;
                    }
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        {
            let mut s = stats.lock().unwrap();
            s.batches += 1;
            s.batched_requests += jobs.len() as u64;
        }
        if batch_tx.send(Batch { jobs }).is_err() {
            return;
        }
        // deferred jobs become the seed of the next batch
        for job in deferred {
            let mut s = stats.lock().unwrap();
            s.batches += 1;
            s.batched_requests += 1;
            drop(s);
            if batch_tx.send(Batch { jobs: vec![job] }).is_err() {
                return;
            }
        }
    }
}

fn worker_loop(
    engine: Arc<Engine>,
    batch_rx: Arc<Mutex<Receiver<Batch>>>,
    stats: Arc<Mutex<StatsInner>>,
) {
    loop {
        let batch = {
            let rx = batch_rx.lock().unwrap();
            match rx.recv() {
                Ok(b) => b,
                Err(_) => return, // channel closed -> shut down
            }
        };
        let reqs: Vec<GenerationRequest> = batch.jobs.iter().map(|j| j.req.clone()).collect();
        match engine.generate_batch(&reqs) {
            Ok(outputs) => {
                let mut s = stats.lock().unwrap();
                for (job, out) in batch.jobs.into_iter().zip(outputs) {
                    let latency = job.enqueued.elapsed();
                    s.latency.record(latency);
                    s.completed += 1;
                    let _ = job.respond.send((Ok(out), latency));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                let mut s = stats.lock().unwrap();
                for job in batch.jobs {
                    let latency = job.enqueued.elapsed();
                    s.failed += 1;
                    let _ = job
                        .respond
                        .send((Err(Error::Coordinator(msg.clone())), latency));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // Coordinator integration tests (with a real engine + artifacts) live
    // in rust/tests/; the batching-class logic is tested in batcher.rs.
}
