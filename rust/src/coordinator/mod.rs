//! Request coordinator: admission queue, dynamic batcher, worker pool.
//!
//! The serving topology mirrors a vLLM-style router scaled to this stack:
//!
//! ```text
//!   clients ──> submit() ──> [QosPolicy] ──> [admission queue]
//!                               │                  │  batcher thread: group
//!                               │ 429/503          │  compatible requests
//!                               ▼ rejection       │  (same steps+scheduler)
//!                             shed                 ▼  up to max_batch
//!                                            [batch channel] ──> workers ──> Engine
//!                                                  ▲                │
//!                                                  └── per-batch timing
//!                                                      (QoS feedback)
//! ```
//!
//! Concurrency uses std threads + mpsc channels (tokio is absent from the
//! offline registry snapshot — DESIGN.md §5); the structure (admission /
//! batching / execution decoupled, graceful drain) is the same.
//!
//! Two batch-composition modes ([`BatchMode`]): the diagram above shows
//! the classic **fixed** batcher; in **continuous** mode (DESIGN.md §9)
//! there is no batcher thread — each worker owns a [`ContinuousBatcher`]
//! cohort over the engine's step-resumable API and pulls the shared
//! admission queue at every iteration boundary, packing a UNet slot
//! budget, so the selective-guidance window's freed slots become
//! admission headroom instead of idle capacity.
//!
//! QoS (DESIGN.md §7) is pluggable: [`Coordinator::start_qos`] installs a
//! [`QosPolicy`] consulted *before* a request enters the queue — it may
//! shed (explicit [`Error::Rejected`]) or widen the request's
//! selective-guidance window — and workers feed per-batch service times
//! back to it. Jobs whose deadline expires while queued are failed with
//! [`Error::DeadlineExceeded`] instead of wasting UNet work.
//!
//! Since the replica-cluster layer (DESIGN.md §11) the coordinator is
//! also the **per-replica worker** of a [`crate::cluster::ReplicaSet`]:
//! admission can be decided upstream (cluster-level QoS over aggregate
//! load) and handed in via [`Coordinator::submit_preadmitted`], and
//! [`Coordinator::shutdown`] sheds queued-but-unadmitted jobs with an
//! explicit 503 ([`Error::Rejected`]) instead of executing them or
//! dropping their tickets — which is what lets the cluster requeue a
//! killed replica's backlog onto survivors without losing requests.

mod batcher;
mod continuous;

pub use batcher::{compatible, BatchClass};
pub use continuous::{ContinuousBatcher, StepOutcome};

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::cache::{canonical_key, CacheConfig, CacheOutcome, RequestCache, SharedUncondCache};
use crate::engine::{Engine, GenerationOutput, GenerationRequest};
use crate::error::{Error, Result};
use crate::guidance::{CostTable, PlanSearch, StepMode};
use crate::metrics::LatencyHistogram;
use crate::qos::{expired, AdmissionDecision, QosMeta, QosPolicy};
use crate::telemetry::{BatcherMetrics, CoordSink, Telemetry};

/// How the coordinator composes engine work (DESIGN.md §5 / §9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchMode {
    /// Classic dynamic batching: group compatible requests, run each
    /// batch's whole trajectory in lock-step.
    #[default]
    Fixed,
    /// Iteration-level (continuous) batching: admit into the in-flight
    /// cohort at step boundaries under a UNet slot budget, retire
    /// finished samples immediately.
    Continuous,
}

impl BatchMode {
    pub fn parse(s: &str) -> Result<BatchMode> {
        match s.to_ascii_lowercase().as_str() {
            "fixed" | "static" => Ok(BatchMode::Fixed),
            "continuous" | "iteration" | "iteration-level" => Ok(BatchMode::Continuous),
            other => Err(Error::Config(format!("unknown batch mode {other:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BatchMode::Fixed => "fixed",
            BatchMode::Continuous => "continuous",
        }
    }
}

/// Coordinator tuning knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Batch composition strategy.
    pub mode: BatchMode,
    /// Maximum requests fused into one engine batch (fixed mode).
    pub max_batch: usize,
    /// UNet slots packed per iteration (continuous mode; a dual step
    /// costs 2 slots, reuse/cond-only steps cost 1). Must be >= 2.
    pub slot_budget: usize,
    /// Worker threads executing batches (fixed mode) or cohorts
    /// (continuous mode).
    pub workers: usize,
    /// How long the fixed batcher waits to fill a batch before
    /// dispatching (unused in continuous mode — admission happens at
    /// every iteration boundary).
    pub batch_wait: Duration,
    /// Fleet-wide amortization tiers (DESIGN.md §13): exact-match
    /// request cache, in-flight dedup, shared uncond-eps cache. All off
    /// by default — misses and disabled runs are bit-exact with an
    /// uncached coordinator.
    pub cache: CacheConfig,
    /// Measured cost table (DESIGN.md §15): when set, continuous-mode
    /// admission can additionally be priced in calibrated milliseconds
    /// (`cost_budget_ms`), an installed QoS policy reads its measured
    /// shed ratio, and [`CoordinatorStats`] exposes the cost block.
    /// `None` keeps every decision in analytic units.
    pub cost_table: Option<Arc<CostTable>>,
    /// Millisecond admission budget per cohort iteration (continuous
    /// mode, requires `cost_table`; 0 = slots only).
    pub cost_budget_ms: f64,
    /// Compiled Pareto frontier (DESIGN.md §16): when set, an installed
    /// QoS policy degrades along the tuned frontier in O(1) at admission
    /// instead of widening analytically, and [`CoordinatorStats`]
    /// exposes the planner counter block. `None` keeps the legacy
    /// actuator.
    pub planner: Option<Arc<PlanSearch>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            mode: BatchMode::Fixed,
            max_batch: 4,
            slot_budget: 8,
            workers: 1,
            batch_wait: Duration::from_millis(2),
            cache: CacheConfig::default(),
            cost_table: None,
            cost_budget_ms: 0.0,
            planner: None,
        }
    }
}

/// Aggregate serving stats (snapshot via [`Coordinator::stats`]).
///
/// The outstanding-request gauges (`queue_depth`, `queue_depth_max`) are
/// mode-independent: they track the shared submission counter, so in
/// continuous mode they cover the admission queue *and* the in-flight
/// cohorts, not just the fixed batcher's pending vec. `batches` /
/// `batched_requests` are fixed-mode counters; the `iterations` / `joins`
/// / `retires` / cohort / slot gauges are their continuous-mode
/// counterparts (zero in the other mode).
#[derive(Debug, Clone, Default)]
pub struct CoordinatorStats {
    /// Batch composition strategy the coordinator runs.
    pub mode: BatchMode,
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    /// Shed at admission by the QoS policy (never entered the queue).
    pub rejected: u64,
    /// Expired in the queue past their deadline (never executed).
    pub deadline_missed: u64,
    /// Shed with an explicit 503 during shutdown drain: admitted into the
    /// queue but never executed (the cluster layer requeues these onto
    /// surviving replicas).
    pub drain_shed: u64,
    /// Cancelled by the client — queued or mid-flight — via a
    /// [`CancelHandle`]; mid-flight cancels return their reserved slots
    /// to admission headroom at the next iteration boundary.
    pub cancelled: u64,
    /// Served straight from the exact-match request cache at admission
    /// (no queue residency, no UNet work; counted in `completed` too).
    pub cache_hits: u64,
    /// Logical requests coalesced onto another in-flight identical
    /// request (each still delivers — and is counted — individually).
    pub dedup_coalesced: u64,
    /// Fixed mode: engine batches dispatched.
    pub batches: u64,
    /// Fixed mode: requests carried by those batches.
    pub batched_requests: u64,
    /// Continuous mode: configured UNet slot budget (0 in fixed mode).
    pub slot_budget: u64,
    /// Continuous mode: cohort iterations executed.
    pub iterations: u64,
    /// Continuous mode: requests admitted into a cohort.
    pub joins: u64,
    /// Continuous mode: samples retired from a cohort.
    pub retires: u64,
    /// Continuous mode: largest cohort observed.
    pub cohort_max: u64,
    /// Continuous mode: cohort size of the most recent iteration.
    pub cohort_last: u64,
    /// Continuous mode: mean fraction of the slot budget used per
    /// iteration (0 before the first iteration / in fixed mode).
    pub slot_utilization: f64,
    /// Outstanding requests right now (queued + executing).
    pub queue_depth: u64,
    /// High-water mark of `queue_depth` since start.
    pub queue_depth_max: u64,
    /// Last selective-guidance window fraction applied by the actuator
    /// (0 when no QoS policy is installed).
    pub actuator_fraction: f64,
    /// Millisecond admission budget per cohort iteration (0 when the
    /// measured-cost tier is off or admission is slots-only).
    pub cost_budget_ms: f64,
    /// Uncovered cost-table lookups priced by the analytic fallback
    /// since start (0 when no table is attached). Nonzero on a
    /// calibrated grid means the table and the runtime disagree.
    pub cost_fallbacks: u64,
    /// Measured-over-analytic price ratio of a batch-1 dual step
    /// ([`CostTable::model_ratio`]; 0 when no table is attached).
    pub cost_model_ratio: f64,
    /// Measured shed ratio of the attached table
    /// ([`CostTable::shed_ratio`]; 0 when no table is attached — the
    /// analytic value is 0.5).
    pub cost_shed_ratio: f64,
    /// Is a compiled frontier attached (DESIGN.md §16)?
    pub planner_attached: bool,
    /// Frontier lookups performed at admission (exactly one per eligible
    /// admission — the O(1)-search ledger).
    pub planner_searches: u64,
    /// Lookups that landed on a frontier point.
    pub planner_frontier_hits: u64,
    /// Lookups that missed every bucket and fell back to the analytic
    /// actuator.
    pub planner_fallbacks: u64,
    /// Demanded savings clamped up at the quality floor's frontier point.
    pub planner_floor_clamps: u64,
    pub latency_ms_mean: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p90: f64,
    pub latency_ms_max: f64,
}

struct StatsInner {
    latency: LatencyHistogram,
    batches: u64,
    batched_requests: u64,
    completed: u64,
    failed: u64,
    deadline_missed: u64,
    drain_shed: u64,
    cancelled: u64,
    // continuous-mode counters
    iterations: u64,
    joins: u64,
    retires: u64,
    slots_used_sum: u64,
    cohort_max: u64,
    cohort_last: u64,
}

/// Streaming options for [`Submit::submit_watched`].
#[derive(Debug, Clone, Copy, Default)]
pub struct WatchOptions {
    /// Decode a preview image into every `preview_every`-th progress
    /// event (0 = progress events only, no intermediate decodes).
    pub preview_every: usize,
}

impl WatchOptions {
    /// Progress events only — no preview decodes.
    pub fn off() -> WatchOptions {
        WatchOptions { preview_every: 0 }
    }
}

/// Client-side cancel switch for one watched submission. Cheap to clone;
/// flipping it aborts the sample at the next iteration boundary (queued:
/// before any UNet work; mid-flight: the cohort drops it and its
/// reserved slots return to admission headroom). The ticket then
/// resolves with [`Error::Cancelled`].
#[derive(Debug, Clone, Default)]
pub struct CancelHandle(Arc<AtomicBool>);

impl CancelHandle {
    pub fn new() -> CancelHandle {
        CancelHandle(Arc::new(AtomicBool::new(false)))
    }

    /// Request cancellation (idempotent).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }

    /// The raw flag the worker loops poll — also what the cluster relay
    /// threads across replica requeues so one handle survives failover.
    pub(crate) fn flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.0)
    }

    /// Rebuild a handle around an existing flag (cluster requeue path).
    pub(crate) fn from_flag(flag: Arc<AtomicBool>) -> CancelHandle {
        CancelHandle(flag)
    }
}

/// One streamed lifecycle event of a watched sample, emitted at the
/// iteration boundary after each engine step the sample rode.
#[derive(Debug, Clone)]
pub struct ProgressEvent {
    /// Iterations completed so far (strictly increasing per sample).
    pub step: usize,
    /// Total iterations this sample executes
    /// ([`GenerationRequest::executed_steps`]).
    pub steps: usize,
    /// Decoded intermediate image — present on every `preview_every`-th
    /// event when previews were requested.
    pub preview: Option<crate::image::RgbImage>,
}

/// A watched submission: the result ticket plus the progress
/// side-channel and the cancel switch.
pub struct Watched {
    pub ticket: Ticket,
    /// Progress/preview events; closes when the sample resolves. Safe to
    /// drop — events are fire-and-forget on the worker side.
    pub progress: Receiver<ProgressEvent>,
    pub cancel: CancelHandle,
}

/// Worker-side half of the progress channel, carried by the job.
#[derive(Clone)]
pub(crate) struct WatchSink {
    pub(crate) tx: Sender<ProgressEvent>,
    pub(crate) preview_every: usize,
}

struct Job {
    req: GenerationRequest,
    meta: QosMeta,
    enqueued: Instant,
    respond: Sender<(Result<GenerationOutput>, Duration)>,
    /// Canonical cache key (Some only when the cache layer is on and
    /// this job is the *primary* of its key): the terminal site that
    /// resolves this job must settle the key — store the output, drop
    /// the in-flight marker, fan out to coalesced waiters.
    key: Option<String>,
    /// Progress event sink for watched submissions (continuous mode
    /// emits per-iteration events; fixed mode runs trajectories
    /// atomically and emits none).
    watch: Option<WatchSink>,
    /// Cancel flag for watched submissions, polled at every admission
    /// and iteration boundary.
    cancel: Option<Arc<AtomicBool>>,
}

impl Job {
    fn cancel_requested(&self) -> bool {
        self.cancel
            .as_ref()
            .is_some_and(|c| c.load(Ordering::SeqCst))
    }
}

struct Batch {
    jobs: Vec<Job>,
}

/// One logical request coalesced onto an identical in-flight primary.
/// Carries its own deadline accounting and trace span: delivery charges
/// each waiter individually and closes each span exactly once.
struct Waiter {
    trace: Option<u64>,
    meta: QosMeta,
    enqueued: Instant,
    respond: Sender<(Result<GenerationOutput>, Duration)>,
}

/// The coordinator's amortization tiers (DESIGN.md §13), interposed at
/// admission — *after* QoS (every logical request is charged) and
/// *before* queueing (hits and joins never occupy queue space).
struct CacheLayer {
    /// Exact-match replay of finished outputs (bit-exact, bounded LRU).
    request: Option<RequestCache>,
    /// Cross-request uncond-eps tier threaded into continuous cohorts.
    shared: Option<Arc<SharedUncondCache>>,
    /// Coalesce identical concurrent requests into one generation.
    dedup: bool,
    /// Keys with a primary generation in flight → their coalesced
    /// waiters. Present-but-empty means "primary running, no joiners".
    inflight: Mutex<HashMap<String, Vec<Waiter>>>,
    hits: AtomicU64,
    coalesced: AtomicU64,
}

impl CacheLayer {
    fn new(cfg: &CacheConfig) -> CacheLayer {
        CacheLayer {
            request: cfg
                .request_cache
                .then(|| RequestCache::new(cfg.request_capacity)),
            shared: cfg
                .shared_uncond
                .then(|| Arc::new(SharedUncondCache::new(cfg.shared_tolerance))),
            dedup: cfg.dedup,
            inflight: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
        }
    }

    /// Whether admission needs a canonical key at all.
    fn keyed(&self) -> bool {
        self.request.is_some() || self.dedup
    }
}

/// Settle a resolved primary's cache key: store an `Ok` output into the
/// request cache **before** removing the in-flight marker (so a
/// concurrent identical submit always finds one of the two — no
/// miss-hole), then fan the result out to every coalesced waiter with
/// per-waiter deadline accounting. Every terminal site for a [`Job`]
/// with `key: Some(_)` must come through here exactly once.
#[allow(clippy::too_many_arguments)]
fn settle_key(
    cache: &Option<Arc<CacheLayer>>,
    key: &Option<String>,
    outcome: std::result::Result<&GenerationOutput, &Error>,
    stats: &Arc<Mutex<StatsInner>>,
    pending: &Arc<AtomicU64>,
    qos: &Option<Arc<dyn QosPolicy>>,
    sink: &Option<Arc<CoordSink>>,
) {
    let (Some(cache), Some(key)) = (cache, key) else {
        return;
    };
    if let (Ok(out), Some(rc)) = (outcome, &cache.request) {
        rc.insert(key, out);
    }
    let waiters = cache
        .inflight
        .lock()
        .unwrap()
        .remove(key)
        .unwrap_or_default();
    let now = Instant::now();
    for w in waiters {
        let waited = now.saturating_duration_since(w.enqueued);
        let prev = pending.fetch_sub(1, Ordering::Relaxed);
        if expired(&w.meta, w.enqueued, now) {
            // the generation outlived this waiter's deadline: its result
            // is useless to *this* client even though the physical work
            // completed — charge the miss to the waiter, not the primary
            stats.lock().unwrap().deadline_missed += 1;
            if let Some(q) = qos {
                q.observe_deadline_miss();
            }
            if let Some(s) = sink {
                s.on_expired(w.trace);
                s.on_queue_depth(prev.saturating_sub(1) as usize);
            }
            let msg = format!(
                "coalesced generation finished after this waiter's deadline \
                 ({:.0} ms waited, deadline {:.0} ms)",
                waited.as_secs_f64() * 1e3,
                w.meta.deadline_ms().unwrap_or(0.0)
            );
            let _ = w.respond.send((Err(Error::DeadlineExceeded(msg)), waited));
            continue;
        }
        match outcome {
            Ok(out) => {
                {
                    let mut s = stats.lock().unwrap();
                    s.completed += 1;
                    s.latency.record(waited);
                }
                if let Some(s) = sink {
                    s.on_retired(w.trace, &out.plan_summary, waited.as_secs_f64() * 1e3);
                    s.on_queue_depth(prev.saturating_sub(1) as usize);
                }
                let _ = w.respond.send((Ok(out.clone()), waited));
            }
            Err(e) => {
                stats.lock().unwrap().failed += 1;
                if let Some(s) = sink {
                    s.on_shed(w.trace, "coalesced_failure");
                    s.on_queue_depth(prev.saturating_sub(1) as usize);
                }
                let _ = w.respond.send((
                    Err(Error::Coordinator(format!("coalesced generation failed: {e}"))),
                    waited,
                ));
            }
        }
    }
}

/// Handle to one in-flight request.
pub struct Ticket {
    rx: Receiver<(Result<GenerationOutput>, Duration)>,
    trace: Option<u64>,
    /// How the cache layer disposed of this request (`None` until known
    /// — and forever, when the cache layer is off). A shared write-once
    /// cell because the cluster path only learns the outcome when the
    /// dispatch thread reaches a replica, after the ticket was returned.
    outcome: Arc<OnceLock<CacheOutcome>>,
}

impl Ticket {
    /// Build a ticket over a raw response channel — the cluster layer
    /// interposes its own channel so it can requeue a failed replica's
    /// jobs before the client sees anything.
    pub(crate) fn from_rx(
        rx: Receiver<(Result<GenerationOutput>, Duration)>,
        trace: Option<u64>,
    ) -> Ticket {
        Ticket { rx, trace, outcome: Arc::new(OnceLock::new()) }
    }

    /// The write-once cache-outcome slot — cloned by the server (to read
    /// after `wait` consumes the ticket) and by the cluster dispatcher
    /// (to copy the replica-side outcome across the relay).
    pub(crate) fn outcome_cell(&self) -> Arc<OnceLock<CacheOutcome>> {
        Arc::clone(&self.outcome)
    }

    /// How the cache layer disposed of this request: `Hit` (replayed
    /// from the exact-match cache), `Dedup` (coalesced onto an identical
    /// in-flight generation), `Miss` (generated, cache layer on), or
    /// `None` (cache layer off, or — cluster path — not yet dispatched).
    pub fn cache_outcome(&self) -> Option<CacheOutcome> {
        self.outcome.get().copied()
    }

    /// Trace span id assigned at admission (None when telemetry is off) —
    /// the correlation key for `{"op":"trace"}` queries and the replay
    /// drivers' conservation accounting (DESIGN.md §12).
    pub fn trace(&self) -> Option<u64> {
        self.trace
    }

    /// Block until the result is ready.
    pub fn wait(self) -> Result<GenerationOutput> {
        Ok(self.wait_timed()?.0)
    }

    /// Block until the result is ready; also return the request's
    /// queue+service latency as measured at completion time (immune to
    /// late consumption by the caller).
    pub fn wait_timed(self) -> Result<(GenerationOutput, Duration)> {
        let (result, latency) = self
            .rx
            .recv()
            .map_err(|_| Error::Coordinator("worker dropped without responding".into()))?;
        Ok((result?, latency))
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<GenerationOutput>> {
        self.rx.try_recv().ok().map(|(r, _)| r)
    }

    /// Non-blocking poll that preserves the completion-time latency and
    /// resolves worker death as an error — the cluster relay's primitive
    /// (the public [`Ticket::try_wait`] drops both). Returns `Some` at
    /// most once per ticket outcome; callers must stop polling after.
    pub(crate) fn try_wait_timed(&self) -> Option<(Result<GenerationOutput>, Duration)> {
        match self.rx.try_recv() {
            Ok(v) => Some(v),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some((
                Err(Error::Coordinator("worker dropped without responding".into())),
                Duration::ZERO,
            )),
        }
    }
}

/// The running coordinator.
pub struct Coordinator {
    submit_tx: Mutex<Option<Sender<Job>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    stats: Arc<Mutex<StatsInner>>,
    submitted: Arc<AtomicU64>,
    rejected: Arc<AtomicU64>,
    /// Outstanding requests (queued + executing).
    pending: Arc<AtomicU64>,
    queue_depth_max: Arc<AtomicU64>,
    qos: Option<Arc<dyn QosPolicy>>,
    draining: Arc<AtomicBool>,
    mode: BatchMode,
    slot_budget: usize,
    /// Telemetry sink (DESIGN.md §12); None when observation is off.
    sink: Option<Arc<CoordSink>>,
    /// Amortization tiers (DESIGN.md §13); None when every tier is off.
    cache: Option<Arc<CacheLayer>>,
    /// Measured cost table (DESIGN.md §15); None prices in analytic units.
    cost_table: Option<Arc<CostTable>>,
    cost_budget_ms: f64,
    /// Compiled Pareto frontier (DESIGN.md §16); None keeps the legacy
    /// analytic actuator.
    planner: Option<Arc<PlanSearch>>,
}

impl Coordinator {
    /// Start the batcher + worker threads over an engine (no QoS policy:
    /// the queue is unbounded and requests are served as submitted).
    pub fn start(engine: Arc<Engine>, config: CoordinatorConfig) -> Arc<Coordinator> {
        Self::start_inner(engine, config, None, None)
    }

    /// Start with a pluggable [`QosPolicy`] ahead of the batcher.
    pub fn start_qos(
        engine: Arc<Engine>,
        config: CoordinatorConfig,
        qos: Arc<dyn QosPolicy>,
    ) -> Arc<Coordinator> {
        Self::start_inner(engine, config, Some(qos), None)
    }

    /// The superset entry point: optional QoS *and* optional telemetry
    /// sink (DESIGN.md §12). When a sink is given, the engine and the
    /// policy are wired into the same registry, every request lifecycle
    /// event lands on the sink, and continuous workers report slot
    /// occupancy through a [`BatcherMetrics`] sharing the sink's scope.
    pub fn start_full(
        engine: Arc<Engine>,
        config: CoordinatorConfig,
        qos: Option<Arc<dyn QosPolicy>>,
        sink: Option<CoordSink>,
    ) -> Arc<Coordinator> {
        Self::start_inner(engine, config, qos, sink)
    }

    fn start_inner(
        engine: Arc<Engine>,
        config: CoordinatorConfig,
        qos: Option<Arc<dyn QosPolicy>>,
        mut sink: Option<CoordSink>,
    ) -> Arc<Coordinator> {
        assert!(config.max_batch >= 1 && config.workers >= 1);
        if config.mode == BatchMode::Continuous {
            assert!(
                config.slot_budget >= 2,
                "continuous mode needs slot_budget >= 2 (a dual step costs 2 slots)"
            );
        }
        config
            .cache
            .validate()
            .expect("cache config validated at coordinator start");
        if config.cost_budget_ms > 0.0 {
            let table = config
                .cost_table
                .as_ref()
                .expect("cost_budget_ms requires a cost table (validated at the config layer)");
            assert!(
                config.cost_budget_ms.is_finite()
                    && config.cost_budget_ms >= table.sample_step_ms(StepMode::Dual),
                "cost_budget_ms must be finite and cover one dual-guidance sample \
                 (validated at the config layer)"
            );
        }
        if let (Some(q), Some(t)) = (&qos, &config.cost_table) {
            // the QoS deadline math prices its shed estimate with the
            // measured ratio instead of the analytic 0.5
            q.attach_cost_table(Arc::clone(t));
        }
        if let (Some(q), Some(p)) = (&qos, &config.planner) {
            // admission rewrites degrade along the compiled frontier
            // instead of widening analytically (DESIGN.md §16)
            q.attach_planner(Arc::clone(p));
        }
        let cache = config.cache.enabled().then(|| Arc::new(CacheLayer::new(&config.cache)));
        if let (Some(s), Some(t)) = (&mut sink, &config.cost_table) {
            // retired plans price their steps into sg_step_cost_ms, and
            // the table's fallback counter reaches /metrics
            s.attach_cost(Arc::clone(t));
        }
        if let (Some(s), Some(p)) = (&mut sink, &config.planner) {
            // the frontier search counters reach /metrics
            s.attach_planner(Arc::clone(p));
        }
        let sink = sink.map(Arc::new);
        if let Some(s) = &sink {
            // one registry for every layer this coordinator drives
            engine.attach_telemetry(s.telemetry());
            if let Some(q) = &qos {
                q.attach_telemetry(s.telemetry());
            }
        }
        let (submit_tx, submit_rx) = mpsc::channel::<Job>();
        let stats = Arc::new(Mutex::new(StatsInner {
            latency: LatencyHistogram::new(),
            batches: 0,
            batched_requests: 0,
            completed: 0,
            failed: 0,
            deadline_missed: 0,
            drain_shed: 0,
            cancelled: 0,
            iterations: 0,
            joins: 0,
            retires: 0,
            slots_used_sum: 0,
            cohort_max: 0,
            cohort_last: 0,
        }));
        let pending = Arc::new(AtomicU64::new(0));
        let draining = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();

        match config.mode {
            BatchMode::Fixed => {
                let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
                let batch_rx = Arc::new(Mutex::new(batch_rx));

                // ---- batcher thread --------------------------------------
                {
                    let stats = Arc::clone(&stats);
                    let pending = Arc::clone(&pending);
                    let draining = Arc::clone(&draining);
                    let max_batch = config.max_batch;
                    let wait = config.batch_wait;
                    let qos = qos.clone();
                    let sink = sink.clone();
                    let cache = cache.clone();
                    handles.push(std::thread::spawn(move || {
                        batcher_loop(
                            submit_rx, batch_tx, max_batch, wait, stats, pending, draining, qos,
                            sink, cache,
                        );
                    }));
                }

                // ---- worker threads --------------------------------------
                for worker_id in 0..config.workers {
                    let engine = Arc::clone(&engine);
                    let batch_rx = Arc::clone(&batch_rx);
                    let stats = Arc::clone(&stats);
                    let pending = Arc::clone(&pending);
                    let draining = Arc::clone(&draining);
                    let qos = qos.clone();
                    let sink = sink.clone();
                    let cache = cache.clone();
                    handles.push(
                        std::thread::Builder::new()
                            .name(format!("sgd-worker-{worker_id}"))
                            .spawn(move || {
                                worker_loop(
                                    engine, batch_rx, stats, pending, draining, qos, sink, cache,
                                )
                            })
                            .expect("spawn worker"),
                    );
                }
            }
            BatchMode::Continuous => {
                // no separate batcher thread: each worker owns a cohort
                // and pulls the shared admission queue at every iteration
                // boundary. The shared backlog holds jobs that fit no
                // cohort *right now* — shared (not per-worker) so a job
                // popped by a full worker is immediately visible to a
                // sibling with headroom instead of pinned behind one
                // cohort's drain.
                let submit_rx = Arc::new(Mutex::new(submit_rx));
                let backlog = Arc::new(Mutex::new(std::collections::VecDeque::new()));
                let batcher_tm = sink
                    .as_ref()
                    .map(|s| BatcherMetrics::new(s.telemetry(), s.scope()));
                for worker_id in 0..config.workers {
                    let engine = Arc::clone(&engine);
                    let submit_rx = Arc::clone(&submit_rx);
                    let backlog = Arc::clone(&backlog);
                    let stats = Arc::clone(&stats);
                    let pending = Arc::clone(&pending);
                    let draining = Arc::clone(&draining);
                    let qos = qos.clone();
                    let sink = sink.clone();
                    let cache = cache.clone();
                    let batcher_tm = batcher_tm.clone();
                    let budget = config.slot_budget;
                    let cost = (config.cost_budget_ms > 0.0)
                        .then(|| config.cost_table.clone())
                        .flatten()
                        .map(|t| (config.cost_budget_ms, t));
                    handles.push(
                        std::thread::Builder::new()
                            .name(format!("sgd-cont-{worker_id}"))
                            .spawn(move || {
                                continuous_worker_loop(
                                    engine, submit_rx, backlog, budget, cost, stats, pending,
                                    draining, qos, sink, cache, batcher_tm, worker_id,
                                )
                            })
                            .expect("spawn continuous worker"),
                    );
                }
            }
        }

        Arc::new(Coordinator {
            submit_tx: Mutex::new(Some(submit_tx)),
            handles: Mutex::new(handles),
            stats,
            submitted: Arc::new(AtomicU64::new(0)),
            rejected: Arc::new(AtomicU64::new(0)),
            pending,
            queue_depth_max: Arc::new(AtomicU64::new(0)),
            qos,
            draining,
            mode: config.mode,
            slot_budget: config.slot_budget,
            sink,
            cache,
            cost_table: config.cost_table,
            cost_budget_ms: config.cost_budget_ms,
            planner: config.planner,
        })
    }

    /// The measured cost table this coordinator prices with, if any.
    pub fn cost_table(&self) -> Option<&Arc<CostTable>> {
        self.cost_table.as_ref()
    }

    /// The compiled frontier this coordinator's admission searches, if
    /// any (DESIGN.md §16).
    pub fn planner(&self) -> Option<&Arc<PlanSearch>> {
        self.planner.as_ref()
    }

    /// The shared uncond-eps cache this coordinator's cohorts publish
    /// into, when the tier is on — the cluster layer reads it for
    /// replica-affinity bookkeeping and tests for its hit counters.
    pub fn shared_cache(&self) -> Option<&Arc<SharedUncondCache>> {
        self.cache.as_ref().and_then(|c| c.shared.as_ref())
    }

    /// Exact-match request-cache counters (zeros when the tier is off).
    pub fn request_cache_stats(&self) -> crate::cache::RequestCacheStats {
        self.cache
            .as_ref()
            .and_then(|c| c.request.as_ref())
            .map(|rc| rc.stats())
            .unwrap_or_default()
    }

    /// The telemetry hub this coordinator reports into, when observed.
    /// The server front-end serves `{"op":"metrics"}` / `{"op":"trace"}`
    /// from here.
    pub fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        self.sink.as_ref().map(|s| s.telemetry())
    }

    /// Enqueue a request; returns a [`Ticket`] for the result.
    pub fn submit(&self, req: GenerationRequest) -> Result<Ticket> {
        self.submit_qos(req, QosMeta::default())
    }

    /// Enqueue with serving metadata (deadline, priority). When a QoS
    /// policy is installed it decides admission here — a rejection is
    /// returned synchronously as [`Error::Rejected`] and the request
    /// never occupies queue space.
    pub fn submit_qos(&self, req: GenerationRequest, meta: QosMeta) -> Result<Ticket> {
        self.submit_inner(req, meta, true, None)
    }

    /// Enqueue a *watched* request: alongside the ticket the caller gets
    /// a per-iteration progress stream (with optional decoded previews
    /// every `watch.preview_every` steps, continuous mode) and a
    /// [`CancelHandle`] that aborts the sample at the next boundary.
    /// Watched submissions bypass the request-cache / dedup tiers — a
    /// cancellable primary must never carry coalesced waiters, and a
    /// replayed hit has no steps to stream.
    pub fn submit_watched(
        &self,
        req: GenerationRequest,
        meta: QosMeta,
        watch: WatchOptions,
    ) -> Result<Watched> {
        let (ptx, progress) = mpsc::channel();
        let cancel = CancelHandle::new();
        let sink = WatchSink { tx: ptx, preview_every: watch.preview_every };
        let ticket =
            self.submit_inner(req, meta, true, Some((sink, cancel.flag())))?;
        Ok(Watched { ticket, progress, cancel })
    }

    /// Enqueue a request whose admission was already decided upstream —
    /// the replica-cluster path: the [`crate::cluster::ReplicaSet`] runs
    /// the (cluster-level) QoS admission against *aggregate* load before
    /// routing, so the per-replica policy must not be consulted a second
    /// time. Everything else (drain refusal, depth gauges, queueing) is
    /// identical to [`Coordinator::submit_qos`]; any installed policy
    /// still receives worker-side feedback.
    pub fn submit_preadmitted(&self, req: GenerationRequest, meta: QosMeta) -> Result<Ticket> {
        self.submit_inner(req, meta, false, None)
    }

    /// The watched preadmitted path: the cluster relay owns the progress
    /// sender and cancel flag (they must survive a replica failover and
    /// requeue — one client-facing handle, N replica attempts), so it
    /// hands both in rather than receiving fresh ones.
    pub(crate) fn submit_preadmitted_watched(
        &self,
        req: GenerationRequest,
        meta: QosMeta,
        watch: Option<(WatchSink, Arc<AtomicBool>)>,
    ) -> Result<Ticket> {
        self.submit_inner(req, meta, false, watch)
    }

    fn submit_inner(
        &self,
        mut req: GenerationRequest,
        mut meta: QosMeta,
        consult_qos: bool,
        watch: Option<(WatchSink, Arc<AtomicBool>)>,
    ) -> Result<Ticket> {
        req.validate()?;
        if self.draining.load(Ordering::SeqCst) {
            return Err(Error::Coordinator("coordinator is draining".into()));
        }
        // Open the trace span before admission so a rejection is still a
        // complete (terminated) span. A cluster front door already began
        // one — meta.trace survives the hop, so the replica appends to
        // the same span instead of forking a new one.
        if let Some(sink) = &self.sink {
            sink.on_submitted();
            if meta.trace.is_none() {
                meta.trace = sink.begin_trace();
            }
        }
        // Reserve the outstanding slot *before* admission so the depth
        // bound is exact under concurrent submitters: each one sees the
        // others' reservations, so max_queue_depth can never be
        // overshot. The reservation also precedes worker visibility, so
        // a fast worker can never decrement `pending` below zero.
        let depth_before = self.pending.fetch_add(1, Ordering::Relaxed) as usize;
        if let Some(qos) = self.qos.as_ref().filter(|_| consult_qos) {
            match qos.admit(&mut req, &mut meta, depth_before) {
                AdmissionDecision::Admit => {}
                AdmissionDecision::Reject(reason) => {
                    self.pending.fetch_sub(1, Ordering::Relaxed);
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                    if let Some(sink) = &self.sink {
                        sink.on_rejected(meta.trace, reason.code(), &reason.message());
                    }
                    return Err(Error::Rejected {
                        code: reason.code(),
                        reason: reason.message(),
                    });
                }
            }
        }
        self.queue_depth_max
            .fetch_max(depth_before as u64 + 1, Ordering::Relaxed);
        if let Some(sink) = &self.sink {
            sink.on_admitted(meta.trace, meta.priority.name(), depth_before + 1);
        }
        let (tx, rx) = mpsc::channel();
        let trace = meta.trace;
        // ---- amortization tiers (DESIGN.md §13), after QoS so every
        // logical request is charged, before queueing so hits and joins
        // never occupy queue space. Watched jobs skip them: a replayed
        // hit has nothing to stream, and a cancellable primary would
        // poison its coalesced waiters -------------------------------
        let mut key = None;
        let outcome_cell = Arc::new(OnceLock::new());
        if let Some(cache) = self
            .cache
            .as_ref()
            .filter(|c| c.keyed() && watch.is_none())
        {
            let admitted_at = Instant::now();
            let k = match canonical_key(&req) {
                Ok(k) => k,
                Err(e) => {
                    self.pending.fetch_sub(1, Ordering::Relaxed);
                    if let Some(sink) = &self.sink {
                        sink.on_shed(trace, "invalid");
                    }
                    return Err(e);
                }
            };
            // exact-match replay: bit-exact output, span closes here
            if let Some(out) = cache.request.as_ref().and_then(|rc| rc.get(&k)) {
                cache.hits.fetch_add(1, Ordering::Relaxed);
                self.submitted.fetch_add(1, Ordering::Relaxed);
                let latency = admitted_at.elapsed();
                {
                    let mut s = self.stats.lock().unwrap();
                    s.completed += 1;
                    s.latency.record(latency);
                }
                let prev = self.pending.fetch_sub(1, Ordering::Relaxed);
                if let Some(sink) = &self.sink {
                    sink.on_cache_hit(trace);
                    sink.on_retired(trace, &out.plan_summary, latency.as_secs_f64() * 1e3);
                    sink.on_queue_depth(prev.saturating_sub(1) as usize);
                }
                let _ = outcome_cell.set(CacheOutcome::Hit);
                let _ = tx.send((Ok(out), latency));
                return Ok(Ticket { rx, trace, outcome: outcome_cell });
            }
            if cache.dedup {
                let mut inflight = cache.inflight.lock().unwrap();
                if let Some(waiters) = inflight.get_mut(&k) {
                    // identical generation already in flight: coalesce.
                    // The span stays open (DedupJoin is non-terminal)
                    // until the primary's terminal site fans out.
                    waiters.push(Waiter {
                        trace,
                        meta,
                        enqueued: admitted_at,
                        respond: tx,
                    });
                    drop(inflight);
                    cache.coalesced.fetch_add(1, Ordering::Relaxed);
                    self.submitted.fetch_add(1, Ordering::Relaxed);
                    if let Some(sink) = &self.sink {
                        sink.on_dedup_join(trace);
                    }
                    let _ = outcome_cell.set(CacheOutcome::Dedup);
                    return Ok(Ticket { rx, trace, outcome: outcome_cell });
                }
                inflight.insert(k.clone(), Vec::new());
            }
            key = Some(k);
            let _ = outcome_cell.set(CacheOutcome::Miss);
        }
        let (watch_sink, cancel_flag) = match watch {
            Some((w, c)) => (Some(w), Some(c)),
            None => (None, None),
        };
        let job = Job {
            req,
            meta,
            enqueued: Instant::now(),
            respond: tx,
            key: key.clone(),
            watch: watch_sink,
            cancel: cancel_flag,
        };
        let send_result = {
            let guard = self.submit_tx.lock().unwrap();
            match guard.as_ref() {
                Some(sender) => sender
                    .send(job)
                    .map_err(|_| Error::Coordinator("queue closed".into())),
                None => Err(Error::Coordinator("coordinator stopped".into())),
            }
        };
        if let Err(e) = send_result {
            self.pending.fetch_sub(1, Ordering::Relaxed);
            if let Some(sink) = &self.sink {
                // the span was admitted above — close it so conservation
                // holds even on the shutdown race
                sink.on_shed(trace, "queue_closed");
            }
            // drop the just-inserted in-flight marker (a racing joiner
            // may already be parked on it)
            settle_key(
                &self.cache, &key, Err(&e), &self.stats, &self.pending, &self.qos, &self.sink,
            );
            return Err(e);
        }
        self.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(Ticket { rx, trace, outcome: outcome_cell })
    }

    /// Submit + wait.
    pub fn generate(&self, req: GenerationRequest) -> Result<GenerationOutput> {
        self.submit(req)?.wait()
    }

    /// Snapshot aggregate stats.
    pub fn stats(&self) -> CoordinatorStats {
        let inner = self.stats.lock().unwrap();
        let actuator_fraction = self
            .qos
            .as_ref()
            .map(|q| q.qos_snapshot().actuator_fraction)
            .unwrap_or(0.0);
        let slot_utilization = if inner.iterations > 0 && self.slot_budget > 0 {
            inner.slots_used_sum as f64 / (inner.iterations as f64 * self.slot_budget as f64)
        } else {
            0.0
        };
        let planner = self
            .planner
            .as_ref()
            .map(|p| p.snapshot())
            .unwrap_or_default();
        CoordinatorStats {
            mode: self.mode,
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: inner.completed,
            failed: inner.failed,
            rejected: self.rejected.load(Ordering::Relaxed),
            deadline_missed: inner.deadline_missed,
            drain_shed: inner.drain_shed,
            cancelled: inner.cancelled,
            cache_hits: self
                .cache
                .as_ref()
                .map(|c| c.hits.load(Ordering::Relaxed))
                .unwrap_or(0),
            dedup_coalesced: self
                .cache
                .as_ref()
                .map(|c| c.coalesced.load(Ordering::Relaxed))
                .unwrap_or(0),
            batches: inner.batches,
            batched_requests: inner.batched_requests,
            slot_budget: if self.mode == BatchMode::Continuous {
                self.slot_budget as u64
            } else {
                0
            },
            iterations: inner.iterations,
            joins: inner.joins,
            retires: inner.retires,
            cohort_max: inner.cohort_max,
            cohort_last: inner.cohort_last,
            slot_utilization,
            queue_depth: self.pending.load(Ordering::Relaxed),
            queue_depth_max: self.queue_depth_max.load(Ordering::Relaxed),
            actuator_fraction,
            cost_budget_ms: self.cost_budget_ms,
            cost_fallbacks: self
                .cost_table
                .as_ref()
                .map(|t| t.fallback_count())
                .unwrap_or(0),
            cost_model_ratio: self
                .cost_table
                .as_ref()
                .map(|t| t.model_ratio())
                .unwrap_or(0.0),
            cost_shed_ratio: self
                .cost_table
                .as_ref()
                .map(|t| t.shed_ratio())
                .unwrap_or(0.0),
            planner_attached: self.planner.is_some(),
            planner_searches: planner.searches,
            planner_frontier_hits: planner.frontier_hits,
            planner_fallbacks: planner.fallbacks,
            planner_floor_clamps: planner.floor_clamps,
            latency_ms_mean: inner.latency.mean_ms(),
            latency_ms_p50: inner.latency.quantile_ms(0.5),
            latency_ms_p90: inner.latency.quantile_ms(0.9),
            latency_ms_max: inner.latency.max_ms(),
        }
    }

    /// Graceful drain: stop accepting, finish *executing* work, join
    /// threads. Jobs that were admitted into the queue but not yet
    /// handed to the engine are failed with an explicit 503
    /// ([`Error::Rejected`]) instead of silently executed or dropped —
    /// every outstanding [`Ticket`] resolves, and the cluster layer can
    /// requeue the shed jobs onto surviving replicas.
    pub fn shutdown(&self) {
        self.draining.store(true, Ordering::SeqCst);
        // closing the submit channel ends the batcher, which ends workers
        *self.submit_tx.lock().unwrap() = None;
        let mut handles = self.handles.lock().unwrap();
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Anything requests can be submitted to — a single [`Coordinator`] or a
/// [`crate::cluster::ReplicaSet`]. The workload replay drivers, the
/// server front-end, and the cluster relay are generic over this, so
/// every serving surface works unchanged against both topologies.
///
/// The *core* operation is [`Submit::submit_watched`] — a submission
/// with a progress/preview side-channel and a cancel switch. The bare
/// [`Submit::submit_qos`] / [`Submit::submit`] forms are blocking-style
/// adapters that drop the side-channel; implementations with a cheaper
/// unwatched path (cache tiers, dedup) override them.
pub trait Submit: Send + Sync {
    /// Enqueue with serving metadata plus a streaming side-channel;
    /// admission (QoS) semantics are the implementation's — see
    /// [`Coordinator::submit_watched`] and
    /// [`crate::cluster::ReplicaSet::submit_watched`].
    fn submit_watched(
        &self,
        req: GenerationRequest,
        meta: QosMeta,
        watch: WatchOptions,
    ) -> Result<Watched>;

    /// Enqueue with serving metadata, no side-channel. The default
    /// adapter discards the progress stream and cancel handle.
    fn submit_qos(&self, req: GenerationRequest, meta: QosMeta) -> Result<Ticket> {
        Ok(self.submit_watched(req, meta, WatchOptions::off())?.ticket)
    }

    /// Enqueue without metadata (best-effort, default priority).
    fn submit(&self, req: GenerationRequest) -> Result<Ticket> {
        self.submit_qos(req, QosMeta::default())
    }
}

impl Submit for Coordinator {
    fn submit_watched(
        &self,
        req: GenerationRequest,
        meta: QosMeta,
        watch: WatchOptions,
    ) -> Result<Watched> {
        Coordinator::submit_watched(self, req, meta, watch)
    }

    // the unwatched path keeps the request-cache / dedup tiers (the
    // default adapter would bypass them)
    fn submit_qos(&self, req: GenerationRequest, meta: QosMeta) -> Result<Ticket> {
        Coordinator::submit_qos(self, req, meta)
    }
}

impl<T: Submit + ?Sized> Submit for Arc<T> {
    fn submit_watched(
        &self,
        req: GenerationRequest,
        meta: QosMeta,
        watch: WatchOptions,
    ) -> Result<Watched> {
        (**self).submit_watched(req, meta, watch)
    }

    fn submit_qos(&self, req: GenerationRequest, meta: QosMeta) -> Result<Ticket> {
        (**self).submit_qos(req, meta)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Fail one queued-but-unadmitted job during shutdown drain with an
/// explicit 503 — never execute it, never drop its ticket unresolved.
/// A shed primary settles its cache key too: coalesced waiters resolve
/// (as coalesced failures) instead of stranding on a dead marker.
#[allow(clippy::too_many_arguments)]
fn shed_draining(
    job: Job,
    stats: &Arc<Mutex<StatsInner>>,
    pending: &Arc<AtomicU64>,
    qos: &Option<Arc<dyn QosPolicy>>,
    sink: &Option<Arc<CoordSink>>,
    cache: &Option<Arc<CacheLayer>>,
) {
    let waited = job.enqueued.elapsed();
    stats.lock().unwrap().drain_shed += 1;
    let prev = pending.fetch_sub(1, Ordering::Relaxed);
    if let Some(s) = sink {
        s.on_shed(job.meta.trace, "drain");
        s.on_queue_depth(prev.saturating_sub(1) as usize);
    }
    let err = Error::Rejected {
        code: 503,
        reason: "coordinator shutting down — queued request shed before execution".into(),
    };
    settle_key(cache, &job.key, Err(&err), stats, pending, qos, sink);
    let _ = job.respond.send((Err(err), waited));
}

#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    submit_rx: Receiver<Job>,
    batch_tx: Sender<Batch>,
    max_batch: usize,
    wait: Duration,
    stats: Arc<Mutex<StatsInner>>,
    pending: Arc<AtomicU64>,
    draining: Arc<AtomicBool>,
    qos: Option<Arc<dyn QosPolicy>>,
    sink: Option<Arc<CoordSink>>,
    cache: Option<Arc<CacheLayer>>,
) {
    loop {
        // block for the first job
        let first = match submit_rx.recv() {
            Ok(j) => j,
            Err(_) => return, // queue closed -> drain done
        };
        if draining.load(Ordering::SeqCst) {
            // shutdown: everything still queued is shed, not batched
            shed_draining(first, &stats, &pending, &qos, &sink, &cache);
            continue;
        }
        let class = BatchClass::of(&first.req);
        let mut jobs = vec![first];
        let deadline = Instant::now() + wait;
        let mut deferred: Vec<Job> = Vec::new();
        while jobs.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match submit_rx.recv_timeout(deadline - now) {
                Ok(job) => {
                    if compatible(&class, &job.req) {
                        jobs.push(job);
                    } else {
                        // incompatible: defer to its own future batch
                        deferred.push(job);
                        // one deferred class at a time is enough; dispatch
                        // current batch promptly to avoid head-of-line
                        // blocking across classes
                        break;
                    }
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        {
            let mut s = stats.lock().unwrap();
            s.batches += 1;
            s.batched_requests += jobs.len() as u64;
        }
        if batch_tx.send(Batch { jobs }).is_err() {
            return;
        }
        // deferred jobs become the seed of the next batch
        for job in deferred {
            let mut s = stats.lock().unwrap();
            s.batches += 1;
            s.batched_requests += 1;
            drop(s);
            if batch_tx.send(Batch { jobs: vec![job] }).is_err() {
                return;
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    engine: Arc<Engine>,
    batch_rx: Arc<Mutex<Receiver<Batch>>>,
    stats: Arc<Mutex<StatsInner>>,
    pending: Arc<AtomicU64>,
    draining: Arc<AtomicBool>,
    qos: Option<Arc<dyn QosPolicy>>,
    sink: Option<Arc<CoordSink>>,
    cache: Option<Arc<CacheLayer>>,
) {
    loop {
        let batch = {
            let rx = batch_rx.lock().unwrap();
            match rx.recv() {
                Ok(b) => b,
                Err(_) => return, // channel closed -> shut down
            }
        };
        // ---- shutdown drain: a dispatched-but-not-executing batch is
        // still queued work — shed it explicitly instead of paying for
        // UNet output nobody is waiting on
        if draining.load(Ordering::SeqCst) {
            for job in batch.jobs {
                shed_draining(job, &stats, &pending, &qos, &sink, &cache);
            }
            continue;
        }
        // ---- deadline expiry: fail stale jobs before paying for UNet
        // work that cannot possibly be useful anymore
        let now = Instant::now();
        let (live, stale): (Vec<Job>, Vec<Job>) = batch
            .jobs
            .into_iter()
            .partition(|j| !expired(&j.meta, j.enqueued, now));
        for job in stale {
            fail_expired(job, &stats, &pending, &qos, &sink, &cache);
        }
        // client-side cancellation before dispatch: fixed-mode
        // trajectories are atomic, so pre-dispatch is the last boundary
        // where a cancel can still save the UNet work
        let (live, cancelled): (Vec<Job>, Vec<Job>) =
            live.into_iter().partition(|j| !j.cancel_requested());
        for job in cancelled {
            fail_cancelled(job, &stats, &pending, &qos, &sink, &cache);
        }
        if live.is_empty() {
            continue;
        }
        let reqs: Vec<GenerationRequest> = live.iter().map(|j| j.req.clone()).collect();
        let t_service = Instant::now();
        let result = engine.generate_batch(&reqs);
        let service = t_service.elapsed();
        match result {
            Ok(outputs) => {
                // feed the QoS loop *before* responding so admission
                // sees fresh estimates as early as possible; the mean
                // *executed* single-pass fraction lets the policy
                // normalize the sample back to a full-CFG baseline
                // (adaptive samples' plans are only known after
                // execution, so request-side fractions would lie).
                // Failed batches feed nothing — their timing is not a
                // service sample.
                if let Some(q) = &qos {
                    let mean_fraction = outputs.iter().map(|o| o.executed_shed()).sum::<f64>()
                        / outputs.len() as f64;
                    q.observe_batch(outputs.len(), service, mean_fraction);
                }
                for (job, out) in live.into_iter().zip(outputs) {
                    let latency = job.enqueued.elapsed();
                    {
                        let mut s = stats.lock().unwrap();
                        s.latency.record(latency);
                        s.completed += 1;
                    }
                    let prev = pending.fetch_sub(1, Ordering::Relaxed);
                    if let Some(sk) = &sink {
                        sk.on_retired(
                            job.meta.trace,
                            &out.plan_summary,
                            latency.as_secs_f64() * 1e3,
                        );
                        sk.on_queue_depth(prev.saturating_sub(1) as usize);
                    }
                    settle_key(&cache, &job.key, Ok(&out), &stats, &pending, &qos, &sink);
                    let _ = job.respond.send((Ok(out), latency));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for job in live {
                    let latency = job.enqueued.elapsed();
                    stats.lock().unwrap().failed += 1;
                    let prev = pending.fetch_sub(1, Ordering::Relaxed);
                    if let Some(sk) = &sink {
                        sk.on_shed(job.meta.trace, "engine_failure");
                        sk.on_queue_depth(prev.saturating_sub(1) as usize);
                    }
                    let err = Error::Coordinator(msg.clone());
                    settle_key(&cache, &job.key, Err(&err), &stats, &pending, &qos, &sink);
                    let _ = job.respond.send((Err(err), latency));
                }
            }
        }
    }
}

/// Resolve one cancelled job: the client abandoned it, so it never runs
/// (queued) or stops riding the cohort (mid-flight — the caller already
/// dropped it from the batcher, returning its reserved slots to
/// admission headroom). The ticket resolves with [`Error::Cancelled`]
/// and the trace span closes with the `cancelled` terminal exactly once.
fn fail_cancelled(
    job: Job,
    stats: &Arc<Mutex<StatsInner>>,
    pending: &Arc<AtomicU64>,
    qos: &Option<Arc<dyn QosPolicy>>,
    sink: &Option<Arc<CoordSink>>,
    cache: &Option<Arc<CacheLayer>>,
) {
    let waited = job.enqueued.elapsed();
    stats.lock().unwrap().cancelled += 1;
    let prev = pending.fetch_sub(1, Ordering::Relaxed);
    if let Some(s) = sink {
        s.on_cancelled(job.meta.trace);
        s.on_queue_depth(prev.saturating_sub(1) as usize);
    }
    let err = Error::Cancelled("cancelled by client".into());
    // watched jobs carry no cache key, but settle defensively anyway —
    // the invariant is "every terminal site settles"
    settle_key(cache, &job.key, Err(&err), stats, pending, qos, sink);
    let _ = job.respond.send((Err(err), waited));
}

/// Fail one queued job whose deadline expired before admission (the
/// continuous-mode mirror of the fixed worker's stale partition). An
/// expired *primary* settles its cache key so coalesced waiters resolve
/// instead of stranding — their generation is never going to run.
fn fail_expired(
    job: Job,
    stats: &Arc<Mutex<StatsInner>>,
    pending: &Arc<AtomicU64>,
    qos: &Option<Arc<dyn QosPolicy>>,
    sink: &Option<Arc<CoordSink>>,
    cache: &Option<Arc<CacheLayer>>,
) {
    let waited = job.enqueued.elapsed();
    stats.lock().unwrap().deadline_missed += 1;
    if let Some(q) = qos {
        q.observe_deadline_miss();
    }
    let prev = pending.fetch_sub(1, Ordering::Relaxed);
    if let Some(s) = sink {
        s.on_expired(job.meta.trace);
        s.on_queue_depth(prev.saturating_sub(1) as usize);
    }
    let msg = format!(
        "expired in queue after {:.0} ms (deadline {:.0} ms)",
        waited.as_secs_f64() * 1e3,
        job.meta.deadline_ms().unwrap_or(0.0)
    );
    let err = Error::DeadlineExceeded(msg);
    settle_key(cache, &job.key, Err(&err), stats, pending, qos, sink);
    let _ = job.respond.send((Err(err), waited));
}

/// Continuous-mode worker: owns one [`ContinuousBatcher`] cohort, admits
/// from the shared queue at every iteration boundary, retires finished
/// samples immediately, and feeds the QoS loop both per-sample service
/// shares and the per-iteration slot occupancy.
///
/// `backlog` is shared across workers: a job that fits no cohort right
/// now goes there (front, preserving FIFO) where any sibling with
/// headroom can claim it at its next boundary — never pinned behind one
/// worker's drain. The receiver mutex is only ever held for non-blocking
/// `try_recv` calls, so an idle worker cannot stall a sibling's
/// per-iteration admission.
#[allow(clippy::too_many_arguments)]
fn continuous_worker_loop(
    engine: Arc<Engine>,
    submit_rx: Arc<Mutex<Receiver<Job>>>,
    backlog: Arc<Mutex<std::collections::VecDeque<Job>>>,
    slot_budget: usize,
    cost: Option<(f64, Arc<CostTable>)>,
    stats: Arc<Mutex<StatsInner>>,
    pending: Arc<AtomicU64>,
    draining: Arc<AtomicBool>,
    qos: Option<Arc<dyn QosPolicy>>,
    sink: Option<Arc<CoordSink>>,
    cache: Option<Arc<CacheLayer>>,
    batcher_tm: Option<BatcherMetrics>,
    worker_id: usize,
) {
    // the shared uncond tier rides the continuous cohort: every worker's
    // batcher publishes into / consumes from the same replica-scoped cache
    let shared = cache.as_ref().and_then(|c| c.shared.clone());
    let fresh_batcher = |tm: &Option<BatcherMetrics>| {
        let mut b = ContinuousBatcher::new(Arc::clone(&engine), slot_budget)
            .expect("slot budget validated at coordinator start");
        if let Some(tm) = tm {
            b = b.with_telemetry(tm.clone());
        }
        if let Some(sc) = &shared {
            b = b.with_shared_cache(Arc::clone(sc));
        }
        if let Some((budget_ms, table)) = &cost {
            b = b
                .with_ms_budget(*budget_ms, Arc::clone(table))
                .expect("cost budget validated at coordinator start");
        }
        b
    };
    let mut batcher = fresh_batcher(&batcher_tm);
    // respond channels of the in-flight samples, keyed by cohort id
    let mut inflight: BTreeMap<u64, Job> = BTreeMap::new();
    loop {
        // ---- admission at the iteration boundary -------------------------
        loop {
            let job = if let Some(j) = backlog.lock().unwrap().pop_front() {
                j
            } else {
                match submit_rx.lock().unwrap().try_recv() {
                    Ok(j) => j,
                    Err(TryRecvError::Empty) => {
                        if batcher.in_flight() == 0 {
                            // idle: nap *outside* the lock, then re-check
                            // (a sibling may also push work to the backlog)
                            std::thread::sleep(Duration::from_millis(1));
                            continue;
                        }
                        break; // run the cohort we have
                    }
                    Err(TryRecvError::Disconnected) => {
                        if batcher.in_flight() == 0 {
                            // queue closed and nothing executing: shed
                            // whatever the shared backlog still holds
                            // (each ticket must resolve — a dropped
                            // backlog would strand its waiters), then
                            // drain. pop_front keeps this safe when
                            // several workers sweep concurrently.
                            while let Some(j) = backlog.lock().unwrap().pop_front() {
                                shed_draining(j, &stats, &pending, &qos, &sink, &cache);
                            }
                            return;
                        }
                        break;
                    }
                }
            };
            // shutdown drain: queued-but-unadmitted jobs are shed with an
            // explicit 503 — the in-flight cohort still runs to completion
            if draining.load(Ordering::SeqCst) {
                shed_draining(job, &stats, &pending, &qos, &sink, &cache);
                continue;
            }
            // deadline expiry before paying for any UNet work
            if expired(&job.meta, job.enqueued, Instant::now()) {
                fail_expired(job, &stats, &pending, &qos, &sink, &cache);
                continue;
            }
            // cancelled while queued: resolve without any UNet work
            if job.cancel_requested() {
                fail_cancelled(job, &stats, &pending, &qos, &sink, &cache);
                continue;
            }
            match batcher.try_admit(&job.req) {
                Ok(Some(id)) => {
                    stats.lock().unwrap().joins += 1;
                    if let Some(sk) = &sink {
                        sk.on_cohort_join(job.meta.trace, worker_id);
                    }
                    inflight.insert(id, job);
                }
                Ok(None) => {
                    // no slot headroom here: park it where any worker
                    // (including this one, once the window frees slots)
                    // can admit it at the next boundary
                    backlog.lock().unwrap().push_front(job);
                    break;
                }
                Err(e) => {
                    let waited = job.enqueued.elapsed();
                    stats.lock().unwrap().failed += 1;
                    let prev = pending.fetch_sub(1, Ordering::Relaxed);
                    if let Some(sk) = &sink {
                        sk.on_shed(job.meta.trace, "invalid");
                        sk.on_queue_depth(prev.saturating_sub(1) as usize);
                    }
                    settle_key(&cache, &job.key, Err(&e), &stats, &pending, &qos, &sink);
                    let _ = job.respond.send((Err(e), waited));
                }
            }
        }
        // ---- mid-flight cancellation at the iteration boundary -----------
        // mirror of the per-sample failure drain: the sample leaves the
        // cohort without finish(), its reserved slots return to admission
        // headroom immediately, and the rest of the cohort is untouched
        let cancel_ids: Vec<u64> = inflight
            .iter()
            .filter(|(_, j)| j.cancel_requested())
            .map(|(&id, _)| id)
            .collect();
        for id in cancel_ids {
            if batcher.cancel(id) {
                let job = inflight.remove(&id).expect("cancelled id has a job");
                fail_cancelled(job, &stats, &pending, &qos, &sink, &cache);
            }
        }
        if batcher.in_flight() == 0 {
            continue; // everything expired/failed/cancelled; back to waiting
        }

        // ---- one engine iteration over the cohort ------------------------
        match batcher.step() {
            Ok(outcome) => {
                if let Some(q) = &qos {
                    q.observe_slots(outcome.slots_used, slot_budget);
                }
                {
                    let mut s = stats.lock().unwrap();
                    s.iterations += 1;
                    s.slots_used_sum += outcome.slots_used as u64;
                    s.cohort_last = outcome.cohort as u64;
                    s.cohort_max = s.cohort_max.max(outcome.cohort as u64);
                }
                // typed per-sample engine failures (cold shared-reuse
                // cache): only the offending sample fails — the cohort,
                // the batcher, and every other in-flight job live on
                for (id, err) in outcome.failed {
                    let job = inflight.remove(&id).expect("failed id has a job");
                    let latency = job.enqueued.elapsed();
                    stats.lock().unwrap().failed += 1;
                    let prev = pending.fetch_sub(1, Ordering::Relaxed);
                    if let Some(sk) = &sink {
                        sk.on_shed(job.meta.trace, "engine_failure");
                        sk.on_queue_depth(prev.saturating_sub(1) as usize);
                    }
                    settle_key(&cache, &job.key, Err(&err), &stats, &pending, &qos, &sink);
                    let _ = job.respond.send((Err(err), latency));
                }
                for (id, out) in outcome.retired {
                    let job = inflight.remove(&id).expect("retired id has a job");
                    let latency = job.enqueued.elapsed();
                    // feed the estimator this sample's *attributed* service
                    // share (1/cohort of each iteration it rode) at its
                    // *executed* shed fraction (known exactly post-run,
                    // adaptive included) — the whole-residency wall
                    // would bill shared iterations N times over
                    if let Some(q) = &qos {
                        let frac = out.executed_shed();
                        let service =
                            Duration::from_secs_f64(out.breakdown.total_ms().max(0.0) / 1e3);
                        q.observe_batch(1, service, frac);
                    }
                    {
                        let mut s = stats.lock().unwrap();
                        s.retires += 1;
                        s.completed += 1;
                        s.latency.record(latency);
                    }
                    let prev = pending.fetch_sub(1, Ordering::Relaxed);
                    if let Some(sk) = &sink {
                        sk.on_retired(
                            job.meta.trace,
                            &out.plan_summary,
                            latency.as_secs_f64() * 1e3,
                        );
                        sk.on_queue_depth(prev.saturating_sub(1) as usize);
                    }
                    settle_key(&cache, &job.key, Ok(&out), &stats, &pending, &qos, &sink);
                    let _ = job.respond.send((Ok(out), latency));
                }
                // ---- progress / preview events for watched samples -------
                // one event per iteration per watched in-flight sample;
                // send failures (dropped receiver) are benign — watching
                // is advisory, never load-bearing for the result path
                for (id, step, steps) in batcher.progress() {
                    let Some(job) = inflight.get(&id) else { continue };
                    let Some(w) = &job.watch else { continue };
                    let preview = if w.preview_every > 0
                        && step > 0
                        && step % w.preview_every == 0
                    {
                        batcher.preview(id).and_then(|r| r.ok())
                    } else {
                        None
                    };
                    let _ = w.tx.send(ProgressEvent { step, steps, preview });
                }
            }
            Err(e) => {
                // an engine failure poisons the whole cohort: fail every
                // in-flight job and restart with a fresh batcher (mirrors
                // the fixed worker's per-batch failure handling)
                let msg = e.to_string();
                for (_, job) in std::mem::take(&mut inflight) {
                    let latency = job.enqueued.elapsed();
                    stats.lock().unwrap().failed += 1;
                    let prev = pending.fetch_sub(1, Ordering::Relaxed);
                    if let Some(sk) = &sink {
                        sk.on_shed(job.meta.trace, "engine_failure");
                        sk.on_queue_depth(prev.saturating_sub(1) as usize);
                    }
                    let err = Error::Coordinator(msg.clone());
                    settle_key(&cache, &job.key, Err(&err), &stats, &pending, &qos, &sink);
                    let _ = job.respond.send((Err(err), latency));
                }
                batcher = fresh_batcher(&batcher_tm);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // Coordinator integration tests (with a real engine + artifacts) live
    // in rust/tests/ (integration_coordinator.rs, integration_qos.rs);
    // continuous-mode end-to-end coverage (synthetic backend, always runs)
    // is in tests/continuous_equivalence.rs; the batching-class logic is
    // tested in batcher.rs and the QoS control law in qos/ (including the
    // engine-free simulator).
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = CoordinatorConfig::default();
        assert!(c.max_batch >= 1 && c.workers >= 1);
        // max_batch = 1 is a legal degenerate configuration: every batch
        // is a singleton and compatibility never has to merge classes
        let single = CoordinatorConfig { max_batch: 1, ..CoordinatorConfig::default() };
        assert_eq!(single.max_batch, 1);
    }

    #[test]
    fn stats_default_zeroed() {
        let s = CoordinatorStats::default();
        assert_eq!(s.rejected, 0);
        assert_eq!(s.deadline_missed, 0);
        assert_eq!(s.drain_shed, 0);
        assert_eq!(s.cancelled, 0);
        assert_eq!(s.queue_depth_max, 0);
        assert_eq!(s.actuator_fraction, 0.0);
        assert_eq!(s.mode, BatchMode::Fixed);
        assert_eq!(s.iterations, 0);
        assert_eq!(s.joins, 0);
        assert_eq!(s.retires, 0);
        assert_eq!(s.slot_utilization, 0.0);
    }

    #[test]
    fn batch_mode_parse_round_trips() {
        assert_eq!(BatchMode::parse("fixed").unwrap(), BatchMode::Fixed);
        assert_eq!(BatchMode::parse("static").unwrap(), BatchMode::Fixed);
        assert_eq!(BatchMode::parse("continuous").unwrap(), BatchMode::Continuous);
        assert_eq!(BatchMode::parse("iteration-level").unwrap(), BatchMode::Continuous);
        assert!(BatchMode::parse("bogus").is_err());
        assert_eq!(BatchMode::Fixed.name(), "fixed");
        assert_eq!(BatchMode::Continuous.name(), "continuous");
        assert_eq!(BatchMode::default(), BatchMode::Fixed);
        // defaults keep the classic batcher with a sane slot budget ready
        let c = CoordinatorConfig::default();
        assert_eq!(c.mode, BatchMode::Fixed);
        assert!(c.slot_budget >= 2);
    }
}
