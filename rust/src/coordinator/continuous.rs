//! Continuous (iteration-level) batching: a slot-budgeted cohort over the
//! engine's step-resumable [`SampleState`] API (DESIGN.md §9).
//!
//! The fixed batcher freezes a batch's composition at dispatch, so a
//! sample's cond-only window only shortens *its own* latency. This
//! batcher re-decides the cohort at **every iteration boundary**: new
//! requests join as soon as slot headroom exists and finished samples
//! retire immediately, vLLM-style, so the UNet slots the selective-
//! guidance window frees become throughput for other requests.
//!
//! Slot accounting: a dual-guidance step costs 2 UNet slots, single-pass
//! steps (reuse / cond-only / unguided) cost 1. Admission charges each
//! sample its **peak remaining** per-iteration cost
//! ([`SampleState::peak_remaining_cost`]) — conservative enough that the
//! per-iteration slot usage can never overshoot the budget, yet exact
//! where it matters: a static-policy sample's peak drops to 1 the moment
//! it enters its window, which is precisely when its headroom becomes
//! admissible capacity.
//!
//! With a calibrated [`CostTable`] attached
//! ([`ContinuousBatcher::with_ms_budget`],
//! DESIGN.md §15), admission additionally reserves each sample's peak
//! remaining cost in *measured milliseconds* against `budget_ms` — the
//! iteration-latency analogue of the slot budget, for backends where a
//! dual step does not cost exactly two singles.
//!
//! The core is single-threaded and deterministic (the threaded driver
//! lives in the coordinator's continuous worker loop), which is what lets
//! `tests/continuous_equivalence.rs` and `benches/continuous_batching.rs`
//! assert the cohort-independence invariant and throughput wins exactly.

use std::sync::Arc;

use crate::cache::SharedUncondCache;
use crate::engine::{Engine, GenerationOutput, GenerationRequest, SampleState};
use crate::error::{Error, Result};
use crate::guidance::{CostTable, StepMode};
use crate::telemetry::BatcherMetrics;

/// A slot-budgeted, continuously re-composed denoising cohort.
pub struct ContinuousBatcher {
    engine: Arc<Engine>,
    slot_budget: usize,
    ids: Vec<u64>,
    states: Vec<SampleState>,
    next_id: u64,
    /// Optional slot-occupancy / join / retire metrics (DESIGN.md §12).
    telemetry: Option<BatcherMetrics>,
    /// Optional cross-request uncond-eps tier (DESIGN.md §13): samples
    /// are begun with the shared plan rule and stepped against the
    /// cache. `None` keeps the batcher bit-exact with the unshared
    /// engine.
    shared: Option<Arc<SharedUncondCache>>,
    /// Optional measured-cost admission tier (DESIGN.md §15): a
    /// millisecond budget and the calibrated table that prices peak
    /// remaining step costs in it. Runs *alongside* the slot budget —
    /// slots guard the compiled batch shapes, milliseconds guard the
    /// iteration latency target. `None` keeps admission purely
    /// slot-priced.
    ms: Option<(f64, Arc<CostTable>)>,
}

/// What one cohort iteration produced.
#[derive(Debug)]
pub struct StepOutcome {
    /// Samples that completed this iteration, with their outputs, keyed
    /// by the id [`ContinuousBatcher::try_admit`] handed out.
    pub retired: Vec<(u64, GenerationOutput)>,
    /// Samples that hit a typed per-sample engine failure (cold reuse
    /// cache under the shared tier) — removed from the cohort without
    /// outputs; the cohort itself keeps running.
    pub failed: Vec<(u64, Error)>,
    /// UNet slots the iteration consumed (always <= the budget).
    pub slots_used: usize,
    /// Cohort size during the iteration.
    pub cohort: usize,
}

impl ContinuousBatcher {
    /// `slot_budget` is the UNet capacity packed per iteration; it must
    /// cover at least one dual-guidance sample (2 slots).
    pub fn new(engine: Arc<Engine>, slot_budget: usize) -> Result<ContinuousBatcher> {
        if slot_budget < 2 {
            return Err(Error::Config(format!(
                "slot_budget {slot_budget} must be >= 2 (a dual-guidance step costs 2 slots)"
            )));
        }
        Ok(ContinuousBatcher {
            engine,
            slot_budget,
            ids: Vec::new(),
            states: Vec::new(),
            next_id: 0,
            telemetry: None,
            shared: None,
            ms: None,
        })
    }

    /// Attach batcher-layer metrics (slot occupancy gauge, join/retire
    /// counters). Builder-style so the coordinator and the benches share
    /// one construction path.
    pub fn with_telemetry(mut self, metrics: BatcherMetrics) -> ContinuousBatcher {
        self.telemetry = Some(metrics);
        self
    }

    /// Attach the cross-request uncond-eps tier: admissions switch to
    /// [`Engine::begin_shared`] and iterations to
    /// [`Engine::step_batch_shared`].
    pub fn with_shared_cache(mut self, cache: Arc<SharedUncondCache>) -> ContinuousBatcher {
        self.shared = Some(cache);
        self
    }

    /// Attach the measured-cost admission tier: admission additionally
    /// reserves each sample's peak remaining *millisecond* cost
    /// ([`SampleState::peak_remaining_cost_ms`]) against `budget_ms`.
    /// The budget must at least cover one dual-guidance sample, the same
    /// floor the slot budget enforces.
    pub fn with_ms_budget(
        mut self,
        budget_ms: f64,
        table: Arc<CostTable>,
    ) -> Result<ContinuousBatcher> {
        if !budget_ms.is_finite() || budget_ms <= 0.0 {
            return Err(Error::Config(format!(
                "budget_ms {budget_ms} must be finite and > 0"
            )));
        }
        let dual = table.sample_step_ms(StepMode::Dual);
        if budget_ms < dual {
            return Err(Error::Config(format!(
                "budget_ms {budget_ms} cannot admit even one dual-guidance sample \
                 (a dual step measures {dual} ms on this table)"
            )));
        }
        self.ms = Some((budget_ms, table));
        Ok(self)
    }

    pub fn slot_budget(&self) -> usize {
        self.slot_budget
    }

    /// Samples currently in the cohort.
    pub fn in_flight(&self) -> usize {
        self.states.len()
    }

    /// Slots the cohort can still claim in the worst remaining case.
    pub fn committed_slots(&self) -> usize {
        self.states.iter().map(|s| s.peak_remaining_cost()).sum()
    }

    /// Budget minus committed slots — the admission headroom.
    pub fn headroom(&self) -> usize {
        self.slot_budget.saturating_sub(self.committed_slots())
    }

    /// Milliseconds the cohort can still claim in the worst remaining
    /// iteration, priced by the attached table. `0.0` when no
    /// millisecond budget is attached.
    pub fn committed_ms(&self) -> f64 {
        match &self.ms {
            Some((_, table)) => {
                self.states.iter().map(|s| s.peak_remaining_cost_ms(table)).sum()
            }
            None => 0.0,
        }
    }

    /// Millisecond budget minus committed milliseconds — the measured
    /// admission headroom. `None` when admission is purely slot-priced.
    pub fn headroom_ms(&self) -> Option<f64> {
        self.ms.as_ref().map(|(budget, _)| (budget - self.committed_ms()).max(0.0))
    }

    /// The attached millisecond budget, if any.
    pub fn ms_budget(&self) -> Option<f64> {
        self.ms.as_ref().map(|(budget, _)| *budget)
    }

    /// The attached cost table, if any.
    pub fn cost_table(&self) -> Option<&Arc<CostTable>> {
        self.ms.as_ref().map(|(_, table)| table)
    }

    /// Peak per-iteration slot cost a request will ever need: what
    /// admission must reserve — `plan.peak_remaining_cost(0)`. 2 for
    /// anything with dual steps in its plan (including reuse refreshes
    /// and the adaptive controller's conservative overlay), 1 for an
    /// all-single-pass trajectory.
    pub fn admission_cost(req: &GenerationRequest) -> Result<usize> {
        Ok(req.plan()?.peak_remaining_cost(0))
    }

    /// Admit `req` into the cohort if its peak slot cost fits the current
    /// headroom; returns the sample's id, or `None` when it must wait for
    /// a later iteration boundary.
    pub fn try_admit(&mut self, req: &GenerationRequest) -> Result<Option<u64>> {
        // shared-tier plans can have a lower peak (no forced cold-cache
        // dual), so admission prices the plan that will actually run
        let plan = match &self.shared {
            Some(_) => req.plan_shared()?,
            None => req.plan()?,
        };
        if plan.peak_remaining_cost(0) > self.headroom() {
            return Ok(None);
        }
        if let Some((budget, table)) = &self.ms {
            // the measured tier prices the same peak in milliseconds;
            // with a proportional table this is exactly the slot check
            // relabeled, so it can never flip a decision the slot budget
            // already made (the bit-exactness invariant)
            if self.committed_ms() + plan.peak_remaining_cost_ms(0, table) > *budget {
                return Ok(None);
            }
        }
        let state = match &self.shared {
            Some(_) => self.engine.begin_shared(req)?,
            None => self.engine.begin(req)?,
        };
        let id = self.next_id;
        self.next_id += 1;
        self.ids.push(id);
        self.states.push(state);
        if let Some(tm) = &self.telemetry {
            tm.on_join(self.committed_slots(), self.states.len());
        }
        Ok(Some(id))
    }

    /// Drop an in-flight sample without finishing it, mirroring the
    /// per-sample failure drain: its reserved slots return to admission
    /// headroom at the next boundary and the rest of the cohort is
    /// untouched. Returns `false` when `id` is not (or no longer) in the
    /// cohort — cancel racing retirement is a benign no-op.
    pub fn cancel(&mut self, id: u64) -> bool {
        match self.ids.iter().position(|&x| x == id) {
            Some(i) => {
                self.ids.swap_remove(i);
                self.states.swap_remove(i);
                if let Some(tm) = &self.telemetry {
                    tm.on_step(0, 0, self.committed_slots(), self.states.len());
                }
                true
            }
            None => false,
        }
    }

    /// Per-sample progress snapshot: `(id, next_step_index, total_steps)`
    /// for every in-flight sample.
    pub fn progress(&self) -> Vec<(u64, usize, usize)> {
        self.ids
            .iter()
            .zip(&self.states)
            .map(|(&id, st)| (id, st.step_index(), st.steps()))
            .collect()
    }

    /// Decode the intermediate latent of an in-flight sample into a
    /// preview image. `None` when `id` already retired or was cancelled.
    pub fn preview(&self, id: u64) -> Option<Result<crate::image::RgbImage>> {
        let i = self.ids.iter().position(|&x| x == id)?;
        Some(self.engine.preview(&self.states[i]))
    }

    /// Run one engine iteration over the cohort and retire every sample
    /// that completed. The per-iteration slot usage is invariantly within
    /// the budget (admission reserves peak remaining costs).
    pub fn step(&mut self) -> Result<StepOutcome> {
        let report = self.engine.step_batch_shared(&mut self.states, self.shared.as_deref())?;
        debug_assert!(
            report.slots_used <= self.slot_budget,
            "iteration used {} slots over budget {}",
            report.slots_used,
            self.slot_budget
        );
        let mut retired = Vec::new();
        let mut failed = Vec::new();
        let mut i = 0;
        while i < self.states.len() {
            if let Some(reason) = self.states[i].failed_reason() {
                // typed per-sample failure: drain without finish() — the
                // sample never completed, only it fails, the cohort lives
                let err = Error::Engine(reason.to_string());
                self.states.swap_remove(i);
                let id = self.ids.swap_remove(i);
                failed.push((id, err));
            } else if self.states[i].is_done() {
                let state = self.states.swap_remove(i);
                let id = self.ids.swap_remove(i);
                retired.push((id, self.engine.finish(state)?));
            } else {
                i += 1;
            }
        }
        if let Some(tm) = &self.telemetry {
            tm.on_step(
                report.slots_used,
                retired.len(),
                self.committed_slots(),
                self.states.len(),
            );
        }
        Ok(StepOutcome { retired, failed, slots_used: report.slots_used, cohort: report.advanced })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::guidance::{GuidanceStrategy, ReuseKind, WindowSpec};
    use crate::runtime::ModelStack;

    fn engine() -> Arc<Engine> {
        Arc::new(Engine::new(Arc::new(ModelStack::synthetic()), EngineConfig::default()))
    }

    fn req(window: f64) -> GenerationRequest {
        GenerationRequest::new("probe")
            .steps(8)
            .selective(WindowSpec::last(window))
            .decode(false)
    }

    #[test]
    fn budget_must_cover_a_dual_step() {
        assert!(ContinuousBatcher::new(engine(), 0).is_err());
        assert!(ContinuousBatcher::new(engine(), 1).is_err());
        assert!(ContinuousBatcher::new(engine(), 2).is_ok());
    }

    #[test]
    fn admission_cost_tracks_the_policy() {
        // any dual step left -> 2 slots reserved
        assert_eq!(ContinuousBatcher::admission_cost(&req(0.0)).unwrap(), 2);
        assert_eq!(ContinuousBatcher::admission_cost(&req(0.5)).unwrap(), 2);
        // whole-trajectory cond-only window -> single-pass everywhere
        assert_eq!(ContinuousBatcher::admission_cost(&req(1.0)).unwrap(), 1);
        // unguided (scale 1) collapses to one pass everywhere
        let unguided = req(0.0).guidance_scale(1.0);
        assert_eq!(ContinuousBatcher::admission_cost(&unguided).unwrap(), 1);
        // a full-window *reuse* trajectory still opens with a cold-cache
        // dual anchor -> 2
        let reuse = req(1.0)
            .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 });
        assert_eq!(ContinuousBatcher::admission_cost(&reuse).unwrap(), 2);
        // generalized schedules price through the same plan IR: a
        // cadence keeps dual anchors -> 2
        let cadence = req(0.0).with_schedule(crate::guidance::GuidanceSchedule::Cadence {
            every: 4,
        });
        assert_eq!(ContinuousBatcher::admission_cost(&cadence).unwrap(), 2);
    }

    #[test]
    fn shared_cache_failures_drain_without_poisoning() {
        let mut cb = ContinuousBatcher::new(engine(), 4)
            .unwrap()
            .with_shared_cache(Arc::new(crate::cache::SharedUncondCache::new(0.25)));
        // full-window reuse against an empty shared cache: typed failure
        let cold = req(1.0)
            .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 });
        let cold_id = cb.try_admit(&cold).unwrap().unwrap();
        let good_id = cb.try_admit(&req(0.0)).unwrap().unwrap();
        let oc = cb.step().unwrap();
        assert_eq!(oc.failed.len(), 1);
        assert_eq!(oc.failed[0].0, cold_id);
        assert!(matches!(oc.failed[0].1, Error::Engine(_)));
        assert_eq!(cb.in_flight(), 1);
        // the surviving cohort-mate runs to completion
        let mut done = Vec::new();
        let mut guard = 0;
        while cb.in_flight() > 0 {
            done.extend(cb.step().unwrap().retired.into_iter().map(|(id, _)| id));
            guard += 1;
            assert!(guard < 32);
        }
        assert_eq!(done, vec![good_id]);
    }

    #[test]
    fn windows_free_headroom_mid_flight() {
        let mut cb = ContinuousBatcher::new(engine(), 4).unwrap();
        // two dual-capable samples fill the budget
        assert!(cb.try_admit(&req(0.5)).unwrap().is_some());
        assert!(cb.try_admit(&req(0.5)).unwrap().is_some());
        assert_eq!(cb.headroom(), 0);
        assert!(cb.try_admit(&req(0.5)).unwrap().is_none(), "over-admission");
        // after 4 of 8 steps both enter their cond-only window: peak cost
        // halves and the freed slots become admission headroom
        for _ in 0..4 {
            let oc = cb.step().unwrap();
            assert!(oc.slots_used <= 4);
        }
        assert_eq!(cb.committed_slots(), 2);
        assert_eq!(cb.headroom(), 2);
        assert!(cb.try_admit(&req(0.5)).unwrap().is_some());
        // drain everything; ids retire exactly once
        let mut seen = Vec::new();
        let mut guard = 0;
        while cb.in_flight() > 0 {
            for (id, out) in cb.step().unwrap().retired {
                assert!(out.latent.iter().all(|v| v.is_finite()));
                seen.push(id);
            }
            guard += 1;
            assert!(guard < 64);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn ms_budget_gates_admission_in_measured_milliseconds() {
        use crate::guidance::{CostTable, FallbackPolicy, StepMode};
        // a skewed table: the dual step costs 3x the single, not the
        // analytic 2x — slot headroom alone would over-admit
        let mut t = CostTable::new("synthetic", "synthetic", 8, 10.0, FallbackPolicy::Analytic)
            .unwrap();
        t.insert(1, StepMode::Dual, 30.0).unwrap();
        t.insert(1, StepMode::Single, 10.0).unwrap();
        let table = Arc::new(t);
        // slots would admit three duals (budget 8 >= 3x2); 70 ms admits
        // only two (2 x 30 = 60, a third needs 90)
        let mut cb = ContinuousBatcher::new(engine(), 8)
            .unwrap()
            .with_ms_budget(70.0, Arc::clone(&table))
            .unwrap();
        assert_eq!(cb.ms_budget(), Some(70.0));
        assert!(cb.try_admit(&req(0.5)).unwrap().is_some());
        assert!(cb.try_admit(&req(0.5)).unwrap().is_some());
        assert_eq!(cb.committed_ms(), 60.0);
        assert_eq!(cb.headroom_ms(), Some(10.0));
        assert!(cb.try_admit(&req(0.5)).unwrap().is_none(), "ms budget must gate");
        // a single-pass trajectory still fits the 10 ms left
        assert!(cb.try_admit(&req(1.0)).unwrap().is_some());
        assert_eq!(cb.headroom_ms(), Some(0.0));
        // once the duals enter their cond-only window their peak drops
        // to the single price and milliseconds come back (3 in flight,
        // all single-pass from here: 3 x 10 ms)
        for _ in 0..4 {
            cb.step().unwrap();
        }
        assert_eq!(cb.in_flight(), 3);
        assert_eq!(cb.committed_ms(), 30.0);
        assert!(cb.try_admit(&req(1.0)).unwrap().is_some());
        assert_eq!(table.fallback_count(), 0, "batch-1 pricing is calibrated");
    }

    #[test]
    fn ms_budget_must_cover_a_dual_sample() {
        use crate::guidance::CostTable;
        let table = Arc::new(CostTable::proportional(10.0, &[1]));
        let cb = ContinuousBatcher::new(engine(), 4).unwrap();
        assert!(cb.with_ms_budget(15.0, Arc::clone(&table)).is_err());
        let cb = ContinuousBatcher::new(engine(), 4).unwrap();
        assert!(cb.with_ms_budget(f64::NAN, Arc::clone(&table)).is_err());
        let cb = ContinuousBatcher::new(engine(), 4).unwrap();
        assert!(cb.with_ms_budget(20.0, table).is_ok());
    }

    #[test]
    fn proportional_ms_budget_is_the_slot_budget_relabeled() {
        use crate::guidance::CostTable;
        // budget_ms = slot_budget x unit_ms: every admission decision
        // must match the pure slot batcher exactly
        let unit = 2.5;
        let slot_budget = 4;
        let table = Arc::new(CostTable::proportional(unit, &[1, 2, 4]));
        let mut slots = ContinuousBatcher::new(engine(), slot_budget).unwrap();
        let mut priced = ContinuousBatcher::new(engine(), slot_budget)
            .unwrap()
            .with_ms_budget(slot_budget as f64 * unit, table)
            .unwrap();
        let reqs = [req(0.5), req(1.0), req(0.0), req(1.0), req(0.5)];
        for r in &reqs {
            let a = slots.try_admit(r).unwrap().is_some();
            let b = priced.try_admit(r).unwrap().is_some();
            assert_eq!(a, b, "ms pricing flipped an admission decision");
        }
        let mut guard = 0;
        while slots.in_flight() > 0 || priced.in_flight() > 0 {
            let oa = slots.step().unwrap();
            let ob = priced.step().unwrap();
            assert_eq!(oa.slots_used, ob.slots_used);
            assert_eq!(oa.retired.len(), ob.retired.len());
            guard += 1;
            assert!(guard < 32);
        }
    }

    #[test]
    fn cancel_frees_headroom_and_never_retires() {
        let mut cb = ContinuousBatcher::new(engine(), 4).unwrap();
        let a = cb.try_admit(&req(0.0)).unwrap().unwrap();
        let b = cb.try_admit(&req(0.0)).unwrap().unwrap();
        assert_eq!(cb.headroom(), 0);
        cb.step().unwrap();
        // previews and progress cover exactly the in-flight set
        assert_eq!(cb.progress().len(), 2);
        assert!(cb.preview(a).is_some());
        // cancel mid-flight: slots come back immediately, sample is gone
        assert!(cb.cancel(a));
        assert!(!cb.cancel(a), "double-cancel must be a no-op");
        assert_eq!(cb.in_flight(), 1);
        assert_eq!(cb.headroom(), 2);
        assert!(cb.preview(a).is_none());
        assert!(cb.try_admit(&req(0.0)).unwrap().is_some(), "freed slots admit");
        // the cancelled id never shows up in retired
        let mut seen = Vec::new();
        let mut guard = 0;
        while cb.in_flight() > 0 {
            seen.extend(cb.step().unwrap().retired.into_iter().map(|(id, _)| id));
            guard += 1;
            assert!(guard < 32);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![b, 2]);
    }
}
