//! Batch-compatibility rules for the dynamic batcher.
//!
//! Requests fuse into one engine batch when they step in lock-step: same
//! step count and scheduler kind. Prompts, seeds, guidance scales and
//! selective-guidance windows may differ per sample — the engine splits
//! the unconditional pass per iteration (engine/mod.rs), which is exactly
//! what makes *mixed* optimized/baseline traffic batchable.

use crate::engine::GenerationRequest;
use crate::scheduler::SchedulerKind;

/// The lock-step compatibility class of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchClass {
    pub steps: usize,
    pub scheduler: SchedulerKind,
}

impl BatchClass {
    pub fn of(req: &GenerationRequest) -> BatchClass {
        BatchClass { steps: req.steps, scheduler: req.scheduler }
    }
}

/// Can `req` join a batch of class `class`?
pub fn compatible(class: &BatchClass, req: &GenerationRequest) -> bool {
    BatchClass::of(req) == *class
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guidance::WindowSpec;
    use crate::testutil::prop::forall;

    #[test]
    fn same_steps_and_scheduler_compatible() {
        let a = GenerationRequest::new("a").steps(50);
        let b = GenerationRequest::new("completely different prompt")
            .steps(50)
            .seed(99)
            .guidance_scale(9.6)
            .selective(WindowSpec::last(0.5));
        assert!(compatible(&BatchClass::of(&a), &b));
    }

    #[test]
    fn different_steps_incompatible() {
        let a = GenerationRequest::new("a").steps(50);
        let b = GenerationRequest::new("b").steps(25);
        assert!(!compatible(&BatchClass::of(&a), &b));
    }

    #[test]
    fn different_scheduler_incompatible() {
        let a = GenerationRequest::new("a").scheduler(SchedulerKind::Pndm);
        let b = GenerationRequest::new("b").scheduler(SchedulerKind::Ddim);
        assert!(!compatible(&BatchClass::of(&a), &b));
    }

    #[test]
    fn both_axes_mismatched_incompatible() {
        let a = GenerationRequest::new("a").steps(50).scheduler(SchedulerKind::Pndm);
        let b = GenerationRequest::new("b").steps(25).scheduler(SchedulerKind::Ddim);
        assert!(!compatible(&BatchClass::of(&a), &b));
        assert!(!compatible(&BatchClass::of(&b), &a));
    }

    #[test]
    fn singleton_class_admits_itself() {
        // the max_batch = 1 degenerate case: every batch is a singleton,
        // so the only compatibility question is reflexivity — which must
        // hold for any request, whatever its knobs
        let r = GenerationRequest::new("solo")
            .steps(1)
            .seed(123)
            .guidance_scale(1.0)
            .selective(WindowSpec::last(1.0));
        assert!(compatible(&BatchClass::of(&r), &r));
    }

    #[test]
    fn window_and_scale_never_split_classes() {
        // mixed optimized/baseline traffic is the whole point: the
        // engine splits the uncond pass per iteration, so windows and
        // scales must not fragment batches
        let base = GenerationRequest::new("a").steps(50);
        let class = BatchClass::of(&base);
        for f in [0.0, 0.2, 0.5, 1.0] {
            for gs in [1.0f32, 7.5, 15.0] {
                let r = GenerationRequest::new("b")
                    .steps(50)
                    .selective(WindowSpec::last(f))
                    .guidance_scale(gs);
                assert!(compatible(&class, &r), "f={f} gs={gs}");
            }
        }
    }

    #[test]
    fn compatibility_is_equivalence() {
        forall("batch class equivalence", 100, |g| {
            let mk = |g: &mut crate::testutil::prop::Gen| {
                GenerationRequest::new("p")
                    .steps(*g.choose(&[10usize, 25, 50]))
                    .scheduler(*g.choose(&[SchedulerKind::Pndm, SchedulerKind::Ddim]))
                    .seed(g.u64())
            };
            let a = mk(g);
            let b = mk(g);
            let c = mk(g);
            // reflexive
            assert!(compatible(&BatchClass::of(&a), &a));
            // symmetric
            assert_eq!(
                compatible(&BatchClass::of(&a), &b),
                compatible(&BatchClass::of(&b), &a)
            );
            // transitive
            if compatible(&BatchClass::of(&a), &b) && compatible(&BatchClass::of(&b), &c) {
                assert!(compatible(&BatchClass::of(&a), &c));
            }
        });
    }
}
