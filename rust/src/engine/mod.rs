//! The denoising engine: the paper's optimized inference loop.
//!
//! Per iteration the engine consults the [`SelectiveGuidancePolicy`]:
//!
//! * `Dual`    — two UNet executions (conditional + unconditional) and an
//!               on-device Eq.-1 combine — classic classifier-free
//!               guidance;
//! * `CondOnly`/`Unguided` — a single conditional execution, `eps_hat =
//!               eps_c` — the paper's optimized iteration, at half the
//!               UNet cost;
//! * `Reuse`   — a single conditional execution plus the Eq.-1 combine
//!               against a **cached** (zero-order hold) or **linearly
//!               extrapolated** unconditional eps from the most recent
//!               dual iterations — guidance kept at single-pass cost
//!               (DESIGN.md §8).
//!
//! [`Engine::generate`] runs one request; [`Engine::generate_batch`] runs
//! a compatible batch in lock-step, bucketizing UNet calls into the
//! compiled batch sizes (dynamic batching, DESIGN.md §5). Per-sample
//! policies may differ inside one batch: at each step the batch splits
//! into dual / reuse / cond-only sub-sets and only the dual set pays for
//! the second pass.

use std::sync::Arc;
use std::time::Instant;

use crate::config::{DualStrategy, EngineConfig};
use crate::error::{Error, Result};
use crate::guidance::{
    guidance_delta, AdaptiveConfig, AdaptiveController, AdaptiveDecision, GuidanceMode,
    GuidanceStrategy, ReuseKind, SelectiveGuidancePolicy, WindowSpec,
};
use crate::image::RgbImage;
use crate::metrics::StepBreakdown;
use crate::rng::Rng;
use crate::runtime::ModelStack;
use crate::scheduler::{NoiseSchedule, Scheduler, SchedulerKind};
use crate::tokenizer::Tokenizer;

/// One image-generation request.
#[derive(Debug, Clone)]
pub struct GenerationRequest {
    pub prompt: String,
    pub steps: usize,
    pub guidance_scale: f32,
    pub window: WindowSpec,
    /// What optimized-window iterations execute: drop guidance (the
    /// paper's default) or reuse a cached/extrapolated uncond eps.
    pub strategy: GuidanceStrategy,
    pub scheduler: SchedulerKind,
    pub seed: u64,
    pub decode: bool,
    /// Online skip controller (paper's future-work variant); supersedes
    /// the static `window` when set.
    pub adaptive: Option<AdaptiveConfig>,
}

impl GenerationRequest {
    pub fn new(prompt: impl Into<String>) -> Self {
        let cfg = EngineConfig::default();
        GenerationRequest {
            prompt: prompt.into(),
            steps: cfg.steps,
            guidance_scale: cfg.guidance_scale,
            window: cfg.window,
            strategy: cfg.guidance_strategy,
            scheduler: cfg.scheduler,
            seed: cfg.seed,
            decode: cfg.decode_images,
            adaptive: None,
        }
    }

    /// Builder setters ------------------------------------------------
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    pub fn guidance_scale(mut self, s: f32) -> Self {
        self.guidance_scale = s;
        self
    }

    /// Apply a selective-guidance window (the paper's optimization).
    pub fn selective(mut self, w: WindowSpec) -> Self {
        self.window = w;
        self
    }

    /// Choose what the optimized window runs (guidance-reuse lattice).
    pub fn strategy(mut self, s: GuidanceStrategy) -> Self {
        self.strategy = s;
        self
    }

    pub fn scheduler(mut self, k: SchedulerKind) -> Self {
        self.scheduler = k;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn decode(mut self, decode: bool) -> Self {
        self.decode = decode;
        self
    }

    /// Enable the adaptive (online) skip controller.
    pub fn adaptive(mut self, cfg: AdaptiveConfig) -> Self {
        self.adaptive = Some(cfg);
        self
    }

    pub fn policy(&self) -> Result<SelectiveGuidancePolicy> {
        SelectiveGuidancePolicy::with_strategy(self.window, self.guidance_scale, self.strategy)
    }

    pub fn validate(&self) -> Result<()> {
        if self.prompt.trim().is_empty() {
            return Err(Error::Request("empty prompt".into()));
        }
        if self.steps == 0 || self.steps > 1000 {
            return Err(Error::Request(format!("steps {} outside [1, 1000]", self.steps)));
        }
        self.policy()?;
        if let Some(a) = &self.adaptive {
            a.validate()?;
        }
        Ok(())
    }
}

/// The result of one generation.
#[derive(Debug, Clone)]
pub struct GenerationOutput {
    /// Final latent (x0-space), C*H*W.
    pub latent: Vec<f32>,
    /// Decoded image (when `decode` was requested).
    pub image: Option<RgbImage>,
    /// End-to-end wall time, milliseconds.
    pub wall_ms: f64,
    /// Per-component time totals across the loop.
    pub breakdown: StepBreakdown,
    /// UNet executions actually performed.
    pub unet_evals: usize,
    /// Steps run (== request.steps).
    pub steps: usize,
    /// Guidance strategy the request ran with — reported from the
    /// *executed* request, so QoS actuation (which may rewrite the
    /// strategy at admission) is reflected honestly.
    pub strategy: GuidanceStrategy,
}

/// Per-sample history of true unconditional eps evaluations — the state
/// behind the Reuse guidance modes. Dual iterations record; reuse
/// iterations estimate (zero-order hold, or a linear forecast through
/// the last two anchors).
struct UncondCache {
    /// Second-most-recent (iteration, eps_u) anchor.
    prev: Option<(usize, Vec<f32>)>,
    /// Most recent (iteration, eps_u) anchor.
    last: Option<(usize, Vec<f32>)>,
}

impl UncondCache {
    fn new() -> UncondCache {
        UncondCache { prev: None, last: None }
    }

    fn record(&mut self, i: usize, eps: Vec<f32>) {
        self.prev = self.last.take();
        self.last = Some((i, eps));
    }

    /// Estimated uncond eps for iteration `i`; None while cold (the
    /// policy's cold-start rule keeps that unreachable in practice).
    fn estimate(&self, i: usize, kind: ReuseKind) -> Option<Vec<f32>> {
        let (i2, last) = self.last.as_ref()?;
        match (kind, &self.prev) {
            (ReuseKind::Hold, _) | (ReuseKind::Extrapolate, None) => Some(last.clone()),
            (ReuseKind::Extrapolate, Some((i1, prev))) => {
                // linear forecast through the two anchors, weighted by
                // iteration distance (anchors are strictly increasing)
                let w = (i - i2) as f32 / (i2 - i1) as f32;
                Some(last.iter().zip(prev.iter()).map(|(l, p)| l + (l - p) * w).collect())
            }
        }
    }
}

/// The serving engine: a [`ModelStack`] plus engine defaults.
pub struct Engine {
    stack: Arc<ModelStack>,
    config: EngineConfig,
    tokenizer: Tokenizer,
}

impl Engine {
    pub fn new(stack: Arc<ModelStack>, config: EngineConfig) -> Engine {
        let m = stack.model();
        let tokenizer = Tokenizer::new(m.vocab_size, m.seq_len);
        Engine { stack, config, tokenizer }
    }

    pub fn stack(&self) -> &Arc<ModelStack> {
        &self.stack
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// A request pre-filled from the engine defaults.
    pub fn request(&self, prompt: &str) -> GenerationRequest {
        GenerationRequest {
            prompt: prompt.to_string(),
            steps: self.config.steps,
            guidance_scale: self.config.guidance_scale,
            window: self.config.window,
            strategy: self.config.guidance_strategy,
            scheduler: self.config.scheduler,
            seed: self.config.seed,
            decode: self.config.decode_images,
            adaptive: None,
        }
    }

    /// Generate one image.
    pub fn generate(&self, req: &GenerationRequest) -> Result<GenerationOutput> {
        let mut outs = self.generate_batch(std::slice::from_ref(req))?;
        Ok(outs.pop().expect("one output per request"))
    }

    /// Generate a batch in lock-step. All requests must share `steps` and
    /// `scheduler` (the batcher guarantees this); prompts, seeds, windows
    /// and scales may differ per sample.
    pub fn generate_batch(&self, reqs: &[GenerationRequest]) -> Result<Vec<GenerationOutput>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let t_start = Instant::now();
        let steps = reqs[0].steps;
        let sched_kind = reqs[0].scheduler;
        for r in reqs {
            r.validate()?;
            if r.steps != steps || r.scheduler != sched_kind {
                return Err(Error::Request(
                    "batched requests must share steps and scheduler".into(),
                ));
            }
        }
        let n = reqs.len();
        let m = self.stack.model();
        let latent_elems = m.latent_elems();
        let ctx_elems = m.ctx_elems();

        let mut breakdown = StepBreakdown::default();
        let mut unet_evals = 0usize;
        let mut evals_per_sample = vec![0usize; n];
        let mut controllers: Vec<Option<AdaptiveController>> =
            reqs.iter().map(|r| r.adaptive.map(|a| a.controller())).collect();

        // ---- per-request setup ------------------------------------------
        let t0 = Instant::now();
        let policies: Vec<SelectiveGuidancePolicy> =
            reqs.iter().map(|r| r.policy()).collect::<Result<_>>()?;
        let cond_ctx: Vec<Vec<f32>> = reqs
            .iter()
            .map(|r| self.stack.encode_text(&self.tokenizer.encode(&r.prompt)))
            .collect::<Result<_>>()?;
        let uncond_ctx = self.stack.uncond_ctx()?;
        let mut schedulers: Vec<Box<dyn Scheduler>> = (0..n)
            .map(|_| sched_kind.build(NoiseSchedule::default(), steps))
            .collect();
        let mut rngs: Vec<Rng> =
            reqs.iter().map(|r| Rng::for_stream(r.seed, 0)).collect();
        let mut latents: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let mut l = rngs[i].normal_vec(latent_elems);
                let sigma = schedulers[i].init_noise_sigma();
                for v in l.iter_mut() {
                    *v *= sigma;
                }
                l
            })
            .collect();
        breakdown.overhead_ms += t0.elapsed().as_secs_f64() * 1e3;

        // scratch buffers reused across steps (no steady-state allocation
        // beyond the PJRT boundary)
        let mut in_latents: Vec<f32> = Vec::with_capacity(n * latent_elems);
        let mut in_ts: Vec<f32> = Vec::with_capacity(n);
        let mut in_ctx: Vec<f32> = Vec::with_capacity(n * ctx_elems);

        // per-sample uncond-eps history for the Reuse guidance modes;
        // recording is gated so the default (drop-guidance) path keeps
        // its no-steady-state-allocation property
        let mut caches: Vec<UncondCache> = (0..n).map(|_| UncondCache::new()).collect();
        let wants_reuse: Vec<bool> = (0..n)
            .map(|s| {
                reqs[s].adaptive.is_none()
                    && matches!(policies[s].strategy(), GuidanceStrategy::Reuse { .. })
            })
            .collect();

        // ---- the denoising loop ------------------------------------------
        let strategy = self.config.dual_strategy;
        for i in 0..steps {
            // which samples need the unconditional pass this iteration?
            let modes: Vec<GuidanceMode> = (0..n)
                .map(|s| match controllers[s].as_mut() {
                    Some(ctrl) => match ctrl.decide(i, steps) {
                        AdaptiveDecision::Dual => {
                            GuidanceMode::Dual { scale: reqs[s].guidance_scale }
                        }
                        AdaptiveDecision::CondOnly => GuidanceMode::CondOnly,
                    },
                    None => policies[s].decide(i, steps),
                })
                .collect();
            let dual: Vec<usize> = (0..n)
                .filter(|&s| matches!(modes[s], GuidanceMode::Dual { .. }))
                .collect();
            let reuse: Vec<usize> = (0..n)
                .filter(|&s| matches!(modes[s], GuidanceMode::Reuse { .. }))
                .collect();
            let single: Vec<usize> = (0..n)
                .filter(|&s| {
                    matches!(modes[s], GuidanceMode::CondOnly | GuidanceMode::Unguided)
                })
                .collect();

            let t0 = Instant::now();
            let scaled: Vec<Vec<f32>> = (0..n)
                .map(|s| schedulers[s].scale_model_input(&latents[s], i))
                .collect();
            breakdown.scheduler_ms += t0.elapsed().as_secs_f64() * 1e3;

            // per-sample eps_hat for this iteration
            let mut eps_hat: Vec<Vec<f32>> = vec![Vec::new(); n];

            match strategy {
                DualStrategy::TwoB1 => {
                    // 1) conditional pass for every sample (bucketized)
                    let t0 = Instant::now();
                    let all: Vec<usize> = (0..n).collect();
                    let eps_cond = self.unet_over(
                        &all,
                        &scaled,
                        &mut in_latents,
                        &mut in_ts,
                        &mut in_ctx,
                        |s| &cond_ctx[s],
                        |s| schedulers[s].model_timestep(i),
                    )?;
                    unet_evals += n;
                    for e in evals_per_sample.iter_mut() {
                        *e += 1;
                    }
                    breakdown.unet_cond_ms += t0.elapsed().as_secs_f64() * 1e3;

                    // 2) unconditional pass only for Dual samples
                    if !dual.is_empty() {
                        let t0 = Instant::now();
                        let eps_uncond = self.unet_over(
                            &dual,
                            &scaled,
                            &mut in_latents,
                            &mut in_ts,
                            &mut in_ctx,
                            |_| &uncond_ctx,
                            |s| schedulers[s].model_timestep(i),
                        )?;
                        unet_evals += dual.len();
                        breakdown.unet_uncond_ms += t0.elapsed().as_secs_f64() * 1e3;

                        // 3) Eq.-1 combine on device
                        for (di, &s) in dual.iter().enumerate() {
                            let GuidanceMode::Dual { scale } = modes[s] else { unreachable!() };
                            evals_per_sample[s] += 1;
                            let t0 = Instant::now();
                            let u = &eps_uncond[di * latent_elems..(di + 1) * latent_elems];
                            let c = &eps_cond[s * latent_elems..(s + 1) * latent_elems];
                            if let Some(ctrl) = controllers[s].as_mut() {
                                ctrl.observe_delta(guidance_delta(c, u));
                            }
                            if wants_reuse[s] {
                                caches[s].record(i, u.to_vec());
                            }
                            eps_hat[s] = self.stack.cfg_combine(1, u, c, scale)?;
                            breakdown.combine_ms += t0.elapsed().as_secs_f64() * 1e3;
                        }
                    }
                    // reuse samples: Eq.-1 combine against the cached /
                    // extrapolated uncond eps (no second UNet pass)
                    for &s in &reuse {
                        let GuidanceMode::Reuse { scale, kind } = modes[s] else {
                            unreachable!()
                        };
                        let t0 = Instant::now();
                        let c = &eps_cond[s * latent_elems..(s + 1) * latent_elems];
                        let u_hat = caches[s]
                            .estimate(i, kind)
                            .expect("reuse step with a cold uncond cache");
                        eps_hat[s] = self.stack.cfg_combine(1, &u_hat, c, scale)?;
                        breakdown.combine_ms += t0.elapsed().as_secs_f64() * 1e3;
                    }
                    for &s in &single {
                        eps_hat[s] =
                            eps_cond[s * latent_elems..(s + 1) * latent_elems].to_vec();
                    }
                }
                DualStrategy::FusedB2 => {
                    // HF-pipeline style: each dual sample runs one fused
                    // batch-2 [cond, uncond] execution
                    for &s in &dual {
                        let GuidanceMode::Dual { scale } = modes[s] else { unreachable!() };
                        let t0 = Instant::now();
                        in_latents.clear();
                        in_latents.extend_from_slice(&scaled[s]);
                        in_latents.extend_from_slice(&scaled[s]);
                        let t_s = schedulers[s].model_timestep(i);
                        in_ctx.clear();
                        in_ctx.extend_from_slice(&cond_ctx[s]);
                        in_ctx.extend_from_slice(&uncond_ctx);
                        let both =
                            self.stack.unet_eps(2, &in_latents, &[t_s, t_s], &in_ctx)?;
                        unet_evals += 2;
                        evals_per_sample[s] += 2;
                        breakdown.unet_cond_ms += t0.elapsed().as_secs_f64() * 1e3 / 2.0;
                        breakdown.unet_uncond_ms += t0.elapsed().as_secs_f64() * 1e3 / 2.0;
                        let t0 = Instant::now();
                        let (c, u) = both.split_at(latent_elems);
                        if let Some(ctrl) = controllers[s].as_mut() {
                            ctrl.observe_delta(guidance_delta(c, u));
                        }
                        if wants_reuse[s] {
                            caches[s].record(i, u.to_vec());
                        }
                        eps_hat[s] = self.stack.cfg_combine(1, u, c, scale)?;
                        breakdown.combine_ms += t0.elapsed().as_secs_f64() * 1e3;
                    }
                    // optimized samples (reuse + cond-only/unguided): one
                    // bucketized cond pass, then per-mode post-processing
                    let others: Vec<usize> = (0..n)
                        .filter(|&s| !matches!(modes[s], GuidanceMode::Dual { .. }))
                        .collect();
                    if !others.is_empty() {
                        let t0 = Instant::now();
                        let eps_cond = self.unet_over(
                            &others,
                            &scaled,
                            &mut in_latents,
                            &mut in_ts,
                            &mut in_ctx,
                            |s| &cond_ctx[s],
                            |s| schedulers[s].model_timestep(i),
                        )?;
                        unet_evals += others.len();
                        breakdown.unet_cond_ms += t0.elapsed().as_secs_f64() * 1e3;
                        for (oi, &s) in others.iter().enumerate() {
                            evals_per_sample[s] += 1;
                            let c = &eps_cond[oi * latent_elems..(oi + 1) * latent_elems];
                            if let GuidanceMode::Reuse { scale, kind } = modes[s] {
                                let t0 = Instant::now();
                                let u_hat = caches[s]
                                    .estimate(i, kind)
                                    .expect("reuse step with a cold uncond cache");
                                eps_hat[s] = self.stack.cfg_combine(1, &u_hat, c, scale)?;
                                breakdown.combine_ms += t0.elapsed().as_secs_f64() * 1e3;
                            } else {
                                eps_hat[s] = c.to_vec();
                            }
                        }
                    }
                }
            }

            // 4) scheduler update per sample
            let t0 = Instant::now();
            for s in 0..n {
                latents[s] = schedulers[s].step(i, &latents[s], &eps_hat[s], &mut rngs[s]);
            }
            breakdown.scheduler_ms += t0.elapsed().as_secs_f64() * 1e3;
        }

        // consistency: per-sample counts must sum to the executed total,
        // and static-policy samples must match their analytic cost model.
        // Hard asserts (not debug_assert): the cost model is the contract
        // QoS feasibility and the benches are built on, so `--release`
        // tests must check it too.
        assert_eq!(unet_evals, evals_per_sample.iter().sum::<usize>());
        for (s, req) in reqs.iter().enumerate() {
            if req.adaptive.is_none() {
                assert_eq!(
                    evals_per_sample[s],
                    policies[s].total_unet_evals(steps),
                    "sample {s}: executed evals diverge from the policy cost model"
                );
            }
        }

        // ---- decode + package -------------------------------------------
        // each output carries its 1/N share of the shared loop costs plus
        // its own decode time (cloning the whole-batch totals would
        // over-report N× when aggregating per-request breakdowns)
        let shared = breakdown.scaled(1.0 / n as f64);
        let mut outputs = Vec::with_capacity(n);
        for (s, req) in reqs.iter().enumerate() {
            let mut per_sample = shared.clone();
            let image = if req.decode {
                let t0 = Instant::now();
                let chw = self.stack.decode(&latents[s])?;
                let img = RgbImage::from_chw_f32(&chw, m.image_size, m.image_size)?;
                per_sample.overhead_ms += t0.elapsed().as_secs_f64() * 1e3;
                Some(img)
            } else {
                None
            };
            outputs.push(GenerationOutput {
                latent: std::mem::take(&mut latents[s]),
                image,
                wall_ms: 0.0, // patched below with the shared wall time
                breakdown: per_sample,
                // per-request count of actually-executed evaluations
                unet_evals: evals_per_sample[s],
                steps,
                strategy: req.strategy,
            });
        }
        let wall = t_start.elapsed().as_secs_f64() * 1e3;
        for o in outputs.iter_mut() {
            o.wall_ms = wall;
        }
        Ok(outputs)
    }

    /// Run the UNet for the sample subset `subset`, bucketizing into the
    /// compiled batch sizes. Returns eps flattened in subset order.
    #[allow(clippy::too_many_arguments)]
    fn unet_over<'a>(
        &self,
        subset: &[usize],
        scaled_latents: &[Vec<f32>],
        in_latents: &mut Vec<f32>,
        in_ts: &mut Vec<f32>,
        in_ctx: &mut Vec<f32>,
        ctx_of: impl Fn(usize) -> &'a [f32],
        t_of: impl Fn(usize) -> f32,
    ) -> Result<Vec<f32>> {
        let m = self.stack.model();
        let latent_elems = m.latent_elems();
        let mut out = Vec::with_capacity(subset.len() * latent_elems);
        let mut cursor = 0usize;
        for bucket in self.stack.bucketize(subset.len()) {
            in_latents.clear();
            in_ts.clear();
            in_ctx.clear();
            for &s in &subset[cursor..cursor + bucket] {
                in_latents.extend_from_slice(&scaled_latents[s]);
                in_ts.push(t_of(s));
                in_ctx.extend_from_slice(ctx_of(s));
            }
            let eps = self.stack.unet_eps(bucket, in_latents, in_ts, in_ctx)?;
            out.extend_from_slice(&eps);
            cursor += bucket;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder_chain() {
        let r = GenerationRequest::new("a cat")
            .steps(25)
            .guidance_scale(9.0)
            .selective(WindowSpec::last(0.3))
            .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 })
            .scheduler(SchedulerKind::Ddim)
            .seed(7)
            .decode(false);
        assert_eq!(r.steps, 25);
        assert_eq!(r.guidance_scale, 9.0);
        assert_eq!(r.window, WindowSpec::last(0.3));
        assert_eq!(
            r.strategy,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 }
        );
        assert_eq!(r.scheduler, SchedulerKind::Ddim);
        assert_eq!(r.seed, 7);
        assert!(!r.decode);
        assert!(r.validate().is_ok());
    }

    #[test]
    fn request_validation() {
        assert!(GenerationRequest::new("").validate().is_err());
        assert!(GenerationRequest::new("x").steps(0).validate().is_err());
        assert!(GenerationRequest::new("x")
            .selective(WindowSpec::last(1.5))
            .validate()
            .is_err());
        assert!(GenerationRequest::new("x").guidance_scale(-2.0).validate().is_err());
    }

    #[test]
    fn default_request_matches_paper_setup() {
        let r = GenerationRequest::new("prompt");
        assert_eq!(r.steps, 50); // "Denoising iterations were fixed at 50"
        assert_eq!(r.guidance_scale, 7.5);
        assert_eq!(r.window, WindowSpec::none());
        // the paper's optimized iteration drops guidance outright
        assert_eq!(r.strategy, GuidanceStrategy::CondOnly);
    }

    #[test]
    fn uncond_cache_hold_and_extrapolate() {
        let mut c = UncondCache::new();
        assert!(c.estimate(0, ReuseKind::Hold).is_none());
        c.record(2, vec![1.0, 2.0]);
        // one anchor: both kinds hold
        assert_eq!(c.estimate(3, ReuseKind::Hold).unwrap(), vec![1.0, 2.0]);
        assert_eq!(c.estimate(3, ReuseKind::Extrapolate).unwrap(), vec![1.0, 2.0]);
        c.record(4, vec![3.0, 2.0]);
        // hold replays the newest anchor
        assert_eq!(c.estimate(5, ReuseKind::Hold).unwrap(), vec![3.0, 2.0]);
        // extrapolate continues the (2 -> 4) trend one half-gap further:
        // slope (3-1)/2 = 1 per iteration on the first element
        assert_eq!(c.estimate(5, ReuseKind::Extrapolate).unwrap(), vec![4.0, 2.0]);
        assert_eq!(c.estimate(6, ReuseKind::Extrapolate).unwrap(), vec![5.0, 2.0]);
    }
}
