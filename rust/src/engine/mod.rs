//! The denoising engine: the paper's optimized inference loop.
//!
//! Every request's per-step guidance decisions are compiled ahead of
//! time into a [`GuidancePlan`] (DESIGN.md §10); the loop executes the
//! plan verbatim, one [`GuidanceMode`] per iteration:
//!
//! * `Dual`    — two UNet executions (conditional + unconditional) and an
//!               on-device Eq.-1 combine — classic classifier-free
//!               guidance;
//! * `CondOnly`/`Unguided` — a single conditional execution, `eps_hat =
//!               eps_c` — the paper's optimized iteration, at half the
//!               UNet cost;
//! * `Reuse`   — a single conditional execution plus the Eq.-1 combine
//!               against a **cached** (zero-order hold) or **linearly
//!               extrapolated** unconditional eps from the most recent
//!               dual iterations — guidance kept at single-pass cost
//!               (DESIGN.md §8).
//!
//! The denoise loop is **step-resumable** (DESIGN.md §9): [`Engine::begin`]
//! turns a request into a [`SampleState`], [`Engine::step_batch`] advances
//! any set of in-flight states by one iteration each — bucketizing UNet
//! calls into the compiled batch sizes — and [`Engine::finish`] packages a
//! completed state into a [`GenerationOutput`]. Samples inside one
//! `step_batch` cohort may sit at *different* step indices, step counts
//! and schedulers; per-sample policies may differ too: at each iteration
//! the cohort splits into dual / reuse / cond-only sub-sets and only the
//! dual set pays for the second pass. A sample's output is a pure
//! function of its own request — cohort composition can never leak into
//! the result (the continuous batcher and its CI equivalence tests are
//! built on that invariant).
//!
//! [`Engine::generate`] runs one request; [`Engine::generate_batch`] runs
//! a compatible batch in lock-step on top of the same three primitives
//! (dynamic batching, DESIGN.md §5).

use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::cache::{SharedKey, SharedUncondCache};
use crate::config::{DualStrategy, EngineConfig};
use crate::error::{Error, Result};
use crate::guidance::{
    guidance_delta, AdaptiveConfig, AdaptiveController, AdaptiveDecision, GuidanceMode,
    GuidancePlan, GuidanceSchedule, GuidanceStrategy, ReuseKind, SelectiveGuidancePolicy,
    WindowSpec,
};
use crate::image::RgbImage;
use crate::metrics::StepBreakdown;
use crate::rng::Rng;
use crate::runtime::ModelStack;
use crate::scheduler::{NoiseSchedule, Scheduler, SchedulerKind};
use crate::telemetry::{EngineMetrics, Telemetry};
use crate::tokenizer::Tokenizer;

/// img2img entry point: a clean init latent plus a `strength` mapping
/// onto a truncated scheduler range. The request's scheduler is still
/// built for the *full* step count; only the last
/// `round(steps * strength)` steps execute, entered by forward-noising
/// the init latent to that trajectory position
/// ([`crate::scheduler::Scheduler::add_noise`]).
#[derive(Debug, Clone)]
pub struct InitImage {
    /// Explicit init latent (C*H*W, model latent space). `None` derives
    /// a deterministic synthetic init from the request seed (RNG stream
    /// 1 — stream 0 drives the denoise noise draws), so every surface
    /// can exercise img2img without shipping a latent.
    pub latent: Option<Arc<Vec<f32>>>,
    /// Fraction of the trajectory re-run, in (0, 1]: executed steps =
    /// `round(steps * strength)` clamped to `[1, steps]`. 1.0 runs the
    /// full range (a noised init instead of pure noise).
    pub strength: f64,
}

/// One image-generation request.
#[derive(Debug, Clone)]
pub struct GenerationRequest {
    pub prompt: String,
    pub steps: usize,
    pub guidance_scale: f32,
    /// Which steps are guided vs optimized — the generalized window
    /// (contiguous windows, segments, limited intervals, cadences).
    pub schedule: GuidanceSchedule,
    /// What optimized-schedule iterations execute: drop guidance (the
    /// paper's default) or reuse a cached/extrapolated uncond eps.
    pub strategy: GuidanceStrategy,
    pub scheduler: SchedulerKind,
    pub seed: u64,
    pub decode: bool,
    /// Online skip controller (paper's future-work variant); supersedes
    /// the static `schedule` when set.
    pub adaptive: Option<AdaptiveConfig>,
    /// img2img: init latent + strength-truncated scheduler range.
    /// `None` is the classic text2img full trajectory.
    pub init: Option<InitImage>,
    /// Pre-compiled guidance plan shared across a variations fan-out
    /// ([`GenerationRequest::variations`]): N seeds differ only in
    /// their noise stream, so the plan IR is compiled once and cloned
    /// per sample instead of recompiled N times.
    pub shared_plan: Option<Arc<GuidancePlan>>,
}

impl GenerationRequest {
    pub fn new(prompt: impl Into<String>) -> Self {
        let cfg = EngineConfig::default();
        GenerationRequest {
            prompt: prompt.into(),
            steps: cfg.steps,
            guidance_scale: cfg.guidance_scale,
            schedule: cfg.schedule,
            strategy: cfg.guidance_strategy,
            scheduler: cfg.scheduler,
            seed: cfg.seed,
            decode: cfg.decode_images,
            adaptive: None,
            init: None,
            shared_plan: None,
        }
    }

    /// Builder setters ------------------------------------------------
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    pub fn guidance_scale(mut self, s: f32) -> Self {
        self.guidance_scale = s;
        self
    }

    /// Apply a selective-guidance window (the paper's optimization).
    pub fn selective(mut self, w: WindowSpec) -> Self {
        self.schedule = GuidanceSchedule::Window(w);
        self
    }

    /// Apply a generalized guidance schedule (segments / limited
    /// interval / cadence).
    pub fn with_schedule(mut self, s: GuidanceSchedule) -> Self {
        self.schedule = s;
        self
    }

    /// Choose what the optimized window runs (guidance-reuse lattice).
    pub fn strategy(mut self, s: GuidanceStrategy) -> Self {
        self.strategy = s;
        self
    }

    pub fn scheduler(mut self, k: SchedulerKind) -> Self {
        self.scheduler = k;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn decode(mut self, decode: bool) -> Self {
        self.decode = decode;
        self
    }

    /// Enable the adaptive (online) skip controller.
    pub fn adaptive(mut self, cfg: AdaptiveConfig) -> Self {
        self.adaptive = Some(cfg);
        self
    }

    /// img2img from a seed-derived synthetic init latent.
    pub fn img2img(mut self, strength: f64) -> Self {
        self.init = Some(InitImage { latent: None, strength });
        self
    }

    /// img2img from an explicit init latent.
    pub fn init_latent(mut self, latent: Arc<Vec<f32>>, strength: f64) -> Self {
        self.init = Some(InitImage { latent: Some(latent), strength });
        self
    }

    /// Denoising iterations this request actually executes: `steps` for
    /// text2img, the strength-truncated suffix for img2img. Plans are
    /// compiled — and costs priced — over this count.
    pub fn executed_steps(&self) -> usize {
        match &self.init {
            Some(init) => {
                (((self.steps as f64) * init.strength).round() as usize).clamp(1, self.steps)
            }
            None => self.steps,
        }
    }

    /// Expand this request into `n` seed variations sharing ONE
    /// compiled [`GuidancePlan`]: schedule, scale, strategy and step
    /// count are identical across the fan-out, so the IR is compiled
    /// once and cloned per sample instead of recompiled N times.
    pub fn variations(&self, n: usize) -> Result<Vec<GenerationRequest>> {
        if n == 0 {
            return Err(Error::Request("variations must be >= 1".into()));
        }
        self.validate()?;
        let plan = Arc::new(self.plan()?);
        Ok((0..n)
            .map(|i| {
                let mut r = self.clone();
                r.seed = self.seed.wrapping_add(i as u64);
                r.shared_plan = Some(Arc::clone(&plan));
                r
            })
            .collect())
    }

    pub fn policy(&self) -> Result<SelectiveGuidancePolicy> {
        SelectiveGuidancePolicy::with_schedule(
            self.schedule.clone(),
            self.guidance_scale,
            self.strategy,
        )
    }

    /// Compile this request's guidance plan: the per-step decisions the
    /// engine executes and every layer audits against. Adaptive requests
    /// get the conservative all-dual overlay (the controller's online
    /// decisions are recorded into it as they execute).
    pub fn plan(&self) -> Result<GuidancePlan> {
        if let Some(p) = &self.shared_plan {
            return Ok((**p).clone());
        }
        if self.adaptive.is_some() {
            // still validate the static triple the request carries
            self.policy()?;
            return Ok(GuidancePlan::conservative_dual(self.guidance_scale, self.executed_steps()));
        }
        GuidancePlan::compile(
            &self.schedule,
            self.guidance_scale,
            self.strategy,
            self.executed_steps(),
        )
    }

    /// The plan [`Engine::begin_shared`] executes: compiled with the
    /// cross-request anchor rule (DESIGN.md §13) — reuse steps are not
    /// forced Dual by a cold *local* cache, because the anchor may come
    /// from the shared uncond tier. Identical to
    /// [`GenerationRequest::plan`] for adaptive and non-reuse requests.
    pub fn plan_shared(&self) -> Result<GuidancePlan> {
        if self.adaptive.is_some() {
            return self.plan();
        }
        // a variations fan-out's shared plan is reusable here unless the
        // strategy has reuse steps (only those differ between the local
        // and cross-request anchor rules)
        if let Some(p) = &self.shared_plan {
            if self.strategy.shared_consumer_kind().is_none() {
                return Ok((**p).clone());
            }
        }
        GuidancePlan::compile_shared(
            &self.schedule,
            self.guidance_scale,
            self.strategy,
            self.executed_steps(),
        )
    }

    /// Plan-derived *effective shed*: the fraction of this request's
    /// steps that run a single UNet pass. This is the derived view the
    /// QoS feedback loop and the simulator key on (refresh and
    /// cold-cache steps pay dual cost, so raw schedule fractions would
    /// lie). 0 for adaptive requests (unpredictable, priced
    /// conservatively) and invalid requests.
    pub fn effective_shed(&self) -> f64 {
        self.plan().map(|p| p.effective_fraction()).unwrap_or(0.0)
    }

    pub fn validate(&self) -> Result<()> {
        if self.prompt.trim().is_empty() {
            return Err(Error::Request("empty prompt".into()));
        }
        if self.steps == 0 || self.steps > 1000 {
            return Err(Error::Request(format!("steps {} outside [1, 1000]", self.steps)));
        }
        self.policy()?;
        if let Some(init) = &self.init {
            if !init.strength.is_finite() || init.strength <= 0.0 || init.strength > 1.0 {
                return Err(Error::Request(format!(
                    "img2img strength {} outside (0, 1]",
                    init.strength
                )));
            }
            if let Some(l) = &init.latent {
                if l.is_empty() {
                    return Err(Error::Request("img2img init latent is empty".into()));
                }
            }
        }
        if let Some(a) = &self.adaptive {
            a.validate()?;
            // the controller supersedes the static schedule entirely, so
            // carrying both is a conflict to reject loudly, not a field
            // to discard silently
            if self.schedule != GuidanceSchedule::none() {
                return Err(Error::Request(
                    "adaptive guidance supersedes the static schedule — configure one, \
                     not both"
                        .into(),
                ));
            }
        }
        Ok(())
    }
}

/// The result of one generation.
#[derive(Debug, Clone)]
pub struct GenerationOutput {
    /// Final latent (x0-space), C*H*W.
    pub latent: Vec<f32>,
    /// Decoded image (when `decode` was requested).
    pub image: Option<RgbImage>,
    /// End-to-end wall time, milliseconds.
    pub wall_ms: f64,
    /// Per-component time totals across the loop.
    pub breakdown: StepBreakdown,
    /// UNet executions actually performed.
    pub unet_evals: usize,
    /// Steps run (== the request's *executed* step count: `steps` for
    /// text2img, the strength-truncated suffix for img2img).
    pub steps: usize,
    /// Guidance strategy the request ran with — reported from the
    /// *executed* request, so QoS actuation (which may rewrite the
    /// strategy at admission) is reflected honestly.
    pub strategy: GuidanceStrategy,
    /// Run-length summary of the *executed* guidance plan (e.g.
    /// `"40D 10C"`), from the same IR the eval-count invariant audits —
    /// echoed on the wire so clients can see exactly what ran.
    pub plan_summary: String,
}

impl GenerationOutput {
    /// *Executed* effective shed: the fraction of steps that actually
    /// ran a single UNet pass (`evals == 2·steps − single_pass_steps`).
    /// This is what QoS service feedback keys on — for adaptive samples
    /// the plan is only known after execution, so the request-side
    /// [`GenerationRequest::effective_shed`] would under-report.
    pub fn executed_shed(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        (2 * self.steps - self.unet_evals) as f64 / self.steps as f64
    }
}

/// The deterministic synthetic init latent used when an img2img request
/// carries a strength but no explicit latent: RNG stream 1 of the
/// request seed (stream 0 drives the denoise noise draws), so the init
/// is reproducible from the request alone on every surface.
pub fn synthetic_init_latent(seed: u64, elems: usize) -> Vec<f32> {
    Rng::for_stream(seed, 1).normal_vec(elems)
}

/// Per-sample history of true unconditional eps evaluations — the state
/// behind the Reuse guidance modes. Dual iterations record; reuse
/// iterations estimate (zero-order hold, or a linear forecast through
/// the last two anchors).
struct UncondCache {
    /// Second-most-recent (iteration, eps_u) anchor.
    prev: Option<(usize, Vec<f32>)>,
    /// Most recent (iteration, eps_u) anchor.
    last: Option<(usize, Vec<f32>)>,
}

impl UncondCache {
    fn new() -> UncondCache {
        UncondCache { prev: None, last: None }
    }

    fn record(&mut self, i: usize, eps: Vec<f32>) {
        self.prev = self.last.take();
        self.last = Some((i, eps));
    }

    /// Any anchor recorded yet? Cheap cold-cache probe — `estimate`
    /// clones the eps tensor.
    fn warm(&self) -> bool {
        self.last.is_some()
    }

    /// Estimated uncond eps for iteration `i`; None while cold (the
    /// policy's cold-start rule keeps that unreachable in practice).
    fn estimate(&self, i: usize, kind: ReuseKind) -> Option<Vec<f32>> {
        let (i2, last) = self.last.as_ref()?;
        match (kind, &self.prev) {
            (ReuseKind::Hold, _) | (ReuseKind::Extrapolate, None) => Some(last.clone()),
            (ReuseKind::Extrapolate, Some((i1, prev))) => {
                // linear forecast through the two anchors, weighted by
                // iteration distance (anchors are strictly increasing)
                let w = (i - i2) as f32 / (i2 - i1) as f32;
                Some(last.iter().zip(prev.iter()).map(|(l, p)| l + (l - p) * w).collect())
            }
        }
    }
}

/// One in-flight sample: everything the denoise loop needs to advance a
/// request by one iteration, resumable at any step boundary.
///
/// Built by [`Engine::begin`], advanced by [`Engine::step_batch`],
/// consumed by [`Engine::finish`]. The state is fully self-contained —
/// scheduler history, RNG stream, uncond-eps cache, adaptive controller —
/// so a sample's trajectory is identical whether it runs solo, in a
/// lock-step batch, or through a continuously re-composed cohort.
pub struct SampleState {
    req: GenerationRequest,
    /// The compiled per-step guidance decisions. For adaptive requests
    /// this starts as the conservative all-dual overlay and is rewritten
    /// step by step with what the controller actually ran, so the
    /// finish-time invariant `unet_evals == plan.total_unet_evals()`
    /// holds for every sample.
    plan: GuidancePlan,
    controller: Option<AdaptiveController>,
    scheduler: Box<dyn Scheduler>,
    rng: Rng,
    latent: Vec<f32>,
    cond_ctx: Vec<f32>,
    cache: UncondCache,
    wants_reuse: bool,
    /// Shared-tier uncond eps staged by phase 1 of
    /// [`Engine::step_batch_shared`] for this iteration's combine
    /// (consumed exactly once).
    shared_eps: Option<Vec<f32>>,
    /// Typed per-sample failure (cold reuse cache under the shared
    /// tier): the sample stops advancing and the serving layer drains
    /// it with `Error::Engine` — the cohort keeps running.
    failed: Option<String>,
    /// Next iteration to execute (== completed iterations).
    step: usize,
    /// Iterations this trajectory runs ([`GenerationRequest::executed_steps`]).
    steps: usize,
    /// Scheduler-index offset of iteration 0: `0` for text2img, the
    /// skipped prefix for img2img (the scheduler is built for the full
    /// request step count; the plan covers only the executed suffix).
    offset: usize,
    unet_evals: usize,
    /// This sample's attributed share of loop costs (1/cohort per step).
    breakdown: StepBreakdown,
    started: Instant,
}

impl SampleState {
    /// All `steps` iterations executed?
    pub fn is_done(&self) -> bool {
        self.step >= self.steps
    }

    /// Next iteration index (== iterations completed so far).
    pub fn step_index(&self) -> usize {
        self.step
    }

    /// Total iterations this trajectory runs.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Absolute scheduler index of the next iteration (== `step_index`
    /// for text2img; shifted by the skipped prefix for img2img).
    fn sched_index(&self) -> usize {
        self.offset + self.step
    }

    /// The request this state executes.
    pub fn request(&self) -> &GenerationRequest {
        &self.req
    }

    /// UNet executions performed so far.
    pub fn unet_evals(&self) -> usize {
        self.unet_evals
    }

    /// The typed per-sample failure, if any. Failed samples never
    /// advance again and must not be `finish`ed.
    pub fn failed_reason(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    /// The uncond eps a Reuse step combines against: the staged
    /// shared-tier consumption, else the local estimate. A cold cache
    /// marks the sample failed (typed, per-sample) instead of
    /// panicking the worker thread.
    fn reuse_uncond(&mut self, kind: ReuseKind) -> Option<Vec<f32>> {
        if let Some(u) = self.shared_eps.take() {
            return Some(u);
        }
        match self.cache.estimate(self.step, kind) {
            Some(u) => Some(u),
            None => {
                self.failed =
                    Some(format!("reuse step {} with a cold uncond cache", self.step));
                None
            }
        }
    }

    /// The compiled guidance plan this sample executes (for adaptive
    /// samples: the conservative overlay, rewritten as steps run).
    pub fn plan(&self) -> &GuidancePlan {
        &self.plan
    }

    /// UNet-slot cost of the *next* iteration: 2 for a dual step, 1 for
    /// reuse/cond-only/unguided, 0 when done. Adaptive samples read the
    /// conservative overlay (2 until executed — the controller is
    /// stateful; peeking would perturb it).
    pub fn next_cost(&self) -> usize {
        self.plan.next_cost(self.step)
    }

    /// Summed UNet-slot cost of the remaining trajectory, straight from
    /// the plan IR.
    pub fn remaining_cost(&self) -> usize {
        self.plan.remaining_cost(self.step)
    }

    /// Largest per-iteration UNet-slot cost any *remaining* step can
    /// incur — `plan.peak_remaining_cost(step)`. This is the continuous
    /// batcher's admission currency: a cohort whose peak costs sum
    /// within the slot budget can never overshoot it, and a sample that
    /// has entered its selective-guidance window drops to 1 — freeing
    /// admission headroom immediately.
    pub fn peak_remaining_cost(&self) -> usize {
        self.plan.peak_remaining_cost(self.step)
    }

    /// [`Self::peak_remaining_cost`] priced through a measured
    /// [`crate::guidance::CostTable`] — the continuous batcher's
    /// admission currency under a millisecond budget (DESIGN.md §15).
    pub fn peak_remaining_cost_ms(&self, table: &crate::guidance::CostTable) -> f64 {
        self.plan.peak_remaining_cost_ms(self.step, table)
    }
}

/// What one [`Engine::step_batch`] call executed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepReport {
    /// Samples advanced one iteration (the active cohort size).
    pub advanced: usize,
    /// Samples whose trajectory completed during this call.
    pub finished: usize,
    /// UNet executions performed — the guidance slot cost of the
    /// iteration (a dual step costs 2, single-pass modes cost 1).
    pub slots_used: usize,
}

/// The serving engine: a [`ModelStack`] plus engine defaults.
pub struct Engine {
    stack: Arc<ModelStack>,
    config: EngineConfig,
    tokenizer: Tokenizer,
    /// Optional metric handles (eval counts, per-phase loop time) —
    /// write-once so an `Arc<Engine>` shared across coordinators and
    /// replicas reports into one bundle. Absent = zero overhead.
    telemetry: OnceLock<EngineMetrics>,
}

impl Engine {
    pub fn new(stack: Arc<ModelStack>, config: EngineConfig) -> Engine {
        let m = stack.model();
        let tokenizer = Tokenizer::new(m.vocab_size, m.seq_len);
        Engine { stack, config, tokenizer, telemetry: OnceLock::new() }
    }

    /// Attach engine-layer telemetry (idempotent: the first attachment
    /// wins, so replicas sharing one engine share one bundle).
    pub fn attach_telemetry(&self, t: &Arc<Telemetry>) {
        let _ = self.telemetry.set(EngineMetrics::new(t));
    }

    pub fn stack(&self) -> &Arc<ModelStack> {
        &self.stack
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// A request pre-filled from the engine defaults.
    pub fn request(&self, prompt: &str) -> GenerationRequest {
        GenerationRequest {
            prompt: prompt.to_string(),
            steps: self.config.steps,
            guidance_scale: self.config.guidance_scale,
            schedule: self.config.schedule.clone(),
            strategy: self.config.guidance_strategy,
            scheduler: self.config.scheduler,
            seed: self.config.seed,
            decode: self.config.decode_images,
            adaptive: self.config.adaptive,
            init: None,
            shared_plan: None,
        }
    }

    /// Generate one image.
    pub fn generate(&self, req: &GenerationRequest) -> Result<GenerationOutput> {
        let mut outs = self.generate_batch(std::slice::from_ref(req))?;
        Ok(outs.pop().expect("one output per request"))
    }

    /// Generate a batch in lock-step. All requests must share `steps` and
    /// `scheduler` (the batcher guarantees this); prompts, seeds, windows
    /// and scales may differ per sample. Built on the step-resumable
    /// [`Engine::begin`] / [`Engine::step_batch`] / [`Engine::finish`]
    /// primitives.
    pub fn generate_batch(&self, reqs: &[GenerationRequest]) -> Result<Vec<GenerationOutput>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let steps = reqs[0].steps;
        let sched_kind = reqs[0].scheduler;
        for r in reqs {
            r.validate()?;
            if r.steps != steps || r.scheduler != sched_kind {
                return Err(Error::Request(
                    "batched requests must share steps and scheduler".into(),
                ));
            }
        }
        let mut states: Vec<SampleState> =
            reqs.iter().map(|r| self.begin(r)).collect::<Result<_>>()?;
        let mut total_evals = 0usize;
        for _ in 0..steps {
            total_evals += self.step_batch(&mut states)?.slots_used;
        }
        // consistency: per-sample counts must sum to the executed total.
        // Hard assert (not debug_assert): the cost model is the contract
        // QoS feasibility and the benches are built on, so `--release`
        // tests must check it too (the per-sample analytic-cost assert
        // lives in `finish`).
        assert_eq!(total_evals, states.iter().map(|s| s.unet_evals).sum::<usize>());
        states.into_iter().map(|s| self.finish(s)).collect()
    }

    /// Validate a request and build its step-resumable [`SampleState`]
    /// (text encoding, scheduler, seeded noise stream, initial latent).
    pub fn begin(&self, req: &GenerationRequest) -> Result<SampleState> {
        req.validate()?;
        let plan = req.plan()?;
        self.begin_with_plan(req, plan)
    }

    /// [`Engine::begin`] for a cohort with a shared uncond cache
    /// attached (DESIGN.md §13): the plan is compiled with the
    /// cross-request anchor rule, so reuse steps may precede any local
    /// dual pass. Only meaningful when the state is then driven by
    /// [`Engine::step_batch_shared`] with a cache — a reuse step that
    /// finds neither a shared entry nor a local anchor fails that
    /// sample with a typed `Error::Engine`.
    pub fn begin_shared(&self, req: &GenerationRequest) -> Result<SampleState> {
        req.validate()?;
        let plan = req.plan_shared()?;
        self.begin_with_plan(req, plan)
    }

    fn begin_with_plan(&self, req: &GenerationRequest, plan: GuidancePlan) -> Result<SampleState> {
        let started = Instant::now();
        let m = self.stack.model();
        let cond_ctx = self.stack.encode_text(&self.tokenizer.encode(&req.prompt))?;
        // the scheduler always spans the FULL request step count; img2img
        // enters the trajectory at `offset` and runs only the suffix
        let scheduler = req.scheduler.build(NoiseSchedule::default(), req.steps);
        let steps = req.executed_steps();
        let offset = req.steps - steps;
        let mut rng = Rng::for_stream(req.seed, 0);
        let latent = if let Some(init) = &req.init {
            let x0: Vec<f32> = match &init.latent {
                Some(l) => {
                    if l.len() != m.latent_elems() {
                        return Err(Error::Request(format!(
                            "init latent has {} elems, model expects {}",
                            l.len(),
                            m.latent_elems()
                        )));
                    }
                    l.as_ref().clone()
                }
                None => synthetic_init_latent(req.seed, m.latent_elems()),
            };
            // same stream position as text2img's init draw, so the two
            // workloads stay on identical per-step noise streams
            let noise = rng.normal_vec(m.latent_elems());
            scheduler.add_noise(offset, &x0, &noise)
        } else {
            let mut latent = rng.normal_vec(m.latent_elems());
            let sigma = scheduler.init_noise_sigma();
            for v in latent.iter_mut() {
                *v *= sigma;
            }
            latent
        };
        // per-sample uncond-eps recording is gated so plans without any
        // reuse step never clone eps tensors they won't read
        let wants_reuse = plan.has_reuse();
        let mut breakdown = StepBreakdown::default();
        breakdown.overhead_ms += started.elapsed().as_secs_f64() * 1e3;
        if let Some(tm) = self.telemetry.get() {
            tm.on_begin();
        }
        Ok(SampleState {
            req: req.clone(),
            plan,
            controller: req.adaptive.map(|a| a.controller()),
            scheduler,
            rng,
            latent,
            cond_ctx,
            cache: UncondCache::new(),
            wants_reuse,
            shared_eps: None,
            failed: None,
            step: 0,
            steps,
            offset,
            unet_evals: 0,
            breakdown,
            started,
        })
    }

    /// Advance every unfinished sample in `states` by exactly one
    /// iteration, bucketizing the UNet calls across the whole cohort.
    ///
    /// Samples may sit at different step indices, step counts and
    /// schedulers — this is the iteration-level primitive the continuous
    /// batcher composes. Finished samples are skipped (zero cost), so
    /// callers may keep a mixed done/unfinished slice. Each active sample
    /// is charged `1/active` of the iteration's shared loop time.
    pub fn step_batch(&self, states: &mut [SampleState]) -> Result<StepReport> {
        self.step_batch_shared(states, None)
    }

    /// [`Engine::step_batch`] with an optional cross-request
    /// [`SharedUncondCache`] attached (DESIGN.md §13). With `None` the
    /// loop is bit-exact with the unshared engine (it *is* the unshared
    /// engine — `step_batch` delegates here). With a cache:
    ///
    /// * every dual step publishes its uncond eps under the sample's
    ///   (scheduler, step, sigma-bucket, negative-hash) key;
    /// * a Reuse-strategy sample's planned-Reuse step consumes a shared
    ///   entry when one exists within the divergence tolerance
    ///   (preferring it over local extrapolation);
    /// * a Reuse-strategy sample's planned-Dual step past step 0 may
    ///   *downgrade* to shared reuse on a tolerable entry — the
    ///   executed plan is rewritten so the eval-count invariant holds;
    /// * a reuse step with neither a shared entry nor a local anchor
    ///   fails that sample only (typed `Error::Engine`, drained by the
    ///   caller via [`SampleState::failed_reason`]) — never the cohort.
    pub fn step_batch_shared(
        &self,
        states: &mut [SampleState],
        shared: Option<&SharedUncondCache>,
    ) -> Result<StepReport> {
        let n = states.len();
        let active: Vec<usize> =
            (0..n).filter(|&s| !states[s].is_done() && states[s].failed.is_none()).collect();
        if active.is_empty() {
            return Ok(StepReport::default());
        }
        let m = self.stack.model();
        let latent_elems = m.latent_elems();
        let ctx_elems = m.ctx_elems();
        let mut bd = StepBreakdown::default();
        let mut slots_used = 0usize;

        // 1) per-sample guidance decision: static samples execute their
        // compiled plan verbatim; adaptive samples ask the (stateful)
        // controller exactly once and record the executed mode back into
        // the plan overlay, keeping the IR the audit trail for both.
        let mut modes: Vec<GuidanceMode> = vec![GuidanceMode::Unguided; n];
        for &s in &active {
            let st = &mut states[s];
            let mut mode = match st.controller.as_mut() {
                Some(ctrl) => {
                    let mode = match ctrl.decide(st.step, st.steps) {
                        AdaptiveDecision::Dual => {
                            GuidanceMode::Dual { scale: st.req.guidance_scale }
                        }
                        AdaptiveDecision::CondOnly => GuidanceMode::CondOnly,
                    };
                    st.plan.record_executed(st.step, mode);
                    mode
                }
                None => st.plan.mode(st.step),
            };
            // shared-uncond tier: only static Reuse-strategy samples
            // participate as consumers (adaptive controllers never emit
            // Reuse, and CondOnly samples have nothing to combine —
            // eligibility is `GuidanceStrategy::shared_consumer_kind`)
            if let (Some(cache), Some(kind)) = (shared, st.req.strategy.shared_consumer_kind()) {
                if st.controller.is_none() && st.wants_reuse {
                    let gi = st.sched_index();
                    let key = SharedKey::new(
                        st.req.scheduler.name(),
                        gi,
                        st.scheduler.model_timestep(gi),
                    );
                    match mode {
                        // a planned dual step past the first iteration
                        // downgrades to shared reuse when a tolerable
                        // entry exists; the executed plan is rewritten
                        // so the eval-count invariant keeps holding
                        GuidanceMode::Dual { scale } if st.step > 0 => {
                            if let Some(eps) = cache.consume(&key, &st.latent) {
                                st.cache.record(st.step, eps.clone());
                                st.shared_eps = Some(eps);
                                mode = GuidanceMode::Reuse { scale, kind };
                                st.plan.record_executed(st.step, mode);
                            }
                        }
                        // a planned reuse step prefers the shared entry
                        // (fresher than local hold/extrapolation); a
                        // miss over a cold local cache fails this
                        // sample only — before any UNet work
                        GuidanceMode::Reuse { .. } => {
                            if let Some(eps) = cache.consume(&key, &st.latent) {
                                st.cache.record(st.step, eps.clone());
                                st.shared_eps = Some(eps);
                            } else if !st.cache.warm() {
                                st.failed = Some(format!(
                                    "reuse step {} with a cold uncond cache",
                                    st.step
                                ));
                            }
                        }
                        _ => {}
                    }
                }
            }
            modes[s] = mode;
        }
        // samples that failed the cold-cache probe are excluded before
        // any UNet work; the caller drains them as `Error::Engine`
        let active: Vec<usize> =
            active.into_iter().filter(|&s| states[s].failed.is_none()).collect();
        if active.is_empty() {
            return Ok(StepReport::default());
        }
        let dual: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&s| matches!(modes[s], GuidanceMode::Dual { .. }))
            .collect();
        let reuse: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&s| matches!(modes[s], GuidanceMode::Reuse { .. }))
            .collect();

        // 2) scheduler input scaling + per-sample model timesteps
        let t0 = Instant::now();
        let mut scaled: Vec<Vec<f32>> = vec![Vec::new(); n];
        let mut t_model: Vec<f32> = vec![0.0; n];
        for &s in &active {
            let st = &states[s];
            scaled[s] = st.scheduler.scale_model_input(&st.latent, st.sched_index());
            t_model[s] = st.scheduler.model_timestep(st.sched_index());
        }
        bd.scheduler_ms += t0.elapsed().as_secs_f64() * 1e3;

        // per-sample eps_hat for this iteration
        let mut eps_hat: Vec<Vec<f32>> = vec![Vec::new(); n];
        // scratch buffers for the bucketized UNet calls, sized once for
        // the iteration's worst case
        let mut in_latents: Vec<f32> = Vec::with_capacity(active.len() * latent_elems);
        let mut in_ts: Vec<f32> = Vec::with_capacity(active.len());
        let mut in_ctx: Vec<f32> = Vec::with_capacity(active.len() * ctx_elems);
        // the unconditional context (mutex + clone) is only fetched when
        // some sample actually runs a true dual step this iteration — the
        // cond-only window phase pays nothing for it
        let uncond_ctx: Option<Vec<f32>> =
            if dual.is_empty() { None } else { Some(self.stack.uncond_ctx()?) };

        match self.config.dual_strategy {
            DualStrategy::TwoB1 => {
                // 1) conditional pass for every active sample (bucketized)
                let t0 = Instant::now();
                let eps_cond = {
                    let view: &[SampleState] = states;
                    self.unet_over(
                        &active,
                        &scaled,
                        &mut in_latents,
                        &mut in_ts,
                        &mut in_ctx,
                        |s| view[s].cond_ctx.as_slice(),
                        |s| t_model[s],
                    )?
                };
                slots_used += active.len();
                bd.unet_cond_ms += t0.elapsed().as_secs_f64() * 1e3;

                // 2) unconditional pass only for Dual samples
                let eps_uncond = if dual.is_empty() {
                    Vec::new()
                } else {
                    let uctx = uncond_ctx.as_deref().expect("uncond ctx fetched for dual steps");
                    let t0 = Instant::now();
                    let out = self.unet_over(
                        &dual,
                        &scaled,
                        &mut in_latents,
                        &mut in_ts,
                        &mut in_ctx,
                        |_| uctx,
                        |s| t_model[s],
                    )?;
                    slots_used += dual.len();
                    bd.unet_uncond_ms += t0.elapsed().as_secs_f64() * 1e3;
                    out
                };
                // position of each state inside the cond output
                let mut pos = vec![usize::MAX; n];
                for (k, &s) in active.iter().enumerate() {
                    pos[s] = k;
                }

                // 3) Eq.-1 combine on device (+ cache/controller updates)
                for (di, &s) in dual.iter().enumerate() {
                    let GuidanceMode::Dual { scale } = modes[s] else { unreachable!() };
                    let t0 = Instant::now();
                    let u = &eps_uncond[di * latent_elems..(di + 1) * latent_elems];
                    let c = &eps_cond[pos[s] * latent_elems..(pos[s] + 1) * latent_elems];
                    let st = &mut states[s];
                    if let Some(ctrl) = st.controller.as_mut() {
                        ctrl.observe_delta(guidance_delta(c, u));
                    }
                    if st.wants_reuse {
                        st.cache.record(st.step, u.to_vec());
                    }
                    if let Some(cache) = shared {
                        cache.publish(
                            SharedKey::new(st.req.scheduler.name(), st.sched_index(), t_model[s]),
                            &st.latent,
                            u,
                        );
                    }
                    eps_hat[s] = self.stack.cfg_combine(1, u, c, scale)?;
                    bd.combine_ms += t0.elapsed().as_secs_f64() * 1e3;
                }
                // reuse samples: Eq.-1 combine against the shared /
                // cached / extrapolated uncond eps (no second UNet pass)
                for &s in &reuse {
                    let GuidanceMode::Reuse { scale, kind } = modes[s] else {
                        unreachable!()
                    };
                    let t0 = Instant::now();
                    let c = &eps_cond[pos[s] * latent_elems..(pos[s] + 1) * latent_elems];
                    let Some(u_hat) = states[s].reuse_uncond(kind) else {
                        continue;
                    };
                    eps_hat[s] = self.stack.cfg_combine(1, &u_hat, c, scale)?;
                    bd.combine_ms += t0.elapsed().as_secs_f64() * 1e3;
                }
                for &s in &active {
                    if matches!(modes[s], GuidanceMode::CondOnly | GuidanceMode::Unguided) {
                        eps_hat[s] =
                            eps_cond[pos[s] * latent_elems..(pos[s] + 1) * latent_elems].to_vec();
                    }
                }
            }
            DualStrategy::FusedB2 => {
                // HF-pipeline style: each dual sample runs one fused
                // batch-2 [cond, uncond] execution
                for &s in &dual {
                    let GuidanceMode::Dual { scale } = modes[s] else { unreachable!() };
                    let t0 = Instant::now();
                    in_latents.clear();
                    in_latents.extend_from_slice(&scaled[s]);
                    in_latents.extend_from_slice(&scaled[s]);
                    let t_s = t_model[s];
                    in_ctx.clear();
                    in_ctx.extend_from_slice(&states[s].cond_ctx);
                    in_ctx.extend_from_slice(
                        uncond_ctx.as_deref().expect("uncond ctx fetched for dual steps"),
                    );
                    let both = self.stack.unet_eps(2, &in_latents, &[t_s, t_s], &in_ctx)?;
                    slots_used += 2;
                    bd.unet_cond_ms += t0.elapsed().as_secs_f64() * 1e3 / 2.0;
                    bd.unet_uncond_ms += t0.elapsed().as_secs_f64() * 1e3 / 2.0;
                    let t0 = Instant::now();
                    let (c, u) = both.split_at(latent_elems);
                    let st = &mut states[s];
                    if let Some(ctrl) = st.controller.as_mut() {
                        ctrl.observe_delta(guidance_delta(c, u));
                    }
                    if st.wants_reuse {
                        st.cache.record(st.step, u.to_vec());
                    }
                    if let Some(cache) = shared {
                        cache.publish(
                            SharedKey::new(st.req.scheduler.name(), st.sched_index(), t_model[s]),
                            &st.latent,
                            u,
                        );
                    }
                    eps_hat[s] = self.stack.cfg_combine(1, u, c, scale)?;
                    bd.combine_ms += t0.elapsed().as_secs_f64() * 1e3;
                }
                // optimized samples (reuse + cond-only/unguided): one
                // bucketized cond pass, then per-mode post-processing
                let others: Vec<usize> = active
                    .iter()
                    .copied()
                    .filter(|&s| !matches!(modes[s], GuidanceMode::Dual { .. }))
                    .collect();
                if !others.is_empty() {
                    let t0 = Instant::now();
                    let eps_cond = {
                        let view: &[SampleState] = states;
                        self.unet_over(
                            &others,
                            &scaled,
                            &mut in_latents,
                            &mut in_ts,
                            &mut in_ctx,
                            |s| view[s].cond_ctx.as_slice(),
                            |s| t_model[s],
                        )?
                    };
                    slots_used += others.len();
                    bd.unet_cond_ms += t0.elapsed().as_secs_f64() * 1e3;
                    for (oi, &s) in others.iter().enumerate() {
                        let c = &eps_cond[oi * latent_elems..(oi + 1) * latent_elems];
                        if let GuidanceMode::Reuse { scale, kind } = modes[s] {
                            let t0 = Instant::now();
                            let Some(u_hat) = states[s].reuse_uncond(kind) else {
                                continue;
                            };
                            eps_hat[s] = self.stack.cfg_combine(1, &u_hat, c, scale)?;
                            bd.combine_ms += t0.elapsed().as_secs_f64() * 1e3;
                        } else {
                            eps_hat[s] = c.to_vec();
                        }
                    }
                }
            }
        }

        // 4) scheduler update + per-sample accounting. Samples that
        // failed mid-combine (cold cache reached past the phase-1
        // probe) are frozen: no scheduler step, no eval charge.
        let t0 = Instant::now();
        for &s in &active {
            let st = &mut states[s];
            if st.failed.is_some() {
                continue;
            }
            let gi = st.sched_index();
            st.latent = st.scheduler.step(gi, &st.latent, &eps_hat[s], &mut st.rng);
            st.unet_evals += modes[s].unet_evals();
            st.step += 1;
        }
        bd.scheduler_ms += t0.elapsed().as_secs_f64() * 1e3;

        // each active sample carries 1/|active| of the shared loop cost
        // (cloning whole-cohort totals would over-report N×)
        let share = bd.scaled(1.0 / active.len() as f64);
        let mut advanced = 0usize;
        let mut finished = 0usize;
        for &s in &active {
            if states[s].failed.is_some() {
                continue;
            }
            advanced += 1;
            states[s].breakdown.accumulate(&share);
            if states[s].is_done() {
                finished += 1;
            }
        }
        debug_assert_eq!(
            slots_used,
            active.iter().map(|&s| modes[s].unet_evals()).sum::<usize>()
        );
        if let Some(tm) = self.telemetry.get() {
            // dual samples cost two UNet executions, every other mode one
            tm.on_step(&bd, slots_used - (active.len() - dual.len()), active.len() - dual.len());
        }
        Ok(StepReport { advanced, finished, slots_used })
    }

    /// Package a completed [`SampleState`] into a [`GenerationOutput`]
    /// (decode included when the request asked for it).
    ///
    /// Hard-asserts the single system-wide cost invariant — executed
    /// UNet evals == `plan.total_unet_evals()` — for *every* sample
    /// (static plans are compiled ahead of time; adaptive samples audit
    /// against their executed overlay), and that the trajectory actually
    /// ran to completion.
    pub fn finish(&self, mut state: SampleState) -> Result<GenerationOutput> {
        assert!(
            state.is_done(),
            "finish() on an unfinished sample (step {}/{})",
            state.step,
            state.steps
        );
        assert_eq!(
            state.unet_evals,
            state.plan.total_unet_evals(),
            "executed evals diverge from the guidance plan"
        );
        if let Some(tm) = self.telemetry.get() {
            tm.on_finish();
        }
        let m = self.stack.model();
        let image = if state.req.decode {
            let t0 = Instant::now();
            let chw = self.stack.decode(&state.latent)?;
            let img = RgbImage::from_chw_f32(&chw, m.image_size, m.image_size)?;
            state.breakdown.overhead_ms += t0.elapsed().as_secs_f64() * 1e3;
            Some(img)
        } else {
            None
        };
        Ok(GenerationOutput {
            latent: state.latent,
            image,
            wall_ms: state.started.elapsed().as_secs_f64() * 1e3,
            breakdown: state.breakdown,
            unet_evals: state.unet_evals,
            steps: state.steps,
            strategy: state.req.strategy,
            plan_summary: state.plan.summary(),
        })
    }

    /// Decode the *current* latent of an in-flight sample — the
    /// progressive-preview primitive behind the streaming server's
    /// `preview` event frames. Pure read: the sample's trajectory, RNG
    /// stream and caches are untouched, so previewing cannot perturb
    /// the bit-exactness invariant.
    pub fn preview(&self, state: &SampleState) -> Result<RgbImage> {
        let m = self.stack.model();
        let chw = self.stack.decode(&state.latent)?;
        RgbImage::from_chw_f32(&chw, m.image_size, m.image_size)
    }

    /// Run the UNet for the sample subset `subset`, bucketizing into the
    /// compiled batch sizes. Returns eps flattened in subset order.
    #[allow(clippy::too_many_arguments)]
    fn unet_over<'a>(
        &self,
        subset: &[usize],
        scaled_latents: &[Vec<f32>],
        in_latents: &mut Vec<f32>,
        in_ts: &mut Vec<f32>,
        in_ctx: &mut Vec<f32>,
        ctx_of: impl Fn(usize) -> &'a [f32],
        t_of: impl Fn(usize) -> f32,
    ) -> Result<Vec<f32>> {
        let m = self.stack.model();
        let latent_elems = m.latent_elems();
        let mut out = Vec::with_capacity(subset.len() * latent_elems);
        let mut cursor = 0usize;
        for bucket in self.stack.bucketize(subset.len()) {
            in_latents.clear();
            in_ts.clear();
            in_ctx.clear();
            for &s in &subset[cursor..cursor + bucket] {
                in_latents.extend_from_slice(&scaled_latents[s]);
                in_ts.push(t_of(s));
                in_ctx.extend_from_slice(ctx_of(s));
            }
            let eps = self.stack.unet_eps(bucket, in_latents, in_ts, in_ctx)?;
            out.extend_from_slice(&eps);
            cursor += bucket;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder_chain() {
        let r = GenerationRequest::new("a cat")
            .steps(25)
            .guidance_scale(9.0)
            .selective(WindowSpec::last(0.3))
            .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 })
            .scheduler(SchedulerKind::Ddim)
            .seed(7)
            .decode(false);
        assert_eq!(r.steps, 25);
        assert_eq!(r.guidance_scale, 9.0);
        assert_eq!(r.schedule, GuidanceSchedule::Window(WindowSpec::last(0.3)));
        assert_eq!(
            r.strategy,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 }
        );
        assert_eq!(r.scheduler, SchedulerKind::Ddim);
        assert_eq!(r.seed, 7);
        assert!(!r.decode);
        assert!(r.validate().is_ok());
    }

    #[test]
    fn request_validation() {
        assert!(GenerationRequest::new("").validate().is_err());
        assert!(GenerationRequest::new("x").steps(0).validate().is_err());
        assert!(GenerationRequest::new("x")
            .selective(WindowSpec::last(1.5))
            .validate()
            .is_err());
        assert!(GenerationRequest::new("x").guidance_scale(-2.0).validate().is_err());
        // adaptive supersedes the static schedule: both together is a
        // loud conflict, never a silent precedence rule
        assert!(GenerationRequest::new("x")
            .with_schedule(GuidanceSchedule::Cadence { every: 4 })
            .adaptive(AdaptiveConfig::default())
            .validate()
            .is_err());
        assert!(GenerationRequest::new("x")
            .adaptive(AdaptiveConfig::default())
            .validate()
            .is_ok());
    }

    #[test]
    fn default_request_matches_paper_setup() {
        let r = GenerationRequest::new("prompt");
        assert_eq!(r.steps, 50); // "Denoising iterations were fixed at 50"
        assert_eq!(r.guidance_scale, 7.5);
        assert_eq!(r.schedule, GuidanceSchedule::none());
        // the paper's optimized iteration drops guidance outright
        assert_eq!(r.strategy, GuidanceStrategy::CondOnly);
    }

    #[test]
    fn schedule_requests_run_end_to_end() {
        // a cadence schedule through the real engine: evals must equal
        // the compiled plan's total (finish hard-asserts the invariant)
        let e = Engine::new(
            Arc::new(crate::runtime::ModelStack::synthetic()),
            EngineConfig::default(),
        );
        let req = GenerationRequest::new("a cat")
            .steps(9)
            .scheduler(SchedulerKind::Ddim)
            .with_schedule(GuidanceSchedule::Cadence { every: 3 })
            .decode(false);
        let plan = req.plan().unwrap();
        // dual at steps 0, 3, 6 -> 3 dual + 6 single = 12 evals
        assert_eq!(plan.total_unet_evals(), 12);
        let out = e.generate(&req).unwrap();
        assert_eq!(out.unet_evals, 12);
        assert_eq!(out.plan_summary, "1D 2C 1D 2C 1D 2C");
        assert!((req.effective_shed() - 6.0 / 9.0).abs() < 1e-12);
        // executed shed (what QoS feedback keys on) agrees with the plan
        assert!((out.executed_shed() - 6.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn step_resumable_state_matches_generate() {
        // driving begin/step_batch/finish by hand must reproduce
        // Engine::generate bit-for-bit — the continuous batcher's
        // foundational property
        let e = Engine::new(
            Arc::new(crate::runtime::ModelStack::synthetic()),
            EngineConfig::default(),
        );
        let req = GenerationRequest::new("a person holding a cat")
            .steps(8)
            .scheduler(SchedulerKind::Ddim)
            .selective(WindowSpec::last(0.5))
            .seed(7)
            .decode(false);
        let reference = e.generate(&req).unwrap();

        let mut states = vec![e.begin(&req).unwrap()];
        let mut iterations = 0;
        while !states[0].is_done() {
            let report = e.step_batch(&mut states).unwrap();
            assert_eq!(report.advanced, 1);
            iterations += 1;
        }
        assert_eq!(iterations, 8);
        // stepping a finished cohort is a no-op
        assert_eq!(e.step_batch(&mut states).unwrap(), StepReport::default());
        let out = e.finish(states.pop().unwrap()).unwrap();
        assert_eq!(out.latent, reference.latent);
        assert_eq!(out.unet_evals, reference.unet_evals);
    }

    #[test]
    fn sample_state_slot_costs() {
        let e = Engine::new(
            Arc::new(crate::runtime::ModelStack::synthetic()),
            EngineConfig::default(),
        );
        // last-50% cond-only window over 8 steps: duals then singles
        let req = GenerationRequest::new("p")
            .steps(8)
            .selective(WindowSpec::last(0.5))
            .decode(false);
        let mut states = vec![e.begin(&req).unwrap()];
        assert_eq!(states[0].next_cost(), 2);
        assert_eq!(states[0].peak_remaining_cost(), 2);
        for _ in 0..4 {
            e.step_batch(&mut states).unwrap();
        }
        // inside the window: both the next step and the whole remaining
        // trajectory are single-pass — admission headroom appears here
        assert_eq!(states[0].next_cost(), 1);
        assert_eq!(states[0].peak_remaining_cost(), 1);
        for _ in 0..4 {
            e.step_batch(&mut states).unwrap();
        }
        assert!(states[0].is_done());
        assert_eq!(states[0].next_cost(), 0);
        assert_eq!(states[0].peak_remaining_cost(), 0);
        // a reuse window keeps peak cost 2 while refresh steps remain
        let reuse = GenerationRequest::new("p")
            .steps(8)
            .selective(WindowSpec::last(0.5))
            .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 1 })
            .decode(false);
        let st = e.begin(&reuse).unwrap();
        assert_eq!(st.peak_remaining_cost(), 2);
    }

    #[test]
    fn cold_reuse_cache_fails_sample_not_cohort() {
        // a full-window shared-reuse plan against an empty shared cache
        // must fail typed — one sample, not the worker/cohort
        let e = Engine::new(
            Arc::new(crate::runtime::ModelStack::synthetic()),
            EngineConfig::default(),
        );
        let shared = crate::cache::SharedUncondCache::new(0.25);
        let bad = GenerationRequest::new("cold consumer")
            .steps(4)
            .selective(WindowSpec::last(1.0))
            .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 })
            .decode(false);
        // begin (local rule) forces step 0 Dual; begin_shared plans it Reuse
        assert_eq!(e.begin(&bad).unwrap().next_cost(), 2);
        let good = GenerationRequest::new("healthy cohort mate").steps(4).decode(false);
        let mut states = vec![e.begin_shared(&bad).unwrap(), e.begin_shared(&good).unwrap()];
        let report = e.step_batch_shared(&mut states, Some(&shared)).unwrap();
        assert_eq!(
            states[0].failed_reason(),
            Some("reuse step 0 with a cold uncond cache")
        );
        assert_eq!(report.advanced, 1);
        // the failed sample is frozen; its cohort-mate runs to completion
        for _ in 0..3 {
            e.step_batch_shared(&mut states, Some(&shared)).unwrap();
        }
        assert!(states[1].is_done());
        assert_eq!(states[0].step_index(), 0);
        let good_state = states.pop().unwrap();
        e.finish(good_state).unwrap();
    }

    #[test]
    fn shared_tier_serves_trailing_consumer() {
        // publisher A runs full CFG a few steps ahead; consumer B's
        // full-window reuse plan consumes A's published uncond eps at
        // every step — full guidance at single-pass cost
        let e = Engine::new(
            Arc::new(crate::runtime::ModelStack::synthetic()),
            EngineConfig::default(),
        );
        let shared = crate::cache::SharedUncondCache::new(0.5);
        let a = GenerationRequest::new("trending prompt").steps(6).seed(11).decode(false);
        let b = GenerationRequest::new("trending prompt")
            .steps(6)
            .seed(11)
            .selective(WindowSpec::last(1.0))
            .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 })
            .decode(false);
        let mut states = vec![e.begin_shared(&a).unwrap()];
        // A steps ahead alone, publishing steps 0..3
        for _ in 0..3 {
            e.step_batch_shared(&mut states, Some(&shared)).unwrap();
        }
        states.push(e.begin_shared(&b).unwrap());
        while states.iter().any(|s| !s.is_done()) {
            let r = e.step_batch_shared(&mut states, Some(&shared)).unwrap();
            assert!(r.advanced > 0, "no sample failed");
        }
        let b_state = states.pop().unwrap();
        assert!(b_state.failed_reason().is_none());
        let out = e.finish(b_state).unwrap();
        // every one of B's steps ran single-pass off the shared tier
        assert_eq!(out.unet_evals, 6);
        assert!(shared.stats().hits >= 6);
    }

    #[test]
    fn uncond_cache_hold_and_extrapolate() {
        let mut c = UncondCache::new();
        assert!(c.estimate(0, ReuseKind::Hold).is_none());
        c.record(2, vec![1.0, 2.0]);
        // one anchor: both kinds hold
        assert_eq!(c.estimate(3, ReuseKind::Hold).unwrap(), vec![1.0, 2.0]);
        assert_eq!(c.estimate(3, ReuseKind::Extrapolate).unwrap(), vec![1.0, 2.0]);
        c.record(4, vec![3.0, 2.0]);
        // hold replays the newest anchor
        assert_eq!(c.estimate(5, ReuseKind::Hold).unwrap(), vec![3.0, 2.0]);
        // extrapolate continues the (2 -> 4) trend one half-gap further:
        // slope (3-1)/2 = 1 per iteration on the first element
        assert_eq!(c.estimate(5, ReuseKind::Extrapolate).unwrap(), vec![4.0, 2.0]);
        assert_eq!(c.estimate(6, ReuseKind::Extrapolate).unwrap(), vec![5.0, 2.0]);
    }

    #[test]
    fn img2img_truncates_the_trajectory() {
        let e = Engine::new(
            Arc::new(crate::runtime::ModelStack::synthetic()),
            EngineConfig::default(),
        );
        let req = GenerationRequest::new("a cat")
            .steps(10)
            .scheduler(SchedulerKind::Ddim)
            .img2img(0.4)
            .decode(false);
        assert_eq!(req.executed_steps(), 4);
        // the plan covers only the executed suffix, so pricing shrinks too
        assert_eq!(req.plan().unwrap().total_unet_evals(), 8);
        let out = e.generate(&req).unwrap();
        assert_eq!(out.steps, 4);
        assert_eq!(out.unet_evals, 8);
        // bad strengths are rejected; wrong-size explicit latents fail at begin
        assert!(GenerationRequest::new("x").img2img(0.0).validate().is_err());
        assert!(GenerationRequest::new("x").img2img(1.5).validate().is_err());
        let wrong = GenerationRequest::new("x")
            .steps(4)
            .init_latent(Arc::new(vec![0.0; 3]), 0.5)
            .decode(false);
        assert!(e.begin(&wrong).is_err());
    }

    #[test]
    fn img2img_synthetic_init_is_the_seeded_latent() {
        // strength-only img2img == the same request with its synthetic
        // init passed explicitly (every surface derives the same init)
        let e = Engine::new(
            Arc::new(crate::runtime::ModelStack::synthetic()),
            EngineConfig::default(),
        );
        let elems = e.stack().model().latent_elems();
        let init = Arc::new(synthetic_init_latent(9, elems));
        let a = GenerationRequest::new("p").steps(8).seed(9).img2img(0.5).decode(false);
        let b = GenerationRequest::new("p")
            .steps(8)
            .seed(9)
            .init_latent(init, 0.5)
            .decode(false);
        assert_eq!(e.generate(&a).unwrap().latent, e.generate(&b).unwrap().latent);
    }

    #[test]
    fn variations_share_one_plan_and_match_standalone_requests() {
        let e = Engine::new(
            Arc::new(crate::runtime::ModelStack::synthetic()),
            EngineConfig::default(),
        );
        let base = GenerationRequest::new("v")
            .steps(6)
            .selective(WindowSpec::last(0.5))
            .decode(false);
        let vars = base.variations(3).unwrap();
        assert_eq!(vars.len(), 3);
        let p0 = vars[0].shared_plan.as_ref().unwrap();
        assert!(vars.iter().all(|r| Arc::ptr_eq(r.shared_plan.as_ref().unwrap(), p0)));
        assert_eq!(vars[1].seed, base.seed.wrapping_add(1));
        // a variation's output is bit-exact with the standalone request
        // at the same seed — the shared plan is an amortization, never a
        // semantic change
        let mut solo = base.clone();
        solo.seed = base.seed.wrapping_add(1);
        assert_eq!(e.generate(&vars[1]).unwrap().latent, e.generate(&solo).unwrap().latent);
        assert!(base.variations(0).is_err());
    }
}
