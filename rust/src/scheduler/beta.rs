//! β-schedules and the cumulative noise tables shared by all schedulers.

/// How β_t varies over the training timesteps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BetaSchedule {
    /// Linear in β (DDPM paper).
    Linear,
    /// Linear in sqrt(β) (Stable Diffusion's `scaled_linear`).
    ScaledLinear,
    /// Cosine ᾱ schedule (Nichol & Dhariwal) with β clipping.
    Cosine,
}

/// Precomputed noise tables over the training timesteps.
#[derive(Debug, Clone)]
pub struct NoiseSchedule {
    pub betas: Vec<f64>,
    pub alphas: Vec<f64>,
    pub alphas_cumprod: Vec<f64>,
    pub kind: BetaSchedule,
}

impl Default for NoiseSchedule {
    /// Stable Diffusion's defaults: scaled-linear, β in [8.5e-4, 1.2e-2],
    /// 1000 train timesteps.
    fn default() -> Self {
        NoiseSchedule::new(BetaSchedule::ScaledLinear, 1000, 0.00085, 0.012)
    }
}

impl NoiseSchedule {
    pub fn new(kind: BetaSchedule, train_timesteps: usize, beta_start: f64, beta_end: f64) -> Self {
        assert!(train_timesteps >= 2);
        assert!(0.0 < beta_start && beta_start <= beta_end && beta_end < 1.0);
        let n = train_timesteps;
        let betas: Vec<f64> = match kind {
            BetaSchedule::Linear => (0..n)
                .map(|i| beta_start + (beta_end - beta_start) * i as f64 / (n - 1) as f64)
                .collect(),
            BetaSchedule::ScaledLinear => {
                let (s, e) = (beta_start.sqrt(), beta_end.sqrt());
                (0..n)
                    .map(|i| {
                        let b = s + (e - s) * i as f64 / (n - 1) as f64;
                        b * b
                    })
                    .collect()
            }
            BetaSchedule::Cosine => {
                let f = |t: f64| ((t + 0.008) / 1.008 * std::f64::consts::FRAC_PI_2).cos().powi(2);
                (0..n)
                    .map(|i| {
                        let t0 = i as f64 / n as f64;
                        let t1 = (i + 1) as f64 / n as f64;
                        (1.0 - f(t1) / f(t0)).clamp(1e-8, 0.999)
                    })
                    .collect()
            }
        };
        let alphas: Vec<f64> = betas.iter().map(|b| 1.0 - b).collect();
        let mut alphas_cumprod = Vec::with_capacity(n);
        let mut acc = 1.0;
        for &a in &alphas {
            acc *= a;
            alphas_cumprod.push(acc);
        }
        NoiseSchedule { betas, alphas, alphas_cumprod, kind }
    }

    pub fn train_timesteps(&self) -> usize {
        self.betas.len()
    }

    /// ᾱ_t (cumulative product of α up to and including t).
    pub fn alpha_bar(&self, t: usize) -> f64 {
        self.alphas_cumprod[t]
    }

    /// ᾱ for "one before the trajectory starts" (t = -1) == 1.
    pub fn alpha_bar_prev(&self, t_prev: Option<usize>) -> f64 {
        match t_prev {
            Some(t) => self.alphas_cumprod[t],
            None => 1.0,
        }
    }

    /// σ_t in the variance-exploding parameterization:
    /// `sigma_t = sqrt((1 - ᾱ_t) / ᾱ_t)` — used by the Euler family.
    pub fn sigma(&self, t: usize) -> f64 {
        let ab = self.alpha_bar(t);
        ((1.0 - ab) / ab).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    #[test]
    fn default_matches_sd_config() {
        let s = NoiseSchedule::default();
        assert_eq!(s.train_timesteps(), 1000);
        assert!((s.betas[0] - 0.00085).abs() < 1e-12);
        assert!((s.betas[999] - 0.012).abs() < 1e-12);
    }

    #[test]
    fn alpha_bar_strictly_decreasing_all_kinds() {
        for kind in [BetaSchedule::Linear, BetaSchedule::ScaledLinear, BetaSchedule::Cosine] {
            let s = NoiseSchedule::new(kind, 1000, 0.00085, 0.012);
            for t in 1..1000 {
                assert!(
                    s.alpha_bar(t) < s.alpha_bar(t - 1),
                    "{kind:?}: alpha_bar not decreasing at {t}"
                );
            }
            assert!(s.alpha_bar(0) < 1.0);
            assert!(s.alpha_bar(999) > 0.0);
        }
    }

    #[test]
    fn betas_within_bounds() {
        forall("beta bounds", 30, |g| {
            let n = g.usize_in(2, 2000);
            let b0 = g.f64_in(1e-5, 1e-3);
            let b1 = g.f64_in(b0, 0.05);
            for kind in [BetaSchedule::Linear, BetaSchedule::ScaledLinear] {
                let s = NoiseSchedule::new(kind, n, b0, b1);
                for &b in &s.betas {
                    assert!(b >= b0 - 1e-12 && b <= b1 + 1e-12, "{kind:?} b={b}");
                }
            }
        });
    }

    #[test]
    fn cosine_betas_clipped() {
        let s = NoiseSchedule::new(BetaSchedule::Cosine, 1000, 0.00085, 0.012);
        for &b in &s.betas {
            assert!(b > 0.0 && b <= 0.999);
        }
    }

    #[test]
    fn sigma_increasing_in_t() {
        let s = NoiseSchedule::default();
        assert!(s.sigma(999) > s.sigma(500));
        assert!(s.sigma(500) > s.sigma(0));
        assert!(s.sigma(0) > 0.0);
    }

    #[test]
    fn alpha_bar_prev_boundary() {
        let s = NoiseSchedule::default();
        assert_eq!(s.alpha_bar_prev(None), 1.0);
        assert_eq!(s.alpha_bar_prev(Some(10)), s.alpha_bar(10));
    }

    #[test]
    fn cumprod_consistency() {
        let s = NoiseSchedule::default();
        let mut acc = 1.0;
        for t in 0..100 {
            acc *= s.alphas[t];
            assert!((s.alpha_bar(t) - acc).abs() < 1e-12);
        }
    }
}
