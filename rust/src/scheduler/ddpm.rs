//! DDPM ancestral sampling (Ho et al. 2020), adapted to subsampled
//! inference timesteps.

use super::{leading_timesteps, NoiseSchedule, Scheduler, SchedulerKind};
use crate::rng::Rng;

/// Stochastic DDPM stepper.
#[derive(Debug, Clone)]
pub struct Ddpm {
    schedule: NoiseSchedule,
    timesteps: Vec<usize>,
}

impl Ddpm {
    pub fn new(schedule: NoiseSchedule, num_steps: usize) -> Self {
        let timesteps = leading_timesteps(schedule.train_timesteps(), num_steps);
        Ddpm { schedule, timesteps }
    }
}

impl Scheduler for Ddpm {
    fn timesteps(&self) -> &[usize] {
        &self.timesteps
    }

    fn add_noise(&self, i: usize, x0: &[f32], noise: &[f32]) -> Vec<f32> {
        assert_eq!(x0.len(), noise.len());
        let ab = self.schedule.alpha_bar(self.timesteps[i]);
        let sqrt_ab = ab.sqrt() as f32;
        let sqrt_1mab = (1.0 - ab).sqrt() as f32;
        x0.iter().zip(noise).map(|(&x, &e)| sqrt_ab * x + sqrt_1mab * e).collect()
    }

    fn step(&mut self, i: usize, sample: &[f32], eps: &[f32], rng: &mut Rng) -> Vec<f32> {
        assert_eq!(sample.len(), eps.len());
        let t = self.timesteps[i];
        let t_prev = self.timesteps.get(i + 1).copied();
        let ab_t = self.schedule.alpha_bar(t);
        let ab_prev = self.schedule.alpha_bar_prev(t_prev);
        // effective single-step alpha/beta over the (possibly subsampled)
        // interval [t_prev, t]
        let alpha_t = ab_t / ab_prev;
        let beta_t = 1.0 - alpha_t;

        // mean: standard posterior mean via predicted x0 (clip-free)
        let sqrt_ab_t = ab_t.sqrt();
        let sqrt_1mab_t = (1.0 - ab_t).sqrt();
        // posterior variance (Ho et al. eq. 7): β̃ = (1-ᾱ_prev)/(1-ᾱ_t) β_t
        let var = if t_prev.is_some() {
            ((1.0 - ab_prev) / (1.0 - ab_t) * beta_t).max(0.0)
        } else {
            0.0 // final step is deterministic
        };
        let sigma = var.sqrt() as f32;

        let c_x0 = (ab_prev.sqrt() * beta_t / (1.0 - ab_t)) as f32;
        let c_xt = (alpha_t.sqrt() * (1.0 - ab_prev) / (1.0 - ab_t)) as f32;

        sample
            .iter()
            .zip(eps)
            .map(|(&x, &e)| {
                let x0 = ((x as f64 - sqrt_1mab_t * e as f64) / sqrt_ab_t) as f32;
                let mean = c_x0 * x0 + c_xt * x;
                if sigma > 0.0 {
                    mean + sigma * rng.next_normal() as f32
                } else {
                    mean
                }
            })
            .collect()
    }

    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Ddpm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    fn make(n: usize) -> Ddpm {
        Ddpm::new(NoiseSchedule::default(), n)
    }

    #[test]
    fn reproducible_with_same_rng_seed() {
        let mut s1 = make(10);
        let mut s2 = make(10);
        let x = vec![0.5f32; 8];
        let e = vec![0.1f32; 8];
        let out1 = s1.step(0, &x, &e, &mut Rng::new(7));
        let out2 = s2.step(0, &x, &e, &mut Rng::new(7));
        assert_eq!(out1, out2);
    }

    #[test]
    fn stochastic_across_seeds() {
        let mut s = make(10);
        let x = vec![0.5f32; 8];
        let e = vec![0.1f32; 8];
        let out1 = s.step(0, &x, &e, &mut Rng::new(1));
        let out2 = s.step(0, &x, &e, &mut Rng::new(2));
        assert_ne!(out1, out2);
    }

    #[test]
    fn final_step_deterministic() {
        // last step has zero posterior variance: rng must not matter
        let mut s = make(5);
        let x = vec![0.5f32; 8];
        let e = vec![0.1f32; 8];
        let out1 = s.step(4, &x, &e, &mut Rng::new(1));
        let out2 = s.step(4, &x, &e, &mut Rng::new(2));
        assert_eq!(out1, out2);
    }

    #[test]
    fn mean_matches_ddim_direction() {
        // DDPM's posterior mean and DDIM's deterministic step both move
        // toward the same x0; with eps=0 and x fixed, both should shrink
        // x by a similar factor (not equal — different interpolants).
        let mut ddpm = make(10);
        let x = vec![1.0f32; 4];
        let e = vec![0.0f32; 4];
        // average many stochastic draws to estimate the mean
        let mut acc = vec![0.0f64; 4];
        let trials = 4000;
        for seed in 0..trials {
            let out = ddpm.step(0, &x, &e, &mut Rng::new(seed));
            for (a, o) in acc.iter_mut().zip(out) {
                *a += o as f64;
            }
        }
        let mean = acc[0] / trials as f64;
        let mut ddim = super::super::Ddim::new(NoiseSchedule::default(), 10);
        let ddim_out = ddim.step(0, &x, &e, &mut Rng::new(0));
        assert!(
            (mean - ddim_out[0] as f64).abs() < 0.05,
            "ddpm mean {mean} vs ddim {}",
            ddim_out[0]
        );
    }

    #[test]
    fn variance_positive_mid_trajectory() {
        forall("ddpm variance sign", 30, |g| {
            let n = g.usize_in(2, 50);
            let mut s = make(n);
            let i = g.usize_in(0, n - 2); // non-final
            let x = vec![0.0f32; 64];
            let e = vec![0.0f32; 64];
            let out = s.step(i, &x, &e, &mut Rng::new(g.u64()));
            // zero mean inputs + noise => some nonzero outputs
            assert!(out.iter().any(|v| *v != 0.0), "no noise injected at step {i}");
        });
    }
}
