//! Euler-discrete and Euler-ancestral steppers (k-diffusion style),
//! operating in sigma space: `x = sqrt(ᾱ) x0 + sqrt(1-ᾱ) ε` is rewritten
//! as `x/sqrt(ᾱ) = x0 + σ ε` with `σ = sqrt((1-ᾱ)/ᾱ)`.

use super::{leading_timesteps, NoiseSchedule, Scheduler, SchedulerKind};
use crate::rng::Rng;

fn sigmas_for(schedule: &NoiseSchedule, timesteps: &[usize]) -> Vec<f64> {
    // one sigma per inference step, plus the terminal 0
    let mut s: Vec<f64> = timesteps.iter().map(|&t| schedule.sigma(t)).collect();
    s.push(0.0);
    s
}

/// Deterministic Euler stepper.
#[derive(Debug, Clone)]
pub struct Euler {
    timesteps: Vec<usize>,
    sigmas: Vec<f64>,
}

impl Euler {
    pub fn new(schedule: NoiseSchedule, num_steps: usize) -> Self {
        let timesteps = leading_timesteps(schedule.train_timesteps(), num_steps);
        let sigmas = sigmas_for(&schedule, &timesteps);
        Euler { timesteps, sigmas }
    }
}

fn euler_step(sample: &[f32], eps: &[f32], sigma: f64, sigma_next: f64) -> Vec<f32> {
    // derivative d = eps (the eps-prediction is the score direction in
    // sigma space); x_{i+1} = x + (σ_{i+1} - σ_i) d
    let dt = (sigma_next - sigma) as f32;
    sample.iter().zip(eps).map(|(&x, &e)| x + dt * e).collect()
}

impl Scheduler for Euler {
    fn timesteps(&self) -> &[usize] {
        &self.timesteps
    }

    fn init_noise_sigma(&self) -> f32 {
        self.sigmas[0] as f32
    }

    fn scale_model_input(&self, sample: &[f32], i: usize) -> Vec<f32> {
        let s = self.sigmas[i];
        let scale = (1.0 / (s * s + 1.0).sqrt()) as f32;
        sample.iter().map(|&x| x * scale).collect()
    }

    fn add_noise(&self, i: usize, x0: &[f32], noise: &[f32]) -> Vec<f32> {
        assert_eq!(x0.len(), noise.len());
        let s = self.sigmas[i] as f32;
        x0.iter().zip(noise).map(|(&x, &e)| x + s * e).collect()
    }

    fn step(&mut self, i: usize, sample: &[f32], eps: &[f32], _rng: &mut Rng) -> Vec<f32> {
        assert_eq!(sample.len(), eps.len());
        euler_step(sample, eps, self.sigmas[i], self.sigmas[i + 1])
    }

    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Euler
    }
}

/// Stochastic Euler-ancestral stepper.
#[derive(Debug, Clone)]
pub struct EulerAncestral {
    timesteps: Vec<usize>,
    sigmas: Vec<f64>,
}

impl EulerAncestral {
    pub fn new(schedule: NoiseSchedule, num_steps: usize) -> Self {
        let timesteps = leading_timesteps(schedule.train_timesteps(), num_steps);
        let sigmas = sigmas_for(&schedule, &timesteps);
        EulerAncestral { timesteps, sigmas }
    }
}

impl Scheduler for EulerAncestral {
    fn timesteps(&self) -> &[usize] {
        &self.timesteps
    }

    fn init_noise_sigma(&self) -> f32 {
        self.sigmas[0] as f32
    }

    fn scale_model_input(&self, sample: &[f32], i: usize) -> Vec<f32> {
        let s = self.sigmas[i];
        let scale = (1.0 / (s * s + 1.0).sqrt()) as f32;
        sample.iter().map(|&x| x * scale).collect()
    }

    fn add_noise(&self, i: usize, x0: &[f32], noise: &[f32]) -> Vec<f32> {
        assert_eq!(x0.len(), noise.len());
        let s = self.sigmas[i] as f32;
        x0.iter().zip(noise).map(|(&x, &e)| x + s * e).collect()
    }

    fn step(&mut self, i: usize, sample: &[f32], eps: &[f32], rng: &mut Rng) -> Vec<f32> {
        assert_eq!(sample.len(), eps.len());
        let sigma = self.sigmas[i];
        let sigma_next = self.sigmas[i + 1];
        // ancestral split: sigma_next^2 = sigma_down^2 + sigma_up^2
        let (sigma_down, sigma_up) = if sigma_next == 0.0 {
            (0.0, 0.0)
        } else {
            let up2 = sigma_next.powi(2) * (sigma.powi(2) - sigma_next.powi(2)) / sigma.powi(2);
            let up = up2.max(0.0).sqrt().min(sigma_next);
            let down = (sigma_next.powi(2) - up * up).max(0.0).sqrt();
            (down, up)
        };
        let mut out = euler_step(sample, eps, sigma, sigma_down);
        if sigma_up > 0.0 {
            for v in out.iter_mut() {
                *v += (sigma_up as f32) * rng.next_normal() as f32;
            }
        }
        out
    }

    fn kind(&self) -> SchedulerKind {
        SchedulerKind::EulerAncestral
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    #[test]
    fn sigmas_descend_to_zero() {
        let e = Euler::new(NoiseSchedule::default(), 50);
        assert_eq!(e.sigmas.len(), 51);
        assert!(e.sigmas.windows(2).all(|w| w[0] > w[1]));
        assert_eq!(*e.sigmas.last().unwrap(), 0.0);
        assert!(e.init_noise_sigma() > 1.0); // SD's terminal sigma ~ 14
    }

    #[test]
    fn zero_eps_is_identity() {
        let mut e = Euler::new(NoiseSchedule::default(), 10);
        let x = vec![0.3f32; 8];
        let eps = vec![0.0f32; 8];
        let out = e.step(0, &x, &eps, &mut Rng::new(0));
        assert_eq!(out, x);
    }

    #[test]
    fn oracle_recovery_full_trajectory() {
        // x = x0 + sigma*eps with fixed eps; stepping with that eps must
        // return exactly x0 at sigma=0 (Euler integrates a straight ray).
        forall("euler oracle", 20, |g| {
            let n = g.usize_in(2, 60);
            let mut e = Euler::new(NoiseSchedule::default(), n);
            let dim = 10;
            let x0: Vec<f32> = (0..dim).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let eps: Vec<f32> = (0..dim).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let s0 = e.sigmas[0] as f32;
            let mut x: Vec<f32> = x0.iter().zip(&eps).map(|(&a, &b)| a + s0 * b).collect();
            let mut rng = Rng::new(0);
            for i in 0..n {
                x = e.step(i, &x, &eps, &mut rng);
            }
            for (a, b) in x.iter().zip(&x0) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn scale_model_input_bounded() {
        let e = Euler::new(NoiseSchedule::default(), 10);
        let x = vec![1.0f32; 4];
        for i in 0..10 {
            let scaled = e.scale_model_input(&x, i);
            assert!(scaled[0] > 0.0 && scaled[0] <= 1.0);
        }
        // high sigma -> strong downscaling at the first step
        assert!(e.scale_model_input(&x, 0)[0] < 0.2);
    }

    #[test]
    fn ancestral_variance_decomposition() {
        let ea = EulerAncestral::new(NoiseSchedule::default(), 20);
        for i in 0..19 {
            let sigma = ea.sigmas[i];
            let sigma_next = ea.sigmas[i + 1];
            let up2 = sigma_next.powi(2) * (sigma.powi(2) - sigma_next.powi(2)) / sigma.powi(2);
            let up = up2.max(0.0).sqrt().min(sigma_next);
            let down = (sigma_next.powi(2) - up * up).max(0.0).sqrt();
            assert!(((down * down + up * up) - sigma_next * sigma_next).abs() < 1e-9);
        }
    }

    #[test]
    fn ancestral_reproducible_and_stochastic() {
        let mut ea = EulerAncestral::new(NoiseSchedule::default(), 10);
        let x = vec![0.5f32; 8];
        let eps = vec![0.1f32; 8];
        let a = ea.step(0, &x, &eps, &mut Rng::new(5));
        let b = ea.step(0, &x, &eps, &mut Rng::new(5));
        let c = ea.step(0, &x, &eps, &mut Rng::new(6));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn ancestral_final_step_deterministic() {
        let mut ea = EulerAncestral::new(NoiseSchedule::default(), 10);
        let x = vec![0.5f32; 8];
        let eps = vec![0.1f32; 8];
        let a = ea.step(9, &x, &eps, &mut Rng::new(1));
        let b = ea.step(9, &x, &eps, &mut Rng::new(2));
        assert_eq!(a, b);
    }
}
