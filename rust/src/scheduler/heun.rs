//! Heun's second-order method (Karras et al. 2022, Algorithm 1 without
//! stochastic churn) in sigma space.
//!
//! Heun needs a *second* model evaluation at the predicted point to form
//! the trapezoidal correction. The engine drives this through the
//! [`Scheduler`] contract by treating each inference step as one call —
//! Heun here applies the correction using the *same* eps for both slopes
//! when no second evaluation is available (degenerating to Euler), and
//! exposes [`Heun::step2`] for callers that can afford the second eval.
//! The serving engine uses the one-eval path (the paper's cost model
//! counts UNet evaluations; doubling them would confound Table 1), while
//! tests exercise both.

use super::{leading_timesteps, NoiseSchedule, Scheduler, SchedulerKind};
use crate::rng::Rng;

/// Heun stepper (deterministic).
#[derive(Debug, Clone)]
pub struct Heun {
    timesteps: Vec<usize>,
    sigmas: Vec<f64>,
}

impl Heun {
    pub fn new(schedule: NoiseSchedule, num_steps: usize) -> Self {
        let timesteps = leading_timesteps(schedule.train_timesteps(), num_steps);
        let mut sigmas: Vec<f64> = timesteps.iter().map(|&t| schedule.sigma(t)).collect();
        sigmas.push(0.0);
        Heun { timesteps, sigmas }
    }

    /// Full two-evaluation Heun step: the caller provides a closure that
    /// evaluates eps at (sample, step-index-like sigma position).
    pub fn step2(
        &self,
        i: usize,
        sample: &[f32],
        eps: &[f32],
        eval_at_next: impl FnOnce(&[f32]) -> Vec<f32>,
    ) -> Vec<f32> {
        let sigma = self.sigmas[i];
        let sigma_next = self.sigmas[i + 1];
        let dt = (sigma_next - sigma) as f32;
        // Euler predictor
        let predicted: Vec<f32> =
            sample.iter().zip(eps).map(|(&x, &e)| x + dt * e).collect();
        if sigma_next == 0.0 {
            return predicted; // final step: Euler per Karras Alg. 1
        }
        // trapezoidal corrector
        let eps2 = eval_at_next(&predicted);
        sample
            .iter()
            .zip(eps.iter().zip(&eps2))
            .map(|(&x, (&e1, &e2))| x + dt * 0.5 * (e1 + e2))
            .collect()
    }
}

impl Scheduler for Heun {
    fn timesteps(&self) -> &[usize] {
        &self.timesteps
    }

    fn init_noise_sigma(&self) -> f32 {
        self.sigmas[0] as f32
    }

    fn scale_model_input(&self, sample: &[f32], i: usize) -> Vec<f32> {
        let s = self.sigmas[i];
        let scale = (1.0 / (s * s + 1.0).sqrt()) as f32;
        sample.iter().map(|&x| x * scale).collect()
    }

    fn add_noise(&self, i: usize, x0: &[f32], noise: &[f32]) -> Vec<f32> {
        assert_eq!(x0.len(), noise.len());
        let s = self.sigmas[i] as f32;
        x0.iter().zip(noise).map(|(&x, &e)| x + s * e).collect()
    }

    fn step(&mut self, i: usize, sample: &[f32], eps: &[f32], _rng: &mut Rng) -> Vec<f32> {
        // one-eval contract: both slopes equal -> Euler step
        assert_eq!(sample.len(), eps.len());
        let dt = (self.sigmas[i + 1] - self.sigmas[i]) as f32;
        sample.iter().zip(eps).map(|(&x, &e)| x + dt * e).collect()
    }

    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Heun
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    fn make(n: usize) -> Heun {
        Heun::new(NoiseSchedule::default(), n)
    }

    #[test]
    fn one_eval_path_equals_euler() {
        let mut h = make(10);
        let mut e = super::super::Euler::new(NoiseSchedule::default(), 10);
        let x: Vec<f32> = (0..6).map(|i| i as f32 * 0.3 - 0.9).collect();
        let eps: Vec<f32> = (0..6).map(|i| 0.4 - i as f32 * 0.1).collect();
        let mut rng = Rng::new(0);
        assert_eq!(h.step(2, &x, &eps, &mut rng), e.step(2, &x, &eps, &mut rng));
    }

    #[test]
    fn step2_with_equal_slopes_equals_euler() {
        let h = make(10);
        let x = vec![1.0f32; 4];
        let eps = vec![0.5f32; 4];
        let euler: Vec<f32> = {
            let dt = (h.sigmas[1] - h.sigmas[0]) as f32;
            x.iter().map(|&v| v + dt * 0.5).collect()
        };
        let out = h.step2(0, &x, &eps, |_| eps.clone());
        for (a, b) in out.iter().zip(&euler) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn step2_final_step_is_euler_predictor() {
        let h = make(5);
        let x = vec![0.3f32; 4];
        let eps = vec![0.2f32; 4];
        let mut called = false;
        let out = h.step2(4, &x, &eps, |_| {
            called = true;
            vec![0.0; 4]
        });
        assert!(!called, "final step must not request a second eval");
        let dt = (0.0 - h.sigmas[4]) as f32;
        for (o, &xv) in out.iter().zip(&x) {
            assert!((o - (xv + dt * 0.2)).abs() < 1e-6);
        }
    }

    #[test]
    fn heun_beats_euler_on_curved_trajectory() {
        // integrate dx/dsigma = -x (a curved exact solution x ∝ e^{-sigma})
        // from sigma_0 to 0; Heun's trapezoidal correction must land
        // closer to the exact endpoint than Euler for the same step count
        forall("heun order", 10, |g| {
            let n = g.usize_in(8, 40);
            let h = make(n);
            let x0 = g.f32_in(0.5, 2.0);
            // run both integrators with slope field f(x) = -x
            let mut xe = vec![x0];
            let mut xh = vec![x0];
            for i in 0..n {
                let dt = (h.sigmas[i + 1] - h.sigmas[i]) as f32;
                // euler
                let e1 = -xe[0];
                xe[0] += dt * e1;
                // heun via step2
                let cur = xh[0];
                let eps1 = vec![-cur];
                let out = h.step2(i, &[cur], &eps1, |pred| vec![-pred[0]]);
                xh[0] = out[0];
            }
            // dx/dsigma = -x integrated from sigma_0 down to 0:
            // x(0) = x0 * e^{sigma_0} (dsigma < 0 makes x grow)
            let exact_end = x0 * ((h.sigmas[0] as f32).exp());
            let err_e = (xe[0] - exact_end).abs();
            let err_h = (xh[0] - exact_end).abs();
            assert!(
                err_h <= err_e * 1.001,
                "heun {err_h} should beat euler {err_e} (n={n})"
            );
        });
    }
}
