//! DDIM (Song et al. 2021) with η = 0 — fully deterministic stepping.

use super::{leading_timesteps, NoiseSchedule, Scheduler, SchedulerKind};
use crate::rng::Rng;

/// Deterministic DDIM stepper.
#[derive(Debug, Clone)]
pub struct Ddim {
    schedule: NoiseSchedule,
    timesteps: Vec<usize>,
}

impl Ddim {
    pub fn new(schedule: NoiseSchedule, num_steps: usize) -> Self {
        let timesteps = leading_timesteps(schedule.train_timesteps(), num_steps);
        Ddim { schedule, timesteps }
    }

    /// Predicted x0 from (x_t, eps): `(x_t - sqrt(1-ᾱ_t) eps) / sqrt(ᾱ_t)`.
    pub fn predict_x0(&self, i: usize, sample: &[f32], eps: &[f32]) -> Vec<f32> {
        let t = self.timesteps[i];
        let ab = self.schedule.alpha_bar(t);
        let sqrt_ab = ab.sqrt() as f32;
        let sqrt_1mab = (1.0 - ab).sqrt() as f32;
        sample
            .iter()
            .zip(eps)
            .map(|(&x, &e)| (x - sqrt_1mab * e) / sqrt_ab)
            .collect()
    }
}

impl Scheduler for Ddim {
    fn timesteps(&self) -> &[usize] {
        &self.timesteps
    }

    fn add_noise(&self, i: usize, x0: &[f32], noise: &[f32]) -> Vec<f32> {
        assert_eq!(x0.len(), noise.len());
        let ab = self.schedule.alpha_bar(self.timesteps[i]);
        let sqrt_ab = ab.sqrt() as f32;
        let sqrt_1mab = (1.0 - ab).sqrt() as f32;
        x0.iter().zip(noise).map(|(&x, &e)| sqrt_ab * x + sqrt_1mab * e).collect()
    }

    fn step(&mut self, i: usize, sample: &[f32], eps: &[f32], _rng: &mut Rng) -> Vec<f32> {
        assert_eq!(sample.len(), eps.len());
        let t = self.timesteps[i];
        let t_prev = self.timesteps.get(i + 1).copied();
        let ab_t = self.schedule.alpha_bar(t);
        let ab_prev = self.schedule.alpha_bar_prev(t_prev);

        let sqrt_ab_t = ab_t.sqrt() as f32;
        let sqrt_1mab_t = (1.0 - ab_t).sqrt() as f32;
        let sqrt_ab_prev = ab_prev.sqrt() as f32;
        let sqrt_1mab_prev = (1.0 - ab_prev).sqrt() as f32;

        // x0 estimate, then reproject to t_prev along the same eps
        sample
            .iter()
            .zip(eps)
            .map(|(&x, &e)| {
                let x0 = (x - sqrt_1mab_t * e) / sqrt_ab_t;
                sqrt_ab_prev * x0 + sqrt_1mab_prev * e
            })
            .collect()
    }

    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Ddim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    fn make(n: usize) -> Ddim {
        Ddim::new(NoiseSchedule::default(), n)
    }

    #[test]
    fn deterministic() {
        let mut s1 = make(10);
        let mut s2 = make(10);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(999); // rng must not matter for DDIM
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        let e: Vec<f32> = (0..8).map(|i| (i as f32 - 4.0) * 0.2).collect();
        assert_eq!(s1.step(0, &x, &e, &mut r1), s2.step(0, &x, &e, &mut r2));
    }

    #[test]
    fn zero_eps_rescales_toward_x0() {
        // with eps = 0, x_{t-1} = sqrt(ᾱ_prev/ᾱ_t) * x_t
        let mut s = make(10);
        let x = vec![1.0f32; 4];
        let e = vec![0.0f32; 4];
        let t = s.timesteps[0];
        let tp = s.timesteps[1];
        let expect = (s.schedule.alpha_bar(tp) / s.schedule.alpha_bar(t)).sqrt() as f32;
        let out = s.step(0, &x, &e, &mut Rng::new(0));
        for v in out {
            assert!((v - expect).abs() < 1e-6, "{v} vs {expect}");
        }
    }

    #[test]
    fn exact_x0_recovery_with_oracle_eps() {
        // Construct x_t = sqrt(ᾱ_t) x0 + sqrt(1-ᾱ_t) ε with a FIXED ε.
        // Feeding that exact ε at every step must hand back x0 at the end
        // (DDIM inverts its own forward map along a fixed noise ray).
        forall("ddim oracle recovery", 20, |g| {
            let n = g.usize_in(2, 50);
            let mut s = make(n);
            let dim = 12;
            let x0: Vec<f32> = (0..dim).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let eps: Vec<f32> = (0..dim).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let t0 = s.timesteps[0];
            let ab = s.schedule.alpha_bar(t0);
            let mut x: Vec<f32> = x0
                .iter()
                .zip(&eps)
                .map(|(&x0v, &ev)| (ab.sqrt() as f32) * x0v + ((1.0 - ab).sqrt() as f32) * ev)
                .collect();
            let mut rng = Rng::new(0);
            for i in 0..n {
                x = s.step(i, &x, &eps, &mut rng);
            }
            for (a, b) in x.iter().zip(&x0) {
                assert!((a - b).abs() < 2e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn predict_x0_consistency() {
        // predict_x0 then re-noising at the same t returns the sample
        forall("ddim x0 consistency", 30, |g| {
            let n = g.usize_in(1, 50);
            let s = make(n);
            let i = g.usize_in(0, n - 1);
            let dim = 6;
            let x: Vec<f32> = (0..dim).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let e: Vec<f32> = (0..dim).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let x0 = s.predict_x0(i, &x, &e);
            let t = s.timesteps[i];
            let ab = s.schedule.alpha_bar(t);
            for d in 0..dim {
                let renoised = (ab.sqrt() as f32) * x0[d] + ((1.0 - ab).sqrt() as f32) * e[d];
                assert!((renoised - x[d]).abs() < 1e-3, "{renoised} vs {}", x[d]);
            }
        });
    }

    #[test]
    fn last_step_lands_in_x0_space() {
        // final step uses ᾱ_prev = 1, so output == predicted x0
        let mut s = make(5);
        let i = 4;
        let x = vec![0.7f32; 4];
        let e = vec![0.3f32; 4];
        let x0 = s.predict_x0(i, &x, &e);
        let out = s.step(i, &x, &e, &mut Rng::new(0));
        for (a, b) in out.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
