//! PNDM / PLMS (Liu et al. 2022) — the HF Stable Diffusion pipeline's
//! default scheduler, i.e. the one the paper's Table 1 timings ran under.
//!
//! This is the `skip_prk_steps=true` variant the SD pipeline uses: pure
//! linear-multistep (Adams–Bashforth) on the eps history with lower-order
//! warmup for the first steps, stepping in ᾱ space like DDIM.

use super::{leading_timesteps, NoiseSchedule, Scheduler, SchedulerKind};
use crate::rng::Rng;

/// PLMS stepper with eps-history state (reset between trajectories).
#[derive(Debug, Clone)]
pub struct Pndm {
    schedule: NoiseSchedule,
    timesteps: Vec<usize>,
    /// Most-recent-first history of eps predictions (max 4).
    eps_history: Vec<Vec<f32>>,
}

impl Pndm {
    pub fn new(schedule: NoiseSchedule, num_steps: usize) -> Self {
        let timesteps = leading_timesteps(schedule.train_timesteps(), num_steps);
        Pndm { schedule, timesteps, eps_history: Vec::new() }
    }

    /// Adams–Bashforth blend of the eps history (order = history length).
    fn blended_eps(&self, eps: &[f32]) -> Vec<f32> {
        let h = &self.eps_history;
        match h.len() {
            0 => eps.to_vec(),
            1 => eps
                .iter()
                .zip(&h[0])
                .map(|(&e, &e1)| (3.0 * e - e1) / 2.0)
                .collect(),
            2 => eps
                .iter()
                .zip(&h[0])
                .zip(&h[1])
                .map(|((&e, &e1), &e2)| (23.0 * e - 16.0 * e1 + 5.0 * e2) / 12.0)
                .collect(),
            _ => eps
                .iter()
                .zip(&h[0])
                .zip(&h[1])
                .zip(&h[2])
                .map(|(((&e, &e1), &e2), &e3)| {
                    (55.0 * e - 59.0 * e1 + 37.0 * e2 - 9.0 * e3) / 24.0
                })
                .collect(),
        }
    }

    /// The DDIM-style transfer x_t -> x_{t_prev} under a given eps.
    fn transfer(&self, i: usize, sample: &[f32], eps: &[f32]) -> Vec<f32> {
        let t = self.timesteps[i];
        let t_prev = self.timesteps.get(i + 1).copied();
        let ab_t = self.schedule.alpha_bar(t);
        let ab_prev = self.schedule.alpha_bar_prev(t_prev);
        let sqrt_ab_t = ab_t.sqrt() as f32;
        let sqrt_1mab_t = (1.0 - ab_t).sqrt() as f32;
        let sqrt_ab_prev = ab_prev.sqrt() as f32;
        let sqrt_1mab_prev = (1.0 - ab_prev).sqrt() as f32;
        sample
            .iter()
            .zip(eps)
            .map(|(&x, &e)| {
                let x0 = (x - sqrt_1mab_t * e) / sqrt_ab_t;
                sqrt_ab_prev * x0 + sqrt_1mab_prev * e
            })
            .collect()
    }
}

impl Scheduler for Pndm {
    fn timesteps(&self) -> &[usize] {
        &self.timesteps
    }

    fn add_noise(&self, i: usize, x0: &[f32], noise: &[f32]) -> Vec<f32> {
        assert_eq!(x0.len(), noise.len());
        let ab = self.schedule.alpha_bar(self.timesteps[i]);
        let sqrt_ab = ab.sqrt() as f32;
        let sqrt_1mab = (1.0 - ab).sqrt() as f32;
        x0.iter().zip(noise).map(|(&x, &e)| sqrt_ab * x + sqrt_1mab * e).collect()
    }

    fn step(&mut self, i: usize, sample: &[f32], eps: &[f32], _rng: &mut Rng) -> Vec<f32> {
        assert_eq!(sample.len(), eps.len());
        let blended = self.blended_eps(eps);
        // update history (most recent first, cap 3 past values + current)
        self.eps_history.insert(0, eps.to_vec());
        self.eps_history.truncate(3);
        self.transfer(i, sample, &blended)
    }

    fn reset(&mut self) {
        self.eps_history.clear();
    }

    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Pndm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    fn make(n: usize) -> Pndm {
        Pndm::new(NoiseSchedule::default(), n)
    }

    #[test]
    fn first_step_equals_ddim() {
        // with empty history, PLMS order-1 == DDIM
        let mut p = make(10);
        let mut d = super::super::Ddim::new(NoiseSchedule::default(), 10);
        let x: Vec<f32> = (0..8).map(|i| (i as f32) * 0.1 - 0.4).collect();
        let e: Vec<f32> = (0..8).map(|i| (i as f32) * -0.05 + 0.2).collect();
        let mut rng = Rng::new(0);
        assert_eq!(p.step(0, &x, &e, &mut rng), d.step(0, &x, &e, &mut rng));
    }

    #[test]
    fn constant_eps_history_collapses_to_ddim() {
        // if all eps are identical, every AB blend equals eps, so the
        // whole PLMS trajectory equals the DDIM trajectory
        forall("plms constant eps", 15, |g| {
            let n = g.usize_in(2, 30);
            let mut p = make(n);
            let mut d = super::super::Ddim::new(NoiseSchedule::default(), n);
            let dim = 8;
            let e: Vec<f32> = (0..dim).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let mut xp: Vec<f32> = (0..dim).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let mut xd = xp.clone();
            let mut rng = Rng::new(0);
            for i in 0..n {
                xp = p.step(i, &xp, &e, &mut rng);
                xd = d.step(i, &xd, &e, &mut rng);
            }
            for (a, b) in xp.iter().zip(&xd) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn history_orders_engage() {
        let mut p = make(10);
        let x = vec![0.0f32; 4];
        let mut rng = Rng::new(0);
        assert_eq!(p.eps_history.len(), 0);
        p.step(0, &x, &[1.0; 4], &mut rng);
        assert_eq!(p.eps_history.len(), 1);
        p.step(1, &x, &[2.0; 4], &mut rng);
        p.step(2, &x, &[3.0; 4], &mut rng);
        p.step(3, &x, &[4.0; 4], &mut rng);
        assert_eq!(p.eps_history.len(), 3); // capped
        assert_eq!(p.eps_history[0][0], 4.0); // most recent first
    }

    #[test]
    fn reset_clears_history() {
        let mut p = make(10);
        let x = vec![0.0f32; 4];
        p.step(0, &x, &[1.0; 4], &mut Rng::new(0));
        assert!(!p.eps_history.is_empty());
        p.reset();
        assert!(p.eps_history.is_empty());
    }

    #[test]
    fn ab2_blend_coefficients() {
        let mut p = make(10);
        p.eps_history = vec![vec![1.0f32]];
        let blended = p.blended_eps(&[2.0]);
        // (3*2 - 1)/2 = 2.5
        assert!((blended[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn ab4_blend_coefficients() {
        let mut p = make(10);
        p.eps_history = vec![vec![1.0f32], vec![1.0], vec![1.0]];
        let blended = p.blended_eps(&[1.0]);
        // all-equal history: (55-59+37-9)/24 = 24/24 = 1
        assert!((blended[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn multistep_differs_from_ddim_with_varying_eps() {
        let mut p = make(10);
        let mut d = super::super::Ddim::new(NoiseSchedule::default(), 10);
        let x = vec![0.5f32; 4];
        let mut rng = Rng::new(0);
        let mut xp = x.clone();
        let mut xd = x;
        for i in 0..4 {
            let e = vec![(i as f32 + 1.0) * 0.1; 4];
            xp = p.step(i, &xp, &e, &mut rng);
            xd = d.step(i, &xd, &e, &mut rng);
        }
        assert!((xp[0] - xd[0]).abs() > 1e-6, "PLMS should diverge from DDIM");
    }
}
