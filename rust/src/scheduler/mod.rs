//! Diffusion timestep schedulers (pure-Rust host math).
//!
//! The denoising loop the paper optimizes ("executed multiple times,
//! usually ranging from 50 to 200 iterations for SD", §2) is driven by a
//! scheduler that maps the UNet's noise prediction to the next latent.
//! The paper's experiments use the HF pipeline's default (PNDM); we
//! implement DDIM, DDPM, PNDM/PLMS, and Euler(+ancestral) so the
//! ablations (DESIGN.md §6, ablation B) can show that the selective-
//! guidance saving is scheduler-independent.
//!
//! All schedulers share a [`NoiseSchedule`] (β-schedule + cumulative-ᾱ
//! tables over `train_timesteps`) and the standard "leading" inference
//! timestep spacing used by the HF Stable Diffusion pipeline.

mod beta;
mod ddim;
mod ddpm;
mod dpm;
mod euler;
mod heun;
mod pndm;

pub use beta::{BetaSchedule, NoiseSchedule};
pub use ddim::Ddim;
pub use ddpm::Ddpm;
pub use dpm::DpmSolverPP;
pub use euler::{Euler, EulerAncestral};
pub use heun::Heun;
pub use pndm::Pndm;

use crate::error::{Error, Result};
use crate::rng::Rng;

/// Which scheduler to run (config/CLI surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    Ddim,
    Ddpm,
    Pndm,
    Euler,
    EulerAncestral,
    DpmSolverPP,
    Heun,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ddim" => Ok(SchedulerKind::Ddim),
            "ddpm" => Ok(SchedulerKind::Ddpm),
            "pndm" | "plms" => Ok(SchedulerKind::Pndm),
            "euler" => Ok(SchedulerKind::Euler),
            "euler-a" | "euler_ancestral" | "eulera" => Ok(SchedulerKind::EulerAncestral),
            "dpm" | "dpm++" | "dpm-solver++" | "dpmpp" => Ok(SchedulerKind::DpmSolverPP),
            "heun" => Ok(SchedulerKind::Heun),
            other => Err(Error::Config(format!("unknown scheduler {other:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Ddim => "ddim",
            SchedulerKind::Ddpm => "ddpm",
            SchedulerKind::Pndm => "pndm",
            SchedulerKind::Euler => "euler",
            SchedulerKind::EulerAncestral => "euler-a",
            SchedulerKind::DpmSolverPP => "dpm++",
            SchedulerKind::Heun => "heun",
        }
    }

    /// Whether the scheduler draws random noise during stepping.
    pub fn is_stochastic(&self) -> bool {
        matches!(self, SchedulerKind::Ddpm | SchedulerKind::EulerAncestral)
    }

    /// Instantiate with the given schedule and inference step count.
    pub fn build(&self, schedule: NoiseSchedule, num_steps: usize) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Ddim => Box::new(Ddim::new(schedule, num_steps)),
            SchedulerKind::Ddpm => Box::new(Ddpm::new(schedule, num_steps)),
            SchedulerKind::Pndm => Box::new(Pndm::new(schedule, num_steps)),
            SchedulerKind::Euler => Box::new(Euler::new(schedule, num_steps)),
            SchedulerKind::EulerAncestral => {
                Box::new(EulerAncestral::new(schedule, num_steps))
            }
            SchedulerKind::DpmSolverPP => Box::new(DpmSolverPP::new(schedule, num_steps)),
            SchedulerKind::Heun => Box::new(Heun::new(schedule, num_steps)),
        }
    }
}

/// A configured scheduler instance driving one denoising trajectory.
///
/// Contract:
/// * `timesteps()` is strictly decreasing, length == `num_steps`.
/// * `step(i, ...)` consumes the UNet output for `timesteps()[i]` and
///   returns the latent for `timesteps()[i+1]` (or the final x0-space
///   latent for the last step).
/// * Schedulers are stateful only where the algorithm requires history
///   (PNDM); `reset()` clears that state between trajectories.
pub trait Scheduler: Send {
    /// Descending train-timestep indices for each inference step.
    fn timesteps(&self) -> &[usize];

    /// The continuous timestep value fed to the UNet at step `i`.
    fn model_timestep(&self, i: usize) -> f32 {
        self.timesteps()[i] as f32
    }

    /// Scale the initial N(0,1) latent (sigma-space schedulers != 1).
    fn init_noise_sigma(&self) -> f32 {
        1.0
    }

    /// Scale the latent before feeding the UNet at step `i` (identity for
    /// ᾱ-space schedulers, `1/sqrt(sigma^2+1)` for Euler).
    fn scale_model_input(&self, sample: &[f32], _i: usize) -> Vec<f32> {
        sample.to_vec()
    }

    /// Forward-diffuse a clean latent to the noise level *entering* step
    /// `i` (`i == 0` is the fully-noised trajectory start; valid for
    /// `i < timesteps().len()`). This is the img2img entry point: an
    /// init latent re-noised to step `i` continues the reverse
    /// trajectory from there, in whatever latent space (ᾱ or rescaled
    /// sigma) this scheduler steps in.
    fn add_noise(&self, i: usize, x0: &[f32], noise: &[f32]) -> Vec<f32>;

    /// Advance one step: latent(t_i) + eps -> latent(t_{i+1}).
    fn step(&mut self, i: usize, sample: &[f32], eps: &[f32], rng: &mut Rng) -> Vec<f32>;

    /// Clear multistep history (PNDM) for a fresh trajectory.
    fn reset(&mut self) {}

    /// Scheduler identity, for logs/metrics.
    fn kind(&self) -> SchedulerKind;
}

/// Shared inference-timestep spacing ("leading" spacing, HF default):
/// `t_i = (T / n) * i`, emitted in descending order.
pub(crate) fn leading_timesteps(train_timesteps: usize, num_steps: usize) -> Vec<usize> {
    assert!(num_steps >= 1 && num_steps <= train_timesteps);
    let ratio = train_timesteps / num_steps;
    let mut ts: Vec<usize> = (0..num_steps).map(|i| i * ratio).collect();
    ts.reverse();
    ts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    #[test]
    fn kind_parse_round_trip() {
        for k in [
            SchedulerKind::Ddim,
            SchedulerKind::Ddpm,
            SchedulerKind::Pndm,
            SchedulerKind::Euler,
            SchedulerKind::EulerAncestral,
            SchedulerKind::DpmSolverPP,
            SchedulerKind::Heun,
        ] {
            assert_eq!(SchedulerKind::parse(k.name()).unwrap(), k);
        }
        assert!(SchedulerKind::parse("nope").is_err());
    }

    #[test]
    fn leading_spacing_descending_unique() {
        let ts = leading_timesteps(1000, 50);
        assert_eq!(ts.len(), 50);
        assert_eq!(ts[0], 980);
        assert_eq!(ts[49], 0);
        assert!(ts.windows(2).all(|w| w[0] > w[1]));
    }

    #[test]
    fn all_schedulers_satisfy_timestep_contract() {
        forall("timestep contract", 40, |g| {
            let n = g.usize_in(1, 100);
            let kind = *g.choose(&[
                SchedulerKind::Ddim,
                SchedulerKind::Ddpm,
                SchedulerKind::Pndm,
                SchedulerKind::Euler,
                SchedulerKind::EulerAncestral,
                SchedulerKind::DpmSolverPP,
                SchedulerKind::Heun,
            ]);
            let sched = kind.build(NoiseSchedule::default(), n);
            let ts = sched.timesteps();
            assert_eq!(ts.len(), n);
            assert!(ts.windows(2).all(|w| w[0] > w[1]), "{kind:?} not descending");
            assert!(*ts.last().unwrap() < 1000);
        });
    }

    #[test]
    fn full_trajectories_stay_finite() {
        forall("finite trajectories", 12, |g| {
            let n = g.usize_in(2, 20);
            let kind = *g.choose(&[
                SchedulerKind::Ddim,
                SchedulerKind::Ddpm,
                SchedulerKind::Pndm,
                SchedulerKind::Euler,
                SchedulerKind::EulerAncestral,
                SchedulerKind::DpmSolverPP,
                SchedulerKind::Heun,
            ]);
            let mut sched = kind.build(NoiseSchedule::default(), n);
            let mut rng = Rng::new(g.u64());
            let dim = 16;
            let mut x: Vec<f32> = rng.normal_vec(dim);
            for v in x.iter_mut() {
                *v *= sched.init_noise_sigma();
            }
            for i in 0..n {
                let eps = rng.normal_vec(dim);
                x = sched.step(i, &x, &eps, &mut rng);
                assert!(x.iter().all(|v| v.is_finite()), "{kind:?} step {i} produced non-finite");
            }
        });
    }

    #[test]
    fn add_noise_finite_for_all_kinds_and_offsets() {
        forall("add_noise finite", 30, |g| {
            let n = g.usize_in(1, 50);
            let kind = *g.choose(&[
                SchedulerKind::Ddim,
                SchedulerKind::Ddpm,
                SchedulerKind::Pndm,
                SchedulerKind::Euler,
                SchedulerKind::EulerAncestral,
                SchedulerKind::DpmSolverPP,
                SchedulerKind::Heun,
            ]);
            let sched = kind.build(NoiseSchedule::default(), n);
            let i = g.usize_in(0, n - 1);
            let dim = 8;
            let x0: Vec<f32> = (0..dim).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let eps: Vec<f32> = (0..dim).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let x = sched.add_noise(i, &x0, &eps);
            assert_eq!(x.len(), dim);
            assert!(x.iter().all(|v| v.is_finite()), "{kind:?} add_noise({i}) non-finite");
        });
    }

    #[test]
    fn add_noise_oracle_recovery_for_memoryless_deterministic_kinds() {
        // DDIM and Euler invert their own forward map along a fixed
        // noise ray from ANY entry offset — the property img2img's
        // truncated trajectory relies on.
        forall("add_noise oracle", 20, |g| {
            let n = g.usize_in(2, 40);
            let kind = *g.choose(&[SchedulerKind::Ddim, SchedulerKind::Euler]);
            let mut sched = kind.build(NoiseSchedule::default(), n);
            let offset = g.usize_in(0, n - 1);
            let dim = 10;
            let x0: Vec<f32> = (0..dim).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let eps: Vec<f32> = (0..dim).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let mut x = sched.add_noise(offset, &x0, &eps);
            let mut rng = Rng::new(0);
            for i in offset..n {
                x = sched.step(i, &x, &eps, &mut rng);
            }
            for (a, b) in x.iter().zip(&x0) {
                assert!((a - b).abs() < 2e-3, "{kind:?} offset {offset}: {a} vs {b}");
            }
        });
    }
}
