//! DPM-Solver++(2M) (Lu et al. 2022) — the multistep second-order solver
//! widely used for low-step-count SD inference. Included beyond the
//! paper's PNDM default so ablation B can show the selective-guidance
//! saving carries over to modern solvers.
//!
//! Implementation follows the data-prediction (x0) formulation in
//! log-SNR (lambda) space:
//!
//!   lambda_t = log(alpha_t / sigma_t),  alpha_t = sqrt(ᾱ), sigma_t = sqrt(1-ᾱ)
//!   first step / order-1:  x <- (sigma_next/sigma) x - alpha_next (e^{-h}-1) x0
//!   order-2 (2M):          replace x0 with x0 + (x0 - x0_prev) / (2 r)
//! with h = lambda_next - lambda, r = h_prev / h.

use super::{leading_timesteps, NoiseSchedule, Scheduler, SchedulerKind};
use crate::rng::Rng;

/// DPM-Solver++(2M) stepper.
#[derive(Debug, Clone)]
pub struct DpmSolverPP {
    timesteps: Vec<usize>,
    /// alpha_t = sqrt(ᾱ) per inference step, plus terminal 1.0 (t = -1).
    alphas: Vec<f64>,
    /// sigma_t = sqrt(1-ᾱ) per inference step, plus terminal 0.0.
    sigmas: Vec<f64>,
    /// previous step's x0 prediction (order-2 history).
    x0_prev: Option<Vec<f32>>,
    /// previous step's h (lambda gap).
    h_prev: Option<f64>,
}

impl DpmSolverPP {
    pub fn new(schedule: NoiseSchedule, num_steps: usize) -> Self {
        let timesteps = leading_timesteps(schedule.train_timesteps(), num_steps);
        let mut alphas: Vec<f64> = timesteps
            .iter()
            .map(|&t| schedule.alpha_bar(t).sqrt())
            .collect();
        let mut sigmas: Vec<f64> = timesteps
            .iter()
            .map(|&t| (1.0 - schedule.alpha_bar(t)).sqrt())
            .collect();
        alphas.push(1.0);
        // avoid log(0): terminal sigma is clamped tiny
        sigmas.push(1e-6);
        DpmSolverPP { timesteps, alphas, sigmas, x0_prev: None, h_prev: None }
    }

    fn lambda(&self, i: usize) -> f64 {
        (self.alphas[i] / self.sigmas[i]).ln()
    }

    /// Data prediction x0 = (x - sigma eps) / alpha at step i.
    fn predict_x0(&self, i: usize, sample: &[f32], eps: &[f32]) -> Vec<f32> {
        let a = self.alphas[i] as f32;
        let s = self.sigmas[i] as f32;
        sample.iter().zip(eps).map(|(&x, &e)| (x - s * e) / a).collect()
    }
}

impl Scheduler for DpmSolverPP {
    fn timesteps(&self) -> &[usize] {
        &self.timesteps
    }

    fn add_noise(&self, i: usize, x0: &[f32], noise: &[f32]) -> Vec<f32> {
        assert_eq!(x0.len(), noise.len());
        let a = self.alphas[i] as f32;
        let s = self.sigmas[i] as f32;
        x0.iter().zip(noise).map(|(&x, &e)| a * x + s * e).collect()
    }

    fn step(&mut self, i: usize, sample: &[f32], eps: &[f32], _rng: &mut Rng) -> Vec<f32> {
        assert_eq!(sample.len(), eps.len());
        let x0 = self.predict_x0(i, sample, eps);
        let h = self.lambda(i + 1) - self.lambda(i);
        let sigma_ratio = (self.sigmas[i + 1] / self.sigmas[i]) as f32;
        let alpha_next = self.alphas[i + 1];
        let phi = (-(h)).exp_m1(); // e^{-h} - 1  (negative for h > 0)
        let coef = (-alpha_next * phi) as f32;

        let d: Vec<f32> = match (&self.x0_prev, self.h_prev) {
            (Some(prev), Some(hp)) if hp > 0.0 => {
                // 2M correction: extrapolate the data prediction
                let r = hp / h;
                let c = (1.0 / (2.0 * r)) as f32;
                x0.iter().zip(prev).map(|(&d0, &dp)| d0 + c * (d0 - dp)).collect()
            }
            _ => x0.clone(),
        };

        let out = sample
            .iter()
            .zip(&d)
            .map(|(&x, &dv)| sigma_ratio * x + coef * dv)
            .collect();
        self.x0_prev = Some(x0);
        self.h_prev = Some(h);
        out
    }

    fn reset(&mut self) {
        self.x0_prev = None;
        self.h_prev = None;
    }

    fn kind(&self) -> SchedulerKind {
        SchedulerKind::DpmSolverPP
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    fn make(n: usize) -> DpmSolverPP {
        DpmSolverPP::new(NoiseSchedule::default(), n)
    }

    #[test]
    fn lambda_strictly_increasing() {
        let s = make(20);
        for i in 0..20 {
            assert!(s.lambda(i + 1) > s.lambda(i), "lambda not increasing at {i}");
        }
    }

    #[test]
    fn deterministic_and_rng_free() {
        let mut a = make(10);
        let mut b = make(10);
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        let e: Vec<f32> = (0..8).map(|i| 0.2 - i as f32 * 0.05).collect();
        assert_eq!(
            a.step(0, &x, &e, &mut Rng::new(1)),
            b.step(0, &x, &e, &mut Rng::new(999))
        );
    }

    #[test]
    fn oracle_recovery() {
        // x_t = alpha x0 + sigma eps with a FIXED eps: the solver's data
        // prediction is exact at every step, so the trajectory lands on x0.
        forall("dpm oracle recovery", 15, |g| {
            let n = g.usize_in(3, 40);
            let mut s = make(n);
            let dim = 8;
            let x0: Vec<f32> = (0..dim).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let eps: Vec<f32> = (0..dim).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let mut x: Vec<f32> = x0
                .iter()
                .zip(&eps)
                .map(|(&x0v, &ev)| s.alphas[0] as f32 * x0v + s.sigmas[0] as f32 * ev)
                .collect();
            let mut rng = Rng::new(0);
            for i in 0..n {
                // oracle eps at step i: re-noise x0 consistently
                let e_i: Vec<f32> = x
                    .iter()
                    .zip(&x0)
                    .map(|(&xv, &x0v)| {
                        (xv - s.alphas[i] as f32 * x0v) / s.sigmas[i] as f32
                    })
                    .collect();
                x = s.step(i, &x, &e_i, &mut rng);
            }
            for (a, b) in x.iter().zip(&x0) {
                assert!((a - b).abs() < 5e-3, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn second_order_engages_after_first_step() {
        let mut s = make(10);
        let x = vec![0.5f32; 4];
        let e = vec![0.1f32; 4];
        let mut rng = Rng::new(0);
        assert!(s.x0_prev.is_none());
        s.step(0, &x, &e, &mut rng);
        assert!(s.x0_prev.is_some());
        assert!(s.h_prev.is_some());
        s.reset();
        assert!(s.x0_prev.is_none());
    }

    #[test]
    fn constant_x0_fixed_point() {
        // if eps always re-noises the SAME x0, 2M's correction vanishes
        // (x0 - x0_prev = 0) and stepping is stable
        let mut s = make(15);
        let x0 = vec![1.0f32; 4];
        let mut x: Vec<f32> = x0
            .iter()
            .map(|&v| s.alphas[0] as f32 * v + s.sigmas[0] as f32 * 0.3)
            .collect();
        let mut rng = Rng::new(0);
        for i in 0..15 {
            let e_i: Vec<f32> = x
                .iter()
                .zip(&x0)
                .map(|(&xv, &x0v)| (xv - s.alphas[i] as f32 * x0v) / s.sigmas[i] as f32)
                .collect();
            x = s.step(i, &x, &e_i, &mut rng);
            assert!(x.iter().all(|v| v.is_finite()));
        }
        for (a, b) in x.iter().zip(&x0) {
            assert!((a - b).abs() < 5e-3);
        }
    }
}
