//! # Selective Guidance serving stack
//!
//! Production reproduction of *"Selective Guidance: Are All the Denoising
//! Steps of Guided Diffusion Important?"* (Golnari, Yao & He, 2023).
//!
//! The paper observes that classifier-free guidance (CFG) runs the
//! denoising UNet **twice** per iteration (conditional + unconditional,
//! combined by Eq. 1) and that the *later* iterations of the denoising
//! loop tolerate dropping the unconditional pass — halving their cost.
//! Optimizing the last 20% of 50 iterations saves ~8.2% of end-to-end
//! latency with imperceptible quality change; the last 50% saves ~20.3%.
//!
//! This crate is the Layer-3 **rust coordinator** of a three-layer stack:
//!
//! * L1 — Pallas kernels (attention, fused GroupNorm+SiLU, Eq.-1 combine),
//! * L2 — a JAX latent-diffusion model (UNet + text encoder + VAE),
//! * L3 — this crate: request routing, dynamic batching, the denoising
//!   loop with the per-iteration **selective-guidance decision**, PJRT
//!   execution of the AOT artifacts, metrics, a QoS layer
//!   ([`qos`]) that turns the selective-guidance window into a
//!   deadline-aware load-shedding actuator, and a replica-cluster layer
//!   ([`cluster`]) that routes each request by its compiled plan cost
//!   across heterogeneous engine replicas.
//!
//! Python runs once at build time (`make artifacts`); the request path is
//! 100% rust. See `DESIGN.md` for the full architecture and the
//! experiment index mapping every paper table/figure to a bench target.

pub mod benchutil;
pub mod cache;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod error;
pub mod guidance;
pub mod image;
pub mod json;
pub mod metrics;
pub mod prompts;
pub mod qos;
pub mod quality;
pub mod rng;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod telemetry;
pub mod testutil;
pub mod tokenizer;
pub mod workload;
pub mod xla;

pub use error::{Error, Result};

/// One-stop imports for examples and downstream users.
pub mod prelude {
    pub use crate::cache::{CacheConfig, CacheOutcome, RequestCache, SharedUncondCache};
    pub use crate::cluster::{
        ClusterConfig, ClusterStats, ReplicaSet, ReplicaSpec, RoutePolicy, Router,
    };
    pub use crate::config::EngineConfig;
    pub use crate::coordinator::{
        BatchMode, CancelHandle, ContinuousBatcher, Coordinator, CoordinatorConfig,
        ProgressEvent, Submit, WatchOptions, Watched,
    };
    pub use crate::engine::{
        Engine, GenerationOutput, GenerationRequest, InitImage, SampleState,
    };
    pub use crate::error::{Error, Result};
    pub use crate::guidance::{
        GuidanceMode, GuidancePlan, GuidanceSchedule, GuidanceStrategy, ReuseKind, Segment,
        SegmentMode, SelectiveGuidancePolicy, StepPlan, WindowPosition, WindowSpec,
    };
    pub use crate::qos::{DeadlineQos, Priority, QosConfig, QosMeta, QosPolicy};
    pub use crate::quality::{mse, psnr, ssim};
    pub use crate::runtime::ModelStack;
    pub use crate::scheduler::{Scheduler, SchedulerKind};
    pub use crate::telemetry::{Clock, Telemetry, TraceEvent, TraceId};
}
