//! Fleet-wide guidance amortization (DESIGN.md §13).
//!
//! The paper's saving is per-request: optimized steps skip that
//! request's own uncond UNet pass. For a fixed negative prompt the
//! uncond eps depends only on (scheduler, step, latent-trajectory
//! statistics) — not on the conditional prompt — so concurrent
//! requests can amortize each other's dual passes. Three tiers, each
//! independently switchable and all off by default:
//!
//! - [`SharedUncondCache`] — cohort/replica-scoped uncond-eps sharing:
//!   a Reuse-strategy sample consumes an eps recorded by a *different*
//!   in-flight sample, guarded by a trajectory-divergence bound that
//!   falls back to a local dual pass.
//! - [`RequestCache`] — exact-match output replay: a bounded LRU keyed
//!   on the full canonical request identity replays stored outputs
//!   bit-exactly.
//! - in-flight dedup (coordinator admission, keyed by
//!   [`canonical_key`]) — identical concurrent requests coalesce into
//!   one physical generation with fan-out delivery.
//!
//! House invariant: cache misses and cache-disabled runs stay
//! bit-exact with the unshared engine (`tests/prop_cache.rs`).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::TomlDoc;
use crate::engine::{GenerationOutput, GenerationRequest};
use crate::error::{Error, Result};

/// `[cache]` section: the three sharing tiers. Everything defaults to
/// off — sharing changes failure and freshness semantics, so opting
/// *in* is the explicit act (unlike `[telemetry]`).
#[derive(Debug, Clone, PartialEq)]
pub struct CacheConfig {
    /// Exact-match request cache (bit-exact output replay).
    pub request_cache: bool,
    /// Request-cache LRU capacity (entries).
    pub request_capacity: usize,
    /// In-flight dedup: coalesce identical concurrent requests.
    pub dedup: bool,
    /// Cross-request uncond-eps sharing (continuous cohorts only).
    pub shared_uncond: bool,
    /// Divergence tolerance for the shared tier: a consumer whose
    /// latent statistics drift further than this (relative to the
    /// publisher's) falls back to its own dual pass.
    pub shared_tolerance: f64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            request_cache: false,
            request_capacity: 256,
            dedup: false,
            shared_uncond: false,
            shared_tolerance: 0.25,
        }
    }
}

impl CacheConfig {
    /// Any tier on?
    pub fn enabled(&self) -> bool {
        self.request_cache || self.dedup || self.shared_uncond
    }

    /// Do admissions need a canonical key (request cache or dedup)?
    pub fn keyed(&self) -> bool {
        self.request_cache || self.dedup
    }

    pub fn validate(&self) -> Result<()> {
        if self.request_cache && self.request_capacity == 0 {
            return Err(Error::Config("cache request_capacity must be >= 1".into()));
        }
        if self.shared_uncond
            && !(self.shared_tolerance.is_finite() && self.shared_tolerance > 0.0)
        {
            return Err(Error::Config(format!(
                "cache shared_tolerance {} must be finite and > 0",
                self.shared_tolerance
            )));
        }
        Ok(())
    }

    /// Build from the `[cache]` TOML section (missing keys keep
    /// defaults). Knobs without their enabling switch are an operator
    /// error, not a silent no-op (mirroring `[telemetry]`/`[guidance]`).
    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = CacheConfig::default();
        if let Some(v) = doc.get("cache", "request_cache") {
            cfg.request_cache = v
                .as_bool()
                .ok_or_else(|| Error::Config("cache request_cache must be bool".into()))?;
        }
        if let Some(v) = doc.get("cache", "dedup") {
            cfg.dedup =
                v.as_bool().ok_or_else(|| Error::Config("cache dedup must be bool".into()))?;
        }
        if let Some(v) = doc.get("cache", "shared_uncond") {
            cfg.shared_uncond = v
                .as_bool()
                .ok_or_else(|| Error::Config("cache shared_uncond must be bool".into()))?;
        }
        match doc.get("cache", "request_capacity") {
            Some(v) if cfg.request_cache => {
                cfg.request_capacity = v
                    .as_usize()
                    .ok_or_else(|| Error::Config("cache request_capacity must be int".into()))?;
            }
            Some(_) => {
                return Err(Error::Config(
                    "cache request_capacity requires request_cache = true".into(),
                ));
            }
            None => {}
        }
        match doc.get("cache", "shared_tolerance") {
            Some(v) if cfg.shared_uncond => {
                cfg.shared_tolerance = v
                    .as_f64()
                    .ok_or_else(|| Error::Config("cache shared_tolerance must be number".into()))?;
            }
            Some(_) => {
                return Err(Error::Config(
                    "cache shared_tolerance requires shared_uncond = true".into(),
                ));
            }
            None => {}
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// How an admission was served by the cache layer — echoed on the wire
/// as `"cache":"hit|dedup|miss"`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// No reusable state: a physical generation ran (or will run).
    Miss,
    /// Served bit-exactly from the request cache.
    Hit,
    /// Coalesced onto an identical in-flight generation.
    Dedup,
}

impl CacheOutcome {
    pub fn label(&self) -> &'static str {
        match self {
            CacheOutcome::Miss => "miss",
            CacheOutcome::Hit => "hit",
            CacheOutcome::Dedup => "dedup",
        }
    }
}

/// Canonical request-cache / dedup key: the full generation identity.
///
/// The issue's minimum is prompt × seed × plan digest × scheduler ×
/// steps × size — but the plan *summary* alone is ambiguous (`Hold`
/// and `Extrapolate` both print `R`; every adaptive request summarizes
/// all-dual; the guidance scale is absent), so the key also folds in
/// the raw strategy/schedule/adaptive triple and the exact scale bits.
/// Two requests share a key only if the engine would produce
/// bit-identical outputs for them.
pub fn canonical_key(req: &GenerationRequest) -> Result<String> {
    let plan = req.plan()?;
    let mut key = format!(
        "prompt={:?} seed={} steps={} sched={} scale={:08x} plan={} strategy={:?} \
         schedule={:?} adaptive={:?} decode={}",
        req.prompt,
        req.seed,
        req.steps,
        req.scheduler.name(),
        req.guidance_scale.to_bits(),
        plan.summary(),
        req.strategy,
        req.schedule,
        req.adaptive,
        req.decode,
    );
    // img2img identity: two requests whose plans agree can still start
    // from different latents. Strength enters as exact bits (it picks
    // the scheduler offset AND scales the init noise), the latent as a
    // content hash — "synthetic" marks the seed-derived init, already
    // covered by the seed field. text2img keys are unchanged.
    if let Some(init) = &req.init {
        key.push_str(&format!(" strength={:016x} init=", init.strength.to_bits()));
        match &init.latent {
            Some(lat) => key.push_str(&format!("{:016x}", fnv1a_f32(lat))),
            None => key.push_str("synthetic"),
        }
    }
    Ok(key)
}

/// FNV-1a over the raw f32 bits — a cheap content digest for explicit
/// init latents (collision-resistant enough for a cache key that also
/// carries the full request identity).
fn fnv1a_f32(data: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &f in data {
        for b in f.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Counters snapshot for the exact-match request cache.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RequestCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    /// Approximate resident payload bytes (latent f32s + RGB pixels).
    pub bytes: u64,
}

struct RequestLru {
    map: HashMap<String, GenerationOutput>,
    /// LRU order, least-recent first.
    order: VecDeque<String>,
}

/// Exact-match output cache: bounded LRU of completed
/// [`GenerationOutput`]s keyed by [`canonical_key`]. Replays are
/// clones of the stored output — bit-exact by construction.
pub struct RequestCache {
    capacity: usize,
    inner: Mutex<RequestLru>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    bytes: AtomicU64,
}

/// Approximate payload size of one cached output.
fn entry_bytes(out: &GenerationOutput) -> u64 {
    let latent = (out.latent.len() * 4) as u64;
    let image = out.image.as_ref().map_or(0, |i| (i.width * i.height * 3) as u64);
    latent + image
}

impl RequestCache {
    pub fn new(capacity: usize) -> RequestCache {
        RequestCache {
            capacity: capacity.max(1),
            inner: Mutex::new(RequestLru { map: HashMap::new(), order: VecDeque::new() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Look up a completed output; a hit refreshes LRU recency.
    pub fn get(&self, key: &str) -> Option<GenerationOutput> {
        let mut lru = self.inner.lock().expect("request cache lock");
        match lru.map.get(key).cloned() {
            Some(out) => {
                if let Some(pos) = lru.order.iter().position(|k| k == key) {
                    let k = lru.order.remove(pos).expect("lru position valid");
                    lru.order.push_back(k);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(out)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store a completed output, evicting least-recent entries past
    /// capacity.
    pub fn insert(&self, key: String, out: GenerationOutput) {
        let mut lru = self.inner.lock().expect("request cache lock");
        let added = entry_bytes(&out);
        if let Some(prev) = lru.map.insert(key.clone(), out) {
            // replacing an identical key: refresh recency, swap bytes
            self.bytes.fetch_sub(entry_bytes(&prev), Ordering::Relaxed);
            if let Some(pos) = lru.order.iter().position(|k| *k == key) {
                lru.order.remove(pos);
            }
        }
        lru.order.push_back(key);
        self.bytes.fetch_add(added, Ordering::Relaxed);
        while lru.map.len() > self.capacity {
            let oldest = lru.order.pop_front().expect("over-capacity lru has entries");
            if let Some(evicted) = lru.map.remove(&oldest) {
                self.bytes.fetch_sub(entry_bytes(&evicted), Ordering::Relaxed);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn stats(&self) -> RequestCacheStats {
        let entries = self.inner.lock().expect("request cache lock").map.len();
        RequestCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
            bytes: self.bytes.load(Ordering::Relaxed),
        }
    }
}

/// Key for one shared uncond-eps entry. The uncond pass conditions on
/// the *negative* prompt only, so the conditional prompt is absent by
/// design; what remains is the denoising position.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SharedKey {
    /// Scheduler family — different schedulers visit different sigma
    /// trajectories for the same step index.
    pub scheduler: &'static str,
    /// Step index within the trajectory.
    pub step: usize,
    /// Model timestep quantized to 1/16 units (the sigma bucket): two
    /// requests with different step counts share entries only when
    /// they land in the same bucket.
    pub sigma_mq: i64,
    /// Hash of the negative prompt. The stack serves a single fixed
    /// (empty) negative prompt today, so this is constant — the key
    /// dimension exists so per-request negatives can never alias.
    pub neg_hash: u64,
}

impl SharedKey {
    pub fn new(scheduler: &'static str, step: usize, model_timestep: f32) -> SharedKey {
        SharedKey {
            scheduler,
            step,
            sigma_mq: (model_timestep as f64 * 16.0).round() as i64,
            neg_hash: 0,
        }
    }
}

/// Counters snapshot for the shared uncond tier.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SharedCacheStats {
    pub published: u64,
    pub hits: u64,
    pub misses: u64,
    /// Lookups that found an entry but failed the divergence bound.
    pub rejected: u64,
    pub entries: usize,
}

struct SharedEntry {
    eps: Vec<f32>,
    /// Publisher latent statistics at record time — the staleness bound
    /// compares the consumer's trajectory against these.
    mean: f32,
    std: f32,
}

struct SharedInner {
    map: HashMap<SharedKey, SharedEntry>,
    order: VecDeque<SharedKey>,
}

/// Cross-request uncond-eps cache. Publishers are dual-guidance steps
/// (any strategy); consumers are Reuse-strategy samples whose latent
/// statistics stay within `tolerance` of the publisher's — beyond it
/// the lookup is rejected and the consumer pays its own dual pass.
pub struct SharedUncondCache {
    tolerance: f64,
    capacity: usize,
    inner: Mutex<SharedInner>,
    published: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    rejected: AtomicU64,
}

/// Mean / standard deviation of a latent tensor — the trajectory
/// statistic the divergence bound is expressed over.
fn latent_stats(x: &[f32]) -> (f32, f32) {
    if x.is_empty() {
        return (0.0, 0.0);
    }
    let n = x.len() as f64;
    let mean = x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    (mean as f32, var.sqrt() as f32)
}

impl SharedUncondCache {
    pub fn new(tolerance: f64) -> SharedUncondCache {
        SharedUncondCache {
            tolerance,
            capacity: 4096,
            inner: Mutex::new(SharedInner { map: HashMap::new(), order: VecDeque::new() }),
            published: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    pub fn tolerance(&self) -> f64 {
        self.tolerance
    }

    /// Record the uncond eps a dual step just computed, tagged with the
    /// publisher's latent statistics. Later publishes overwrite —
    /// fresher trajectories serve consumers better.
    pub fn publish(&self, key: SharedKey, latent: &[f32], eps: &[f32]) {
        let (mean, std) = latent_stats(latent);
        let mut inner = self.inner.lock().expect("shared cache lock");
        if inner.map.insert(key, SharedEntry { eps: eps.to_vec(), mean, std }).is_none() {
            inner.order.push_back(key);
            while inner.map.len() > self.capacity {
                let oldest = inner.order.pop_front().expect("over-capacity cache has entries");
                inner.map.remove(&oldest);
            }
        }
        self.published.fetch_add(1, Ordering::Relaxed);
    }

    /// Fetch a shared eps for a consumer at `latent`, applying the
    /// divergence bound: relative distance of (mean, std) from the
    /// publisher's statistics must stay within the tolerance.
    pub fn consume(&self, key: &SharedKey, latent: &[f32]) -> Option<Vec<f32>> {
        let inner = self.inner.lock().expect("shared cache lock");
        let Some(entry) = inner.map.get(key) else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        if entry.eps.len() != latent.len() {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let (mean, std) = latent_stats(latent);
        let scale = (entry.std.abs() as f64).max(1e-3);
        let divergence =
            ((mean - entry.mean).abs() as f64 + (std - entry.std).abs() as f64) / scale;
        if divergence > self.tolerance {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(entry.eps.clone())
    }

    pub fn stats(&self) -> SharedCacheStats {
        let entries = self.inner.lock().expect("shared cache lock").map.len();
        SharedCacheStats {
            published: self.published.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            entries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guidance::{GuidanceStrategy, ReuseKind};

    fn out(latent: Vec<f32>) -> GenerationOutput {
        GenerationOutput {
            latent,
            image: None,
            wall_ms: 0.0,
            breakdown: Default::default(),
            unet_evals: 0,
            steps: 1,
            strategy: GuidanceStrategy::CondOnly,
            plan_summary: "1D".into(),
        }
    }

    #[test]
    fn config_defaults_off_and_validates() {
        let cfg = CacheConfig::default();
        assert!(!cfg.enabled());
        assert!(!cfg.keyed());
        cfg.validate().unwrap();
        let mut bad = CacheConfig { request_cache: true, request_capacity: 0, ..cfg.clone() };
        assert!(bad.validate().is_err());
        bad = CacheConfig { shared_uncond: true, shared_tolerance: 0.0, ..cfg };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn config_from_toml_and_orphan_knobs() {
        let doc = TomlDoc::parse(
            "[cache]\nrequest_cache = true\nrequest_capacity = 16\ndedup = true\n\
             shared_uncond = true\nshared_tolerance = 0.5\n",
        )
        .unwrap();
        let cfg = CacheConfig::from_toml(&doc).unwrap();
        assert!(cfg.request_cache && cfg.dedup && cfg.shared_uncond);
        assert_eq!(cfg.request_capacity, 16);
        assert!((cfg.shared_tolerance - 0.5).abs() < 1e-12);
        // knobs without their switch are operator errors
        let doc = TomlDoc::parse("[cache]\nrequest_capacity = 16\n").unwrap();
        assert!(CacheConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[cache]\nshared_tolerance = 0.5\n").unwrap();
        assert!(CacheConfig::from_toml(&doc).is_err());
        // missing section keeps the all-off default
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(CacheConfig::from_toml(&doc).unwrap(), CacheConfig::default());
    }

    #[test]
    fn canonical_key_separates_lookalike_requests() {
        use crate::guidance::WindowSpec;
        let base = || {
            GenerationRequest::new("a castle at dusk")
                .steps(8)
                .decode(false)
                .selective(WindowSpec::last(0.5))
                .strategy(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 })
        };
        let a = canonical_key(&base()).unwrap();
        // identical requests agree
        assert_eq!(a, canonical_key(&base()).unwrap());
        // the plan summary alone would NOT separate these: same R-window
        let b = canonical_key(&base().strategy(GuidanceStrategy::Reuse {
            kind: ReuseKind::Extrapolate,
            refresh_every: 0,
        }))
        .unwrap();
        assert_ne!(a, b);
        assert_ne!(a, canonical_key(&base().seed(7)).unwrap());
        assert_ne!(a, canonical_key(&base().guidance_scale(7.0)).unwrap());
        assert_ne!(a, canonical_key(&base().decode(true)).unwrap());
    }

    #[test]
    fn canonical_key_folds_img2img_identity() {
        use std::sync::Arc;
        let base = || GenerationRequest::new("a castle at dusk").steps(8).decode(false);
        let text = canonical_key(&base()).unwrap();
        // text2img keys are untouched by the img2img extension
        assert!(!text.contains("strength="));
        let syn = canonical_key(&base().img2img(0.5)).unwrap();
        assert_ne!(text, syn);
        assert!(syn.ends_with("init=synthetic"));
        // strength enters as exact bits even when executed_steps agree
        let syn51 = canonical_key(&base().img2img(0.51)).unwrap();
        assert_eq!(base().img2img(0.5).executed_steps(), base().img2img(0.51).executed_steps());
        assert_ne!(syn, syn51);
        // an explicit latent is content-hashed, not position-blind
        let lat = |v: Vec<f32>| canonical_key(&base().init_latent(Arc::new(v), 0.5)).unwrap();
        let a = lat(vec![1.0, 2.0]);
        assert_ne!(a, syn);
        assert_ne!(a, lat(vec![2.0, 1.0]));
        assert_eq!(a, lat(vec![1.0, 2.0]));
    }

    #[test]
    fn request_cache_lru_and_counters() {
        let cache = RequestCache::new(2);
        cache.insert("a".into(), out(vec![0.0; 4]));
        cache.insert("b".into(), out(vec![0.0; 8]));
        assert_eq!(cache.stats().bytes, 48);
        assert!(cache.get("a").is_some()); // refreshes "a"
        cache.insert("c".into(), out(vec![0.0; 2])); // evicts "b" (least recent)
        assert!(cache.get("b").is_none());
        assert!(cache.get("a").is_some());
        assert!(cache.get("c").is_some());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions, s.entries), (3, 1, 1, 2));
        assert_eq!(s.bytes, 16 + 8);
    }

    #[test]
    fn shared_cache_divergence_bound() {
        let cache = SharedUncondCache::new(0.25);
        let key = SharedKey::new("ddim", 3, 961.0);
        let publisher: Vec<f32> = (0..32).map(|i| (i as f32 / 31.0) * 2.0 - 1.0).collect();
        assert!(cache.consume(&key, &publisher).is_none()); // cold
        cache.publish(key, &publisher, &[0.5; 32]);
        // same trajectory: within tolerance
        assert_eq!(cache.consume(&key, &publisher), Some(vec![0.5; 32]));
        // wildly divergent consumer: rejected, falls back to dual
        let divergent = vec![100.0; 32];
        assert!(cache.consume(&key, &divergent).is_none());
        // different sigma bucket is a distinct key
        let other = SharedKey::new("ddim", 3, 900.0);
        assert!(cache.consume(&other, &publisher).is_none());
        let s = cache.stats();
        assert_eq!((s.published, s.hits, s.misses, s.rejected, s.entries), (1, 1, 2, 1, 1));
    }

    #[test]
    fn shared_key_quantizes_sigma() {
        assert_eq!(SharedKey::new("pndm", 0, 1.0).sigma_mq, 16);
        // buckets are 1/16 of a model timestep wide
        assert_eq!(SharedKey::new("pndm", 0, 1.03).sigma_mq, 16);
        assert_ne!(SharedKey::new("pndm", 0, 1.10).sigma_mq, 16);
    }

    #[test]
    fn outcome_labels() {
        assert_eq!(CacheOutcome::Miss.label(), "miss");
        assert_eq!(CacheOutcome::Hit.label(), "hit");
        assert_eq!(CacheOutcome::Dedup.label(), "dedup");
    }
}
