//! In-crate stand-in for the PJRT `xla` bindings.
//!
//! The serving stack executes its AOT artifacts through a thin PJRT
//! surface (client / executable / buffer / literal). On machines with
//! the native XLA toolchain those types come from the real bindings; the
//! offline registry snapshot this repo must build from has none, so this
//! module provides the same *shape* as a null backend: every entry point
//! type-checks, and the first call that would need a real device —
//! [`PjRtClient::cpu`] — returns a descriptive error.
//!
//! The rest of the stack is designed so that this degrades gracefully:
//! [`crate::runtime::ModelStack::load`] is the only constructor that
//! touches PJRT, integration tests skip via `require_artifacts!`, the
//! QoS control loop ships its own artifact-free evaluation path
//! ([`crate::qos::sim`]), and the engine itself runs end-to-end on the
//! deterministic synthetic backend
//! ([`crate::runtime::ModelStack::synthetic`]) so equivalence tests and
//! quality benches don't need the toolchain either. Swapping the real
//! bindings back in is a one-line change: replace `use crate::xla;` with
//! the external crate in `runtime/mod.rs` and `error.rs` (DESIGN.md §2).

use std::fmt;

/// Error type mirroring `xla::Error` — an opaque message.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    fn unavailable(what: &str) -> Error {
        Error::new(format!(
            "{what}: PJRT runtime unavailable — this build uses the in-crate \
             xla stub (see rust/src/xla/mod.rs and DESIGN.md §2)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Stub PJRT client. [`PjRtClient::cpu`] fails, so no downstream method
/// is ever reached at runtime; they exist to keep the call sites typed.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real bindings construct a host-CPU PJRT client here; the stub
    /// reports that no runtime is linked in.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    /// Upload a host tensor (`data` flattened, `dims` its shape) to the
    /// device identified by `device` (None = default).
    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("buffer_from_host_buffer"))
    }

    /// Compile an [`XlaComputation`] for this client's platform.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile"))
    }
}

/// Parsed HLO module (the AOT artifacts ship HLO text).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO **text** file (the artifact interchange format).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, device-loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute over device buffers; one output list per device.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute_b"))
    }
}

/// A device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy device → host as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("to_literal_sync"))
    }
}

/// A host-side tensor value.
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Unwrap a 1-element tuple literal (artifacts are lowered with
    /// `return_tuple=True`).
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("to_tuple1"))
    }

    /// Flatten to a host vector of element type `T`.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        let msg = err.to_string();
        assert!(msg.contains("PJRT runtime unavailable"), "{msg}");
        assert!(msg.contains("xla stub"), "{msg}");
    }

    #[test]
    fn stub_error_is_std_error() {
        let err = Error::new("boom");
        let dy: &dyn std::error::Error = &err;
        assert_eq!(dy.to_string(), "boom");
    }
}
