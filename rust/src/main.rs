//! `sgd-serve` — the selective-guidance serving binary.
//!
//! ```text
//! sgd-serve generate --prompt "A person holding a cat" [--steps 50]
//!           [--guidance-scale 7.5] [--window 0.2] [--position last]
//!           [--strategy cond-only|hold|extrapolate] [--refresh-every 0]
//!           [--scheduler pndm] [--seed 0] [--out out.png]
//!           [--mode fixed|continuous] [--slot-budget 8]
//!           [--artifacts artifacts/tiny]
//! sgd-serve serve    [--bind 127.0.0.1:7878] [--workers 1]
//!           [--mode fixed|continuous] [--max-batch 4] [--slot-budget 8]
//!           [--config configs/serve.toml]
//!           [--qos] [--max-queue 64] [--quality-floor 0.5]
//!           [--deadline-ms 0]
//! sgd-serve info     [--artifacts artifacts/tiny]
//! ```
//!
//! `--mode continuous` (or `mode = "continuous"` in the config's
//! `[server]` section) switches the coordinator to iteration-level
//! batching under a UNet slot budget (DESIGN.md §9); `--qos` (or
//! `enabled = true` in `[qos]`) turns on deadline-aware admission control
//! with the selective-guidance window as the load-shedding actuator
//! (DESIGN.md §7).

use std::path::Path;
use std::sync::Arc;

use selective_guidance::cli::Cli;
use selective_guidance::config::{EngineConfig, RunConfig};
use selective_guidance::coordinator::{BatchMode, Coordinator, CoordinatorConfig};
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::error::{Error, Result};
use selective_guidance::guidance::{GuidanceStrategy, WindowSpec};
use selective_guidance::qos::DeadlineQos;
use selective_guidance::runtime::ModelStack;
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::server::Server;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let cli = Cli::parse()?;
    match cli.command.as_deref() {
        Some("generate") => cmd_generate(&cli),
        Some("serve") => cmd_serve(&cli),
        Some("info") => cmd_info(&cli),
        Some(other) => Err(Error::Config(format!("unknown command {other:?}"))),
        None => {
            eprintln!("usage: sgd-serve <generate|serve|info> [options]");
            Ok(())
        }
    }
}

fn artifacts_dir(cli: &Cli) -> String {
    cli.opt("artifacts")
        .map(String::from)
        .or_else(|| std::env::var("SG_ARTIFACTS").ok())
        .unwrap_or_else(|| "artifacts/tiny".into())
}

fn window_from(cli: &Cli) -> Result<WindowSpec> {
    let fraction: f64 = cli.opt_or("window", 0.0)?;
    let position = cli.opt("position").unwrap_or("last");
    let w = match position {
        "last" => WindowSpec::last(fraction),
        "first" => WindowSpec::first(fraction),
        "middle" => WindowSpec::middle(fraction),
        other => return Err(Error::Config(format!("unknown position {other:?}"))),
    };
    w.validate()?;
    Ok(w)
}

fn cmd_generate(cli: &Cli) -> Result<()> {
    let dir = artifacts_dir(cli);
    eprintln!("loading artifacts from {dir} ...");
    let stack = Arc::new(ModelStack::load(&dir)?);
    let engine = Arc::new(Engine::new(stack, EngineConfig::default()));

    let prompt = cli
        .opt("prompt")
        .ok_or_else(|| Error::Config("--prompt is required".into()))?;
    let strategy = GuidanceStrategy::parse(
        cli.opt("strategy").unwrap_or("cond-only"),
        cli.opt_or("refresh-every", 0)?,
    )?;
    let req = GenerationRequest::new(prompt)
        .steps(cli.opt_or("steps", 50)?)
        .guidance_scale(cli.opt_or("guidance-scale", 7.5)?)
        .selective(window_from(cli)?)
        .strategy(strategy)
        .scheduler(SchedulerKind::parse(cli.opt("scheduler").unwrap_or("pndm"))?)
        .seed(cli.opt_or("seed", 0)?);

    let mode = match cli.opt("mode") {
        Some(m) => BatchMode::parse(m)?,
        None => BatchMode::Fixed,
    };
    let out = if mode == BatchMode::Continuous {
        let slot_budget: usize = cli.opt_or("slot-budget", 8)?;
        if slot_budget < 2 {
            return Err(Error::Config(format!(
                "--slot-budget {slot_budget} must be >= 2 (a dual step costs 2 slots)"
            )));
        }
        // route through a continuous-mode coordinator: same output
        // (cohort composition can't affect a sample), exercised the way
        // the server runs it
        let coordinator = Coordinator::start(
            Arc::clone(&engine),
            CoordinatorConfig { mode, slot_budget, ..CoordinatorConfig::default() },
        );
        let out = coordinator.generate(req)?;
        coordinator.shutdown();
        out
    } else {
        engine.generate(&req)?
    };
    println!(
        "generated in {:.1} ms  (unet evals: {}, cond {:.1} ms, uncond {:.1} ms, combine {:.1} ms, scheduler {:.1} ms)",
        out.wall_ms,
        out.unet_evals,
        out.breakdown.unet_cond_ms,
        out.breakdown.unet_uncond_ms,
        out.breakdown.combine_ms,
        out.breakdown.scheduler_ms,
    );
    if let Some(img) = &out.image {
        let path = cli.opt("out").unwrap_or("out.png");
        img.save_png(Path::new(path))?;
        println!("wrote {path} ({}x{})", img.width, img.height);
    }
    Ok(())
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    let mut run_cfg = match cli.opt("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(b) = cli.opt("bind") {
        run_cfg.server.bind = b.to_string();
    }
    if let Some(m) = cli.opt("mode") {
        run_cfg.server.mode = BatchMode::parse(m)?;
    }
    run_cfg.server.workers = cli.opt_or("workers", run_cfg.server.workers)?;
    run_cfg.server.max_batch = cli.opt_or("max-batch", run_cfg.server.max_batch)?;
    run_cfg.server.slot_budget = cli.opt_or("slot-budget", run_cfg.server.slot_budget)?;
    run_cfg.server.validate()?;

    // QoS overrides: the flag force-enables, the knobs refine the config
    if cli.flag("qos") {
        run_cfg.qos.enabled = true;
    }
    run_cfg.qos.max_queue_depth = cli.opt_or("max-queue", run_cfg.qos.max_queue_depth)?;
    run_cfg.qos.floor_fraction = cli.opt_or("quality-floor", run_cfg.qos.floor_fraction)?;
    run_cfg.qos.default_deadline_ms =
        cli.opt_or("deadline-ms", run_cfg.qos.default_deadline_ms)?;
    run_cfg.qos.validate()?;

    let dir = cli
        .opt("artifacts")
        .map(String::from)
        .or(run_cfg.artifacts_dir.clone())
        .unwrap_or_else(|| artifacts_dir(cli));
    eprintln!("loading artifacts from {dir} ...");
    let stack = Arc::new(ModelStack::load(&dir)?);
    let engine = Arc::new(Engine::new(stack, run_cfg.engine.clone()));
    let coord_cfg = CoordinatorConfig {
        mode: run_cfg.server.mode,
        max_batch: run_cfg.server.max_batch,
        slot_budget: run_cfg.server.slot_budget,
        workers: run_cfg.server.workers,
        batch_wait: std::time::Duration::from_millis(run_cfg.server.batch_wait_ms),
    };
    match run_cfg.server.mode {
        BatchMode::Continuous => println!(
            "batching: continuous (slot budget {} per iteration, {} worker cohort(s))",
            run_cfg.server.slot_budget, run_cfg.server.workers
        ),
        BatchMode::Fixed => println!(
            "batching: fixed (max batch {}, wait {} ms)",
            run_cfg.server.max_batch, run_cfg.server.batch_wait_ms
        ),
    }
    let coordinator = if run_cfg.qos.enabled {
        println!(
            "qos: enabled (max queue {}, quality floor {:.0}%, default deadline {} ms)",
            run_cfg.qos.max_queue_depth,
            run_cfg.qos.floor_fraction * 100.0,
            run_cfg.qos.default_deadline_ms,
        );
        Coordinator::start_qos(engine, coord_cfg, Arc::new(DeadlineQos::new(run_cfg.qos.clone())?))
    } else {
        Coordinator::start(engine, coord_cfg)
    };
    let server = Server::start(coordinator, &run_cfg.server.bind)?;
    println!("sgd-serve listening on {}", server.addr());
    println!("protocol: JSON lines; try: {{\"op\":\"ping\"}}");
    // serve until the listener thread exits (shutdown op or signal)
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let dir = artifacts_dir(cli);
    let stack = ModelStack::load(&dir)?;
    let m = stack.model();
    println!("preset:       {}", m.preset);
    println!("latent:       {}x{}x{}", m.latent_channels, m.latent_size, m.latent_size);
    println!("image:        {0}x{0}", m.image_size);
    println!("text:         seq_len={} dim={} vocab={}", m.seq_len, m.text_dim, m.vocab_size);
    println!("batch sizes:  {:?}", m.batch_sizes);
    println!("artifacts:    {}", stack.manifest().artifacts.len());
    Ok(())
}
