//! `sgd-serve` — the selective-guidance serving binary.
//!
//! ```text
//! sgd-serve generate --prompt "A person holding a cat" [--steps 50]
//!           [--guidance-scale 7.5] [--window 0.2]
//!           [--position last|first|middle|offset(x)]
//!           [--segments "0.0-0.2,0.8-1.0"] [--interval 0.25-0.75]
//!           [--cadence 4]
//!           [--strategy cond-only|hold|extrapolate] [--refresh-every 0]
//!           [--adaptive] [--adaptive-threshold 0.05]
//!           [--adaptive-patience 2] [--adaptive-min-dual 0.3]
//!           [--adaptive-probe-every 8]
//!           [--scheduler pndm] [--seed 0] [--out out.png]
//!           [--strength 0.6] [--init-latent latent.f32]
//!           [--variations 4]
//!           [--mode fixed|continuous] [--slot-budget 8]
//!           [--artifacts artifacts/tiny]
//! sgd-serve serve    [--bind 127.0.0.1:7878] [--workers 1]
//!           [--mode fixed|continuous] [--max-batch 4] [--slot-budget 8]
//!           [--config configs/serve.toml]
//!           [--replicas 4] [--route plan-cost|round-robin]
//!           [--replica-budgets 8,4,2]
//!           [--window 0.2] [--position ...] [--segments ...]
//!           [--interval ...] [--cadence ...]
//!           [--qos] [--max-queue 64] [--quality-floor 0.5]
//!           [--deadline-ms 0] [--adaptive] [--adaptive-threshold ...]
//!           [--request-cache] [--dedup] [--preview-every 0]
//!           [--metrics-addr 127.0.0.1:9090] [--no-telemetry]
//!           [--cost-table cost_table.json] [--frontier frontier.json]
//! sgd-serve calibrate [--artifacts artifacts/tiny] [--synthetic]
//!           [--grid 1,2,4] [--samples 9] [--warmup 3] [--fast]
//!           [--out cost_table.json]
//! sgd-serve tune     [--artifacts artifacts/tiny] [--synthetic]
//!           [--cost-table cost_table.json] [--fast]
//!           [--out frontier.json]
//! sgd-serve info     [--artifacts artifacts/tiny]
//! ```
//!
//! `--strength s` truncates the denoising loop to `round(steps * s)`
//! iterations from a synthetic init latent (img2img); `--init-latent
//! path` reads an explicit init latent (raw little-endian f32s) and
//! requires `--strength`. `--variations n` fans one prompt into n seed
//! variations sharing one compiled guidance plan; outputs are written
//! as `out-0.png`, `out-1.png`, ... `serve --preview-every k` sets the
//! default preview cadence pushed to v2 streaming clients.
//!
//! The schedule flags are mutually exclusive: `--window`/`--position`
//! express the paper's contiguous window, `--segments`/`--interval`/
//! `--cadence` the generalized schedules (DESIGN.md §10). On `serve`
//! they (and the `[engine]`/`[guidance]` config sections) set the
//! serving default applied to requests that carry no guidance fields of
//! their own.
//!
//! `--mode continuous` (or `mode = "continuous"` in the config's
//! `[server]` section) switches the coordinator to iteration-level
//! batching under a UNet slot budget (DESIGN.md §9); `--qos` (or
//! `enabled = true` in `[qos]`) turns on deadline-aware admission control
//! with the selective-guidance window as the load-shedding actuator
//! (DESIGN.md §7).
//!
//! Telemetry (DESIGN.md §12) is on by default: every layer reports into
//! a process-wide metrics registry + trace store, served via the
//! `metrics`/`trace` wire ops. `--metrics-addr host:port` (or
//! `[telemetry] metrics_addr`) additionally opens a plain-HTTP
//! Prometheus scrape endpoint; `--no-telemetry` (or `[telemetry]
//! enabled = false`) opts out entirely.
//!
//! `calibrate` microbenchmarks the loaded runtime over its compiled
//! batch buckets (warmup discard, outlier-rejected median-of-N) and
//! writes a sealed, checksummed cost manifest (DESIGN.md §15);
//! `--synthetic` measures the in-crate synthetic backend (the CI shape),
//! `--fast` uses the cheap smoke grid. `serve --cost-table path` (or a
//! `[cost]` config section) loads such a manifest — validated against
//! the running backend + model fingerprint — and every scheduling layer
//! (continuous admission, QoS deadlines, cluster routing) prices steps
//! in measured milliseconds instead of analytic UNet-eval units.
//!
//! `tune` sweeps the selective-guidance schedule grammar on the loaded
//! runtime, scores every candidate (SSIM against the full-CFG baseline,
//! milliseconds from a cost table), prunes to the Pareto frontier and
//! writes a sealed, checksummed frontier manifest (DESIGN.md §16).
//! `serve --frontier path` (or a `[planner]` config section) loads such
//! a manifest — validated against the running backend + model
//! fingerprint — and QoS admission answers "cheapest plan above the
//! deadline's quality" with one O(1) indexed lookup instead of the
//! analytic window-widening actuator.
//!
//! `--replicas N` (or a `[cluster]` config section) runs a replica set
//! instead of a single coordinator (DESIGN.md §11): each replica is its
//! own coordinator shaped by the `[server]` keys (overridable per
//! replica via `[cluster.replica.N]` sections, or heterogeneously via
//! `--replica-budgets 8,4,2` — one continuous replica per listed slot
//! budget), requests are routed by compiled plan cost (`--route
//! round-robin` keeps the replica-blind baseline), and QoS admission
//! moves cluster-level over aggregate load.

use std::path::Path;
use std::sync::Arc;

use selective_guidance::cli::Cli;
use selective_guidance::cluster::{ClusterConfig, ReplicaSet, ReplicaSpec, RoutePolicy};
use selective_guidance::config::{CostConfig, EngineConfig, PlannerConfig, RunConfig};
use selective_guidance::coordinator::{BatchMode, Coordinator, CoordinatorConfig};
use selective_guidance::engine::{Engine, GenerationRequest};
use selective_guidance::error::{Error, Result};
use selective_guidance::guidance::{
    AdaptiveConfig, CostManifest, CostTable, FrontierManifest, GuidanceSchedule,
    GuidanceStrategy, PlanSearch, StepMode, TunerConfig, WindowPosition,
};
use selective_guidance::qos::DeadlineQos;
use selective_guidance::runtime::{calibrate, tune, CalibrationConfig, ModelStack};
use selective_guidance::scheduler::SchedulerKind;
use selective_guidance::server::{GuidanceDefaults, MetricsScrape, Server};
use selective_guidance::telemetry::CoordSink;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let cli = Cli::parse()?;
    match cli.command.as_deref() {
        Some("generate") => cmd_generate(&cli),
        Some("serve") => cmd_serve(&cli),
        Some("calibrate") => cmd_calibrate(&cli),
        Some("tune") => cmd_tune(&cli),
        Some("info") => cmd_info(&cli),
        Some(other) => Err(Error::Config(format!("unknown command {other:?}"))),
        None => {
            eprintln!("usage: sgd-serve <generate|serve|calibrate|tune|info> [options]");
            Ok(())
        }
    }
}

fn artifacts_dir(cli: &Cli) -> String {
    cli.opt("artifacts")
        .map(String::from)
        .or_else(|| std::env::var("SG_ARTIFACTS").ok())
        .unwrap_or_else(|| "artifacts/tiny".into())
}

/// Build the guidance schedule from the CLI: `--window`/`--position`
/// (contiguous, incl. `offset(x)` placements) or one of the generalized
/// schedules (`--segments` / `--interval` / `--cadence`). Mutual
/// exclusion and dispatch are the shared
/// [`GuidanceSchedule::from_parts`] rule; `None` = no schedule flag
/// given (keep the surface's default).
fn schedule_from(cli: &Cli) -> Result<Option<GuidanceSchedule>> {
    // a bare `--cadence` (no value) parses as a flag; reject instead of
    // silently running the full-CFG default
    for key in ["window", "position", "segments", "interval", "cadence"] {
        if cli.flag(key) {
            return Err(Error::Config(format!("--{key} needs a value")));
        }
    }
    let window = match cli.opt("window") {
        Some(_) => {
            let fraction: f64 = cli.opt_or("window", 0.0)?;
            let position = WindowPosition::parse(cli.opt("position").unwrap_or("last"))?;
            Some((fraction, position))
        }
        None => {
            // --position alone still selects a (zero-width) window so a
            // typo'd combination errors via validation rather than
            // silently ignoring the flag
            match cli.opt("position") {
                Some(p) => Some((0.0, WindowPosition::parse(p)?)),
                None => None,
            }
        }
    };
    let cadence = cli.opt_parse::<usize>("cadence")?;
    GuidanceSchedule::from_parts(window, cli.opt("segments"), cli.opt("interval"), cadence)
}

/// Build the adaptive-controller config from the CLI on top of an
/// optional config-file base: `--adaptive` enables it (keeping any
/// base knobs), the `--adaptive-*` knobs refine whatever is enabled.
/// Knobs without the flag *or* an enabled base are an operator error,
/// not a silent no-op (mirrors the TOML and wire surfaces).
fn adaptive_from(cli: &Cli, base: Option<AdaptiveConfig>) -> Result<Option<AdaptiveConfig>> {
    if cli.opt("adaptive").is_some() {
        return Err(Error::Config(
            "--adaptive is a flag and takes no value (use --adaptive-* for the knobs)".into(),
        ));
    }
    let knobs = [
        "adaptive-threshold",
        "adaptive-patience",
        "adaptive-min-dual",
        "adaptive-probe-every",
    ];
    // a value-less knob parses as a bare flag; reject instead of
    // silently running with the default (mirrors schedule_from)
    for key in knobs {
        if cli.flag(key) {
            return Err(Error::Config(format!("--{key} needs a value")));
        }
    }
    let enabled = cli.flag("adaptive") || base.is_some();
    if !enabled {
        if let Some(orphan) = knobs.iter().find(|&&k| cli.opt(k).is_some()) {
            return Err(Error::Config(format!("--{orphan} requires --adaptive")));
        }
        return Ok(None);
    }
    let d = base.unwrap_or_default();
    let a = AdaptiveConfig {
        threshold: cli.opt_or("adaptive-threshold", d.threshold)?,
        patience: cli.opt_or("adaptive-patience", d.patience)?,
        min_dual_fraction: cli.opt_or("adaptive-min-dual", d.min_dual_fraction)?,
        probe_every: cli.opt_or("adaptive-probe-every", d.probe_every)?,
    };
    a.validate()?;
    Ok(Some(a))
}

fn cmd_generate(cli: &Cli) -> Result<()> {
    let dir = artifacts_dir(cli);
    eprintln!("loading artifacts from {dir} ...");
    let stack = Arc::new(ModelStack::load(&dir)?);
    let engine = Arc::new(Engine::new(stack, EngineConfig::default()));

    let prompt = cli
        .opt("prompt")
        .ok_or_else(|| Error::Config("--prompt is required".into()))?;
    let strategy = GuidanceStrategy::parse(
        cli.opt("strategy").unwrap_or("cond-only"),
        cli.opt_or("refresh-every", 0)?,
    )?;
    let mut req = GenerationRequest::new(prompt)
        .steps(cli.opt_or("steps", 50)?)
        .guidance_scale(cli.opt_or("guidance-scale", 7.5)?)
        .with_schedule(schedule_from(cli)?.unwrap_or_else(GuidanceSchedule::none))
        .strategy(strategy)
        .scheduler(SchedulerKind::parse(cli.opt("scheduler").unwrap_or("pndm"))?)
        // parse as i64 then validate: shared with TOML/wire/workload, so
        // `--seed -1` is a config error, not a silent u64 wrap
        .seed(
            selective_guidance::config::seed_from_i64(cli.opt_or("seed", 0i64)?)
                .map_err(Error::Config)?,
        );
    if let Some(a) = adaptive_from(cli, None)? {
        req = req.adaptive(a);
    }

    // img2img: --strength alone denoises a synthetic init latent;
    // --init-latent reads an explicit one (raw little-endian f32s) and
    // requires --strength to say how far back to noise it
    for key in ["strength", "init-latent", "variations"] {
        if cli.flag(key) {
            return Err(Error::Config(format!("--{key} needs a value")));
        }
    }
    let strength = cli.opt_parse::<f64>("strength")?;
    match (cli.opt("init-latent"), strength) {
        (Some(path), Some(s)) => {
            let latent = read_latent_f32(Path::new(path))?;
            req = req.init_latent(Arc::new(latent), s);
        }
        (Some(_), None) => {
            return Err(Error::Config("--init-latent requires --strength".into()));
        }
        (None, Some(s)) => req = req.img2img(s),
        (None, None) => {}
    }

    // variations fan one prompt into n seed variations sharing one
    // compiled guidance plan (seeds --seed .. --seed+n-1)
    let n: usize = cli.opt_or("variations", 1)?;
    if n == 0 {
        return Err(Error::Config("--variations must be >= 1".into()));
    }
    let reqs = if n > 1 { req.variations(n)? } else { vec![req] };

    let mode = match cli.opt("mode") {
        Some(m) => BatchMode::parse(m)?,
        None => BatchMode::Fixed,
    };
    // route through a continuous-mode coordinator when asked: same
    // output (cohort composition can't affect a sample), exercised the
    // way the server runs it — and a variations fan-out cohorts together
    let coordinator = if mode == BatchMode::Continuous {
        let slot_budget: usize = cli.opt_or("slot-budget", 8)?;
        if slot_budget < 2 {
            return Err(Error::Config(format!(
                "--slot-budget {slot_budget} must be >= 2 (a dual step costs 2 slots)"
            )));
        }
        Some(Coordinator::start(
            Arc::clone(&engine),
            CoordinatorConfig { mode, slot_budget, ..CoordinatorConfig::default() },
        ))
    } else {
        None
    };
    let many = reqs.len() > 1;
    for (i, req) in reqs.into_iter().enumerate() {
        let out = match &coordinator {
            Some(c) => c.generate(req)?,
            None => engine.generate(&req)?,
        };
        let label = if many { format!("variation {i} ") } else { String::new() };
        println!(
            "{label}generated in {:.1} ms  (unet evals: {}, cond {:.1} ms, uncond {:.1} ms, combine {:.1} ms, scheduler {:.1} ms)",
            out.wall_ms,
            out.unet_evals,
            out.breakdown.unet_cond_ms,
            out.breakdown.unet_uncond_ms,
            out.breakdown.combine_ms,
            out.breakdown.scheduler_ms,
        );
        println!("executed plan: {}", out.plan_summary);
        if let Some(img) = &out.image {
            let base = cli.opt("out").unwrap_or("out.png");
            let path = if many { indexed_path(base, i) } else { base.to_string() };
            img.save_png(Path::new(&path))?;
            println!("wrote {path} ({}x{})", img.width, img.height);
        }
    }
    if let Some(c) = coordinator {
        c.shutdown();
    }
    Ok(())
}

/// Read a raw init latent: the file is little-endian f32s, C*H*W in the
/// model's latent space (what `SampleState::latent` holds).
fn read_latent_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .map_err(|e| Error::io(format!("reading init latent {}", path.display()), e))?;
    if bytes.is_empty() || bytes.len() % 4 != 0 {
        return Err(Error::Config(format!(
            "init latent {}: {} bytes is not a whole number of f32s",
            path.display(),
            bytes.len()
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// `out.png` -> `out-3.png` for variation fan-out outputs.
fn indexed_path(base: &str, i: usize) -> String {
    match base.rsplit_once('.') {
        Some((stem, ext)) => format!("{stem}-{i}.{ext}"),
        None => format!("{base}-{i}"),
    }
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    let mut run_cfg = match cli.opt("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(b) = cli.opt("bind") {
        run_cfg.server.bind = b.to_string();
    }
    if let Some(m) = cli.opt("mode") {
        run_cfg.server.mode = BatchMode::parse(m)?;
    }
    run_cfg.server.workers = cli.opt_or("workers", run_cfg.server.workers)?;
    run_cfg.server.max_batch = cli.opt_or("max-batch", run_cfg.server.max_batch)?;
    run_cfg.server.slot_budget = cli.opt_or("slot-budget", run_cfg.server.slot_budget)?;
    if cli.flag("preview-every") {
        return Err(Error::Config("--preview-every needs a value".into()));
    }
    run_cfg.server.preview_every =
        cli.opt_or("preview-every", run_cfg.server.preview_every)?;
    run_cfg.server.validate()?;

    // guidance overrides compose with the config file: schedule flags
    // replace the configured default schedule; `--adaptive`
    // force-enables (keeping config knobs) and `--adaptive-*` refine
    // whatever the config enabled. validate() rejects conflicting
    // combinations (e.g. an adaptive config plus a schedule flag).
    if let Some(s) = schedule_from(cli)? {
        run_cfg.engine.schedule = s;
    }
    run_cfg.engine.adaptive = adaptive_from(cli, run_cfg.engine.adaptive)?;
    run_cfg.engine.validate()?;

    // QoS overrides: the flag force-enables, the knobs refine the config
    if cli.flag("qos") {
        run_cfg.qos.enabled = true;
    }
    run_cfg.qos.max_queue_depth = cli.opt_or("max-queue", run_cfg.qos.max_queue_depth)?;
    run_cfg.qos.floor_fraction = cli.opt_or("quality-floor", run_cfg.qos.floor_fraction)?;
    run_cfg.qos.default_deadline_ms =
        cli.opt_or("deadline-ms", run_cfg.qos.default_deadline_ms)?;
    run_cfg.qos.validate()?;

    // cache overrides: the flags force-enable tiers on top of [cache]
    if cli.flag("request-cache") {
        run_cfg.cache.request_cache = true;
    }
    if cli.flag("dedup") {
        run_cfg.cache.dedup = true;
    }
    run_cfg.cache.validate()?;

    // cost overrides: --cost-table points the [cost] section at a sealed
    // manifest (flags win over the config file's table_path)
    if cli.flag("cost-table") {
        return Err(Error::Config("--cost-table needs a value".into()));
    }
    if let Some(path) = cli.opt("cost-table") {
        if run_cfg.cost.calibrate_on_start {
            return Err(Error::Config(
                "--cost-table conflicts with [cost] calibrate_on_start — \
                 configure exactly one table source"
                    .into(),
            ));
        }
        run_cfg.cost.table_path = Some(path.to_string());
    }
    run_cfg.cost.validate()?;

    // planner overrides: --frontier points the [planner] section at a
    // sealed frontier manifest (flags win over the config file's path)
    if cli.flag("frontier") {
        return Err(Error::Config("--frontier needs a value".into()));
    }
    if let Some(path) = cli.opt("frontier") {
        if run_cfg.planner.tune_on_start {
            return Err(Error::Config(
                "--frontier conflicts with [planner] tune_on_start — \
                 configure exactly one frontier source"
                    .into(),
            ));
        }
        run_cfg.planner.frontier_path = Some(path.to_string());
    }
    run_cfg.planner.validate()?;

    // telemetry overrides: --no-telemetry opts out, --metrics-addr
    // opens (or re-binds) the Prometheus scrape endpoint
    if cli.flag("metrics-addr") {
        return Err(Error::Config("--metrics-addr needs a value".into()));
    }
    if cli.flag("no-telemetry") {
        run_cfg.telemetry.enabled = false;
        run_cfg.telemetry.metrics_addr = None;
        run_cfg.telemetry.trace_jsonl = None;
    }
    if let Some(addr) = cli.opt("metrics-addr") {
        if !run_cfg.telemetry.enabled {
            return Err(Error::Config("--metrics-addr requires telemetry enabled".into()));
        }
        run_cfg.telemetry.metrics_addr = Some(addr.to_string());
    }
    run_cfg.telemetry.validate()?;
    let telemetry = run_cfg.telemetry.build();

    // ---- cluster surface: the [cluster] section plus --replicas /
    // --route / --replica-budgets overrides (flags win)
    for key in ["replicas", "route", "replica-budgets"] {
        if cli.flag(key) {
            return Err(Error::Config(format!("--{key} needs a value")));
        }
    }
    let mut cluster_cfg = run_cfg.cluster.clone();
    if cli.opt("replicas").is_some() && cli.opt("replica-budgets").is_some() {
        return Err(Error::Config(
            "--replicas and --replica-budgets are mutually exclusive (the budget list \
             already fixes the replica count)"
                .into(),
        ));
    }
    if let Some(list) = cli.opt("replica-budgets") {
        // heterogeneous continuous fleet: one replica per listed budget
        let mut specs = Vec::new();
        for part in list.split(',') {
            let budget: usize = part.trim().parse().map_err(|_| {
                Error::Config(format!("--replica-budgets: cannot parse {part:?}"))
            })?;
            specs.push(ReplicaSpec {
                mode: BatchMode::Continuous,
                slot_budget: budget,
                ..ReplicaSpec::from_server(&run_cfg.server)
            });
        }
        let mut cfg = cluster_cfg.take().unwrap_or_default();
        cfg.replicas = specs;
        cluster_cfg = Some(cfg);
    }
    if let Some(n) = cli.opt_parse::<usize>("replicas")? {
        if n == 0 {
            return Err(Error::Config("--replicas must be >= 1".into()));
        }
        // grow-only: configured per-replica shapes are kept, extras
        // inherit the [server] shape. Shrinking would silently discard
        // explicit [cluster.replica.N] overrides — make the operator
        // edit the config instead.
        let base = ReplicaSpec::from_server(&run_cfg.server);
        let mut cfg = cluster_cfg
            .take()
            .unwrap_or(ClusterConfig { replicas: Vec::new(), ..ClusterConfig::default() });
        if n < cfg.replicas.len() {
            return Err(Error::Config(format!(
                "--replicas {n} would drop {} configured replica(s) — shrink the \
                 [cluster] section instead",
                cfg.replicas.len() - n
            )));
        }
        cfg.replicas.resize(n, base);
        cluster_cfg = Some(cfg);
    }
    if let Some(r) = cli.opt("route") {
        let policy = RoutePolicy::parse(r)?;
        match cluster_cfg.as_mut() {
            Some(cfg) => cfg.route = policy,
            None => {
                return Err(Error::Config(
                    "--route requires --replicas, --replica-budgets or a [cluster] \
                     config section"
                        .into(),
                ))
            }
        }
    }
    // the cache tiers follow the merged [cache] + flag view everywhere:
    // the cluster parses the same [cache] section itself, so this only
    // layers the flag overrides on top
    if let Some(cfg) = cluster_cfg.as_mut() {
        cfg.cache = run_cfg.cache.clone();
    }
    if run_cfg.cache.enabled() {
        println!(
            "cache: request_cache={} (capacity {}), dedup={}, shared_uncond={}",
            run_cfg.cache.request_cache,
            run_cfg.cache.request_capacity,
            run_cfg.cache.dedup,
            run_cfg.cache.shared_uncond,
        );
    }

    let dir = cli
        .opt("artifacts")
        .map(String::from)
        .or(run_cfg.artifacts_dir.clone())
        .unwrap_or_else(|| artifacts_dir(cli));
    eprintln!("loading artifacts from {dir} ...");
    let stack = Arc::new(ModelStack::load(&dir)?);

    // measured-cost plan model (DESIGN.md §15): resolve the [cost]
    // section against the loaded runtime (the manifest binds to backend
    // + model fingerprint), then inject the table into whichever
    // scheduling plane this deployment runs
    let cost_table = cost_table_from(&run_cfg.cost, &stack)?;
    if let Some(t) = &cost_table {
        if run_cfg.cost.budget_ms > 0.0 {
            let dual = t.sample_step_ms(StepMode::Dual);
            if run_cfg.cost.budget_ms < dual {
                return Err(Error::Config(format!(
                    "cost budget_ms {} cannot admit even one dual-guidance sample \
                     (measured {dual:.3} ms) — raise the budget or recalibrate",
                    run_cfg.cost.budget_ms
                )));
            }
        }
        println!(
            "cost: measured table ({} / {}, buckets {:?}, fallback {}), model ratio \
             {:.2}, shed ratio {:.2}",
            t.backend(),
            t.preset(),
            t.batches(),
            t.fallback().name(),
            t.model_ratio(),
            t.shed_ratio(),
        );
        if run_cfg.cost.budget_ms > 0.0 {
            println!(
                "cost: continuous admission budget {} ms per iteration",
                run_cfg.cost.budget_ms
            );
        }
    }
    // deadline-optimal plan search (DESIGN.md §16): resolve the
    // [planner] section into a sealed Pareto frontier — loaded (and
    // validated against this runtime) or swept on start — and hand the
    // O(1) search to whichever scheduling plane this deployment runs
    let plan_search = plan_search_from(&run_cfg.planner, &stack, cost_table.as_ref())?;
    if let Some(cfg) = cluster_cfg.as_mut() {
        if let Some(t) = &cost_table {
            // one fleet-shared table: replica weights, job pricing and
            // the ms admission tier all read the same measurements
            cfg.cost_tables = vec![Arc::clone(t)];
            cfg.cost_budget_ms = run_cfg.cost.budget_ms;
        }
        if let Some(p) = &plan_search {
            // one fleet-shared frontier: every replica's admission
            // degrades along the same sealed trade-off curve
            cfg.planners = vec![Arc::clone(p)];
        }
    }
    if let Some(cfg) = &cluster_cfg {
        cfg.validate()?;
    }

    let engine = Arc::new(Engine::new(Arc::clone(&stack), run_cfg.engine.clone()));
    if run_cfg.qos.enabled {
        println!(
            "qos: enabled (max queue {}, quality floor {:.0}%, default deadline {} ms)",
            run_cfg.qos.max_queue_depth,
            run_cfg.qos.floor_fraction * 100.0,
            run_cfg.qos.default_deadline_ms,
        );
    }
    if run_cfg.engine.schedule != GuidanceSchedule::none() {
        println!(
            "guidance default: {} ({})",
            run_cfg.engine.schedule.label(),
            run_cfg.engine.guidance_strategy.label(),
        );
    }
    if let Some(a) = &run_cfg.engine.adaptive {
        println!(
            "adaptive: enabled by default (threshold {}, patience {}, min dual {:.0}%, \
             probe every {})",
            a.threshold,
            a.patience,
            a.min_dual_fraction * 100.0,
            a.probe_every,
        );
    }
    let defaults = GuidanceDefaults::from_engine(&run_cfg.engine)
        .with_preview_every(run_cfg.server.preview_every);
    if run_cfg.server.preview_every > 0 {
        println!(
            "streaming: default preview every {} steps (v2 \"stream\": true)",
            run_cfg.server.preview_every
        );
    }
    let server = match cluster_cfg {
        Some(cfg) => {
            println!("cluster: {} replica(s), route {}", cfg.replicas.len(), cfg.route.name());
            for (i, spec) in cfg.replicas.iter().enumerate() {
                match spec.mode {
                    BatchMode::Continuous => println!(
                        "  replica {i}: continuous (slot budget {}, {} worker cohort(s))",
                        spec.slot_budget, spec.workers
                    ),
                    BatchMode::Fixed => println!(
                        "  replica {i}: fixed (max batch {}, wait {} ms, {} worker(s))",
                        spec.max_batch, spec.batch_wait_ms, spec.workers
                    ),
                }
            }
            let qos = if run_cfg.qos.enabled {
                Some(Arc::new(DeadlineQos::new(run_cfg.qos.clone())?)
                    as Arc<dyn selective_guidance::qos::QosPolicy>)
            } else {
                None
            };
            let set = ReplicaSet::start_full(engine, cfg, qos, telemetry.clone())?;
            Server::start_cluster(set, &run_cfg.server.bind, defaults)?
        }
        None => {
            let coord_cfg = CoordinatorConfig {
                mode: run_cfg.server.mode,
                max_batch: run_cfg.server.max_batch,
                slot_budget: run_cfg.server.slot_budget,
                workers: run_cfg.server.workers,
                batch_wait: std::time::Duration::from_millis(run_cfg.server.batch_wait_ms),
                cache: run_cfg.cache.clone(),
                cost_table: cost_table.clone(),
                cost_budget_ms: run_cfg.cost.budget_ms,
                planner: plan_search.clone(),
            };
            match run_cfg.server.mode {
                BatchMode::Continuous => println!(
                    "batching: continuous (slot budget {} per iteration, {} worker cohort(s))",
                    run_cfg.server.slot_budget, run_cfg.server.workers
                ),
                BatchMode::Fixed => println!(
                    "batching: fixed (max batch {}, wait {} ms)",
                    run_cfg.server.max_batch, run_cfg.server.batch_wait_ms
                ),
            }
            let qos = if run_cfg.qos.enabled {
                Some(Arc::new(DeadlineQos::new(run_cfg.qos.clone())?)
                    as Arc<dyn selective_guidance::qos::QosPolicy>)
            } else {
                None
            };
            let sink = telemetry.as_ref().map(|t| CoordSink::new(t, "single", true));
            let coordinator = Coordinator::start_full(engine, coord_cfg, qos, sink);
            Server::start_with_defaults(coordinator, &run_cfg.server.bind, defaults)?
        }
    };
    // the scrape listener lives exactly as long as the server below
    let scrape = match (&telemetry, run_cfg.telemetry.metrics_addr.as_deref()) {
        (Some(t), Some(addr)) => {
            let s = MetricsScrape::start(Arc::clone(t), addr)?;
            println!("metrics: Prometheus scrape endpoint on http://{}/metrics", s.addr());
            Some(s)
        }
        _ => None,
    };
    println!("sgd-serve listening on {}", server.addr());
    println!("protocol: JSON lines; try: {{\"op\":\"ping\"}}");
    // serve until the shutdown op stops the listener (or the process is
    // signalled)
    while !server.stopped() {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    drop(scrape);
    if let (Some(t), Some(path)) = (&telemetry, run_cfg.telemetry.trace_jsonl.as_deref()) {
        std::fs::write(path, t.traces().export_jsonl())
            .map_err(|e| Error::io(format!("writing {path}"), e))?;
        println!("wrote trace spans to {path}");
    }
    Ok(())
}

/// Resolve the `[cost]` section against the loaded runtime: load (or
/// calibrate) the sealed manifest, refuse a backend / model-fingerprint
/// mismatch, build the table and prove it covers the scheduling
/// currency. `None` = no cost source configured, every layer keeps
/// pricing in analytic units.
fn cost_table_from(cost: &CostConfig, stack: &ModelStack) -> Result<Option<Arc<CostTable>>> {
    if !cost.enabled() {
        return Ok(None);
    }
    let manifest = match &cost.table_path {
        Some(path) => {
            let m = CostManifest::load(Path::new(path))?;
            stack.validate_cost_manifest(&m)?;
            println!("cost: loaded sealed manifest {path} (checksum {})", m.checksum);
            m
        }
        None => {
            eprintln!("cost: calibrating loaded runtime (fast grid) ...");
            calibrate(stack, &CalibrationConfig::fast())?
        }
    };
    let table = manifest.table(cost.fallback)?;
    // reject-policy tables must cover every compiled bucket up front
    table.validate_covers(&stack.model().batch_sizes)?;
    // regardless of policy, the per-sample scheduling currency (batch-1
    // dual/single) must be measured — a table that can only price it
    // analytically would fall back on every admission decision
    for mode in [StepMode::Dual, StepMode::Single] {
        if !table.covers(1, mode) {
            return Err(Error::Config(format!(
                "cost table does not cover batch 1 {} — the per-sample scheduling \
                 currency must be measured; recalibrate with 1 in the grid",
                mode.name()
            )));
        }
    }
    Ok(Some(Arc::new(table)))
}

/// Resolve the `[planner]` section against the loaded runtime: load the
/// sealed frontier manifest (refusing a backend / model-fingerprint
/// mismatch, like the cost path) or sweep one on start, then compile it
/// into the O(1) admission search (DESIGN.md §16). `None` = planner
/// off; under pressure admission degrades via the legacy analytic
/// actuator.
fn plan_search_from(
    planner: &PlannerConfig,
    stack: &Arc<ModelStack>,
    cost_table: Option<&Arc<CostTable>>,
) -> Result<Option<Arc<PlanSearch>>> {
    if !planner.enabled() {
        return Ok(None);
    }
    let manifest = match &planner.frontier_path {
        Some(path) => {
            let m = FrontierManifest::load(Path::new(path))?;
            stack.validate_frontier_manifest(&m)?;
            println!("planner: loaded sealed frontier {path} (checksum {})", m.checksum);
            m
        }
        None => {
            // tune_on_start: RunConfig cross-validation guarantees a
            // [cost] source, so a resolved table is present here
            let table = cost_table.ok_or_else(|| {
                Error::Config(
                    "planner tune_on_start requires a resolved cost table to price the sweep"
                        .into(),
                )
            })?;
            let cfg = if planner.fast { TunerConfig::fast() } else { TunerConfig::default() };
            eprintln!(
                "planner: sweeping {} schedule candidates on start ...",
                cfg.candidates().len()
            );
            tune(Arc::clone(stack), &cfg, table)?
        }
    };
    let plans: usize = manifest.buckets.iter().map(|b| b.points.len()).sum();
    println!(
        "planner: frontier ready — {} steps bucket(s), {} non-dominated plan(s) \
         ({} candidates swept)",
        manifest.buckets.len(),
        plans,
        manifest.candidates_swept,
    );
    Ok(Some(Arc::new(PlanSearch::new(manifest)?)))
}

/// `sgd-serve tune`: sweep the selective-guidance schedule grammar on
/// the loaded runtime into a sealed Pareto-frontier manifest
/// (DESIGN.md §16). Every candidate is scored on quality (SSIM against
/// the full-CFG render at the same seed) and cost (milliseconds from a
/// cost table: a sealed `--cost-table` manifest, else a fast
/// calibration of this runtime); dominated plans are pruned.
/// `--synthetic` sweeps the in-crate synthetic backend (the CI smoke
/// shape); `--fast` uses the cheap sweep grid.
fn cmd_tune(cli: &Cli) -> Result<()> {
    for key in ["cost-table", "out"] {
        if cli.flag(key) {
            return Err(Error::Config(format!("--{key} needs a value")));
        }
    }
    let stack = if cli.flag("synthetic") {
        Arc::new(ModelStack::synthetic())
    } else {
        let dir = artifacts_dir(cli);
        eprintln!("loading artifacts from {dir} ...");
        Arc::new(ModelStack::load(&dir)?)
    };
    // price the sweep in measured milliseconds through the same [cost]
    // resolution path `serve` uses (manifest validation + coverage
    // checks included)
    let cost_cfg = match cli.opt("cost-table") {
        Some(path) => CostConfig { table_path: Some(path.to_string()), ..CostConfig::default() },
        None => CostConfig { calibrate_on_start: true, ..CostConfig::default() },
    };
    let table = cost_table_from(&cost_cfg, &stack)?.expect("cost source configured");
    let cfg = if cli.flag("fast") { TunerConfig::fast() } else { TunerConfig::default() };
    eprintln!(
        "tuning: sweeping {} schedule candidates over steps buckets {:?} ...",
        cfg.candidates().len(),
        cfg.steps_buckets,
    );
    let manifest = tune(Arc::clone(&stack), &cfg, &table)?;
    for bucket in &manifest.buckets {
        println!(
            "frontier @ {} steps (full CFG {:.1} ms): {} non-dominated plan(s)",
            bucket.steps,
            bucket.full_cost_ms,
            bucket.points.len()
        );
        for p in &bucket.points {
            println!(
                "  {:<28} ssim {:.4}  cost {:>7.1} ms  (saving {:.0}%)",
                p.label,
                p.ssim,
                p.cost_ms,
                p.saving(bucket.full_cost_ms) * 100.0,
            );
        }
    }
    let out = cli.opt("out").unwrap_or("frontier.json");
    manifest.save(Path::new(out))?;
    println!(
        "wrote sealed frontier manifest to {out} (model fingerprint {}, checksum {})",
        manifest.model_fingerprint, manifest.checksum,
    );
    Ok(())
}

/// `sgd-serve calibrate`: microbench the loaded runtime into a sealed
/// cost manifest (DESIGN.md §15). `--synthetic` measures the in-crate
/// synthetic backend (the CI smoke shape); `--fast` is the cheap
/// median-of-3 grid; `--grid 1,2,4` restricts the batch buckets.
fn cmd_calibrate(cli: &Cli) -> Result<()> {
    for key in ["grid", "samples", "warmup", "out"] {
        if cli.flag(key) {
            return Err(Error::Config(format!("--{key} needs a value")));
        }
    }
    let mut cfg =
        if cli.flag("fast") { CalibrationConfig::fast() } else { CalibrationConfig::default() };
    if let Some(list) = cli.opt("grid") {
        let mut grid = Vec::new();
        for part in list.split(',') {
            grid.push(
                part.trim()
                    .parse()
                    .map_err(|_| Error::Config(format!("--grid: cannot parse {part:?}")))?,
            );
        }
        cfg.grid = grid;
    }
    cfg.samples = cli.opt_or("samples", cfg.samples)?;
    cfg.warmup = cli.opt_or("warmup", cfg.warmup)?;

    let stack = if cli.flag("synthetic") {
        ModelStack::synthetic()
    } else {
        let dir = artifacts_dir(cli);
        eprintln!("loading artifacts from {dir} ...");
        ModelStack::load(&dir)?
    };
    let manifest = calibrate(&stack, &cfg)?;
    println!(
        "calibrated {} / {} (resolution {}, {} samples, {} warmup per point):",
        manifest.backend, manifest.preset, manifest.resolution, manifest.samples, manifest.warmup,
    );
    for r in &manifest.rows {
        println!(
            "  batch {:>3}: dual {:.4} ms, single {:.4} ms  (ratio {:.2})",
            r.batch,
            r.dual_ms,
            r.single_ms,
            r.dual_ms / r.single_ms,
        );
    }
    let out = cli.opt("out").unwrap_or("cost_table.json");
    manifest.save(Path::new(out))?;
    println!(
        "wrote sealed cost manifest to {out} (model fingerprint {}, checksum {})",
        manifest.model_fingerprint, manifest.checksum,
    );
    Ok(())
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let dir = artifacts_dir(cli);
    let stack = ModelStack::load(&dir)?;
    let m = stack.model();
    println!("preset:       {}", m.preset);
    println!("latent:       {}x{}x{}", m.latent_channels, m.latent_size, m.latent_size);
    println!("image:        {0}x{0}", m.image_size);
    println!("text:         seq_len={} dim={} vocab={}", m.seq_len, m.text_dim, m.vocab_size);
    println!("batch sizes:  {:?}", m.batch_sizes);
    println!("artifacts:    {}", stack.manifest().artifacts.len());
    Ok(())
}
