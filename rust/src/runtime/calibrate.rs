//! Runtime calibration: microbench the loaded [`ModelStack`] into a
//! sealed [`CostManifest`] (DESIGN.md §15).
//!
//! The analytic cost model prices a dual step at exactly two singles.
//! Reality disagrees per backend, per batch bucket and per resolution —
//! so the calibrator *measures* the loaded runtime: for each batch
//! bucket on the grid it times the dual-step shape (two UNet passes +
//! the CFG combine) and the single-step shape (one UNet pass), with
//! warmup discard, median-of-N and outlier rejection, and seals the
//! result in a checksummed manifest bound to the backend + model
//! fingerprint. CI calibrates the synthetic stack (`calibrate --fast`);
//! a machine with the PJRT artifacts calibrates the real thing.
//!
//! Wall-clock enters the repo *only here*: the manifest is the boundary.
//! Everything downstream (scheduling, routing, benches) consumes the
//! table deterministically.

use std::time::Instant;

use super::ModelStack;
use crate::error::{Error, Result};
use crate::guidance::{CostManifest, CostRow};

/// Grid + sampling knobs for one calibration pass.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationConfig {
    /// Batch buckets to measure. Empty = every compiled batch size.
    /// Buckets the runtime has no compiled executable for are rejected
    /// (a table must never claim coverage it cannot serve).
    pub grid: Vec<usize>,
    /// Timed samples per (batch, mode) grid point; the reported value is
    /// the outlier-rejected median. Must be odd so the median is a real
    /// sample (keeps the manifest reproducible from its inputs).
    pub samples: usize,
    /// Leading evaluations discarded per grid point (cache warmup,
    /// first-touch page faults).
    pub warmup: usize,
}

impl Default for CalibrationConfig {
    fn default() -> Self {
        CalibrationConfig { grid: Vec::new(), samples: 9, warmup: 3 }
    }
}

impl CalibrationConfig {
    /// The CI smoke shape: still statistically honest (median of 3, one
    /// warmup) but cheap enough to run on every push.
    pub fn fast() -> Self {
        CalibrationConfig { grid: Vec::new(), samples: 3, warmup: 1 }
    }

    pub fn validate(&self) -> Result<()> {
        if self.samples == 0 || self.samples % 2 == 0 {
            return Err(Error::Config(format!(
                "calibration samples {} must be odd and >= 1 (the median must be a \
                 real sample)",
                self.samples
            )));
        }
        Ok(())
    }
}

/// Median of a non-empty, sorted slice (odd lengths index the middle
/// sample; even lengths — possible after outlier rejection — average
/// the two middle samples).
fn median(sorted: &[f64]) -> f64 {
    let n = sorted.len();
    if n % 2 == 1 {
        sorted[n / 2]
    } else {
        (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
    }
}

/// Outlier-rejected median: sort, take the median, drop samples outside
/// ±50% of it (scheduler preemptions, thermal events), re-median what
/// survives. The median itself always survives its own band, so the
/// result is well-defined.
fn robust_median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let m = median(&xs);
    let kept: Vec<f64> = xs.into_iter().filter(|x| *x >= m * 0.5 && *x <= m * 1.5).collect();
    median(&kept)
}

/// Time one invocation of `f` in milliseconds.
fn time_ms(f: &mut dyn FnMut() -> Result<()>) -> Result<f64> {
    let t0 = Instant::now();
    f()?;
    Ok(t0.elapsed().as_secs_f64() * 1e3)
}

/// Measure the loaded runtime over the grid and seal the result.
///
/// Per (batch, mode) grid point: `warmup` discarded invocations, then
/// `samples` timed ones, reduced by [`robust_median`]. The dual shape is
/// two UNet passes + the CFG combine (what a guided step executes); the
/// single shape is one UNet pass (cond-only — reuse adds a combine, but
/// that is noise next to a UNet pass and the table keys on the
/// UNet-count shape). `analytic_unit_ms` — the fallback price of one
/// eval unit — is the measured batch-1 single.
pub fn calibrate(stack: &ModelStack, cfg: &CalibrationConfig) -> Result<CostManifest> {
    cfg.validate()?;
    let model = stack.model();
    let compiled = &model.batch_sizes;
    let mut grid: Vec<usize> = if cfg.grid.is_empty() { compiled.clone() } else { cfg.grid.clone() };
    grid.sort_unstable();
    grid.dedup();
    for &b in &grid {
        if !compiled.contains(&b) {
            return Err(Error::Config(format!(
                "calibration grid batch {b} has no compiled executable \
                 (available: {compiled:?})"
            )));
        }
    }

    let ctx1 = stack.uncond_ctx()?;
    let mut rows = Vec::with_capacity(grid.len());
    for &b in &grid {
        let latents = vec![0.1f32; b * model.latent_elems()];
        let ts = vec![500.0f32; b];
        let ctx: Vec<f32> = ctx1.iter().copied().cycle().take(b * model.ctx_elems()).collect();

        let mut dual = || -> Result<()> {
            let eps_u = stack.unet_eps(b, &latents, &ts, &ctx)?;
            let eps_c = stack.unet_eps(b, &latents, &ts, &ctx)?;
            stack.cfg_combine(b, &eps_u, &eps_c, 7.5)?;
            Ok(())
        };
        let mut single = || -> Result<()> {
            stack.unet_eps(b, &latents, &ts, &ctx)?;
            Ok(())
        };

        let measure = |f: &mut dyn FnMut() -> Result<()>| -> Result<f64> {
            for _ in 0..cfg.warmup {
                f()?;
            }
            let mut samples = Vec::with_capacity(cfg.samples);
            for _ in 0..cfg.samples {
                samples.push(time_ms(f)?);
            }
            // floor: the synthetic stack can run a step in < 1 µs; a
            // zero-priced entry would be rejected by the table builder
            Ok(robust_median(samples).max(1e-6))
        };
        rows.push(CostRow {
            batch: b,
            dual_ms: measure(&mut dual)?,
            single_ms: measure(&mut single)?,
        });
    }

    let unit_ms = rows
        .iter()
        .find(|r| r.batch == 1)
        .map(|r| r.single_ms)
        // grids without batch 1 still need a fallback unit: pro-rate the
        // smallest measured bucket
        .unwrap_or_else(|| rows[0].single_ms / rows[0].batch as f64);
    Ok(CostManifest::seal(
        env!("CARGO_PKG_VERSION"),
        stack.backend_name(),
        model.preset.clone(),
        stack.manifest().model_fingerprint(),
        model.latent_size,
        cfg.samples,
        cfg.warmup,
        unit_ms,
        rows,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guidance::{FallbackPolicy, StepMode};

    #[test]
    fn robust_median_rejects_outliers() {
        // a 100x scheduler hiccup must not drag the median band
        let m = robust_median(vec![1.0, 1.1, 0.9, 1.05, 100.0]);
        assert!((0.9..=1.1).contains(&m), "{m}");
        // symmetric small set
        assert_eq!(robust_median(vec![2.0]), 2.0);
        assert_eq!(robust_median(vec![1.0, 1.0, 1.0]), 1.0);
    }

    #[test]
    fn calibrate_synthetic_stack_covers_its_buckets() {
        let stack = ModelStack::synthetic();
        let m = calibrate(&stack, &CalibrationConfig::fast()).unwrap();
        assert_eq!(m.backend, "synthetic");
        assert_eq!(m.preset, "synthetic");
        assert_eq!(m.grid, vec![1, 2, 4]);
        assert_eq!(m.model_fingerprint, stack.manifest().model_fingerprint());
        for r in &m.rows {
            assert!(r.dual_ms > 0.0 && r.single_ms > 0.0, "{r:?}");
        }
        // the sealed manifest validates against the stack it measured
        stack.validate_cost_manifest(&m).unwrap();
        // a reject-policy table built from it covers every compiled bucket
        let t = m.table(FallbackPolicy::Reject).unwrap();
        t.validate_covers(&stack.model().batch_sizes).unwrap();
        for &b in &stack.model().batch_sizes {
            assert!(t.step_ms(b, StepMode::Dual) > 0.0);
        }
        assert_eq!(t.fallback_count(), 0);
    }

    #[test]
    fn grid_outside_compiled_buckets_rejected() {
        let stack = ModelStack::synthetic();
        let cfg = CalibrationConfig { grid: vec![1, 8], ..CalibrationConfig::default() };
        let err = calibrate(&stack, &cfg).unwrap_err();
        assert!(err.to_string().contains("no compiled executable"), "{err}");
        // even samples are a config error, not a skewed median
        let cfg = CalibrationConfig { samples: 4, ..CalibrationConfig::default() };
        assert!(calibrate(&stack, &cfg).is_err());
    }

    #[test]
    fn backend_mismatch_refused() {
        let stack = ModelStack::synthetic();
        let mut m = calibrate(&stack, &CalibrationConfig::fast()).unwrap();
        m.backend = "pjrt".into();
        let err = stack.validate_cost_manifest(&m).unwrap_err();
        assert!(err.to_string().contains("backend"), "{err}");
    }
}
