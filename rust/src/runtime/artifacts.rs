//! Artifact manifest model — the contract between `python/compile/aot.py`
//! and the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::json::{self, Value};

/// Element type of a tensor boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(Error::Artifact(format!("unsupported dtype {other:?}"))),
        }
    }
}

/// Shape+dtype of one executable input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<Self> {
        let name = v
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| Error::Artifact("tensor spec missing name".into()))?
            .to_string();
        let dtype = DType::parse(
            v.get("dtype")
                .and_then(Value::as_str)
                .ok_or_else(|| Error::Artifact(format!("tensor {name}: missing dtype")))?,
        )?;
        let shape = v
            .get("shape")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::Artifact(format!("tensor {name}: missing shape")))?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| Error::Artifact(format!("tensor {name}: bad dim")))
            })
            .collect::<Result<Vec<usize>>>()?;
        Ok(TensorSpec { name, dtype, shape })
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub name: String,
    pub hlo_file: String,
    pub params_file: Option<String>,
    pub param_count: usize,
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactMeta {
    fn from_json(name: &str, v: &Value) -> Result<Self> {
        let hlo_file = v
            .get("hlo")
            .and_then(Value::as_str)
            .ok_or_else(|| Error::Artifact(format!("{name}: missing hlo path")))?
            .to_string();
        let params_file = match v.get("params") {
            Some(Value::Str(s)) => Some(s.clone()),
            Some(Value::Null) | None => None,
            Some(other) => {
                return Err(Error::Artifact(format!("{name}: bad params field {other}")))
            }
        };
        let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.get(key)
                .and_then(Value::as_arr)
                .ok_or_else(|| Error::Artifact(format!("{name}: missing {key}")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(ArtifactMeta {
            name: name.to_string(),
            hlo_file,
            params_file,
            param_count: v.get("param_count").and_then(Value::as_usize).unwrap_or(0),
            batch: v.get("batch").and_then(Value::as_usize).unwrap_or(1),
            inputs: parse_specs("inputs")?,
            outputs: parse_specs("outputs")?,
        })
    }
}

/// Model-level metadata shared by all artifacts of a preset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelMeta {
    pub preset: String,
    pub latent_channels: usize,
    pub latent_size: usize,
    pub image_size: usize,
    pub seq_len: usize,
    pub text_dim: usize,
    pub vocab_size: usize,
    pub batch_sizes: Vec<usize>,
}

impl ModelMeta {
    /// Elements in one latent sample (C*H*W).
    pub fn latent_elems(&self) -> usize {
        self.latent_channels * self.latent_size * self.latent_size
    }

    /// Elements in one context tensor (S*D).
    pub fn ctx_elems(&self) -> usize {
        self.seq_len * self.text_dim
    }

    /// Elements in one decoded image (3*H*W).
    pub fn image_elems(&self) -> usize {
        3 * self.image_size * self.image_size
    }
}

/// The parsed manifest for one preset directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let v = json::from_file(&dir.join("manifest.json"))?;
        Self::from_json(dir, &v)
    }

    pub fn from_json(dir: &Path, v: &Value) -> Result<Manifest> {
        let version = v.get("version").and_then(Value::as_i64).unwrap_or(0);
        if version != 1 {
            return Err(Error::Artifact(format!(
                "manifest version {version} unsupported (want 1)"
            )));
        }
        let preset = v
            .get("preset")
            .and_then(Value::as_str)
            .ok_or_else(|| Error::Artifact("manifest missing preset".into()))?
            .to_string();
        let m = v
            .get("model")
            .ok_or_else(|| Error::Artifact("manifest missing model".into()))?;
        let req = |key: &str| -> Result<usize> {
            m.get(key)
                .and_then(Value::as_usize)
                .ok_or_else(|| Error::Artifact(format!("model missing {key}")))
        };
        let batch_sizes = m
            .get("batch_sizes")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::Artifact("model missing batch_sizes".into()))?
            .iter()
            .map(|b| b.as_usize().ok_or_else(|| Error::Artifact("bad batch size".into())))
            .collect::<Result<Vec<usize>>>()?;
        if batch_sizes.is_empty() || !batch_sizes.contains(&1) {
            return Err(Error::Artifact("batch_sizes must contain 1".into()));
        }
        let model = ModelMeta {
            preset,
            latent_channels: req("latent_channels")?,
            latent_size: req("latent_size")?,
            image_size: req("image_size")?,
            seq_len: req("seq_len")?,
            text_dim: req("text_dim")?,
            vocab_size: req("vocab_size")?,
            batch_sizes,
        };
        let arts_json = v
            .get("artifacts")
            .ok_or_else(|| Error::Artifact("manifest missing artifacts".into()))?;
        let mut artifacts = BTreeMap::new();
        if let Value::Obj(map) = arts_json {
            for (name, av) in map {
                artifacts.insert(name.clone(), ArtifactMeta::from_json(name, av)?);
            }
        } else {
            return Err(Error::Artifact("artifacts must be an object".into()));
        }
        // required set
        for b in &model.batch_sizes {
            for prefix in ["unet_b", "cfg_combine_b"] {
                let key = format!("{prefix}{b}");
                if !artifacts.contains_key(&key) {
                    return Err(Error::Artifact(format!("manifest missing artifact {key}")));
                }
            }
        }
        for key in ["text_encoder", "vae_decoder"] {
            if !artifacts.contains_key(key) {
                return Err(Error::Artifact(format!("manifest missing artifact {key}")));
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), model, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {name:?}")))
    }

    /// FNV-1a fingerprint of the model *shape* (16 hex digits) — what a
    /// calibrated [`crate::guidance::CostManifest`] binds to, so a
    /// replica refuses a cost table measured against a different model
    /// even when the preset name collides.
    pub fn model_fingerprint(&self) -> String {
        let m = &self.model;
        let canonical = format!(
            "{}|{}|{}|{}|{}|{}|{}|{:?}",
            m.preset,
            m.latent_channels,
            m.latent_size,
            m.image_size,
            m.seq_len,
            m.text_dim,
            m.vocab_size,
            m.batch_sizes
        );
        crate::guidance::cost_table_fingerprint(canonical.as_bytes())
    }

    /// Refuse a mismatched model/cost-table pair: the cost manifest must
    /// have been calibrated against *this* model shape.
    pub fn validate_cost_manifest(&self, cm: &crate::guidance::CostManifest) -> Result<()> {
        if cm.preset != self.model.preset {
            return Err(Error::Artifact(format!(
                "cost manifest was calibrated for preset {:?} but the loaded model is {:?}",
                cm.preset, self.model.preset
            )));
        }
        let want = self.model_fingerprint();
        if cm.model_fingerprint != want {
            return Err(Error::Artifact(format!(
                "cost manifest model fingerprint {} does not match the loaded model ({want}) \
                 — the model shape changed since calibration; run `sgd-serve calibrate` again",
                cm.model_fingerprint
            )));
        }
        if cm.resolution != self.model.latent_size {
            return Err(Error::Artifact(format!(
                "cost manifest resolution {} does not match the model latent size {}",
                cm.resolution, self.model.latent_size
            )));
        }
        Ok(())
    }

    /// Load a params blob (raw little-endian f32) for an artifact.
    pub fn load_params(&self, meta: &ArtifactMeta) -> Result<Option<Vec<f32>>> {
        let Some(file) = &meta.params_file else {
            return Ok(None);
        };
        let path = self.dir.join(file);
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::io(format!("reading {}", path.display()), e))?;
        if bytes.len() != 4 * meta.param_count {
            return Err(Error::Artifact(format!(
                "{}: params file has {} bytes, expected {}",
                meta.name,
                bytes.len(),
                4 * meta.param_count
            )));
        }
        let mut out = Vec::with_capacity(meta.param_count);
        for chunk in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(Some(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_manifest_json() -> String {
        // smallest manifest passing validation
        let art = |b: usize, kind: &str| {
            format!(
                r#""{kind}_b{b}": {{"hlo": "{kind}_b{b}.hlo.txt", "params": null,
                   "param_count": 0, "batch": {b},
                   "inputs": [{{"name": "x", "dtype": "f32", "shape": [{b}, 4]}}],
                   "outputs": [{{"name": "y", "dtype": "f32", "shape": [{b}, 4]}}]}}"#
            )
        };
        format!(
            r#"{{"version": 1, "preset": "t",
               "model": {{"latent_channels": 4, "latent_size": 8, "image_size": 32,
                          "seq_len": 8, "text_dim": 32, "vocab_size": 1024,
                          "batch_sizes": [1]}},
               "artifacts": {{
                 {u}, {c},
                 "text_encoder": {{"hlo": "te.hlo.txt", "params": "te.bin",
                   "param_count": 2, "batch": 1,
                   "inputs": [{{"name": "ids", "dtype": "i32", "shape": [1, 8]}}],
                   "outputs": [{{"name": "ctx", "dtype": "f32", "shape": [1, 8, 32]}}]}},
                 "vae_decoder": {{"hlo": "vae.hlo.txt", "params": null,
                   "param_count": 0, "batch": 1,
                   "inputs": [{{"name": "l", "dtype": "f32", "shape": [1, 4, 8, 8]}}],
                   "outputs": [{{"name": "img", "dtype": "f32", "shape": [1, 3, 32, 32]}}]}}
               }}}}"#,
            u = art(1, "unet"),
            c = art(1, "cfg_combine"),
        )
    }

    #[test]
    fn parse_minimal_manifest() {
        let v = crate::json::from_str(&minimal_manifest_json()).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/x"), &v).unwrap();
        assert_eq!(m.model.preset, "t");
        assert_eq!(m.model.latent_elems(), 4 * 8 * 8);
        assert_eq!(m.model.ctx_elems(), 8 * 32);
        assert_eq!(m.model.image_elems(), 3 * 32 * 32);
        let te = m.artifact("text_encoder").unwrap();
        assert_eq!(te.params_file.as_deref(), Some("te.bin"));
        assert_eq!(te.inputs[0].dtype, DType::I32);
        assert_eq!(te.outputs[0].elements(), 8 * 32);
    }

    #[test]
    fn missing_required_artifact_rejected() {
        let json = minimal_manifest_json().replace("\"vae_decoder\"", "\"vae_dec\"");
        let v = crate::json::from_str(&json).unwrap();
        let err = Manifest::from_json(Path::new("/tmp/x"), &v).unwrap_err();
        assert!(err.to_string().contains("vae_decoder"), "{err}");
    }

    #[test]
    fn wrong_version_rejected() {
        let json = minimal_manifest_json().replace("\"version\": 1", "\"version\": 9");
        let v = crate::json::from_str(&json).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp/x"), &v).is_err());
    }

    #[test]
    fn batch_sizes_must_include_one() {
        let json = minimal_manifest_json().replace("\"batch_sizes\": [1]", "\"batch_sizes\": [2]");
        let v = crate::json::from_str(&json).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp/x"), &v).is_err());
    }

    #[test]
    fn params_size_validated() {
        let dir = std::env::temp_dir().join("sg_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("te.bin"), [0u8; 8]).unwrap(); // 2 f32s
        let v = crate::json::from_str(&minimal_manifest_json()).unwrap();
        let m = Manifest::from_json(&dir, &v).unwrap();
        let te = m.artifact("text_encoder").unwrap().clone();
        let params = m.load_params(&te).unwrap().unwrap();
        assert_eq!(params, vec![0.0, 0.0]);
        // wrong size
        std::fs::write(dir.join("te.bin"), [0u8; 12]).unwrap();
        assert!(m.load_params(&te).is_err());
    }

    #[test]
    fn cost_manifest_must_match_the_loaded_model() {
        use crate::guidance::{CostManifest, CostRow};
        let v = crate::json::from_str(&minimal_manifest_json()).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/x"), &v).unwrap();
        let fp = m.model_fingerprint();
        assert_eq!(fp.len(), 16, "16 hex digits: {fp}");
        let rows = vec![CostRow { batch: 1, dual_ms: 1.0, single_ms: 0.5 }];
        let good =
            CostManifest::seal("0.2.0", "synthetic", "t", fp.clone(), 8, 3, 1, 0.5, rows.clone());
        m.validate_cost_manifest(&good).unwrap();
        let wrong_preset =
            CostManifest::seal("0.2.0", "synthetic", "u", fp.clone(), 8, 3, 1, 0.5, rows.clone());
        let err = m.validate_cost_manifest(&wrong_preset).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)) && err.to_string().contains("preset"), "{err}");
        let wrong_model = CostManifest::seal(
            "0.2.0",
            "synthetic",
            "t",
            "0000000000000000",
            8,
            3,
            1,
            0.5,
            rows.clone(),
        );
        let err = m.validate_cost_manifest(&wrong_model).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        let wrong_res = CostManifest::seal("0.2.0", "synthetic", "t", fp, 16, 3, 1, 0.5, rows);
        let err = m.validate_cost_manifest(&wrong_res).unwrap_err();
        assert!(err.to_string().contains("resolution"), "{err}");
    }

    #[test]
    fn fingerprint_tracks_the_model_shape() {
        let v = crate::json::from_str(&minimal_manifest_json()).unwrap();
        let a = Manifest::from_json(Path::new("/tmp/x"), &v).unwrap();
        let changed = minimal_manifest_json().replace("\"latent_size\": 8", "\"latent_size\": 16");
        let v = crate::json::from_str(&changed).unwrap();
        let b = Manifest::from_json(Path::new("/tmp/x"), &v).unwrap();
        assert_ne!(a.model_fingerprint(), b.model_fingerprint());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        let dir = Path::new("artifacts/tiny");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert_eq!(m.model.preset, "tiny");
            assert!(m.artifacts.len() >= 8);
        }
    }
}
