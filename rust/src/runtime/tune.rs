//! Runtime frontier tuning: sweep the schedule grammar on the loaded
//! [`ModelStack`] into a sealed [`FrontierManifest`] (DESIGN.md §16).
//!
//! The planner's offline half. Every candidate plan in the tuner grid is
//! *executed* on the loaded runtime and scored by SSIM against the
//! full-CFG render of the same (prompt, seed, steps) triple; its price
//! comes from a measured [`CostTable`] (DESIGN.md §15). The Pareto
//! pruning itself lives in `guidance::planner::tune_frontier` — this
//! module only supplies the engine-driven scorer, with the expensive
//! full-CFG baseline rendered once per steps bucket and cached.
//!
//! CI tunes the synthetic stack (`tune --fast`); a machine with the
//! PJRT artifacts tunes the real thing against its calibrated table.

use std::collections::HashMap;
use std::sync::Arc;

use super::ModelStack;
use crate::config::EngineConfig;
use crate::engine::{Engine, GenerationRequest};
use crate::error::Result;
use crate::guidance::{
    tune_frontier, CostTable, FrontierManifest, GuidanceSchedule, GuidanceStrategy,
    TuneProvenance, TunerConfig,
};
use crate::image::RgbImage;
use crate::prompts;
use crate::quality::ssim;
use crate::scheduler::SchedulerKind;

/// The fixed (prompt, seed) probe every candidate is scored on. One
/// probe keeps the sweep affordable and — because both the candidate and
/// its full-CFG baseline share it — the *relative* SSIM ordering is what
/// the frontier ranks, not the absolute number.
const TUNE_SEED: u64 = 42;

/// Sweep the tuner grid on the loaded runtime and seal the frontier.
///
/// `table` prices the candidates (use a calibrated table on real
/// hardware, [`CostTable::proportional`] for deterministic CI); the
/// provenance binds the manifest to this stack so a mismatched runtime
/// refuses to load it.
pub fn tune(stack: Arc<ModelStack>, cfg: &TunerConfig, table: &CostTable) -> Result<FrontierManifest> {
    let model = stack.model();
    let prov = TuneProvenance {
        tool_version: env!("CARGO_PKG_VERSION").to_string(),
        backend: stack.backend_name().to_string(),
        preset: model.preset.clone(),
        model_fingerprint: stack.manifest().model_fingerprint(),
        resolution: model.latent_size,
    };
    let scale = cfg.guidance_scale;
    let engine = Engine::new(stack, EngineConfig::default());
    let request = |sched: GuidanceSchedule, strat: GuidanceStrategy, steps: usize| {
        GenerationRequest::new(prompts::FIG2_PROMPT)
            .steps(steps)
            .scheduler(SchedulerKind::Ddim)
            .guidance_scale(scale)
            .seed(TUNE_SEED)
            .with_schedule(sched)
            .strategy(strat)
            .decode(true)
    };
    // full-CFG baseline per steps bucket, rendered once
    let mut baselines: HashMap<usize, RgbImage> = HashMap::new();
    tune_frontier(cfg, table, &prov, |sched, strat, steps| {
        if !baselines.contains_key(&steps) {
            let base = engine.generate(&request(
                GuidanceSchedule::none(),
                GuidanceStrategy::CondOnly,
                steps,
            ))?;
            baselines.insert(steps, base.image.expect("decode requested"));
        }
        let out = engine.generate(&request(sched.clone(), strat, steps))?;
        Ok(ssim(&baselines[&steps], out.image.as_ref().expect("decode requested")))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tunes_the_synthetic_stack_into_a_sealed_frontier() {
        let stack = Arc::new(ModelStack::synthetic());
        let table = CostTable::proportional(1.0, &stack.model().batch_sizes);
        let m = tune(Arc::clone(&stack), &TunerConfig::fast(), &table).unwrap();
        assert_eq!(m.backend, "synthetic");
        assert_eq!(m.preset, "synthetic");
        assert_eq!(m.model_fingerprint, stack.manifest().model_fingerprint());
        assert_eq!(m.resolution, stack.model().latent_size);
        // the sealed manifest round-trips and re-validates
        let back = FrontierManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back.checksum, m.checksum);
        for b in &m.buckets {
            b.validate().unwrap();
            // every bucket keeps its full-CFG anchor (saving 0, ssim 1)
            let top = b.points.last().unwrap();
            assert_eq!(top.ssim, 1.0);
            assert!((top.cost_ms - b.full_cost_ms).abs() < 1e-9);
            // measured SSIM ranks below the anchor for real shed
            for p in &b.points[..b.points.len() - 1] {
                assert!(p.ssim < 1.0 && p.cost_ms < b.full_cost_ms, "{p:?}");
            }
        }
    }

    #[test]
    fn tuning_is_deterministic() {
        let stack = Arc::new(ModelStack::synthetic());
        let table = CostTable::proportional(1.0, &stack.model().batch_sizes);
        let cfg = TunerConfig::fast();
        let a = tune(Arc::clone(&stack), &cfg, &table).unwrap();
        let b = tune(stack, &cfg, &table).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
}
