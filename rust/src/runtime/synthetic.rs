//! Deterministic synthetic model backend — the artifact-free execution
//! path behind [`crate::runtime::ModelStack::synthetic`].
//!
//! The in-crate `xla` stub (DESIGN.md §2) makes the crate *build* without
//! the native PJRT toolchain, but it errors on first use, which leaves
//! the engine itself untestable in CI. This module closes that gap with
//! a pure-Rust stand-in for the four compiled artifacts: smooth, bounded,
//! fully deterministic functions with the same tensor contracts.
//!
//! Design constraints (what the tests and benches rely on):
//!
//! * **Determinism** — no RNG, no time, no global state; same inputs →
//!   bit-identical outputs on every platform (plain `f32` arithmetic in a
//!   fixed order).
//! * **Batch equivariance** — each sample of a batch is computed
//!   independently with an identical operation order, so
//!   `generate(r)` equals `generate_batch([r, ..])` *bit-for-bit*
//!   regardless of how the batcher buckets the UNet calls.
//! * **Guidance structure** — the synthetic eps depends on the latent,
//!   the timestep, and two bounded context features, so conditional and
//!   unconditional passes genuinely differ (guidance does something) and
//!   eps varies smoothly along a trajectory (caching/extrapolating the
//!   uncond eps is a *better* approximation than dropping it — the
//!   property `benches/fig5_reuse_strategies.rs` quantifies).

use crate::runtime::ModelMeta;

/// The synthetic stand-in for one preset's compiled artifacts.
#[derive(Debug, Clone)]
pub struct SyntheticModel {
    model: ModelMeta,
}

impl SyntheticModel {
    pub fn new(model: ModelMeta) -> SyntheticModel {
        SyntheticModel { model }
    }

    pub fn model(&self) -> &ModelMeta {
        &self.model
    }

    /// A bounded phase fingerprint of one context tensor, resonant with
    /// the synthetic encoder's carrier frequency so different prompts
    /// (and the uncond context) land on well-separated values rather
    /// than averaging out.
    fn ctx_feature(ctx: &[f32]) -> f32 {
        let mut a = 0.0f32;
        let mut b = 0.0f32;
        for (k, &v) in ctx.iter().enumerate() {
            let k = k as f32;
            a += v * (0.37 * k).sin();
            b += v * (0.37 * k).cos();
        }
        let n = ctx.len().max(1) as f32;
        (3.0 * (a + b) / n).tanh()
    }

    /// Synthetic UNet: eps prediction per element, bounded by `tanh`.
    ///
    /// The coefficient split is deliberate (and validated numerically
    /// against an offline replica of the whole pipeline): the **context**
    /// terms carry most of the signal (one direct per-element injection
    /// plus a phase term), so conditional vs unconditional eps differ
    /// strongly, while the **latent/timestep** dependence is gentle and
    /// smooth — the uncond eps drifts slowly along a trajectory, which is
    /// exactly the regime where caching it (Reuse) approximates full CFG
    /// far better than dropping it (CondOnly). `fig5_reuse_strategies`
    /// asserts that ordering end-to-end; raising the latent coefficient
    /// much above ~0.1 makes the hold cache go stale faster than the
    /// guidance signal and breaks it.
    pub fn unet_eps(&self, b: usize, latents: &[f32], ts: &[f32], ctx: &[f32]) -> Vec<f32> {
        let elems = self.model.latent_elems();
        let ctx_elems = self.model.ctx_elems();
        let mut out = Vec::with_capacity(b * elems);
        for s in 0..b {
            let c = &ctx[s * ctx_elems..(s + 1) * ctx_elems];
            let ca = Self::ctx_feature(c);
            let tn = ts[s] / 1000.0;
            let base = s * elems;
            for j in 0..elems {
                let x = latents[base + j];
                let ph = j as f32;
                let v = 0.05 * x
                    + 0.5 * c[j % ctx_elems]
                    + 0.3 * (0.173 * ph + 4.0 * ca + 0.3 * tn).sin()
                    + 0.03 * tn;
                out.push(v.tanh());
            }
        }
        out
    }

    /// Eq.-1 combine: `eps_hat = eps_u + s (eps_c - eps_u)` — the same
    /// math as the Pallas kernel artifact, in host f32.
    pub fn cfg_combine(&self, b: usize, eps_u: &[f32], eps_c: &[f32], scale: f32) -> Vec<f32> {
        let elems = b * self.model.latent_elems();
        let mut out = Vec::with_capacity(elems);
        for j in 0..elems {
            out.push(eps_u[j] + scale * (eps_c[j] - eps_u[j]));
        }
        out
    }

    /// Synthetic text encoder: a deterministic hash of the token ids
    /// seeds two phases; the context is a smooth wave over [S, D]. The
    /// wave's *amplitude* encodes how many real (non-special) tokens the
    /// prompt has, so the unconditional (empty) context always differs in
    /// magnitude from any real prompt — the guidance signal can't vanish
    /// by hash-phase coincidence.
    pub fn encode_text(&self, ids: &[i32]) -> Vec<f32> {
        let mut h = 0x9E37_79B9_7F4A_7C15u64;
        for &id in ids {
            h = h.wrapping_mul(0x0000_0100_0000_01B3).wrapping_add(id as u32 as u64);
        }
        let tau = std::f32::consts::TAU;
        let pa = (h & 0xFFFF) as f32 / 65536.0 * tau;
        let pb = ((h >> 16) & 0xFFFF) as f32 / 65536.0 * tau;
        let words = ids.iter().filter(|&&id| id >= 3).count().min(4);
        let amp = 0.5 + 0.5 * words as f32 / 4.0;
        let n = self.model.ctx_elems();
        let mut out = Vec::with_capacity(n);
        for k in 0..n {
            let k = k as f32;
            out.push(amp * (0.8 * (pa + 0.37 * k).sin() + 0.2 * (pb + 0.11 * k).cos()));
        }
        out
    }

    /// Synthetic VAE decoder: nearest-neighbour upsample of the latent
    /// with a fixed channel mix, bounded into [-1, 1].
    pub fn decode(&self, latent: &[f32]) -> Vec<f32> {
        let m = &self.model;
        let (lc, ls, is) = (m.latent_channels, m.latent_size, m.image_size);
        let mut out = Vec::with_capacity(3 * is * is);
        for c in 0..3 {
            for y in 0..is {
                let ly = y * ls / is;
                for x in 0..is {
                    let lx = x * ls / is;
                    let v0 = latent[(c % lc) * ls * ls + ly * ls + lx];
                    let v1 = latent[((c + 1) % lc) * ls * ls + ly * ls + lx];
                    out.push((0.8 * v0 + 0.3 * v1).tanh());
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelStack;

    fn model() -> SyntheticModel {
        SyntheticModel::new(ModelStack::synthetic().model().clone())
    }

    #[test]
    fn unet_deterministic_and_finite() {
        let m = model();
        let elems = m.model().latent_elems();
        let ctx_elems = m.model().ctx_elems();
        let latents: Vec<f32> = (0..elems).map(|j| ((j as f32) * 0.17).sin()).collect();
        let ctx: Vec<f32> = (0..ctx_elems).map(|j| ((j as f32) * 0.07).cos()).collect();
        let a = m.unet_eps(1, &latents, &[500.0], &ctx);
        let b = m.unet_eps(1, &latents, &[500.0], &ctx);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
    }

    #[test]
    fn unet_batch_equivariant_bitwise() {
        // sample 0 of a batch-2 call must equal its batch-1 result exactly
        let m = model();
        let elems = m.model().latent_elems();
        let ctx_elems = m.model().ctx_elems();
        let l0: Vec<f32> = (0..elems).map(|j| ((j as f32) * 0.19).sin()).collect();
        let l1: Vec<f32> = (0..elems).map(|j| ((j as f32) * 0.31).cos()).collect();
        let c0: Vec<f32> = (0..ctx_elems).map(|j| ((j as f32) * 0.05).sin()).collect();
        let c1: Vec<f32> = (0..ctx_elems).map(|j| ((j as f32) * 0.13).cos()).collect();
        let solo0 = m.unet_eps(1, &l0, &[40.0], &c0);
        let solo1 = m.unet_eps(1, &l1, &[40.0], &c1);
        let both = m.unet_eps(
            2,
            &[l0.clone(), l1.clone()].concat(),
            &[40.0, 40.0],
            &[c0, c1].concat(),
        );
        assert_eq!(&both[..elems], &solo0[..]);
        assert_eq!(&both[elems..], &solo1[..]);
    }

    #[test]
    fn contexts_differ_by_prompt() {
        let m = model();
        let a = m.encode_text(&[1, 2, 3, 4, 0, 0, 0, 0]);
        let b = m.encode_text(&[9, 8, 7, 6, 0, 0, 0, 0]);
        assert_ne!(a, b);
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn combine_matches_eq1() {
        let m = model();
        let u = vec![0.0f32; 4];
        let c = vec![1.0f32; 4];
        // batch-size 1 slice of 4 elems is fine: combine is elementwise
        let out = m.cfg_combine(0, &u, &c, 7.5);
        assert!(out.is_empty());
        let elems = m.model().latent_elems();
        let u = vec![0.5f32; elems];
        let c = vec![1.5f32; elems];
        let out = m.cfg_combine(1, &u, &c, 2.0);
        assert!(out.iter().all(|&v| (v - 2.5).abs() < 1e-6));
    }

    #[test]
    fn decode_shape_and_range() {
        let m = model();
        let elems = m.model().latent_elems();
        let latent: Vec<f32> = (0..elems).map(|j| ((j as f32) * 0.4).sin()).collect();
        let img = m.decode(&latent);
        assert_eq!(img.len(), 3 * m.model().image_size * m.model().image_size);
        assert!(img.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
    }
}
