//! PJRT runtime: load the AOT artifacts and execute them on the hot path.
//!
//! The interchange contract (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): HLO **text** parsed via
//! `HloModuleProto::from_text_file`, compiled once per process with the
//! CPU PJRT client, executed via device buffers. Weights are loaded from
//! the `.params.bin` blobs and kept **resident on device** so the steady
//! state moves only latents/contexts across the host boundary.
//!
//! A second backend, [`ModelStack::synthetic`], swaps the PJRT artifacts
//! for a deterministic pure-Rust model ([`SyntheticModel`]) with the same
//! tensor contracts — the execution path engine tests and quality benches
//! use when the artifacts (and the native toolchain) are absent.

mod artifacts;
mod calibrate;
mod tune;
mod synthetic;

pub use artifacts::{ArtifactMeta, DType, Manifest, ModelMeta, TensorSpec};
pub use calibrate::{calibrate, CalibrationConfig};
pub use tune::tune;
pub use synthetic::SyntheticModel;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{Error, Result};
// PJRT surface: the in-crate stub by default; swap for the native
// bindings by changing this one import (DESIGN.md §2).
use crate::xla;

/// A compiled computation plus its resident parameter buffer.
struct LoadedArtifact {
    meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    /// Device-resident flat parameter vector (None for param-free kernels
    /// like the CFG combine).
    params: Option<xla::PjRtBuffer>,
}

impl LoadedArtifact {
    /// Execute with host f32 inputs (params prepended automatically).
    /// Returns the flattened f32 output.
    fn run_f32(&self, client: &xla::PjRtClient, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let expected = self.meta.inputs.len() - usize::from(self.params.is_some());
        debug_assert_eq!(
            inputs.len(),
            expected,
            "{}: wrong input count",
            self.meta.name
        );
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len() + 1);
        for (data, dims) in inputs {
            bufs.push(client.buffer_from_host_buffer(data, dims, None)?);
        }
        self.execute_buffers(&bufs)
    }

    fn execute_buffers(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<f32>> {
        // assemble: params first (runtime contract), then the inputs
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len() + 1);
        if let Some(p) = &self.params {
            args.push(p);
        }
        args.extend(inputs.iter());
        let out = self.exe.execute_b(&args)?;
        let literal = out[0][0].to_literal_sync()?;
        let inner = literal.to_tuple1()?; // lowered with return_tuple=True
        Ok(inner.to_vec::<f32>()?)
    }

    /// Execute with one i32 input (text encoder).
    fn run_i32(&self, client: &xla::PjRtClient, data: &[i32], dims: &[usize]) -> Result<Vec<f32>> {
        let buf = client.buffer_from_host_buffer(data, dims, None)?;
        self.execute_buffers(&[buf])
    }
}

/// How a [`ModelStack`] executes its four computations.
enum Backend {
    /// AOT artifacts compiled onto the PJRT client (production path).
    Pjrt {
        client: xla::PjRtClient,
        /// UNet executables keyed by batch size.
        unet: BTreeMap<usize, LoadedArtifact>,
        /// CFG-combine executables keyed by batch size.
        combine: BTreeMap<usize, LoadedArtifact>,
        text_encoder: LoadedArtifact,
        vae_decoder: LoadedArtifact,
    },
    /// Deterministic pure-Rust stand-in (tests/benches, no toolchain).
    Synthetic(SyntheticModel),
}

/// The full set of compiled executables for one model preset, ready to
/// serve. Cheap to share behind `Arc` across worker threads.
pub struct ModelStack {
    manifest: Manifest,
    backend: Backend,
    /// Cache of the unconditional context (encode once, reuse forever).
    uncond_ctx: Mutex<Option<Vec<f32>>>,
}

// SAFETY: the PJRT CPU client is thread-safe for compilation and
// execution (XLA's CPU PjRtClient serializes internally where needed);
// our artifacts and resident buffers are immutable after load. The `xla`
// crate only wraps raw pointers without declaring Send/Sync, so we assert
// it here — every mutation after `load()` goes through `Mutex`es.
unsafe impl Send for ModelStack {}
unsafe impl Sync for ModelStack {}

impl ModelStack {
    /// Load every artifact of a preset directory and compile it on the
    /// CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> Result<ModelStack> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;

        let load_one = |name: &str| -> Result<LoadedArtifact> {
            let meta = manifest.artifact(name)?.clone();
            let hlo_path = dir.join(&meta.hlo_file);
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path
                    .to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            let params = match manifest.load_params(&meta)? {
                Some(p) => Some(client.buffer_from_host_buffer(&p, &[p.len()], None)?),
                None => None,
            };
            Ok(LoadedArtifact { meta, exe, params })
        };

        let mut unet = BTreeMap::new();
        let mut combine = BTreeMap::new();
        for &b in &manifest.model.batch_sizes {
            unet.insert(b, load_one(&format!("unet_b{b}"))?);
            combine.insert(b, load_one(&format!("cfg_combine_b{b}"))?);
        }
        let text_encoder = load_one("text_encoder")?;
        let vae_decoder = load_one("vae_decoder")?;

        Ok(ModelStack {
            manifest,
            backend: Backend::Pjrt { client, unet, combine, text_encoder, vae_decoder },
            uncond_ctx: Mutex::new(None),
        })
    }

    /// A fully deterministic artifact-free stack (see [`SyntheticModel`]):
    /// the execution path tests and benches use when the PJRT artifacts
    /// aren't built. Tiny tensor sizes keep end-to-end runs cheap.
    pub fn synthetic() -> ModelStack {
        let model = ModelMeta {
            preset: "synthetic".into(),
            latent_channels: 4,
            latent_size: 8,
            image_size: 32,
            seq_len: 8,
            text_dim: 32,
            vocab_size: 1024,
            batch_sizes: vec![1, 2, 4],
        };
        ModelStack {
            manifest: Manifest {
                dir: PathBuf::from("<synthetic>"),
                model: model.clone(),
                artifacts: BTreeMap::new(),
            },
            backend: Backend::Synthetic(SyntheticModel::new(model)),
            uncond_ctx: Mutex::new(None),
        }
    }

    pub fn model(&self) -> &ModelMeta {
        &self.manifest.model
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Which execution backend serves this stack (a calibrated cost
    /// table binds to it: synthetic milliseconds say nothing about PJRT).
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Pjrt { .. } => "pjrt",
            Backend::Synthetic(_) => "synthetic",
        }
    }

    /// Refuse a mismatched model/cost-table pair: backend first (the
    /// cheap check with the clearest message), then the model binding
    /// (preset, shape fingerprint, resolution — see
    /// [`Manifest::validate_cost_manifest`]).
    pub fn validate_cost_manifest(&self, cm: &crate::guidance::CostManifest) -> Result<()> {
        if cm.backend != self.backend_name() {
            return Err(Error::Artifact(format!(
                "cost manifest was calibrated on the {:?} backend but this replica runs {:?} \
                 — run `sgd-serve calibrate` against this runtime",
                cm.backend,
                self.backend_name()
            )));
        }
        self.manifest.validate_cost_manifest(cm)
    }

    /// Refuse a mismatched model/frontier pair (DESIGN.md §16): a sealed
    /// plan frontier binds to the runtime its sweep measured the same
    /// way a cost manifest does — backend, preset, shape fingerprint and
    /// resolution must all match, else the SSIM/cost trade-offs it
    /// promises say nothing about this deployment.
    pub fn validate_frontier_manifest(
        &self,
        fm: &crate::guidance::FrontierManifest,
    ) -> Result<()> {
        if fm.backend != self.backend_name() {
            return Err(Error::Artifact(format!(
                "frontier manifest was tuned on the {:?} backend but this replica runs {:?} \
                 — run `sgd-serve tune` against this runtime",
                fm.backend,
                self.backend_name()
            )));
        }
        if fm.preset != self.manifest.model.preset {
            return Err(Error::Artifact(format!(
                "frontier manifest was tuned for preset {:?} but the loaded model is {:?}",
                fm.preset, self.manifest.model.preset
            )));
        }
        let want = self.manifest.model_fingerprint();
        if fm.model_fingerprint != want {
            return Err(Error::Artifact(format!(
                "frontier manifest model fingerprint {} does not match the loaded model \
                 ({want}) — the model shape changed since tuning; run `sgd-serve tune` again",
                fm.model_fingerprint
            )));
        }
        if fm.resolution != self.manifest.model.latent_size {
            return Err(Error::Artifact(format!(
                "frontier manifest resolution {} does not match the model latent size {}",
                fm.resolution, self.manifest.model.latent_size
            )));
        }
        Ok(())
    }

    /// Batch sizes with compiled UNet executables, descending.
    pub fn batch_sizes_desc(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.manifest.model.batch_sizes.clone();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }

    /// Decompose a logical batch of `n` samples into available compiled
    /// bucket sizes (greedy, largest first). Always succeeds because
    /// batch size 1 is mandatory.
    pub fn bucketize(&self, n: usize) -> Vec<usize> {
        let sizes = self.batch_sizes_desc();
        let mut rem = n;
        let mut out = Vec::new();
        while rem > 0 {
            let b = sizes.iter().copied().find(|&b| b <= rem).unwrap_or(1);
            out.push(b);
            rem -= b;
        }
        out
    }

    /// Encode a prompt's token ids (shape [1, seq_len]) to a context
    /// tensor (flattened [1, S, D]).
    pub fn encode_text(&self, ids: &[i32]) -> Result<Vec<f32>> {
        let s = self.manifest.model.seq_len;
        if ids.len() != s {
            return Err(Error::Request(format!(
                "token ids length {} != seq_len {}",
                ids.len(),
                s
            )));
        }
        match &self.backend {
            Backend::Pjrt { client, text_encoder, .. } => {
                text_encoder.run_i32(client, ids, &[1, s])
            }
            Backend::Synthetic(m) => Ok(m.encode_text(ids)),
        }
    }

    /// The cached unconditional context (empty prompt).
    pub fn uncond_ctx(&self) -> Result<Vec<f32>> {
        let mut guard = self.uncond_ctx.lock().unwrap();
        if let Some(ctx) = guard.as_ref() {
            return Ok(ctx.clone());
        }
        let tok = crate::tokenizer::Tokenizer::new(
            self.manifest.model.vocab_size,
            self.manifest.model.seq_len,
        );
        let ctx = self.encode_text(&tok.encode_uncond())?;
        *guard = Some(ctx.clone());
        Ok(ctx)
    }

    /// One UNet evaluation over a *compiled* batch size `b`.
    ///
    /// `latents`: b*C*H*W, `ts`: b, `ctx`: b*S*D; returns eps (b*C*H*W).
    pub fn unet_eps(&self, b: usize, latents: &[f32], ts: &[f32], ctx: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        if !m.batch_sizes.contains(&b) {
            return Err(Error::Request(format!("no unet compiled for batch {b}")));
        }
        debug_assert_eq!(latents.len(), b * m.latent_elems());
        debug_assert_eq!(ts.len(), b);
        debug_assert_eq!(ctx.len(), b * m.ctx_elems());
        match &self.backend {
            Backend::Pjrt { client, unet, .. } => {
                let art = unet
                    .get(&b)
                    .ok_or_else(|| Error::Request(format!("no unet compiled for batch {b}")))?;
                art.run_f32(
                    client,
                    &[
                        (latents, &[b, m.latent_channels, m.latent_size, m.latent_size]),
                        (ts, &[b]),
                        (ctx, &[b, m.seq_len, m.text_dim]),
                    ],
                )
            }
            Backend::Synthetic(model) => Ok(model.unet_eps(b, latents, ts, ctx)),
        }
    }

    /// Eq.-1 combine on device (the Pallas kernel artifact):
    /// `eps_hat = eps_u + s (eps_c - eps_u)` over a compiled batch `b`.
    pub fn cfg_combine(
        &self,
        b: usize,
        eps_u: &[f32],
        eps_c: &[f32],
        scale: f32,
    ) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        match &self.backend {
            Backend::Pjrt { client, combine, .. } => {
                let art = combine.get(&b).ok_or_else(|| {
                    Error::Request(format!("no cfg_combine compiled for batch {b}"))
                })?;
                let dims = [b, m.latent_channels, m.latent_size, m.latent_size];
                art.run_f32(client, &[(eps_u, &dims), (eps_c, &dims), (&[scale], &[1])])
            }
            Backend::Synthetic(model) => {
                if !m.batch_sizes.contains(&b) {
                    return Err(Error::Request(format!("no cfg_combine compiled for batch {b}")));
                }
                Ok(model.cfg_combine(b, eps_u, eps_c, scale))
            }
        }
    }

    /// Decode one latent to a flattened [3, image, image] tensor in [-1, 1].
    pub fn decode(&self, latent: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        debug_assert_eq!(latent.len(), m.latent_elems());
        match &self.backend {
            Backend::Pjrt { client, vae_decoder, .. } => vae_decoder.run_f32(
                client,
                &[(latent, &[1, m.latent_channels, m.latent_size, m.latent_size])],
            ),
            Backend::Synthetic(model) => Ok(model.decode(latent)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ModelStack execution tests live in rust/tests/ (integration) since
    // they need built artifacts; here we cover the pure helpers.

    #[test]
    fn bucketize_logic() {
        // fake a stack-free check by replicating the greedy logic
        let sizes = [4usize, 2, 1];
        let bucketize = |n: usize| {
            let mut rem = n;
            let mut out = Vec::new();
            while rem > 0 {
                let b = sizes.iter().copied().find(|&b| b <= rem).unwrap_or(1);
                out.push(b);
                rem -= b;
            }
            out
        };
        assert_eq!(bucketize(1), vec![1]);
        assert_eq!(bucketize(3), vec![2, 1]);
        assert_eq!(bucketize(7), vec![4, 2, 1]);
        assert_eq!(bucketize(8), vec![4, 4]);
        assert_eq!(bucketize(5), vec![4, 1]);
    }

    #[test]
    fn synthetic_stack_serves_all_computations() {
        let stack = ModelStack::synthetic();
        let m = stack.model().clone();
        assert_eq!(stack.batch_sizes_desc(), vec![4, 2, 1]);
        assert_eq!(stack.bucketize(7), vec![4, 2, 1]);
        let ids: Vec<i32> = (0..m.seq_len as i32).collect();
        let ctx = stack.encode_text(&ids).unwrap();
        assert_eq!(ctx.len(), m.ctx_elems());
        let uncond = stack.uncond_ctx().unwrap();
        assert_eq!(uncond.len(), m.ctx_elems());
        assert_ne!(ctx, uncond, "cond and uncond contexts must differ");
        let latents = vec![0.1f32; m.latent_elems()];
        let eps = stack.unet_eps(1, &latents, &[980.0], &ctx).unwrap();
        assert_eq!(eps.len(), m.latent_elems());
        let eps_u = stack.unet_eps(1, &latents, &[980.0], &uncond).unwrap();
        assert_ne!(eps, eps_u, "guidance must have signal to work with");
        let combined = stack.cfg_combine(1, &eps_u, &eps, 7.5).unwrap();
        assert_eq!(combined.len(), m.latent_elems());
        let img = stack.decode(&latents).unwrap();
        assert_eq!(img.len(), m.image_elems());
        // unsupported batch sizes error instead of panicking
        let bad_latents = vec![0.0; 3 * m.latent_elems()];
        let bad_ctx = vec![0.0; 3 * m.ctx_elems()];
        assert!(stack.unet_eps(3, &bad_latents, &[1.0; 3], &bad_ctx).is_err());
    }
}
