//! Deterministic discrete-event model of the serving loop, driving the
//! *real* QoS policy objects.
//!
//! The PJRT artifacts (and therefore the real engine) are a build
//! product that is absent in CI and on dev laptops; the control law
//! still needs an end-to-end evaluation path. This simulator replays an
//! arrival trace through [`DeadlineQos`] — the same admission,
//! actuation and feedback code the coordinator runs — against the §3.3
//! analytic service model (`service = base · (1 − u·f/2)`), in virtual
//! time. Everything is pure math: runs are exactly reproducible and take
//! microseconds per thousand requests, which is what lets
//! `benches/qos_control.rs` sweep arrival rates densely.
//!
//! Fidelity notes: service times are deterministic (no engine jitter)
//! and batching superlinearity is ignored, consistent with the
//! estimator's conservative model (see `feedback.rs`). The engine-in-
//! the-loop path is covered by `tests/integration_qos.rs` and the
//! `slo_serving` bench when artifacts are built.

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, VecDeque};
use std::time::Duration;

use crate::engine::GenerationRequest;
use crate::guidance::{GuidanceSchedule, GuidanceStrategy};

use super::{service_ms_at, AdmissionDecision, DeadlineQos, QosMeta, QosPolicy};

/// Virtual serving-loop parameters.
#[derive(Debug, Clone, Copy)]
pub struct SimSpec {
    /// Full-CFG (dual-pass) service time of one request, virtual ms.
    pub base_service_ms: f64,
    /// UNet share of service time (the §3.3 cost model).
    pub unet_share: f64,
    /// Per-request deadline == the SLO both modes are scored against.
    pub deadline_ms: f64,
    /// Parallel servers.
    pub workers: usize,
    /// Steps carried by the simulated requests (shaping metadata only).
    pub steps: usize,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            base_service_ms: 100.0,
            unet_share: 0.95,
            deadline_ms: 300.0,
            workers: 1,
            steps: 50,
        }
    }
}

/// Outcome of one simulated replay.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimReport {
    pub offered: usize,
    pub completed: usize,
    /// Shed at admission (queue full / deadline infeasible).
    pub rejected: usize,
    /// Expired in the queue before service started (policy mode only).
    pub expired: usize,
    /// Completed within the SLO.
    pub slo_met: usize,
    /// Mean applied window fraction over admitted requests.
    pub mean_fraction: f64,
    pub p50_latency_ms: f64,
    pub p90_latency_ms: f64,
}

impl SimReport {
    /// Fraction of *offered* requests that finished within the SLO —
    /// shed and expired requests count against attainment, so admission
    /// control cannot game the metric by rejecting everything.
    pub fn slo_attainment(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.slo_met as f64 / self.offered as f64
    }
}

/// The post-admission guidance plan one simulated request actually ran,
/// plus its SLO outcome — what [`simulate_trace`] hands quality benches
/// so they can replay exactly these (schedule, strategy) pairs through
/// the real engine and score SSIM against full CFG (DESIGN.md §16).
#[derive(Debug, Clone, PartialEq)]
pub struct AppliedPlan {
    pub schedule: GuidanceSchedule,
    pub strategy: GuidanceStrategy,
    pub steps: usize,
    /// Completed within the SLO (expired / too-late requests are false).
    pub slo_met: bool,
}

#[derive(Debug, Clone, Copy)]
struct Queued {
    arrive_ms: f64,
    service_ms: f64,
    /// Window fraction this request runs at (feedback normalization).
    fraction: f64,
    /// Expiry budget from arrival (None = no deadline enforcement).
    deadline_ms: Option<f64>,
    /// This request's entry in the applied-plan trace.
    plan_idx: usize,
}

/// Completion event ordered by finish time (min-heap via `Reverse`).
#[derive(Debug, Clone, Copy, PartialEq)]
struct Finish {
    at_ms: f64,
    service_ms: f64,
    fraction: f64,
}

impl Eq for Finish {}

impl PartialOrd for Finish {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for Finish {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // finite virtual times only; ties broken by service for determinism
        self.at_ms
            .partial_cmp(&other.at_ms)
            .unwrap_or(CmpOrdering::Equal)
            .then(
                self.service_ms
                    .partial_cmp(&other.service_ms)
                    .unwrap_or(CmpOrdering::Equal),
            )
    }
}

struct SimState<'a> {
    spec: SimSpec,
    policy: Option<&'a DeadlineQos>,
    workers: Vec<f64>,
    queue: VecDeque<Queued>,
    finishes: BinaryHeap<std::cmp::Reverse<Finish>>,
    outstanding: usize,
    latencies: Vec<f64>,
    completed: usize,
    expired: usize,
    slo_met: usize,
    plans: Vec<AppliedPlan>,
}

impl SimState<'_> {
    /// Advance virtual time to `until`: retire finished services and
    /// start queued work as servers free up.
    fn drain(&mut self, until: f64) {
        loop {
            // retire everything that finished by `until`
            while let Some(&std::cmp::Reverse(ev)) = self.finishes.peek() {
                if ev.at_ms > until {
                    break;
                }
                self.finishes.pop();
                self.outstanding -= 1;
                if let Some(p) = self.policy {
                    // the feedback loop sees per-request timings exactly
                    // as the coordinator workers would report them
                    p.observe_batch(1, Duration::from_secs_f64(ev.service_ms / 1e3), ev.fraction);
                }
            }
            let Some(&head) = self.queue.front() else { break };
            let (wi, free) = self
                .workers
                .iter()
                .copied()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(CmpOrdering::Equal))
                .expect("workers >= 1");
            let start = free.max(head.arrive_ms);
            if start > until {
                break;
            }
            self.queue.pop_front();
            // deadline-expire: don't pay for UNet work that is already
            // too late (mirrors the coordinator worker check)
            if let Some(p) = self.policy {
                if let Some(d) = head.deadline_ms {
                    if start > head.arrive_ms + d {
                        self.expired += 1;
                        self.outstanding -= 1;
                        p.observe_deadline_miss();
                        continue;
                    }
                }
            }
            let finish = start + head.service_ms;
            self.workers[wi] = finish;
            self.finishes.push(std::cmp::Reverse(Finish {
                at_ms: finish,
                service_ms: head.service_ms,
                fraction: head.fraction,
            }));
            let latency = finish - head.arrive_ms;
            self.latencies.push(latency);
            self.completed += 1;
            if latency <= self.spec.deadline_ms {
                self.slo_met += 1;
                self.plans[head.plan_idx].slo_met = true;
            }
        }
    }
}

/// Replay `arrivals_ms` (sorted offsets, virtual ms) through the serving
/// model. `policy = None` is the pre-QoS baseline: unbounded FIFO, full
/// dual-pass CFG for everyone. `policy = Some(..)` runs the full control
/// loop; pass a freshly-built [`DeadlineQos`] per run — it accumulates
/// feedback state.
pub fn simulate(arrivals_ms: &[f64], spec: &SimSpec, policy: Option<&DeadlineQos>) -> SimReport {
    simulate_trace(arrivals_ms, spec, policy).0
}

/// [`simulate`] plus the per-request applied-plan trace: one
/// [`AppliedPlan`] per *admitted* request (rejections leave no entry),
/// in arrival order, with its eventual SLO outcome. Quality benches
/// replay the trace's (schedule, strategy) pairs through the real engine
/// to price what the actuation actually cost in SSIM.
pub fn simulate_trace(
    arrivals_ms: &[f64],
    spec: &SimSpec,
    policy: Option<&DeadlineQos>,
) -> (SimReport, Vec<AppliedPlan>) {
    assert!(spec.workers >= 1, "sim needs at least one worker");
    debug_assert!(
        arrivals_ms.windows(2).all(|w| w[1] >= w[0]),
        "arrivals must be sorted"
    );
    let mut st = SimState {
        spec: *spec,
        policy,
        workers: vec![0.0; spec.workers],
        queue: VecDeque::new(),
        finishes: BinaryHeap::new(),
        outstanding: 0,
        latencies: Vec::with_capacity(arrivals_ms.len()),
        completed: 0,
        expired: 0,
        slo_met: 0,
        plans: Vec::with_capacity(arrivals_ms.len()),
    };
    let mut rejected = 0usize;
    let mut fractions: Vec<f64> = Vec::with_capacity(arrivals_ms.len());

    for &t in arrivals_ms {
        st.drain(t);
        match policy {
            Some(p) => {
                let mut req = GenerationRequest::new("qos sim").steps(spec.steps).decode(false);
                let mut meta = QosMeta::with_deadline_ms(spec.deadline_ms);
                match p.admit(&mut req, &mut meta, st.outstanding) {
                    AdmissionDecision::Reject(_) => {
                        rejected += 1;
                    }
                    AdmissionDecision::Admit => {
                        // the service model keys on the plan-derived
                        // *effective* single-pass fraction — the same
                        // view the coordinator feeds back: a reuse
                        // window sheds less than its size (refresh and
                        // cold-cache steps pay dual cost)
                        let f = req.effective_shed();
                        fractions.push(f);
                        st.plans.push(AppliedPlan {
                            schedule: req.schedule.clone(),
                            strategy: req.strategy,
                            steps: req.steps,
                            slo_met: false,
                        });
                        st.queue.push_back(Queued {
                            arrive_ms: t,
                            service_ms: service_ms_at(spec.base_service_ms, spec.unet_share, f),
                            fraction: f,
                            deadline_ms: meta.deadline_ms(),
                            plan_idx: st.plans.len() - 1,
                        });
                        st.outstanding += 1;
                    }
                }
            }
            None => {
                fractions.push(0.0);
                st.plans.push(AppliedPlan {
                    schedule: GuidanceSchedule::none(),
                    strategy: GuidanceStrategy::CondOnly,
                    steps: spec.steps,
                    slo_met: false,
                });
                st.queue.push_back(Queued {
                    arrive_ms: t,
                    service_ms: spec.base_service_ms,
                    fraction: 0.0,
                    deadline_ms: None,
                    plan_idx: st.plans.len() - 1,
                });
                st.outstanding += 1;
            }
        }
    }
    st.drain(f64::INFINITY);

    let mean_fraction = if fractions.is_empty() {
        0.0
    } else {
        fractions.iter().sum::<f64>() / fractions.len() as f64
    };
    let mut sorted = st.latencies.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(CmpOrdering::Equal));
    let pct = |q: f64| {
        if sorted.is_empty() {
            0.0
        } else {
            sorted[((q * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1)]
        }
    };
    let report = SimReport {
        offered: arrivals_ms.len(),
        completed: st.completed,
        rejected,
        expired: st.expired,
        slo_met: st.slo_met,
        mean_fraction,
        p50_latency_ms: pct(0.5),
        p90_latency_ms: pct(0.9),
    };
    (report, st.plans)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::QosConfig;
    use crate::workload::ArrivalProcess;

    fn policy() -> DeadlineQos {
        DeadlineQos::new(QosConfig {
            enabled: true,
            ramp_low: 1,
            ramp_high: 4,
            floor_fraction: 0.5,
            ..QosConfig::default()
        })
        .unwrap()
    }

    fn poisson(rate: f64, n: usize) -> Vec<f64> {
        ArrivalProcess::Poisson { rate_per_s: rate }.arrivals(n, 7)
    }

    #[test]
    fn light_load_is_untouched() {
        // service 100 ms, arrivals every 250 ms: no queue forms
        let arr: Vec<f64> = (0..200).map(|i| i as f64 * 250.0).collect();
        let spec = SimSpec::default();
        let off = simulate(&arr, &spec, None);
        let q = policy();
        let on = simulate(&arr, &spec, Some(&q));
        assert_eq!(off.slo_attainment(), 1.0);
        assert_eq!(on.slo_attainment(), 1.0);
        assert_eq!(on.rejected, 0);
        assert_eq!(on.expired, 0);
        // idle actuator: everyone gets full CFG
        assert_eq!(on.mean_fraction, 0.0);
    }

    #[test]
    fn overload_sheds_and_wins_on_slo() {
        // capacity 10/s at full CFG; offer 2x
        let arr = poisson(20.0, 800);
        let spec = SimSpec::default();
        let off = simulate(&arr, &spec, None);
        let q = policy();
        let on = simulate(&arr, &spec, Some(&q));
        assert!(on.rejected > 0, "overload must shed: {on:?}");
        assert!(
            on.slo_attainment() > off.slo_attainment(),
            "actuator must win at overload: on {:?} vs off {:?}",
            on.slo_attainment(),
            off.slo_attainment()
        );
        // the queue bound keeps served latency near the SLO while the
        // baseline's unbounded queue blows past it
        assert!(on.p90_latency_ms <= spec.deadline_ms * 1.5, "{on:?}");
        assert!(off.p90_latency_ms > spec.deadline_ms * 2.0, "{off:?}");
    }

    #[test]
    fn actuator_widens_under_pressure() {
        // just past capacity: widening (not only shedding) should engage
        let arr = poisson(12.0, 600);
        let q = policy();
        let on = simulate(&arr, &SimSpec::default(), Some(&q));
        assert!(on.mean_fraction > 0.0, "{on:?}");
        assert!(
            on.mean_fraction <= q.config().floor_fraction + 1e-12,
            "quality floor violated: {on:?}"
        );
    }

    #[test]
    fn burst_expires_stale_requests() {
        // 10 simultaneous arrivals, 150 ms deadline, 100 ms service: the
        // cold-start estimator admits them all, then expiry fires for
        // jobs whose turn comes after the deadline
        let arr = vec![0.0; 10];
        let spec = SimSpec { deadline_ms: 150.0, ..SimSpec::default() };
        let q = DeadlineQos::new(QosConfig {
            enabled: true,
            max_queue_depth: 64,
            ..QosConfig::default()
        })
        .unwrap();
        let on = simulate(&arr, &spec, Some(&q));
        assert!(on.expired > 0, "{on:?}");
        assert!(on.completed >= 1, "{on:?}");
        assert_eq!(on.completed + on.expired + on.rejected, 10, "{on:?}");
    }

    #[test]
    fn trace_records_every_admitted_plan_with_its_slo_outcome() {
        let arr = poisson(20.0, 400);
        let spec = SimSpec::default();
        let q = policy();
        let (report, plans) = simulate_trace(&arr, &spec, Some(&q));
        // one entry per admitted request, in arrival order
        assert_eq!(plans.len(), report.offered - report.rejected);
        // SLO flags reconcile exactly with the report
        let met = plans.iter().filter(|p| p.slo_met).count();
        assert_eq!(met, report.slo_met, "{report:?}");
        // widened requests carry their actual post-admission schedule
        assert!(
            plans.iter().any(|p| p.schedule != crate::guidance::GuidanceSchedule::none()),
            "overload must widen some plans"
        );
        assert!(plans.iter().all(|p| p.steps == spec.steps));
        // the wrapper is the same replay minus the trace
        let q2 = policy();
        assert_eq!(simulate(&arr, &spec, Some(&q2)), report);
    }

    #[test]
    fn deterministic_replays() {
        let arr = poisson(15.0, 300);
        let spec = SimSpec::default();
        let a = simulate(&arr, &spec, Some(&policy()));
        let b = simulate(&arr, &spec, Some(&policy()));
        assert_eq!(a, b);
    }

    #[test]
    fn multi_worker_capacity() {
        // 12/s offered: one 10/s worker drowns, two workers keep up
        let arr = poisson(12.0, 400);
        let one = simulate(&arr, &SimSpec::default(), None);
        let two = simulate(&arr, &SimSpec { workers: 2, ..SimSpec::default() }, None);
        assert_eq!(two.completed, two.offered); // baseline never sheds
        assert!(
            two.slo_attainment() > one.slo_attainment(),
            "two workers must beat one: {two:?} vs {one:?}"
        );
        assert!(two.p90_latency_ms < one.p90_latency_ms);
    }
}
