//! Admission control: bounded queues, priority shares, deadline
//! feasibility — explicit rejection instead of unbounded queuing.
//!
//! The pre-QoS coordinator accepted every request and let the queue grow
//! without bound; under sustained overload that turns every response
//! into a deadline miss. Admission control converts the failure mode
//! into an explicit, *early* signal (429-style) the client can act on —
//! retry against another replica, downgrade, or drop.
//!
//! Feasibility is judged against the widest *achievable* shed, which is
//! the same bound whether the subsequent rewrite widens analytically or
//! degrades along a tuned Pareto frontier (DESIGN.md §16): the frontier
//! is floor-clamped, so the quality floor's frontier point never sheds
//! more than the floor window this controller prices with.

use std::time::{Duration, Instant};

use super::feedback::LoadSnapshot;
use super::{service_ms_at_shed, QosConfig, QosMeta};

/// Why a request was shed at admission.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    /// The outstanding-request bound for this priority class is reached.
    QueueFull { depth: usize, limit: usize },
    /// Even with maximal window widening the request cannot finish
    /// before its deadline, so serving it would only waste capacity.
    DeadlineInfeasible { needed_ms: u64, deadline_ms: u64 },
}

impl RejectReason {
    /// HTTP-style status code for the wire protocol.
    pub fn code(&self) -> u16 {
        match self {
            RejectReason::QueueFull { .. } => 429,
            RejectReason::DeadlineInfeasible { .. } => 503,
        }
    }

    pub fn message(&self) -> String {
        match self {
            RejectReason::QueueFull { depth, limit } => {
                format!("queue full: depth {depth} >= class limit {limit}")
            }
            RejectReason::DeadlineInfeasible { needed_ms, deadline_ms } => format!(
                "deadline infeasible: needs ~{needed_ms} ms even at the widest \
                 achievable window, deadline is {deadline_ms} ms"
            ),
        }
    }
}

/// The admission verdict for one request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionDecision {
    Admit,
    Reject(RejectReason),
}

/// Stateless admission rules over a [`QosConfig`]; all the state it
/// consults arrives in the [`LoadSnapshot`].
#[derive(Debug, Clone)]
pub struct AdmissionController {
    cfg: QosConfig,
}

impl AdmissionController {
    pub fn new(cfg: QosConfig) -> AdmissionController {
        AdmissionController { cfg }
    }

    /// Outstanding-request limit for a priority class (≥ 1 so a lone
    /// request of any class is always admissible on an idle server).
    pub fn class_limit(&self, meta: &QosMeta) -> usize {
        let share = meta.priority.queue_share();
        ((self.cfg.max_queue_depth as f64 * share).ceil() as usize).max(1)
    }

    /// Admission decision given the current load. `achievable_fraction`
    /// is the widest selective-guidance window this request can actually
    /// run at — the quality floor for widenable requests, the request's
    /// own fixed fraction for explicit non-`Last` placements the policy
    /// refuses to move. `shed_ratio` is the fraction of a dual step's
    /// time a single step saves: the analytic 0.5, or a calibrated
    /// table's measured value ([`crate::guidance::CostTable::shed_ratio`],
    /// DESIGN.md §15).
    pub fn decide(
        &self,
        meta: &QosMeta,
        load: &LoadSnapshot,
        achievable_fraction: f64,
        shed_ratio: f64,
    ) -> AdmissionDecision {
        let limit = self.class_limit(meta);
        if load.queue_depth >= limit {
            return AdmissionDecision::Reject(RejectReason::QueueFull {
                depth: load.queue_depth,
                limit,
            });
        }
        if let Some(deadline) = meta.deadline {
            // Feasibility uses the *optimistic* bound — service at the
            // widest achievable window — so we only shed what provably
            // cannot make it. No estimate yet (cold start) means no
            // feasibility check; the first batches calibrate the
            // estimator.
            if load.service_ms > 0.0 {
                let best_ms = load.est_wait_ms
                    + service_ms_at_shed(
                        load.service_ms,
                        self.cfg.unet_share,
                        achievable_fraction,
                        shed_ratio,
                    );
                let deadline_ms = deadline.as_secs_f64() * 1e3;
                if best_ms > deadline_ms {
                    return AdmissionDecision::Reject(RejectReason::DeadlineInfeasible {
                        needed_ms: best_ms.round() as u64,
                        deadline_ms: deadline_ms.round() as u64,
                    });
                }
            }
        }
        AdmissionDecision::Admit
    }
}

/// Has a request's deadline passed while it sat in the queue? Used by
/// the coordinator workers to expire stale jobs before paying for their
/// UNet evaluations.
pub fn expired(meta: &QosMeta, enqueued: Instant, now: Instant) -> bool {
    match meta.deadline {
        Some(d) => now.duration_since(enqueued) > d,
        None => false,
    }
}

/// Convenience: duration helper for expiry math in tests and the sim.
pub fn remaining_budget(meta: &QosMeta, waited: Duration) -> Option<Duration> {
    meta.deadline.map(|d| d.saturating_sub(waited))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::Priority;

    /// The achievable fraction most tests use: a widenable request at
    /// the default quality floor.
    const FLOOR: f64 = 0.5;

    fn load(depth: usize, service_ms: f64) -> LoadSnapshot {
        LoadSnapshot {
            queue_depth: depth,
            service_ms,
            est_wait_ms: depth as f64 * service_ms,
            slot_occupancy: 0.0,
        }
    }

    fn cfg() -> QosConfig {
        QosConfig { max_queue_depth: 8, enabled: true, ..QosConfig::default() }
    }

    #[test]
    fn accepts_when_idle() {
        let a = AdmissionController::new(cfg());
        let meta = QosMeta::default();
        assert_eq!(a.decide(&meta, &load(0, 0.0), FLOOR, 0.5), AdmissionDecision::Admit);
        assert_eq!(a.decide(&meta, &load(0, 100.0), FLOOR, 0.5), AdmissionDecision::Admit);
    }

    #[test]
    fn rejects_at_class_limit() {
        let a = AdmissionController::new(cfg());
        // standard: 75% of 8 -> limit 6
        let meta = QosMeta::default();
        assert_eq!(a.class_limit(&meta), 6);
        assert_eq!(a.decide(&meta, &load(5, 100.0), FLOOR, 0.5), AdmissionDecision::Admit);
        assert!(matches!(
            a.decide(&meta, &load(6, 100.0), FLOOR, 0.5),
            AdmissionDecision::Reject(RejectReason::QueueFull { depth: 6, limit: 6 })
        ));
    }

    #[test]
    fn lower_classes_shed_first() {
        let a = AdmissionController::new(cfg());
        let batch = QosMeta { priority: Priority::Batch, ..QosMeta::default() };
        let standard = QosMeta::default();
        let interactive = QosMeta { priority: Priority::Interactive, ..QosMeta::default() };
        assert_eq!(a.class_limit(&batch), 4);
        assert_eq!(a.class_limit(&standard), 6);
        assert_eq!(a.class_limit(&interactive), 8);
        // at depth 5, batch bounces but standard and interactive enter
        assert!(matches!(a.decide(&batch, &load(5, 100.0), FLOOR, 0.5), AdmissionDecision::Reject(_)));
        assert_eq!(a.decide(&standard, &load(5, 100.0), FLOOR, 0.5), AdmissionDecision::Admit);
        assert_eq!(a.decide(&interactive, &load(5, 100.0), FLOOR, 0.5), AdmissionDecision::Admit);
    }

    #[test]
    fn class_limit_never_zero() {
        let tiny = AdmissionController::new(QosConfig { max_queue_depth: 1, ..cfg() });
        let batch = QosMeta { priority: Priority::Batch, ..QosMeta::default() };
        assert_eq!(tiny.class_limit(&batch), 1);
        assert_eq!(tiny.decide(&batch, &load(0, 0.0), FLOOR, 0.5), AdmissionDecision::Admit);
    }

    #[test]
    fn infeasible_deadline_rejected() {
        let a = AdmissionController::new(cfg());
        // 3 queued x 100 ms wait + >=76 ms best-case service > 200 ms deadline
        let meta = QosMeta::with_deadline_ms(200.0);
        assert!(matches!(
            a.decide(&meta, &load(3, 100.0), FLOOR, 0.5),
            AdmissionDecision::Reject(RejectReason::DeadlineInfeasible { .. })
        ));
        // generous deadline admits
        let meta = QosMeta::with_deadline_ms(5000.0);
        assert_eq!(a.decide(&meta, &load(3, 100.0), FLOOR, 0.5), AdmissionDecision::Admit);
        // cold start (no estimate) admits: nothing to extrapolate from
        let meta = QosMeta::with_deadline_ms(1.0);
        assert_eq!(a.decide(&meta, &load(3, 0.0), FLOOR, 0.5), AdmissionDecision::Admit);
    }

    #[test]
    fn non_widenable_requests_judged_at_their_own_fraction() {
        // a request pinned to a narrow window cannot be saved by the
        // floor: feasibility must use ITS fraction, not the floor's
        let a = AdmissionController::new(cfg());
        let meta = QosMeta::with_deadline_ms(80.0);
        // widenable at the floor: ~76 ms best case fits the 80 ms budget
        assert_eq!(a.decide(&meta, &load(0, 100.0), FLOOR, 0.5), AdmissionDecision::Admit);
        // pinned at 10%: ~95 ms best case cannot fit -> shed early
        assert!(matches!(
            a.decide(&meta, &load(0, 100.0), 0.1, 0.5),
            AdmissionDecision::Reject(RejectReason::DeadlineInfeasible { .. })
        ));
    }

    #[test]
    fn measured_shed_ratio_changes_feasibility() {
        let a = AdmissionController::new(cfg());
        // 100 ms base at the 0.5 floor, share 0.95: analytic (ratio 0.5)
        // best case ≈ 76.25 ms — fits an 80 ms deadline
        let meta = QosMeta::with_deadline_ms(80.0);
        assert_eq!(a.decide(&meta, &load(0, 100.0), FLOOR, 0.5), AdmissionDecision::Admit);
        // a backend whose single step saves almost nothing (measured
        // ratio 0.1): best ≈ 95.25 ms — the same deadline is infeasible
        assert!(matches!(
            a.decide(&meta, &load(0, 100.0), FLOOR, 0.1),
            AdmissionDecision::Reject(RejectReason::DeadlineInfeasible { .. })
        ));
        // a backend where the uncond pass dominates (ratio 0.7): best
        // ≈ 66.75 ms — even a 70 ms deadline fits
        let meta = QosMeta::with_deadline_ms(70.0);
        assert_eq!(a.decide(&meta, &load(0, 100.0), FLOOR, 0.7), AdmissionDecision::Admit);
    }

    #[test]
    fn deadline_expiry() {
        let enqueued = Instant::now();
        let meta = QosMeta::with_deadline_ms(50.0);
        assert!(!expired(&meta, enqueued, enqueued + Duration::from_millis(10)));
        assert!(expired(&meta, enqueued, enqueued + Duration::from_millis(60)));
        // no deadline never expires
        assert!(!expired(&QosMeta::default(), enqueued, enqueued + Duration::from_secs(3600)));
    }

    #[test]
    fn remaining_budget_saturates() {
        let meta = QosMeta::with_deadline_ms(100.0);
        assert_eq!(
            remaining_budget(&meta, Duration::from_millis(30)),
            Some(Duration::from_millis(70))
        );
        assert_eq!(
            remaining_budget(&meta, Duration::from_millis(300)),
            Some(Duration::ZERO)
        );
        assert_eq!(remaining_budget(&QosMeta::default(), Duration::ZERO), None);
    }

    #[test]
    fn reject_reason_codes() {
        assert_eq!(RejectReason::QueueFull { depth: 9, limit: 8 }.code(), 429);
        assert_eq!(
            RejectReason::DeadlineInfeasible { needed_ms: 500, deadline_ms: 100 }.code(),
            503
        );
        assert!(RejectReason::QueueFull { depth: 9, limit: 8 }.message().contains("9"));
    }
}
