//! The window actuator: observed load → selective-guidance window
//! fraction.
//!
//! The paper's dial — "optimize the last f of the iterations" — buys
//! roughly `f·u/2` of service time (§3.3, u = UNet share). The actuator
//! turns that dial per request from two signals:
//!
//! 1. **Load ramp** — queue depth between `ramp_low` and `ramp_high`
//!    maps linearly onto `[0, floor_fraction]`, biased per priority
//!    class (batch traffic gives up quality earlier than interactive).
//! 2. **Deadline slack** — if the EWMA-predicted completion overruns the
//!    request's deadline, widen to the *minimal* fraction that fits.
//!
//! The combined position is monotone in load and clamped at the quality
//! floor: heavier load never narrows the window, and quality never drops
//! below the configured floor.
//!
//! Since the guidance-reuse lattice landed (DESIGN.md §8), the actuator
//! escalates through *strategies*, not just window sizes: light load
//! runs full dual-pass CFG, moderate load serves its shed via **Reuse**
//! (cached uncond eps — near-CFG quality at single-pass cost), and only
//! heavy load falls back to the paper's drop-guidance mode. De-escalation
//! is the mirror image, so quality recovers as load drains.

use crate::engine::GenerationRequest;
use crate::guidance::{
    GuidancePlan, GuidanceSchedule, GuidanceStrategy, PlanSearch, SelectedPlan, WindowSpec,
};

use super::feedback::LoadSnapshot;
use super::{QosConfig, QosMeta};

/// Slot occupancy at which the occupancy ramp starts widening (full
/// widening at saturation). Below this the continuous batcher still has
/// real admission headroom and quality is left alone.
pub const SLOT_RAMP_START: f64 = 0.75;

/// Maps load snapshots to window fractions. Pure — all serving state
/// arrives via [`LoadSnapshot`], which keeps the control law trivially
/// testable.
#[derive(Debug, Clone)]
pub struct WindowActuator {
    cfg: QosConfig,
}

impl WindowActuator {
    pub fn new(cfg: QosConfig) -> WindowActuator {
        WindowActuator { cfg }
    }

    /// Load-driven component: the *wider* of two ramps, clamped to the
    /// floor.
    ///
    /// * **Queue depth** — 0 below `ramp_low`, full at or above
    ///   `ramp_high`, linear in between.
    /// * **Slot occupancy** — the continuous batcher's EWMA slot usage;
    ///   0 at or below [`SLOT_RAMP_START`], full at saturation. A
    ///   saturated cohort means admission headroom is gone even while the
    ///   queue is still shallow (retires are absorbed instantly), so
    ///   waiting for depth alone would actuate a whole queue-build-up
    ///   late. Fixed-mode deployments report no occupancy and keep the
    ///   pure depth ramp.
    pub fn fraction_for(&self, load: &LoadSnapshot) -> f64 {
        let d = load.queue_depth;
        let (lo, hi) = (self.cfg.ramp_low, self.cfg.ramp_high);
        // `hi` first so a degenerate ramp (lo == hi) acts as a step up
        let depth_ramp = if d >= hi {
            1.0
        } else if d <= lo {
            0.0
        } else {
            (d - lo) as f64 / (hi - lo) as f64
        };
        let occ_ramp = ((load.slot_occupancy - SLOT_RAMP_START) / (1.0 - SLOT_RAMP_START))
            .clamp(0.0, 1.0);
        (depth_ramp.max(occ_ramp) * self.cfg.floor_fraction)
            .clamp(0.0, self.cfg.floor_fraction)
    }

    /// Full per-request position: load ramp (priority-biased) combined
    /// with the deadline-slack requirement, clamped to the floor.
    pub fn fraction_for_request(&self, load: &LoadSnapshot, meta: &QosMeta) -> f64 {
        let mut f = (self.fraction_for(load) * meta.priority.actuator_bias())
            .clamp(0.0, self.cfg.floor_fraction);
        if let (Some(deadline), true) = (meta.deadline, load.service_ms > 0.0) {
            let budget_ms = deadline.as_secs_f64() * 1e3 - load.est_wait_ms;
            // invert service(f) = s·(1 − u·f/2) <= budget for the
            // smallest sufficient f; budget >= s needs no widening, a
            // negative budget is the admission controller's problem
            // (clamp covers the race between the two checks)
            if budget_ms < load.service_ms {
                let needed =
                    (1.0 - budget_ms / load.service_ms) * 2.0 / self.cfg.unet_share;
                f = f.max(needed.clamp(0.0, self.cfg.floor_fraction));
            }
        }
        f
    }

    /// Full actuation: the *effective* single-pass fraction this request
    /// must shed (from [`Self::fraction_for_request`]), escalated through
    /// the strategy lattice. Positions at or below
    /// `reuse_threshold · floor` are served via guidance reuse with the
    /// window widened so the reuse strategy still delivers the required
    /// shed (refresh steps give part of the window back); past the
    /// threshold the actuator escalates to the paper's drop-guidance
    /// mode. The effective shed is monotone in load either way.
    pub fn plan_for_request(&self, load: &LoadSnapshot, meta: &QosMeta) -> ActuationPlan {
        let f = self.fraction_for_request(load, meta);
        if f <= 0.0 {
            return ActuationPlan { fraction: 0.0, strategy: GuidanceStrategy::CondOnly };
        }
        let m = self.cfg.reuse_refresh_every;
        let strategy = GuidanceStrategy::Reuse {
            kind: crate::guidance::ReuseKind::Hold,
            refresh_every: m,
        };
        if f <= self.cfg.reuse_threshold * self.cfg.floor_fraction {
            // widen so that effective_fraction(window) == f, floor-capped
            let window = (f / strategy.effective_fraction(1.0)).min(self.cfg.floor_fraction);
            if strategy.effective_fraction(window) + 1e-12 >= f {
                return ActuationPlan { fraction: window, strategy };
            }
        }
        ActuationPlan { fraction: f, strategy: GuidanceStrategy::CondOnly }
    }

    /// The plan-rewriting entry point admission calls: escalate through
    /// the lattice for the current load and — when the request's
    /// schedule is rewritable and the escalated plan sheds strictly more
    /// than the request already does — edit the request's schedule and
    /// strategy in place. Returns `(applied_fraction, widened)` for the
    /// stats counters.
    ///
    /// The comparison is in plan-derived *effective shed* terms
    /// ([`GenerationRequest::effective_shed`]): a client's explicit
    /// schedule + strategy is a floor on how much it already gives up,
    /// and the actuator only ever replaces it with a plan that sheds
    /// strictly more (a reuse plan's window can be larger yet shed less
    /// — raw fractions would lie here). Non-`Last` placements and the
    /// richer schedule kinds (segments / interval / cadence) are
    /// deliberate experiments and are never rewritten.
    pub fn rewrite(
        &self,
        req: &mut GenerationRequest,
        load: &LoadSnapshot,
        meta: &QosMeta,
    ) -> (f64, bool) {
        let mut widened = false;
        // adaptive requests run the online controller — the engine
        // ignores the static schedule, so rewriting it would only make
        // the stats lie about shed that never happens
        if req.adaptive.is_none() && req.schedule.widenable() {
            let plan = self.plan_for_request(load, meta);
            let candidate = GuidanceSchedule::Window(WindowSpec::last(plan.fraction));
            // compare executed (plan-derived) shed to executed shed —
            // both floor-rounded at this request's step count — so a
            // rewrite to an equal-shed plan never fires and the
            // "sheds strictly more" contract holds exactly
            let candidate_shed =
                GuidancePlan::compile(&candidate, req.guidance_scale, plan.strategy, req.steps)
                    .map(|p| p.effective_fraction())
                    .unwrap_or(0.0);
            if candidate_shed > req.effective_shed() {
                req.schedule = candidate;
                req.strategy = plan.strategy;
                widened = true;
            }
        }
        (req.schedule.last_fraction(), widened)
    }

    /// Frontier-guided variant of [`Self::rewrite`]: instead of widening
    /// the request's Last window analytically, degrade along the tuned
    /// Pareto frontier (DESIGN.md §16). The load position still comes
    /// from [`Self::fraction_for_request`], but it is converted into a
    /// *cost saving* demand (`fraction · shed_ratio`, the measured
    /// single-vs-dual ratio) and answered by [`PlanSearch::select`] with
    /// the max-SSIM point that covers it — the quality floor becomes the
    /// floor's own frontier point rather than a bare window clamp.
    ///
    /// The rewrite contract is unchanged from the legacy path: adaptive
    /// requests and non-widenable schedules are never touched, and a
    /// selected plan is applied only when its compiled effective shed
    /// strictly exceeds what the request already gives up. A bucket miss
    /// (no tuned steps bucket within 2× of the request) falls back to the
    /// legacy analytic widening, so off-frontier traffic behaves exactly
    /// as before. Returns `(applied_shed, widened, selected_point)`.
    pub fn rewrite_along(
        &self,
        req: &mut GenerationRequest,
        load: &LoadSnapshot,
        meta: &QosMeta,
        search: &PlanSearch,
        shed_ratio: f64,
    ) -> (f64, bool, Option<SelectedPlan>) {
        if req.adaptive.is_some() || !req.schedule.widenable() {
            return (req.schedule.last_fraction(), false, None);
        }
        let f = self.fraction_for_request(load, meta);
        let ratio = shed_ratio.clamp(0.0, 1.0);
        match search.select(req.steps, f * ratio, self.cfg.floor_fraction * ratio) {
            Some(sel) => {
                // same executed-shed comparison as the legacy path: both
                // sides floor-rounded at this request's step count
                let shed = GuidancePlan::compile(
                    &sel.schedule,
                    req.guidance_scale,
                    sel.strategy,
                    req.steps,
                )
                .map(|p| p.effective_fraction())
                .unwrap_or(0.0);
                if shed > req.effective_shed() {
                    req.schedule = sel.schedule.clone();
                    req.strategy = sel.strategy;
                    (shed, true, Some(sel))
                } else {
                    (req.schedule.last_fraction(), false, None)
                }
            }
            None => {
                let (applied, widened) = self.rewrite(req, load, meta);
                (applied, widened, None)
            }
        }
    }
}

/// One actuation decision: the window to apply and what the optimized
/// iterations should run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActuationPlan {
    /// Selective-guidance window fraction (Last placement).
    pub fraction: f64,
    /// Strategy for the optimized iterations.
    pub strategy: GuidanceStrategy,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::Priority;
    use crate::testutil::prop::forall;

    fn actuator(floor: f64, lo: usize, hi: usize) -> WindowActuator {
        WindowActuator::new(QosConfig {
            floor_fraction: floor,
            ramp_low: lo,
            ramp_high: hi,
            ..QosConfig::default()
        })
    }

    fn load(depth: usize, service_ms: f64) -> LoadSnapshot {
        LoadSnapshot {
            queue_depth: depth,
            service_ms,
            est_wait_ms: depth as f64 * service_ms,
            slot_occupancy: 0.0,
        }
    }

    #[test]
    fn slot_occupancy_ramp_widens_without_queue_depth() {
        let a = actuator(0.5, 2, 16);
        let occupied = |occ: f64| LoadSnapshot { slot_occupancy: occ, ..load(0, 0.0) };
        // headroom left: no widening
        assert_eq!(a.fraction_for(&occupied(0.0)), 0.0);
        assert_eq!(a.fraction_for(&occupied(SLOT_RAMP_START)), 0.0);
        // halfway up the occupancy ramp: half the floor
        let mid = SLOT_RAMP_START + (1.0 - SLOT_RAMP_START) / 2.0;
        assert!((a.fraction_for(&occupied(mid)) - 0.25).abs() < 1e-12);
        // saturated cohort: full widening at depth 0
        assert_eq!(a.fraction_for(&occupied(1.0)), 0.5);
        // the wider of the two ramps wins, still floor-clamped
        let both = LoadSnapshot { slot_occupancy: 1.0, ..load(9, 0.0) };
        assert_eq!(a.fraction_for(&both), 0.5);
    }

    #[test]
    fn idle_runs_full_cfg() {
        let a = actuator(0.5, 2, 16);
        assert_eq!(a.fraction_for(&load(0, 100.0)), 0.0);
        assert_eq!(a.fraction_for(&load(2, 100.0)), 0.0);
    }

    #[test]
    fn ramp_reaches_floor() {
        let a = actuator(0.5, 2, 10);
        assert!((a.fraction_for(&load(6, 0.0)) - 0.25).abs() < 1e-12);
        assert_eq!(a.fraction_for(&load(10, 0.0)), 0.5);
        assert_eq!(a.fraction_for(&load(1000, 0.0)), 0.5);
    }

    #[test]
    fn degenerate_ramp_is_a_step() {
        // ramp_low == ramp_high: a step function, still monotone
        let a = actuator(0.4, 3, 3);
        assert_eq!(a.fraction_for(&load(2, 0.0)), 0.0);
        assert_eq!(a.fraction_for(&load(3, 0.0)), 0.4);
        assert_eq!(a.fraction_for(&load(4, 0.0)), 0.4);
    }

    #[test]
    fn monotone_in_load_and_clamped() {
        forall("actuator monotonicity", 100, |g| {
            let floor = g.f64_in(0.05, 1.0);
            let lo = g.usize_in(0, 8);
            let hi = lo + g.usize_in(0, 24);
            let a = actuator(floor, lo, hi);
            let service = g.f64_in(1.0, 500.0);
            let meta = QosMeta { priority: *g.choose(&[
                Priority::Batch,
                Priority::Standard,
                Priority::Interactive,
            ]), ..QosMeta::default() };
            let mut prev = 0.0f64;
            for depth in 0..=(hi + 4) {
                let f = a.fraction_for_request(&load(depth, service), &meta);
                assert!(
                    f + 1e-12 >= prev,
                    "higher load narrowed the window: depth {depth}, {f} < {prev}"
                );
                assert!(f <= floor + 1e-12, "exceeded quality floor: {f} > {floor}");
                assert!(f >= 0.0);
                prev = f;
            }
        });
    }

    #[test]
    fn deadline_slack_forces_widening() {
        let a = actuator(0.5, 100, 200); // load ramp effectively off
        // idle queue, 100 ms service, 90 ms deadline: needs f with
        // 100·(1 − 0.95·f/2) <= 90  ->  f >= 0.2105…
        let meta = QosMeta::with_deadline_ms(90.0);
        let f = a.fraction_for_request(&load(0, 100.0), &meta);
        assert!(f > 0.21 && f < 0.22, "slack widening {f}");
        // plentiful slack: no widening
        let meta = QosMeta::with_deadline_ms(500.0);
        assert_eq!(a.fraction_for_request(&load(0, 100.0), &meta), 0.0);
        // impossible budget clamps at the floor (admission sheds it)
        let meta = QosMeta::with_deadline_ms(1.0);
        assert_eq!(a.fraction_for_request(&load(3, 100.0), &meta), 0.5);
    }

    #[test]
    fn plan_escalates_dual_reuse_cond_only() {
        use crate::guidance::GuidanceStrategy;
        let a = actuator(0.5, 0, 10); // reuse_threshold 0.6, refresh 4 (defaults)
        let meta = QosMeta::default();
        // idle: full CFG, no window
        let p = a.plan_for_request(&load(0, 0.0), &meta);
        assert_eq!(p.fraction, 0.0);
        // moderate load (shed 0.15 <= 0.6*0.5): reuse, window widened by
        // (m+1)/m so the effective shed still matches
        let p = a.plan_for_request(&load(3, 0.0), &meta);
        assert!(matches!(p.strategy, GuidanceStrategy::Reuse { .. }), "{p:?}");
        assert!((p.strategy.effective_fraction(p.fraction) - 0.15).abs() < 1e-9, "{p:?}");
        assert!(p.fraction <= 0.5 + 1e-12);
        // heavy load (shed 0.5 > 0.3): escalate to drop-guidance
        let p = a.plan_for_request(&load(10, 0.0), &meta);
        assert_eq!(p.strategy, GuidanceStrategy::CondOnly);
        assert!((p.fraction - 0.5).abs() < 1e-12);
    }

    #[test]
    fn plan_effective_shed_monotone_in_load() {
        forall("plan monotone effective shed", 100, |g| {
            let floor = g.f64_in(0.05, 1.0);
            let lo = g.usize_in(0, 8);
            let hi = lo + g.usize_in(0, 24);
            let a = WindowActuator::new(QosConfig {
                floor_fraction: floor,
                ramp_low: lo,
                ramp_high: hi,
                reuse_threshold: g.f64_in(0.0, 1.0),
                reuse_refresh_every: g.usize_in(0, 8),
                ..QosConfig::default()
            });
            let meta = QosMeta::default();
            let mut prev = 0.0f64;
            for depth in 0..=(hi + 4) {
                let p = a.plan_for_request(&load(depth, 0.0), &meta);
                let eff = p.strategy.effective_fraction(p.fraction);
                assert!(
                    eff + 1e-9 >= prev,
                    "effective shed fell under load: depth {depth}, {eff} < {prev}"
                );
                assert!(p.fraction <= floor + 1e-12, "window above floor: {p:?}");
                prev = eff;
            }
        });
    }

    #[test]
    fn rewrite_edits_widenable_schedules_only() {
        use crate::engine::GenerationRequest;
        use crate::guidance::{GuidanceSchedule, WindowSpec};
        let a = actuator(0.5, 0, 10);
        let meta = QosMeta::default();
        let heavy = load(10, 0.0);
        // default request: rewritten to the floor window
        let mut req = GenerationRequest::new("p").decode(false);
        let (applied, widened) = a.rewrite(&mut req, &heavy, &meta);
        assert!(widened);
        assert!((applied - 0.5).abs() < 1e-12);
        assert_eq!(req.schedule, GuidanceSchedule::Window(WindowSpec::last(0.5)));
        // a cadence schedule is a deliberate experiment: untouched, and
        // the applied Last fraction reports 0
        let mut req = GenerationRequest::new("p")
            .with_schedule(GuidanceSchedule::Cadence { every: 4 })
            .decode(false);
        let before = req.schedule.clone();
        let (applied, widened) = a.rewrite(&mut req, &heavy, &meta);
        assert!(!widened);
        assert_eq!(applied, 0.0);
        assert_eq!(req.schedule, before);
        // idle load never widens
        let mut req = GenerationRequest::new("p").decode(false);
        let (applied, widened) = a.rewrite(&mut req, &load(0, 0.0), &meta);
        assert!(!widened);
        assert_eq!(applied, 0.0);
        assert_eq!(req.schedule, GuidanceSchedule::none());
        // adaptive requests run the online controller: the engine
        // ignores the static schedule, so the rewriter must too
        let mut req = GenerationRequest::new("p")
            .adaptive(crate::guidance::AdaptiveConfig::default())
            .decode(false);
        let (applied, widened) = a.rewrite(&mut req, &heavy, &meta);
        assert!(!widened, "adaptive request was rewritten");
        assert_eq!(applied, 0.0);
        assert_eq!(req.schedule, GuidanceSchedule::none());
    }

    #[test]
    fn rewrite_never_fires_on_equal_executed_shed() {
        use crate::engine::GenerationRequest;
        use crate::guidance::WindowSpec;
        let a = actuator(0.5, 0, 10);
        let meta = QosMeta::default();
        let heavy = load(10, 0.0);
        // steps=9, explicit Last(0.5) cond-only: executed shed is
        // floor(4.5)/9 = 4/9; the floor candidate Last(0.5) executes the
        // *same* 4/9, so the rewrite must not fire (analytic-vs-floor
        // comparison would claim 0.5 > 4/9 and rewrite to an identical
        // schedule, counting it as widened)
        let mut req = GenerationRequest::new("p")
            .steps(9)
            .selective(WindowSpec::last(0.5))
            .decode(false);
        let before = req.schedule.clone();
        let (_, widened) = a.rewrite(&mut req, &heavy, &meta);
        assert!(!widened, "equal-shed rewrite fired");
        assert_eq!(req.schedule, before);
        assert_eq!(req.strategy, GuidanceStrategy::CondOnly);
    }

    /// A tuned frontier over the default grammar, priced on the
    /// relabeled unit table (shed_ratio 0.5), scored with the fig5/fig6
    /// analytic shape (reuse degrades slower than cond-only).
    fn tuned_search() -> PlanSearch {
        use crate::guidance::{tune_frontier, CostTable, TuneProvenance, TunerConfig};
        let table = CostTable::proportional(1.0, &[1, 2, 4]);
        let cfg = TunerConfig { steps_buckets: vec![50], ..TunerConfig::default() };
        let prov = TuneProvenance {
            tool_version: "test".into(),
            backend: "synthetic".into(),
            preset: "synthetic".into(),
            model_fingerprint: "fp".into(),
            resolution: 8,
        };
        let manifest = tune_frontier(&cfg, &table, &prov, |schedule, strategy, steps| {
            let plan = GuidancePlan::compile(schedule, 7.5, strategy, steps)?;
            let f = plan.effective_fraction();
            let penalty = match strategy {
                GuidanceStrategy::CondOnly => 0.30,
                GuidanceStrategy::Reuse { .. } => 0.12,
            };
            Ok((1.0 - penalty * f * f).clamp(0.0, 1.0))
        })
        .unwrap();
        PlanSearch::new(manifest).unwrap()
    }

    #[test]
    fn rewrite_along_degrades_on_the_frontier() {
        use crate::engine::GenerationRequest;
        let a = actuator(0.5, 0, 10);
        let meta = QosMeta::default();
        let search = tuned_search();
        // idle: the frontier answers with the full-CFG anchor, which
        // sheds nothing — the request is untouched
        let mut req = GenerationRequest::new("p").decode(false);
        let (applied, widened, sel) = a.rewrite_along(&mut req, &load(0, 0.0), &meta, &search, 0.5);
        assert!(!widened && sel.is_none());
        assert_eq!(applied, 0.0);
        assert_eq!(req.schedule, GuidanceSchedule::none());
        // heavy load: rewritten to a frontier point that covers the
        // floor's saving demand (0.5 · 0.5 = 0.25 of full cost)
        let mut req = GenerationRequest::new("p").decode(false);
        let (applied, widened, sel) =
            a.rewrite_along(&mut req, &load(10, 0.0), &meta, &search, 0.5);
        assert!(widened, "heavy load must rewrite the default schedule");
        let sel = sel.expect("frontier point");
        assert!(sel.saving + 1e-9 >= 0.25, "selected saving {} < demanded 0.25", sel.saving);
        assert!(applied > 0.0);
        assert_eq!(req.schedule, sel.schedule);
        assert_eq!(req.strategy, sel.strategy);
        // the frontier answer is at least as good as the legacy widening:
        // same demand, but quality picked across the whole grammar
        assert!(sel.ssim > 0.9, "frontier point quality {}", sel.ssim);
    }

    #[test]
    fn rewrite_along_respects_legacy_guards() {
        use crate::engine::GenerationRequest;
        let a = actuator(0.5, 0, 10);
        let meta = QosMeta::default();
        let search = tuned_search();
        let heavy = load(10, 0.0);
        let before_counts = search.snapshot();
        // adaptive requests are never rewritten and never searched
        let mut req = GenerationRequest::new("p")
            .adaptive(crate::guidance::AdaptiveConfig::default())
            .decode(false);
        let (applied, widened, sel) = a.rewrite_along(&mut req, &heavy, &meta, &search, 0.5);
        assert!(!widened && sel.is_none());
        assert_eq!(applied, 0.0);
        // deliberate experiments (non-widenable schedules) are untouched
        let mut req = GenerationRequest::new("p")
            .with_schedule(GuidanceSchedule::Cadence { every: 4 })
            .decode(false);
        let before = req.schedule.clone();
        let (_, widened, sel) = a.rewrite_along(&mut req, &heavy, &meta, &search, 0.5);
        assert!(!widened && sel.is_none());
        assert_eq!(req.schedule, before);
        assert_eq!(search.snapshot().searches, before_counts.searches, "guards must not search");
        // a step count with no tuned bucket within 2x falls back to the
        // legacy analytic widening (counted as a planner fallback)
        let mut req = GenerationRequest::new("p").steps(500).decode(false);
        let (applied, widened, sel) = a.rewrite_along(&mut req, &heavy, &meta, &search, 0.5);
        assert!(sel.is_none(), "bucket miss must not return a frontier point");
        assert!(widened, "legacy fallback still widens under heavy load");
        assert!((applied - 0.5).abs() < 1e-12);
        assert_eq!(search.snapshot().fallbacks, before_counts.fallbacks + 1);
    }

    #[test]
    fn batch_widens_before_interactive() {
        let a = actuator(0.5, 2, 10);
        let l = load(6, 0.0);
        let batch = QosMeta { priority: Priority::Batch, ..QosMeta::default() };
        let interactive = QosMeta { priority: Priority::Interactive, ..QosMeta::default() };
        let b = a.fraction_for_request(&l, &batch);
        let s = a.fraction_for_request(&l, &QosMeta::default());
        let i = a.fraction_for_request(&l, &interactive);
        assert!(b > s && s > i, "bias ordering: batch {b}, standard {s}, interactive {i}");
    }
}
