//! The QoS feedback path: per-batch engine timings → load snapshots.
//!
//! Coordinator workers report `(batch_size, wall_time)` after every
//! engine batch; the estimator folds that into an EWMA of *per-request*
//! service time. Combined with the instantaneous queue depth this yields
//! the [`LoadSnapshot`] the admission controller and window actuator
//! consume.
//!
//! The per-request time deliberately ignores batching superlinearity
//! (a batch of 4 is cheaper than 4 singles): the estimate then over-
//! approximates service time under load, which errs on the safe side —
//! shed slightly early rather than promise deadlines we cannot keep.

use std::sync::Mutex;
use std::time::Duration;

use crate::metrics::Ewma;

/// Point-in-time view of serving load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadSnapshot {
    /// Outstanding requests (queued + in service).
    pub queue_depth: usize,
    /// EWMA per-request service time, ms (0 until the first batch).
    pub service_ms: f64,
    /// Estimated queueing delay for a new arrival, ms.
    pub est_wait_ms: f64,
    /// EWMA UNet slot occupancy of the continuous batcher in [0, 1]
    /// (0 under fixed batching or before the first iteration). Sustained
    /// occupancy near 1 means admission headroom is gone even while the
    /// queue is still shallow — the actuator treats it as a load signal
    /// alongside queue depth.
    pub slot_occupancy: f64,
}

impl LoadSnapshot {
    /// An idle, uncalibrated system.
    pub fn idle() -> LoadSnapshot {
        LoadSnapshot { queue_depth: 0, service_ms: 0.0, est_wait_ms: 0.0, slot_occupancy: 0.0 }
    }
}

/// Thread-safe EWMA service-time estimator (plus the continuous
/// batcher's slot-occupancy EWMA).
#[derive(Debug)]
pub struct ServiceEstimator {
    ewma: Mutex<Ewma>,
    occupancy: Mutex<Ewma>,
}

impl ServiceEstimator {
    pub fn new(alpha: f64) -> ServiceEstimator {
        ServiceEstimator {
            ewma: Mutex::new(Ewma::new(alpha)),
            occupancy: Mutex::new(Ewma::new(alpha)),
        }
    }

    /// Fold in one finished batch.
    pub fn observe_batch(&self, batch_size: usize, service: Duration) {
        if batch_size == 0 {
            return;
        }
        let per_request_ms = service.as_secs_f64() * 1e3 / batch_size as f64;
        self.ewma.lock().unwrap().observe(per_request_ms);
    }

    /// Fold in one continuous-batcher iteration: `slots_used` of
    /// `slot_budget` UNet slots were packed.
    pub fn observe_slots(&self, slots_used: usize, slot_budget: usize) {
        if slot_budget == 0 {
            return;
        }
        let occ = (slots_used as f64 / slot_budget as f64).clamp(0.0, 1.0);
        self.occupancy.lock().unwrap().observe(occ);
    }

    /// Current per-request service estimate, ms (0 before calibration).
    pub fn service_ms(&self) -> f64 {
        self.ewma.lock().unwrap().value_or(0.0)
    }

    /// Current slot-occupancy estimate in [0, 1] (0 before feedback).
    pub fn slot_occupancy(&self) -> f64 {
        self.occupancy.lock().unwrap().value_or(0.0)
    }

    /// Snapshot against an instantaneous queue depth. The wait estimate
    /// is `depth × service` — single-server FIFO, the conservative
    /// bound (extra workers only make it pessimistic, see module docs).
    pub fn snapshot(&self, queue_depth: usize) -> LoadSnapshot {
        let service_ms = self.service_ms();
        LoadSnapshot {
            queue_depth,
            service_ms,
            est_wait_ms: queue_depth as f64 * service_ms,
            slot_occupancy: self.slot_occupancy(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_start_is_zero() {
        let e = ServiceEstimator::new(0.2);
        assert_eq!(e.service_ms(), 0.0);
        let s = e.snapshot(5);
        assert_eq!(s.queue_depth, 5);
        assert_eq!(s.service_ms, 0.0);
        assert_eq!(s.est_wait_ms, 0.0);
    }

    #[test]
    fn batch_timing_amortized_per_request() {
        let e = ServiceEstimator::new(1.0); // no smoothing: track exactly
        e.observe_batch(4, Duration::from_millis(400));
        assert!((e.service_ms() - 100.0).abs() < 1e-9);
        e.observe_batch(1, Duration::from_millis(50));
        assert!((e.service_ms() - 50.0).abs() < 1e-9);
        // empty batches are ignored
        e.observe_batch(0, Duration::from_secs(999));
        assert!((e.service_ms() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn smoothing_converges() {
        let e = ServiceEstimator::new(0.3);
        for _ in 0..60 {
            e.observe_batch(2, Duration::from_millis(240));
        }
        assert!((e.service_ms() - 120.0).abs() < 1e-3);
    }

    #[test]
    fn wait_scales_with_depth() {
        let e = ServiceEstimator::new(1.0);
        e.observe_batch(1, Duration::from_millis(80));
        assert!((e.snapshot(3).est_wait_ms - 240.0).abs() < 1e-9);
        assert!((e.snapshot(0).est_wait_ms - 0.0).abs() < 1e-9);
    }

    #[test]
    fn idle_snapshot() {
        let s = LoadSnapshot::idle();
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.service_ms, 0.0);
        assert_eq!(s.slot_occupancy, 0.0);
    }

    #[test]
    fn slot_occupancy_tracks_iterations() {
        let e = ServiceEstimator::new(1.0); // no smoothing: track exactly
        assert_eq!(e.slot_occupancy(), 0.0);
        e.observe_slots(8, 8);
        assert!((e.slot_occupancy() - 1.0).abs() < 1e-12);
        e.observe_slots(4, 8);
        assert!((e.slot_occupancy() - 0.5).abs() < 1e-12);
        assert!((e.snapshot(3).slot_occupancy - 0.5).abs() < 1e-12);
        // degenerate budgets are ignored; over-reports clamp to 1
        e.observe_slots(5, 0);
        assert!((e.slot_occupancy() - 0.5).abs() < 1e-12);
        e.observe_slots(20, 8);
        assert!((e.slot_occupancy() - 1.0).abs() < 1e-12);
    }
}
