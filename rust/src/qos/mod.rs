//! QoS subsystem: deadline-aware admission control with selective
//! guidance as the load-shedding actuator.
//!
//! The paper shows the selective-guidance window is a continuous
//! latency/quality dial (last 20% of 50 steps → ~8.2% faster, last 50%
//! → ~20.3%, §3.3). Serving stacks usually treat such dials as static
//! per-request settings; this module closes the loop and drives the dial
//! from observed load instead:
//!
//! ```text
//!             ┌────────────── feedback: per-batch service time ─────────┐
//!             ▼                                                         │
//!   submit → [AdmissionController] → [WindowActuator] → queue → batcher → engine
//!               │ explicit 429/503        │ widens the cond-only
//!               ▼ rejection               ▼ window as load rises
//!             shed                      quality floor clamp
//! ```
//!
//! * [`AdmissionController`] — per-request deadlines, priority classes,
//!   queue-depth bounds, and *explicit* rejection instead of unbounded
//!   queuing (`Error::Rejected`, 429-style).
//! * [`WindowActuator`] — maps load (queue depth, EWMA service time,
//!   deadline slack) to a selective-guidance window fraction per request:
//!   light load runs full dual-pass CFG, heavy load widens the optimized
//!   window up to a configurable quality floor. Since the guidance-reuse
//!   lattice (DESIGN.md §8) it escalates through *strategies* too:
//!   Dual → Reuse (cached uncond eps, near-CFG quality) → CondOnly.
//! * [`ServiceEstimator`] — the feedback path, fed by per-batch timing
//!   from the coordinator workers.
//! * [`DeadlineQos`] — the default [`QosPolicy`] combining the three.
//! * [`sim`] — a deterministic discrete-event model of the serving loop
//!   that exercises the *real* policy objects without PJRT artifacts;
//!   `benches/qos_control.rs` builds its sweeps on it.
//!
//! Related work grounds the actuator choice: guidance can be confined to
//! a limited interval with little quality loss (Kynkäänniemi et al.),
//! and per-input step-level compute adaptation is effective (AdaDiff) —
//! so the window fraction is a safe knob to turn at runtime.

pub mod actuator;
pub mod admission;
pub mod feedback;
pub mod sim;

pub use actuator::{WindowActuator, SLOT_RAMP_START};
pub use admission::{expired, AdmissionController, AdmissionDecision, RejectReason};
pub use feedback::{LoadSnapshot, ServiceEstimator};
pub use sim::{simulate, simulate_trace, AppliedPlan, SimReport, SimSpec};

use std::sync::{Arc, OnceLock};
use std::time::Duration;

use crate::config::TomlDoc;
use crate::engine::GenerationRequest;
use crate::error::{Error, Result};
use crate::guidance::{GuidancePlan, GuidanceSchedule, GuidanceStrategy, WindowSpec};
use crate::metrics::{QosCounters, QosSnapshot};
use crate::telemetry::{QosTelemetry, Telemetry};

/// Request priority class. Lower classes are shed first under load:
/// each class may only occupy a fraction of the admission queue (see
/// [`Priority::queue_share`]), so when the queue fills, `Batch` traffic
/// bounces before `Standard`, and `Interactive` has the full budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    /// Best-effort background work (lowest).
    Batch,
    /// The default class.
    #[default]
    Standard,
    /// Latency-sensitive traffic (highest).
    Interactive,
}

impl Priority {
    pub fn parse(s: &str) -> Result<Priority> {
        match s.to_ascii_lowercase().as_str() {
            "interactive" | "high" => Ok(Priority::Interactive),
            "standard" | "normal" => Ok(Priority::Standard),
            "batch" | "low" => Ok(Priority::Batch),
            other => Err(Error::Config(format!("unknown priority {other:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    /// Fraction of the admission queue this class may occupy.
    pub fn queue_share(&self) -> f64 {
        match self {
            Priority::Interactive => 1.0,
            Priority::Standard => 0.75,
            Priority::Batch => 0.5,
        }
    }

    /// Bias on the load-driven actuator position: lower classes widen
    /// (give up quality) earlier than interactive traffic.
    pub fn actuator_bias(&self) -> f64 {
        match self {
            Priority::Interactive => 0.75,
            Priority::Standard => 1.0,
            Priority::Batch => 1.25,
        }
    }
}

/// Upper bound on deadlines, ms (~30 years). `Duration::from_secs_f64`
/// panics past `Duration::MAX`; every deadline entering the system is
/// validated or clamped against this bound instead.
pub const MAX_DEADLINE_MS: f64 = 1e12;

/// Per-request serving metadata, carried alongside the engine request
/// (the engine itself never sees deadlines — QoS is a serving concern).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct QosMeta {
    /// Completion deadline, measured from submission.
    pub deadline: Option<Duration>,
    pub priority: Priority,
    /// Trace span this request reports into, when telemetry is on. Set
    /// by whichever layer first sees the request (the cluster front door
    /// or the standalone coordinator) and carried through requeues so a
    /// failover keeps appending to the *same* span (DESIGN.md §12).
    pub trace: Option<u64>,
    /// Per-request opt-out from frontier plan search (DESIGN.md §16):
    /// when set, admission uses the legacy analytic widening even with a
    /// planner attached — for clients that depend on the exact legacy
    /// actuator behavior or are running schedule experiments.
    pub planner_opt_out: bool,
}

impl QosMeta {
    /// Deadline helper; `ms` is clamped into `[0, MAX_DEADLINE_MS]`
    /// (non-finite collapses to 0 — immediate expiry, never a panic).
    pub fn with_deadline_ms(ms: f64) -> QosMeta {
        let ms = if ms.is_finite() { ms.clamp(0.0, MAX_DEADLINE_MS) } else { 0.0 };
        QosMeta {
            deadline: Some(Duration::from_secs_f64(ms / 1e3)),
            priority: Priority::Standard,
            trace: None,
            planner_opt_out: false,
        }
    }

    pub fn deadline_ms(&self) -> Option<f64> {
        self.deadline.map(|d| d.as_secs_f64() * 1e3)
    }
}

/// Tuning knobs for the QoS control loop (the `[qos]` config section).
#[derive(Debug, Clone, PartialEq)]
pub struct QosConfig {
    /// Master switch: when false the coordinator runs the pre-QoS
    /// unbounded-queue behavior.
    pub enabled: bool,
    /// Outstanding-request bound; submissions beyond it are rejected
    /// (per-class shares apply, see [`Priority::queue_share`]).
    pub max_queue_depth: usize,
    /// Quality floor: the actuator never widens the cond-only window
    /// beyond this fraction (0.5 ≈ the paper's "last 50%" point).
    pub floor_fraction: f64,
    /// Queue depth at which the actuator starts widening.
    pub ramp_low: usize,
    /// Queue depth at which the actuator reaches the floor.
    pub ramp_high: usize,
    /// Deadline applied to requests that carry none (0 = none).
    pub default_deadline_ms: f64,
    /// EWMA weight for the service-time feedback.
    pub ewma_alpha: f64,
    /// UNet share of service time in the actuator's cost model
    /// (saving ≈ fraction × share / 2, §3.3 of the paper).
    pub unet_share: f64,
    /// Escalation split: actuator positions at or below this fraction of
    /// the floor serve their shed via guidance *reuse* (cached uncond
    /// eps, quality near full CFG); beyond it the actuator escalates to
    /// the paper's drop-guidance mode. 0 disables reuse, 1 never drops.
    pub reuse_threshold: f64,
    /// Refresh cadence for actuator-applied reuse windows (0 = never).
    pub reuse_refresh_every: usize,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            enabled: false,
            max_queue_depth: 64,
            floor_fraction: 0.5,
            ramp_low: 2,
            ramp_high: 16,
            default_deadline_ms: 0.0,
            ewma_alpha: 0.2,
            unet_share: 0.95,
            reuse_threshold: 0.6,
            reuse_refresh_every: 4,
        }
    }
}

impl QosConfig {
    pub fn validate(&self) -> Result<()> {
        if self.max_queue_depth == 0 {
            return Err(Error::Config("qos max_queue_depth must be >= 1".into()));
        }
        if !(0.0..=1.0).contains(&self.floor_fraction) || !self.floor_fraction.is_finite() {
            return Err(Error::Config(format!(
                "qos floor_fraction {} outside [0, 1]",
                self.floor_fraction
            )));
        }
        if self.ramp_low > self.ramp_high {
            return Err(Error::Config(format!(
                "qos ramp_low {} > ramp_high {}",
                self.ramp_low, self.ramp_high
            )));
        }
        if !(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            return Err(Error::Config(format!(
                "qos ewma_alpha {} outside (0, 1]",
                self.ewma_alpha
            )));
        }
        if !(self.unet_share > 0.0 && self.unet_share <= 1.0) {
            return Err(Error::Config(format!(
                "qos unet_share {} outside (0, 1]",
                self.unet_share
            )));
        }
        if !(0.0..=1.0).contains(&self.reuse_threshold) || !self.reuse_threshold.is_finite() {
            return Err(Error::Config(format!(
                "qos reuse_threshold {} outside [0, 1]",
                self.reuse_threshold
            )));
        }
        if !self.default_deadline_ms.is_finite()
            || self.default_deadline_ms < 0.0
            || self.default_deadline_ms > MAX_DEADLINE_MS
        {
            return Err(Error::Config(format!(
                "qos default_deadline_ms {} outside [0, {MAX_DEADLINE_MS}]",
                self.default_deadline_ms
            )));
        }
        Ok(())
    }

    /// Build from a `[qos]` TOML section (missing keys keep defaults).
    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = QosConfig::default();
        if let Some(v) = doc.get("qos", "enabled") {
            cfg.enabled =
                v.as_bool().ok_or_else(|| Error::Config("qos enabled must be bool".into()))?;
        }
        if let Some(v) = doc.get("qos", "max_queue_depth") {
            cfg.max_queue_depth = v
                .as_usize()
                .ok_or_else(|| Error::Config("qos max_queue_depth must be int".into()))?;
        }
        if let Some(v) = doc.get("qos", "floor_fraction") {
            cfg.floor_fraction = v
                .as_f64()
                .ok_or_else(|| Error::Config("qos floor_fraction must be number".into()))?;
        }
        if let Some(v) = doc.get("qos", "ramp_low") {
            cfg.ramp_low =
                v.as_usize().ok_or_else(|| Error::Config("qos ramp_low must be int".into()))?;
        }
        if let Some(v) = doc.get("qos", "ramp_high") {
            cfg.ramp_high =
                v.as_usize().ok_or_else(|| Error::Config("qos ramp_high must be int".into()))?;
        }
        if let Some(v) = doc.get("qos", "default_deadline_ms") {
            cfg.default_deadline_ms = v
                .as_f64()
                .ok_or_else(|| Error::Config("qos default_deadline_ms must be number".into()))?;
        }
        if let Some(v) = doc.get("qos", "ewma_alpha") {
            cfg.ewma_alpha =
                v.as_f64().ok_or_else(|| Error::Config("qos ewma_alpha must be number".into()))?;
        }
        if let Some(v) = doc.get("qos", "unet_share") {
            cfg.unet_share =
                v.as_f64().ok_or_else(|| Error::Config("qos unet_share must be number".into()))?;
        }
        if let Some(v) = doc.get("qos", "reuse_threshold") {
            cfg.reuse_threshold = v
                .as_f64()
                .ok_or_else(|| Error::Config("qos reuse_threshold must be number".into()))?;
        }
        if let Some(v) = doc.get("qos", "reuse_refresh_every") {
            cfg.reuse_refresh_every = v
                .as_usize()
                .ok_or_else(|| Error::Config("qos reuse_refresh_every must be int >= 0".into()))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Predicted service time at a widened window, relative to the full-CFG
/// time `base_ms`: the paper's §3.3 model, saving ≈ fraction·share/2.
pub fn service_ms_at(base_ms: f64, unet_share: f64, fraction: f64) -> f64 {
    service_ms_at_shed(base_ms, unet_share, fraction, 0.5)
}

/// [`service_ms_at`] with the shed ratio as a parameter: the analytic
/// model prices a single step at exactly half a dual (ratio 0.5); a
/// calibrated [`crate::guidance::CostTable`] supplies the *measured*
/// ratio ([`crate::guidance::CostTable::shed_ratio`]) so deadline
/// feasibility predicts in real milliseconds (DESIGN.md §15). A
/// proportional table measures exactly 0.5, making measured pricing a
/// bit-exact relabeling of the analytic path.
pub fn service_ms_at_shed(base_ms: f64, unet_share: f64, fraction: f64, shed_ratio: f64) -> f64 {
    base_ms * (1.0 - unet_share * fraction.clamp(0.0, 1.0) * shed_ratio.clamp(0.0, 1.0))
}

/// The pluggable QoS hook the coordinator consults ahead of the batcher.
///
/// Implementations must be cheap and thread-safe: `admit` runs on the
/// submitting thread with the submission lock *not* held, and
/// `observe_batch` runs on worker threads after each engine batch.
pub trait QosPolicy: Send + Sync {
    /// Admission + shaping for one request given the current outstanding
    /// depth. May mutate `req` (widen the selective-guidance window) and
    /// `meta` (apply a default deadline).
    fn admit(
        &self,
        req: &mut GenerationRequest,
        meta: &mut QosMeta,
        queue_depth: usize,
    ) -> AdmissionDecision;

    /// Feedback: one engine batch of `batch_size` requests completed in
    /// `service` wall time. `mean_fraction` is the mean selective-
    /// guidance window fraction the batch ran at, so implementations can
    /// normalize the sample back to a full-CFG baseline — otherwise the
    /// estimator would absorb the widening speedup and admission would
    /// discount it a second time.
    fn observe_batch(&self, batch_size: usize, service: Duration, mean_fraction: f64);

    /// Feedback: one admitted request expired in the queue past its
    /// deadline (it was never executed).
    fn observe_deadline_miss(&self) {}

    /// Feedback: one continuous-batcher iteration packed `slots_used` of
    /// `slot_budget` UNet slots. Sustained occupancy near 1 is a load
    /// signal alongside queue depth (the cohort is saturated even if the
    /// queue is still shallow). Default: ignored (fixed-mode policies).
    fn observe_slots(&self, _slots_used: usize, _slot_budget: usize) {}

    /// Counters for the stats endpoints.
    fn qos_snapshot(&self) -> QosSnapshot;

    /// Wire the policy into a telemetry registry (queue-depth gauge,
    /// per-class admit/reject counters, actuator-position gauge).
    /// Default: ignored, for policies that predate the telemetry layer.
    fn attach_telemetry(&self, _telemetry: &Arc<Telemetry>) {}

    /// Wire a measured [`crate::guidance::CostTable`] into the policy
    /// (DESIGN.md §15): deadline feasibility and feedback normalization
    /// switch from the analytic shed ratio (0.5) to the table's measured
    /// one. Default: ignored, for policies that price analytically.
    fn attach_cost_table(&self, _table: Arc<crate::guidance::CostTable>) {}

    /// Wire a compiled frontier [`crate::guidance::PlanSearch`] into the
    /// policy (DESIGN.md §16): the actuator degrades along the tuned
    /// Pareto frontier instead of widening analytically. Default:
    /// ignored, for policies that predate the planner.
    fn attach_planner(&self, _search: Arc<crate::guidance::PlanSearch>) {}
}

/// The default policy: deadline-aware admission + load-driven window
/// actuation + EWMA service feedback.
pub struct DeadlineQos {
    cfg: QosConfig,
    admission: AdmissionController,
    actuator: WindowActuator,
    estimator: ServiceEstimator,
    counters: QosCounters,
    telemetry: OnceLock<QosTelemetry>,
    /// Measured cost table (DESIGN.md §15); absent = analytic pricing.
    cost: OnceLock<Arc<crate::guidance::CostTable>>,
    /// Compiled Pareto frontier (DESIGN.md §16); absent = legacy
    /// analytic widening.
    planner: OnceLock<Arc<crate::guidance::PlanSearch>>,
}

impl DeadlineQos {
    pub fn new(cfg: QosConfig) -> Result<DeadlineQos> {
        cfg.validate()?;
        Ok(DeadlineQos {
            admission: AdmissionController::new(cfg.clone()),
            actuator: WindowActuator::new(cfg.clone()),
            estimator: ServiceEstimator::new(cfg.ewma_alpha),
            counters: QosCounters::new(),
            telemetry: OnceLock::new(),
            cost: OnceLock::new(),
            planner: OnceLock::new(),
            cfg,
        })
    }

    pub fn config(&self) -> &QosConfig {
        &self.cfg
    }

    pub fn counters(&self) -> &QosCounters {
        &self.counters
    }

    /// The shed ratio every ms prediction uses: the attached table's
    /// measured value, else the analytic 0.5 (one of two equal UNet
    /// passes). A proportional table measures exactly 0.5, so attaching
    /// one is a bit-exact relabeling of the analytic path.
    pub fn shed_ratio(&self) -> f64 {
        self.cost.get().map(|t| t.shed_ratio()).unwrap_or(0.5)
    }

    /// Current load view (exposed for tests and the simulator).
    pub fn load(&self, queue_depth: usize) -> LoadSnapshot {
        self.estimator.snapshot(queue_depth)
    }

    /// The attached frontier search, when one was wired in (exposed for
    /// the stats endpoints and the simulator).
    pub fn planner(&self) -> Option<&Arc<crate::guidance::PlanSearch>> {
        self.planner.get()
    }
}

impl QosPolicy for DeadlineQos {
    fn admit(
        &self,
        req: &mut GenerationRequest,
        meta: &mut QosMeta,
        queue_depth: usize,
    ) -> AdmissionDecision {
        if meta.deadline.is_none() && self.cfg.default_deadline_ms > 0.0 {
            meta.deadline = Some(Duration::from_secs_f64(self.cfg.default_deadline_ms / 1e3));
        }
        let load = self.estimator.snapshot(queue_depth);
        // Explicit client schedules are a floor, and non-`Last`
        // placements / the richer schedule kinds are deliberate
        // experiments we must not silently move (the paper shows
        // placement matters more than size, Figure 1) — so the widest
        // *executed* shed this request can actually run at, which
        // feasibility must be judged against, differs per request. Like
        // every other consumer since the plan IR, the bound is
        // plan-derived (a reuse schedule's raw fraction would promise a
        // speedup its refresh/cold-cache duals never deliver). Adaptive
        // requests execute the online controller, not the static
        // schedule: feasibility prices them at full dual cost
        // (mirroring the continuous batcher's conservative overlay) and
        // the actuator never rewrites them.
        let achievable = if req.adaptive.is_some() {
            0.0
        } else if req.schedule.widenable() {
            // widest rewrite the actuator could apply: the drop-guidance
            // floor window, compiled at this request's step count
            let floor = GuidanceSchedule::Window(WindowSpec::last(self.cfg.floor_fraction));
            let widest = GuidancePlan::compile(
                &floor,
                req.guidance_scale,
                GuidanceStrategy::CondOnly,
                req.steps,
            )
            .map(|p| p.effective_fraction())
            .unwrap_or(0.0);
            req.effective_shed().max(widest)
        } else {
            req.effective_shed()
        };
        match self.admission.decide(meta, &load, achievable, self.shed_ratio()) {
            AdmissionDecision::Reject(reason) => {
                self.counters.inc_rejected();
                if let Some(tm) = self.telemetry.get() {
                    tm.on_rejected(meta.priority.name(), reason.code());
                }
                AdmissionDecision::Reject(reason)
            }
            AdmissionDecision::Admit => {
                // escalation lattice: Dual (no window) -> Reuse (cached
                // guidance, near-CFG quality) -> CondOnly (drop). The
                // actuator owns the whole rewrite — schedule edit,
                // effective-shed floor, widenability — see
                // WindowActuator::rewrite. With a frontier attached (and
                // the request not opted out) the rewrite degrades along
                // the tuned Pareto frontier instead (DESIGN.md §16).
                let shed_before = req.effective_shed();
                let (applied, widened) = match self.planner.get() {
                    Some(search) if !meta.planner_opt_out => {
                        let (applied, widened, sel) =
                            self.actuator.rewrite_along(req, &load, meta, search, self.shed_ratio());
                        if let (Some(sel), Some(tm)) = (sel, self.telemetry.get()) {
                            tm.on_plan_search(meta.trace, sel.ssim, sel.cost_ms);
                        }
                        (applied, widened)
                    }
                    _ => self.actuator.rewrite(req, &load, meta),
                };
                self.counters.inc_admitted();
                self.counters.observe_fraction(applied, widened);
                if let Some(tm) = self.telemetry.get() {
                    tm.on_admitted(meta.priority.name(), queue_depth);
                    tm.on_actuator(meta.trace, shed_before, applied);
                }
                AdmissionDecision::Admit
            }
        }
    }

    fn observe_batch(&self, batch_size: usize, service: Duration, mean_fraction: f64) {
        // normalize to the full-CFG baseline (inverse of service_ms_at,
        // at the same shed ratio feasibility predicts with): the EWMA
        // must estimate un-widened service time, or feasibility would
        // double-count the widening speedup
        let denom = 1.0
            - self.cfg.unet_share * mean_fraction.clamp(0.0, 1.0) * self.shed_ratio();
        let baseline = Duration::from_secs_f64(service.as_secs_f64() / denom.max(0.05));
        self.estimator.observe_batch(batch_size, baseline);
    }

    fn observe_deadline_miss(&self) {
        self.counters.inc_deadline_missed();
        if let Some(tm) = self.telemetry.get() {
            tm.on_deadline_miss();
        }
    }

    fn observe_slots(&self, slots_used: usize, slot_budget: usize) {
        self.estimator.observe_slots(slots_used, slot_budget);
    }

    fn qos_snapshot(&self) -> QosSnapshot {
        self.counters.snapshot()
    }

    fn attach_telemetry(&self, telemetry: &Arc<Telemetry>) {
        let _ = self.telemetry.set(QosTelemetry::new(telemetry));
    }

    fn attach_cost_table(&self, table: Arc<crate::guidance::CostTable>) {
        let _ = self.cost.set(table);
    }

    fn attach_planner(&self, search: Arc<crate::guidance::PlanSearch>) {
        let _ = self.planner.set(search);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guidance::{GuidanceSchedule, WindowSpec};

    fn loaded_policy(cfg: QosConfig) -> DeadlineQos {
        let q = DeadlineQos::new(cfg).unwrap();
        // prime the feedback loop: 100 ms per request at full CFG
        for _ in 0..20 {
            q.observe_batch(1, Duration::from_millis(100), 0.0);
        }
        q
    }

    #[test]
    fn priority_parse_and_order() {
        assert_eq!(Priority::parse("interactive").unwrap(), Priority::Interactive);
        assert_eq!(Priority::parse("normal").unwrap(), Priority::Standard);
        assert_eq!(Priority::parse("low").unwrap(), Priority::Batch);
        assert!(Priority::parse("bogus").is_err());
        assert!(Priority::Interactive > Priority::Standard);
        assert!(Priority::Standard > Priority::Batch);
        assert_eq!(Priority::default(), Priority::Standard);
    }

    #[test]
    fn config_validation() {
        assert!(QosConfig::default().validate().is_ok());
        assert!(QosConfig { max_queue_depth: 0, ..QosConfig::default() }.validate().is_err());
        assert!(QosConfig { floor_fraction: 1.5, ..QosConfig::default() }.validate().is_err());
        assert!(QosConfig { ramp_low: 9, ramp_high: 3, ..QosConfig::default() }
            .validate()
            .is_err());
        assert!(QosConfig { ewma_alpha: 0.0, ..QosConfig::default() }.validate().is_err());
        assert!(QosConfig { unet_share: 1.5, ..QosConfig::default() }.validate().is_err());
        assert!(QosConfig { reuse_threshold: 1.5, ..QosConfig::default() }.validate().is_err());
        assert!(QosConfig { reuse_threshold: -0.1, ..QosConfig::default() }
            .validate()
            .is_err());
        assert!(QosConfig { default_deadline_ms: -1.0, ..QosConfig::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn service_model_matches_paper() {
        // full widening at 50% with pure-UNet share halves 25% of the time
        assert!((service_ms_at(100.0, 1.0, 0.5) - 75.0).abs() < 1e-9);
        assert_eq!(service_ms_at(100.0, 0.95, 0.0), 100.0);
        // clamped fraction
        assert!((service_ms_at(100.0, 1.0, 2.0) - 50.0).abs() < 1e-9);
        // the parameterized form at the analytic ratio is the same model
        assert_eq!(
            service_ms_at_shed(100.0, 0.95, 0.3, 0.5),
            service_ms_at(100.0, 0.95, 0.3)
        );
        // a measured ratio scales the saving linearly
        assert!((service_ms_at_shed(100.0, 1.0, 0.5, 0.8) - 60.0).abs() < 1e-9);
        assert!((service_ms_at_shed(100.0, 1.0, 0.5, 0.2) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn cost_table_supplies_the_measured_shed_ratio() {
        use crate::guidance::{CostTable, FallbackPolicy, StepMode};
        let q = DeadlineQos::new(QosConfig { enabled: true, ..QosConfig::default() }).unwrap();
        assert_eq!(q.shed_ratio(), 0.5, "analytic default");
        // a proportional table measures exactly 0.5: attaching it is a
        // bit-exact relabeling
        q.attach_cost_table(Arc::new(CostTable::proportional(1.0, &[1])));
        assert_eq!(q.shed_ratio(), 0.5);
        // a skewed table reprices feasibility with its measured ratio
        let q = DeadlineQos::new(QosConfig { enabled: true, ..QosConfig::default() }).unwrap();
        let mut t = CostTable::new("s", "t", 8, 1.0, FallbackPolicy::Analytic).unwrap();
        t.insert(1, StepMode::Dual, 30.0).unwrap();
        t.insert(1, StepMode::Single, 10.0).unwrap();
        q.attach_cost_table(Arc::new(t));
        assert!((q.shed_ratio() - (1.0 - 10.0 / 30.0)).abs() < 1e-12);
        // attach is write-once, mirroring attach_telemetry
        q.attach_cost_table(Arc::new(CostTable::proportional(1.0, &[1])));
        assert!((q.shed_ratio() - (1.0 - 10.0 / 30.0)).abs() < 1e-12);
    }

    #[test]
    fn admit_accepts_idle_and_sheds_when_full() {
        let q = loaded_policy(QosConfig {
            max_queue_depth: 4,
            enabled: true,
            ..QosConfig::default()
        });
        let mut req = GenerationRequest::new("p").decode(false);
        let mut meta = QosMeta::default();
        assert!(matches!(q.admit(&mut req, &mut meta, 0), AdmissionDecision::Admit));
        let mut req2 = GenerationRequest::new("p").decode(false);
        match q.admit(&mut req2, &mut meta, 4) {
            AdmissionDecision::Reject(RejectReason::QueueFull { .. }) => {}
            other => panic!("expected queue-full rejection, got {other:?}"),
        }
        let s = q.qos_snapshot();
        assert_eq!(s.admitted, 1);
        assert_eq!(s.rejected, 1);
    }

    #[test]
    fn admit_widens_window_under_load_but_respects_explicit_windows() {
        let cfg = QosConfig {
            enabled: true,
            ramp_low: 0,
            ramp_high: 4,
            floor_fraction: 0.5,
            max_queue_depth: 64,
            ..QosConfig::default()
        };
        let q = loaded_policy(cfg);
        // deep queue -> full widening to the floor
        let mut req = GenerationRequest::new("p").decode(false);
        let mut meta = QosMeta::default();
        assert!(matches!(q.admit(&mut req, &mut meta, 4), AdmissionDecision::Admit));
        assert_eq!(req.schedule, GuidanceSchedule::Window(WindowSpec::last(0.5)));
        // an explicit larger client window is kept
        let mut req = GenerationRequest::new("p").selective(WindowSpec::last(0.8)).decode(false);
        let mut meta = QosMeta::default();
        q.admit(&mut req, &mut meta, 4);
        assert_eq!(req.schedule, GuidanceSchedule::Window(WindowSpec::last(0.8)));
        // a deliberate non-Last placement is never moved
        let mut req = GenerationRequest::new("p").selective(WindowSpec::first(0.25)).decode(false);
        let mut meta = QosMeta::default();
        q.admit(&mut req, &mut meta, 4);
        assert_eq!(req.schedule, GuidanceSchedule::Window(WindowSpec::first(0.25)));
        // the richer schedule kinds are deliberate experiments too
        let mut req = GenerationRequest::new("p")
            .with_schedule(GuidanceSchedule::Interval { lo: 0.2, hi: 0.8 })
            .decode(false);
        let mut meta = QosMeta::default();
        q.admit(&mut req, &mut meta, 4);
        assert_eq!(req.schedule, GuidanceSchedule::Interval { lo: 0.2, hi: 0.8 });
    }

    #[test]
    fn admit_serves_moderate_load_via_reuse() {
        use crate::guidance::{GuidanceStrategy, ReuseKind};
        let cfg = QosConfig {
            enabled: true,
            ramp_low: 0,
            ramp_high: 4,
            floor_fraction: 0.5,
            max_queue_depth: 64,
            ..QosConfig::default()
        };
        let q = loaded_policy(cfg);
        // moderate depth: shed 0.25 <= reuse_threshold·floor = 0.3, so
        // the request keeps guidance via a (widened) reuse window
        let mut req = GenerationRequest::new("p").decode(false);
        let mut meta = QosMeta::default();
        assert!(matches!(q.admit(&mut req, &mut meta, 2), AdmissionDecision::Admit));
        assert_eq!(
            req.strategy,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 }
        );
        // window widened by (m+1)/m so the effective shed still lands
        assert!(
            (req.strategy.effective_fraction(req.schedule.last_fraction()) - 0.25).abs() < 1e-9
        );
        // heavy depth escalates to the paper's drop-guidance mode
        let mut req = GenerationRequest::new("p").decode(false);
        let mut meta = QosMeta::default();
        assert!(matches!(q.admit(&mut req, &mut meta, 4), AdmissionDecision::Admit));
        assert_eq!(req.strategy, GuidanceStrategy::CondOnly);
        assert_eq!(req.schedule, GuidanceSchedule::Window(WindowSpec::last(0.5)));
    }

    #[test]
    fn admit_never_downgrades_explicit_effective_shed() {
        use crate::guidance::GuidanceStrategy;
        let cfg = QosConfig {
            enabled: true,
            ramp_low: 0,
            ramp_high: 4,
            floor_fraction: 0.5,
            max_queue_depth: 64,
            ..QosConfig::default()
        };
        let q = loaded_policy(cfg);
        // client already sheds 0.3 (cond-only). The depth-2 plan is a
        // reuse window with effective shed 0.25 — a *larger* raw window
        // (0.3125) but less shed, so the request must stay untouched.
        let mut req = GenerationRequest::new("p")
            .selective(WindowSpec::last(0.3))
            .decode(false);
        let mut meta = QosMeta::default();
        assert!(matches!(q.admit(&mut req, &mut meta, 2), AdmissionDecision::Admit));
        assert_eq!(req.schedule, GuidanceSchedule::Window(WindowSpec::last(0.3)));
        assert_eq!(req.strategy, GuidanceStrategy::CondOnly);
    }

    #[test]
    fn adaptive_requests_admitted_but_never_rewritten() {
        use crate::guidance::AdaptiveConfig;
        let cfg = QosConfig {
            enabled: true,
            ramp_low: 0,
            ramp_high: 4,
            floor_fraction: 0.5,
            max_queue_depth: 64,
            ..QosConfig::default()
        };
        let q = loaded_policy(cfg);
        // heavy load: a static request would be widened to the floor,
        // but the controller owns adaptive requests end to end
        let mut req = GenerationRequest::new("p")
            .adaptive(AdaptiveConfig::default())
            .decode(false);
        let mut meta = QosMeta::default();
        assert!(matches!(q.admit(&mut req, &mut meta, 4), AdmissionDecision::Admit));
        assert_eq!(req.schedule, GuidanceSchedule::none());
        assert_eq!(req.strategy, crate::guidance::GuidanceStrategy::CondOnly);
        // feasibility prices adaptive at full dual cost: a deadline that
        // only fits with widening is shed instead of falsely admitted
        let mut req = GenerationRequest::new("p")
            .adaptive(AdaptiveConfig::default())
            .decode(false);
        let mut meta = QosMeta::with_deadline_ms(90.0); // service EWMA is 100 ms
        match q.admit(&mut req, &mut meta, 0) {
            AdmissionDecision::Reject(RejectReason::DeadlineInfeasible { .. }) => {}
            other => panic!("expected infeasible-deadline rejection, got {other:?}"),
        }
    }

    #[test]
    fn feedback_normalizes_widened_batches() {
        let q = DeadlineQos::new(QosConfig {
            enabled: true,
            ewma_alpha: 1.0,
            ..QosConfig::default()
        })
        .unwrap();
        // a batch served at the floor (f=0.5, u=0.95) in 76.25 ms is a
        // 100 ms request at full CFG — the estimator must see 100, or
        // feasibility would discount the widening twice
        q.observe_batch(1, Duration::from_secs_f64(0.07625), 0.5);
        assert!((q.load(0).service_ms - 100.0).abs() < 1e-6);
        // full-CFG batches pass through unchanged
        q.observe_batch(1, Duration::from_millis(100), 0.0);
        assert!((q.load(0).service_ms - 100.0).abs() < 1e-6);
    }

    #[test]
    fn slot_occupancy_feeds_the_load_snapshot() {
        let q = DeadlineQos::new(QosConfig {
            enabled: true,
            ewma_alpha: 1.0,
            ..QosConfig::default()
        })
        .unwrap();
        assert_eq!(q.load(0).slot_occupancy, 0.0);
        q.observe_slots(8, 8);
        assert!((q.load(0).slot_occupancy - 1.0).abs() < 1e-12);
        q.observe_slots(2, 8);
        assert!((q.load(0).slot_occupancy - 0.25).abs() < 1e-12);
    }

    #[test]
    fn saturated_slots_widen_even_at_shallow_depth() {
        // continuous batching: the cohort can be saturated while the
        // queue is still short — occupancy must drive the actuator too
        let q = loaded_policy(QosConfig {
            enabled: true,
            ramp_low: 4,
            ramp_high: 8,
            floor_fraction: 0.5,
            max_queue_depth: 64,
            ..QosConfig::default()
        });
        // below the depth ramp and no occupancy signal: full CFG
        let mut req = GenerationRequest::new("p").decode(false);
        let mut meta = QosMeta::default();
        assert!(matches!(q.admit(&mut req, &mut meta, 0), AdmissionDecision::Admit));
        assert_eq!(req.schedule.last_fraction(), 0.0);
        // saturate the slot budget: same depth now widens
        for _ in 0..50 {
            q.observe_slots(8, 8);
        }
        let mut req = GenerationRequest::new("p").decode(false);
        let mut meta = QosMeta::default();
        assert!(matches!(q.admit(&mut req, &mut meta, 0), AdmissionDecision::Admit));
        assert!(
            req.schedule.last_fraction() > 0.0,
            "saturated slot occupancy must widen the window"
        );
    }

    #[test]
    fn planner_attached_admission_rewrites_on_the_frontier() {
        use crate::guidance::{
            tune_frontier, CostTable, GuidancePlan, GuidanceStrategy, PlanSearch, TuneProvenance,
            TunerConfig,
        };
        let cfg = QosConfig {
            enabled: true,
            ramp_low: 0,
            ramp_high: 4,
            floor_fraction: 0.5,
            max_queue_depth: 64,
            ..QosConfig::default()
        };
        let table = CostTable::proportional(1.0, &[1, 2, 4]);
        let prov = TuneProvenance {
            tool_version: "test".into(),
            backend: "synthetic".into(),
            preset: "synthetic".into(),
            model_fingerprint: "fp".into(),
            resolution: 8,
        };
        let manifest = tune_frontier(
            &TunerConfig::default(),
            &table,
            &prov,
            |schedule, strategy, steps| {
                let f = GuidancePlan::compile(schedule, 7.5, strategy, steps)?.effective_fraction();
                let penalty = match strategy {
                    GuidanceStrategy::CondOnly => 0.30,
                    GuidanceStrategy::Reuse { .. } => 0.12,
                };
                Ok((1.0 - penalty * f * f).clamp(0.0, 1.0))
            },
        )
        .unwrap();
        let search = Arc::new(PlanSearch::new(manifest).unwrap());

        // two identical policies: one with the frontier attached
        let legacy = loaded_policy(cfg.clone());
        let planned = loaded_policy(cfg);
        planned.attach_planner(Arc::clone(&search));
        // attach is write-once, mirroring the other attach hooks
        planned.attach_planner(Arc::clone(&search));
        assert!(planned.planner().is_some() && legacy.planner().is_none());

        // heavy load: the planner answers with a frontier point whose
        // saving covers the floor demand — quality above the legacy
        // cond-only floor window
        let mut req = GenerationRequest::new("p").decode(false);
        let mut meta = QosMeta::default();
        assert!(matches!(planned.admit(&mut req, &mut meta, 4), AdmissionDecision::Admit));
        assert!(req.effective_shed() > 0.0, "heavy load must shed");
        let snap = search.snapshot();
        assert_eq!(snap.searches, 1);
        assert_eq!(snap.frontier_hits, 1);
        assert_eq!(snap.fallbacks, 0);

        // per-request opt-out: bit-exact legacy behavior, not searched
        let mut opted = GenerationRequest::new("p").decode(false);
        let mut opted_meta = QosMeta { planner_opt_out: true, ..QosMeta::default() };
        let mut legacy_req = GenerationRequest::new("p").decode(false);
        let mut legacy_meta = QosMeta::default();
        assert!(matches!(
            planned.admit(&mut opted, &mut opted_meta, 4),
            AdmissionDecision::Admit
        ));
        assert!(matches!(
            legacy.admit(&mut legacy_req, &mut legacy_meta, 4),
            AdmissionDecision::Admit
        ));
        assert_eq!(opted.schedule, legacy_req.schedule);
        assert_eq!(opted.strategy, legacy_req.strategy);
        assert_eq!(search.snapshot().searches, 1, "opted-out request must not search");
    }

    #[test]
    fn default_deadline_applied() {
        let q = loaded_policy(QosConfig {
            enabled: true,
            default_deadline_ms: 2000.0,
            ..QosConfig::default()
        });
        let mut req = GenerationRequest::new("p").decode(false);
        let mut meta = QosMeta::default();
        q.admit(&mut req, &mut meta, 0);
        assert_eq!(meta.deadline, Some(Duration::from_secs(2)));
        // an explicit deadline is not overwritten
        let mut meta = QosMeta::with_deadline_ms(500.0);
        q.admit(&mut req, &mut meta, 0);
        assert_eq!(meta.deadline, Some(Duration::from_millis(500)));
    }

    #[test]
    fn qos_meta_helpers() {
        let m = QosMeta::with_deadline_ms(250.0);
        assert!((m.deadline_ms().unwrap() - 250.0).abs() < 1e-9);
        assert_eq!(QosMeta::default().deadline_ms(), None);
        // hostile inputs clamp instead of panicking in Duration math
        assert!(QosMeta::with_deadline_ms(1e300).deadline_ms().unwrap() <= MAX_DEADLINE_MS);
        assert_eq!(QosMeta::with_deadline_ms(f64::NAN).deadline_ms(), Some(0.0));
        assert_eq!(QosMeta::with_deadline_ms(-10.0).deadline_ms(), Some(0.0));
        // config validation enforces the same bound
        assert!(QosConfig { default_deadline_ms: 1e300, ..QosConfig::default() }
            .validate()
            .is_err());
    }
}
