//! Serving metrics: timers, latency histograms, throughput counters,
//! QoS counters (rejections / deadline misses / actuator position), and
//! the per-step breakdown used by EXPERIMENTS.md §Perf.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::telemetry::Clock;

/// Simple scoped stopwatch on the telemetry [`Clock`] abstraction:
/// wall time by default, deterministic when handed a manual clock (the
/// virtual-time benches assert on metrics built from these).
#[derive(Debug, Clone)]
pub struct Stopwatch {
    clock: Clock,
    start_ns: u64,
}

impl Stopwatch {
    /// Wall-clock stopwatch (the serving default).
    pub fn start() -> Self {
        Self::with_clock(Clock::wall())
    }

    /// Stopwatch on an explicit clock (manual clocks make `elapsed`
    /// deterministic).
    pub fn with_clock(clock: Clock) -> Self {
        let start_ns = clock.now_ns();
        Stopwatch { clock, start_ns }
    }

    pub fn elapsed(&self) -> Duration {
        self.clock.since(self.start_ns)
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.clock.since_ns(self.start_ns) as f64 / 1e6
    }
}

/// Log-bucketed latency histogram (HDR-style): buckets grow geometrically
/// from 1µs to ~17min, ~3.5% relative resolution. Fixed memory, O(1)
/// record, mergeable.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

const BUCKETS_PER_OCTAVE: usize = 20;
const NUM_OCTAVES: usize = 30; // 1µs .. ~17.9min
const NUM_BUCKETS: usize = BUCKETS_PER_OCTAVE * NUM_OCTAVES;
const BASE_NS: f64 = 1_000.0; // 1µs

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: vec![0; NUM_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    fn bucket_index(ns: u64) -> usize {
        if ns == 0 {
            return 0;
        }
        let idx = ((ns as f64 / BASE_NS).log2() * BUCKETS_PER_OCTAVE as f64).floor();
        idx.clamp(0.0, (NUM_BUCKETS - 1) as f64) as usize
    }

    fn bucket_upper_ns(idx: usize) -> f64 {
        BASE_NS * 2f64.powf((idx + 1) as f64 / BUCKETS_PER_OCTAVE as f64)
    }

    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos().min(u128::from(u64::MAX)) as u64;
        self.buckets[Self::bucket_index(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.record(Duration::from_secs_f64(ms / 1e3));
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / self.count as f64 / 1e6
    }

    pub fn min_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min_ns as f64 / 1e6
        }
    }

    pub fn max_ms(&self) -> f64 {
        self.max_ns as f64 / 1e6
    }

    /// Quantile in milliseconds (upper bucket bound — conservative),
    /// clamped to the true recorded maximum: a bucket's upper bound can
    /// exceed every sample that landed in it, and reporting `p99 > max`
    /// is nonsense no dashboard should ever show.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (Self::bucket_upper_ns(i) / 1e6).min(self.max_ms());
            }
        }
        self.max_ms()
    }

    /// Total recorded time in milliseconds (Prometheus `_sum` series).
    pub fn sum_ms(&self) -> f64 {
        self.sum_ns as f64 / 1e6
    }

    /// Cumulative counts for a Prometheus `le` ladder (milliseconds):
    /// `out[i]` = samples whose *bucket* lies entirely at or below
    /// `bounds_ms[i]`. Projecting whole log-buckets keeps the result
    /// cumulative-monotone; a bucket straddling a bound counts toward
    /// the next one (conservative, like the quantiles). Bounds must be
    /// ascending.
    pub fn cumulative_le(&self, bounds_ms: &[f64]) -> Vec<u64> {
        let mut out = Vec::with_capacity(bounds_ms.len());
        let mut seen = 0u64;
        let mut idx = 0usize;
        for &bound in bounds_ms {
            let bound_ns = bound * 1e6;
            while idx < self.buckets.len() && Self::bucket_upper_ns(idx) <= bound_ns {
                seen += self.buckets[idx];
                idx += 1;
            }
            out.push(seen);
        }
        out
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2}ms p50={:.2}ms p90={:.2}ms p99={:.2}ms max={:.2}ms",
            self.count,
            self.mean_ms(),
            self.quantile_ms(0.5),
            self.quantile_ms(0.9),
            self.quantile_ms(0.99),
            self.max_ms()
        )
    }
}

/// Throughput counter over a clock window — wall time by default,
/// deterministic under a manual [`Clock`] (virtual-time benches).
#[derive(Debug)]
pub struct Throughput {
    clock: Clock,
    start_ns: u64,
    items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Self::with_clock(Clock::wall())
    }

    pub fn with_clock(clock: Clock) -> Self {
        let start_ns = clock.now_ns();
        Throughput { clock, start_ns, items: 0 }
    }

    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn items(&self) -> u64 {
        self.items
    }

    pub fn per_second(&self) -> f64 {
        let secs = self.clock.since_ns(self.start_ns) as f64 / 1e9;
        if secs <= 0.0 {
            0.0
        } else {
            self.items as f64 / secs
        }
    }
}

/// Per-step timing breakdown of one generation — who costs what inside
/// the denoising loop (feeds EXPERIMENTS.md §Perf and the microbench).
#[derive(Debug, Clone, Default)]
pub struct StepBreakdown {
    /// UNet executions (conditional pass).
    pub unet_cond_ms: f64,
    /// UNet executions (unconditional pass; 0 on optimized steps).
    pub unet_uncond_ms: f64,
    /// Eq.-1 combine.
    pub combine_ms: f64,
    /// Scheduler update (host math).
    pub scheduler_ms: f64,
    /// Literal/host transfers & everything else.
    pub overhead_ms: f64,
}

impl StepBreakdown {
    pub fn total_ms(&self) -> f64 {
        self.unet_cond_ms
            + self.unet_uncond_ms
            + self.combine_ms
            + self.scheduler_ms
            + self.overhead_ms
    }

    pub fn accumulate(&mut self, other: &StepBreakdown) {
        self.unet_cond_ms += other.unet_cond_ms;
        self.unet_uncond_ms += other.unet_uncond_ms;
        self.combine_ms += other.combine_ms;
        self.scheduler_ms += other.scheduler_ms;
        self.overhead_ms += other.overhead_ms;
    }

    /// Uniformly scaled copy — used to attribute a shared batch loop's
    /// component times across its N samples (1/N each).
    pub fn scaled(&self, factor: f64) -> StepBreakdown {
        StepBreakdown {
            unet_cond_ms: self.unet_cond_ms * factor,
            unet_uncond_ms: self.unet_uncond_ms * factor,
            combine_ms: self.combine_ms * factor,
            scheduler_ms: self.scheduler_ms * factor,
            overhead_ms: self.overhead_ms * factor,
        }
    }
}

/// Exponentially-weighted moving average — the smoothing primitive of
/// the QoS feedback loop (service-rate and actuator-position estimates).
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` in (0, 1]: weight of the newest observation.
    pub fn new(alpha: f64) -> Ewma {
        assert!(alpha > 0.0 && alpha <= 1.0, "ewma alpha {alpha} outside (0, 1]");
        Ewma { alpha, value: None }
    }

    pub fn observe(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        });
    }

    /// Current estimate (None until the first observation).
    pub fn value(&self) -> Option<f64> {
        self.value
    }

    pub fn value_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }
}

/// Lock-free QoS counters, shared between the admission path and the
/// stats endpoints. The actuator position is a gauge stored as
/// milli-units (fraction × 1000) so it fits an atomic integer.
#[derive(Debug, Default)]
pub struct QosCounters {
    admitted: AtomicU64,
    rejected: AtomicU64,
    deadline_missed: AtomicU64,
    /// How many admitted requests had their window widened by the actuator.
    shaped: AtomicU64,
    /// Last applied window fraction, in milli-units.
    actuator_milli: AtomicU64,
}

/// Point-in-time copy of [`QosCounters`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct QosSnapshot {
    pub admitted: u64,
    pub rejected: u64,
    pub deadline_missed: u64,
    pub shaped: u64,
    /// Last applied selective-guidance window fraction in [0, 1].
    pub actuator_fraction: f64,
}

impl QosCounters {
    pub fn new() -> QosCounters {
        QosCounters::default()
    }

    pub fn inc_admitted(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_deadline_missed(&self) {
        self.deadline_missed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the actuator position applied to one admitted request.
    pub fn observe_fraction(&self, fraction: f64, widened: bool) {
        if widened {
            self.shaped.fetch_add(1, Ordering::Relaxed);
        }
        let milli = (fraction.clamp(0.0, 1.0) * 1000.0).round() as u64;
        self.actuator_milli.store(milli, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> QosSnapshot {
        QosSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            deadline_missed: self.deadline_missed.load(Ordering::Relaxed),
            shaped: self.shaped.load(Ordering::Relaxed),
            actuator_fraction: self.actuator_milli.load(Ordering::Relaxed) as f64 / 1000.0,
        }
    }
}

/// Basic mean/std/percentile summary of raw f64 samples (bench harness).
#[derive(Debug, Clone)]
pub struct SampleStats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub max: f64,
}

impl SampleStats {
    pub fn from(samples: &[f64]) -> SampleStats {
        assert!(!samples.is_empty());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| sorted[((q * (n - 1) as f64).round() as usize).min(n - 1)];
        SampleStats {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: pct(0.5),
            p90: pct(0.9),
            max: sorted[n - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_ms(0.5);
        let p90 = h.quantile_ms(0.9);
        let p99 = h.quantile_ms(0.99);
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        // ~3.5% bucket resolution
        assert!((p50 - 0.5).abs() / 0.5 < 0.1, "p50={p50}");
        assert!((p90 - 0.9).abs() / 0.9 < 0.1, "p90={p90}");
    }

    #[test]
    fn histogram_mean_exact() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_millis(10));
        h.record(Duration::from_millis(20));
        assert!((h.mean_ms() - 15.0).abs() < 1e-9);
        assert!((h.min_ms() - 10.0).abs() < 1e-6);
        assert!((h.max_ms() - 20.0).abs() < 1e-6);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_millis(1));
        b.record(Duration::from_millis(100));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.max_ms() >= 100.0);
        assert!(a.min_ms() <= 1.01);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_ms(0.5), 0.0);
        assert_eq!(h.mean_ms(), 0.0);
    }

    #[test]
    fn quantile_clamped_to_max_at_bucket_boundary() {
        // 1.0 ms lands in a log bucket whose upper bound is 1.024 ms:
        // the unclamped quantile would report p99 = 1.024 > max = 1.0.
        let mut h = LatencyHistogram::new();
        h.record_ms(1.0);
        assert!((h.max_ms() - 1.0).abs() < 1e-9);
        let p99 = h.quantile_ms(0.99);
        assert!(p99 <= h.max_ms(), "p99 {p99} exceeds max {}", h.max_ms());
        assert!((p99 - 1.0).abs() < 1e-9);
        // still conservative for samples strictly inside a bucket
        let mut h = LatencyHistogram::new();
        for ms in [0.5, 5.0, 50.0] {
            h.record_ms(ms);
        }
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert!(h.quantile_ms(q) <= h.max_ms(), "q={q}");
        }
    }

    #[test]
    fn cumulative_le_is_monotone_and_conservative() {
        let mut h = LatencyHistogram::new();
        for ms in [0.3, 0.7, 3.0, 40.0, 40.0, 20_000.0] {
            h.record_ms(ms);
        }
        let bounds = [0.5, 1.0, 5.0, 50.0, 1000.0];
        let cum = h.cumulative_le(&bounds);
        assert_eq!(cum.len(), bounds.len());
        assert!(cum.windows(2).all(|w| w[0] <= w[1]), "{cum:?}");
        // every count is a lower bound on the true <=bound count, and
        // the final +Inf-style total is exact
        assert!(cum[0] <= 1);
        assert_eq!(cum[4], 5, "all but the 20s sample sit below 1s");
        assert!(*cum.last().unwrap() <= h.count());
    }

    #[test]
    fn stopwatch_and_throughput_on_manual_clock() {
        let clock = Clock::manual();
        let sw = Stopwatch::with_clock(clock.clone());
        let mut thr = Throughput::with_clock(clock.clone());
        clock.advance_ms(250.0);
        thr.add(5);
        assert_eq!(sw.elapsed_ms(), 250.0);
        assert_eq!(sw.elapsed(), Duration::from_millis(250));
        assert_eq!(thr.per_second(), 20.0);
        clock.advance_ms(750.0);
        thr.add(15);
        assert_eq!(thr.items(), 20);
        assert_eq!(thr.per_second(), 20.0);
    }

    #[test]
    fn breakdown_totals() {
        let mut b = StepBreakdown::default();
        b.unet_cond_ms = 2.0;
        b.unet_uncond_ms = 2.0;
        b.combine_ms = 0.1;
        b.scheduler_ms = 0.05;
        b.overhead_ms = 0.2;
        assert!((b.total_ms() - 4.35).abs() < 1e-12);
        let mut c = StepBreakdown::default();
        c.accumulate(&b);
        c.accumulate(&b);
        assert!((c.total_ms() - 8.7).abs() < 1e-12);
    }

    #[test]
    fn sample_stats() {
        let s = SampleStats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.add(5);
        t.add(3);
        assert_eq!(t.items(), 8);
        assert!(t.per_second() > 0.0);
    }

    #[test]
    fn ewma_tracks_mean() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.value(), None);
        assert_eq!(e.value_or(7.0), 7.0);
        e.observe(10.0); // first observation seeds the estimate exactly
        assert_eq!(e.value(), Some(10.0));
        e.observe(20.0);
        assert!((e.value().unwrap() - 15.0).abs() < 1e-12);
        // converges toward a constant signal
        for _ in 0..50 {
            e.observe(8.0);
        }
        assert!((e.value().unwrap() - 8.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn ewma_rejects_bad_alpha() {
        let _ = Ewma::new(0.0);
    }

    #[test]
    fn qos_counters_roundtrip() {
        let c = QosCounters::new();
        c.inc_admitted();
        c.inc_admitted();
        c.inc_rejected();
        c.inc_deadline_missed();
        c.observe_fraction(0.35, true);
        c.observe_fraction(0.5, false);
        let s = c.snapshot();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.deadline_missed, 1);
        assert_eq!(s.shaped, 1);
        assert!((s.actuator_fraction - 0.5).abs() < 1e-9);
    }
}
