//! Typed configuration tree + a TOML-subset file format.
//!
//! The offline registry snapshot has no `toml`/`serde`, so we parse the
//! subset we need: `[section]` headers and `key = value` pairs with
//! string / integer / float / boolean values and `#` comments — enough
//! for deployment configs like `configs/serve.toml`.

mod toml;

pub use toml::{TomlDoc, TomlValue};

use crate::coordinator::BatchMode;
use crate::error::{Error, Result};
use crate::guidance::{
    AdaptiveConfig, FallbackPolicy, GuidanceSchedule, GuidanceStrategy, SelectiveGuidancePolicy,
    WindowPosition,
};
use crate::qos::QosConfig;
use crate::scheduler::SchedulerKind;

/// How a full-CFG (dual) iteration executes its two UNet passes.
///
/// The HF pipeline fuses them into one batch-2 call; the paper's
/// optimization requires the passes to be separable. On compute-bound
/// accelerators (the paper's V100) batch-2 costs ~2x batch-1, so the
/// strategies tie at baseline and `TwoB1` wins once any window is
/// optimized; on overhead-dominated backends (CPU PJRT) `FusedB2` is
/// sublinear and the trade-off shifts — quantified by ablation A.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DualStrategy {
    /// Two independent batch-1 executions (cond, uncond) — skippable.
    TwoB1,
    /// One fused batch-2 execution [cond, uncond] — HF-pipeline style.
    FusedB2,
}

impl DualStrategy {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "two-b1" | "two_b1" | "split" => Ok(DualStrategy::TwoB1),
            "fused-b2" | "fused_b2" | "fused" => Ok(DualStrategy::FusedB2),
            other => Err(Error::Config(format!("unknown dual strategy {other:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DualStrategy::TwoB1 => "two-b1",
            DualStrategy::FusedB2 => "fused-b2",
        }
    }
}

/// Validate a signed seed value from any config surface (TOML, wire,
/// CLI, workload spec). Seeds are unsigned on the engine side; a
/// negative literal used to wrap silently through `as u64`, turning a
/// typo into a valid-looking 18-quintillion seed. Every surface now
/// routes through this one check so the rejection text matches.
pub fn seed_from_i64(v: i64) -> std::result::Result<u64, String> {
    if v < 0 {
        return Err(format!("seed must be >= 0, got {v}"));
    }
    Ok(v as u64)
}

/// Engine-level defaults applied to requests that don't override them.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Denoising iterations (the paper uses 50).
    pub steps: usize,
    /// Scheduler driving the loop (paper/HF default: PNDM).
    pub scheduler: SchedulerKind,
    /// Classifier-free guidance scale (SD default 7.5).
    pub guidance_scale: f32,
    /// Default guidance schedule (none = full CFG baseline). Windows
    /// come from `[engine] window_fraction`/`window_position`; the
    /// richer kinds from the `[guidance]` section
    /// (`segments`/`interval`/`cadence`).
    pub schedule: GuidanceSchedule,
    /// What optimized-schedule iterations execute (DESIGN.md §8): drop
    /// guidance (the paper) or reuse a cached/extrapolated uncond eps.
    pub guidance_strategy: GuidanceStrategy,
    /// Online adaptive skip controller applied by default (`[guidance]
    /// adaptive = true`); supersedes the static schedule.
    pub adaptive: Option<AdaptiveConfig>,
    /// Whether to run the VAE decode + return images.
    pub decode_images: bool,
    /// Base seed for latent noise streams.
    pub seed: u64,
    /// Dual-pass execution strategy (ablation A).
    pub dual_strategy: DualStrategy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            steps: 50,
            scheduler: SchedulerKind::Pndm,
            guidance_scale: 7.5,
            schedule: GuidanceSchedule::none(),
            guidance_strategy: GuidanceStrategy::CondOnly,
            adaptive: None,
            decode_images: true,
            seed: 0,
            dual_strategy: DualStrategy::TwoB1,
        }
    }
}

impl EngineConfig {
    pub fn validate(&self) -> Result<()> {
        if self.steps == 0 || self.steps > 1000 {
            return Err(Error::Config(format!("steps {} outside [1, 1000]", self.steps)));
        }
        SelectiveGuidancePolicy::with_schedule(
            self.schedule.clone(),
            self.guidance_scale,
            self.guidance_strategy,
        )?;
        if let Some(a) = &self.adaptive {
            a.validate()?;
            // mirror GenerationRequest::validate: the controller
            // supersedes the static schedule, so both together is a
            // config conflict, not a silent precedence rule
            if self.schedule != GuidanceSchedule::none() {
                return Err(Error::Config(
                    "guidance adaptive supersedes the static schedule — configure one, \
                     not both"
                        .into(),
                ));
            }
        }
        Ok(())
    }

    /// Build from the `[engine]` + `[guidance]` TOML sections (missing
    /// keys keep defaults).
    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = EngineConfig::default();
        if let Some(v) = doc.get("engine", "steps") {
            cfg.steps = v.as_usize().ok_or_else(|| Error::Config("steps must be int".into()))?;
        }
        if let Some(v) = doc.get("engine", "scheduler") {
            cfg.scheduler = SchedulerKind::parse(
                v.as_str().ok_or_else(|| Error::Config("scheduler must be string".into()))?,
            )?;
        }
        if let Some(v) = doc.get("engine", "guidance_scale") {
            cfg.guidance_scale =
                v.as_f64().ok_or_else(|| Error::Config("guidance_scale must be number".into()))?
                    as f32;
        }
        // ---- the schedule surface ([engine] window + [guidance]
        // segments/interval/cadence): type extraction only — mutual
        // exclusion and dispatch live in GuidanceSchedule::from_parts,
        // shared with the CLI and wire surfaces
        let position = match doc.get("engine", "window_position") {
            Some(p) => Some(WindowPosition::parse(p.as_str().ok_or_else(|| {
                Error::Config("window_position must be string".into())
            })?)?),
            None => None,
        };
        // window_position alone still selects a (zero-width) window so a
        // typo'd combination is validated instead of silently ignored
        let window = match doc.get("engine", "window_fraction") {
            Some(v) => {
                let f = v
                    .as_f64()
                    .ok_or_else(|| Error::Config("window_fraction must be number".into()))?;
                Some((f, position.unwrap_or(WindowPosition::Last)))
            }
            None => position.map(|p| (0.0, p)),
        };
        let segments = match doc.get("guidance", "segments") {
            Some(v) => {
                Some(v.as_str().ok_or_else(|| Error::Config("segments must be string".into()))?)
            }
            None => None,
        };
        let interval = match doc.get("guidance", "interval") {
            Some(v) => {
                Some(v.as_str().ok_or_else(|| Error::Config("interval must be string".into()))?)
            }
            None => None,
        };
        let cadence = match doc.get("guidance", "cadence") {
            Some(v) => Some(
                v.as_usize().ok_or_else(|| Error::Config("cadence must be int >= 1".into()))?,
            ),
            None => None,
        };
        if let Some(s) = GuidanceSchedule::from_parts(window, segments, interval, cadence)? {
            cfg.schedule = s;
        }
        cfg.adaptive = adaptive_from_toml(doc)?;
        if let Some(v) = doc.get("engine", "guidance_strategy") {
            let name = v
                .as_str()
                .ok_or_else(|| Error::Config("guidance_strategy must be string".into()))?;
            let refresh = match doc.get("engine", "refresh_every") {
                Some(r) => r
                    .as_usize()
                    .ok_or_else(|| Error::Config("refresh_every must be int >= 0".into()))?,
                None => 0,
            };
            cfg.guidance_strategy = GuidanceStrategy::parse(name, refresh)?;
        } else if doc.get("engine", "refresh_every").is_some() {
            // mirror the wire protocol: a cadence without a strategy is
            // an operator error, not a silent no-op
            return Err(Error::Config("refresh_every requires guidance_strategy".into()));
        }
        if let Some(v) = doc.get("engine", "decode_images") {
            cfg.decode_images =
                v.as_bool().ok_or_else(|| Error::Config("decode_images must be bool".into()))?;
        }
        if let Some(v) = doc.get("engine", "seed") {
            let raw = v.as_i64().ok_or_else(|| Error::Config("seed must be int".into()))?;
            cfg.seed = seed_from_i64(raw).map_err(Error::Config)?;
        }
        if let Some(v) = doc.get("engine", "dual_strategy") {
            cfg.dual_strategy = DualStrategy::parse(
                v.as_str().ok_or_else(|| Error::Config("dual_strategy must be string".into()))?,
            )?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Parse the `[guidance]` adaptive-controller keys: `adaptive = true`
/// enables the controller, the `adaptive_*` knobs refine it. Knobs
/// without the switch are an operator error, not a silent no-op
/// (mirroring the `refresh_every` rule).
fn adaptive_from_toml(doc: &TomlDoc) -> Result<Option<AdaptiveConfig>> {
    let enabled = match doc.get("guidance", "adaptive") {
        Some(v) => v
            .as_bool()
            .ok_or_else(|| Error::Config("guidance adaptive must be bool".into()))?,
        None => false,
    };
    let knobs = [
        "adaptive_threshold",
        "adaptive_patience",
        "adaptive_min_dual_fraction",
        "adaptive_probe_every",
    ];
    if !enabled {
        if let Some(orphan) = knobs.iter().find(|&&k| doc.get("guidance", k).is_some()) {
            return Err(Error::Config(format!("{orphan} requires adaptive = true")));
        }
        return Ok(None);
    }
    let mut a = AdaptiveConfig::default();
    if let Some(v) = doc.get("guidance", "adaptive_threshold") {
        a.threshold = v
            .as_f64()
            .ok_or_else(|| Error::Config("adaptive_threshold must be number".into()))?;
    }
    if let Some(v) = doc.get("guidance", "adaptive_patience") {
        a.patience = v
            .as_usize()
            .ok_or_else(|| Error::Config("adaptive_patience must be int".into()))?;
    }
    if let Some(v) = doc.get("guidance", "adaptive_min_dual_fraction") {
        a.min_dual_fraction = v
            .as_f64()
            .ok_or_else(|| Error::Config("adaptive_min_dual_fraction must be number".into()))?;
    }
    if let Some(v) = doc.get("guidance", "adaptive_probe_every") {
        a.probe_every = v
            .as_usize()
            .ok_or_else(|| Error::Config("adaptive_probe_every must be int".into()))?;
    }
    a.validate()?;
    Ok(Some(a))
}

/// Server front-end settings.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub bind: String,
    /// Batch composition: classic fixed batches or continuous
    /// (iteration-level) batching under a UNet slot budget (DESIGN.md §9).
    pub mode: BatchMode,
    pub max_batch: usize,
    /// Continuous mode: UNet slots packed per iteration (a dual step
    /// costs 2, single-pass steps cost 1). Must be >= 2.
    pub slot_budget: usize,
    pub workers: usize,
    /// Batching window: how long the fixed batcher waits to fill a batch.
    pub batch_wait_ms: u64,
    /// Default preview cadence for streamed (`"stream": true`) requests
    /// that don't set their own `preview_every`: decode + push an
    /// intermediate preview frame every N denoising steps. 0 disables
    /// previews (progress events still flow).
    pub preview_every: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            bind: "127.0.0.1:7878".into(),
            mode: BatchMode::Fixed,
            max_batch: 4,
            slot_budget: 8,
            workers: 1,
            batch_wait_ms: 2,
            preview_every: 0,
        }
    }
}

impl ServerConfig {
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 || self.workers == 0 {
            return Err(Error::Config("max_batch and workers must be >= 1".into()));
        }
        // the bound only binds when the knob is actually read; a fixed-mode
        // config carrying a stale slot_budget must not fail startup
        if self.mode == BatchMode::Continuous && self.slot_budget < 2 {
            return Err(Error::Config(format!(
                "slot_budget {} must be >= 2 (a dual-guidance step costs 2 slots)",
                self.slot_budget
            )));
        }
        Ok(())
    }

    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = ServerConfig::default();
        if let Some(v) = doc.get("server", "bind") {
            cfg.bind = v
                .as_str()
                .ok_or_else(|| Error::Config("bind must be string".into()))?
                .to_string();
        }
        if let Some(v) = doc.get("server", "mode") {
            cfg.mode = BatchMode::parse(
                v.as_str().ok_or_else(|| Error::Config("mode must be string".into()))?,
            )?;
        }
        if let Some(v) = doc.get("server", "max_batch") {
            cfg.max_batch =
                v.as_usize().ok_or_else(|| Error::Config("max_batch must be int".into()))?;
        }
        if let Some(v) = doc.get("server", "slot_budget") {
            cfg.slot_budget =
                v.as_usize().ok_or_else(|| Error::Config("slot_budget must be int".into()))?;
        }
        if let Some(v) = doc.get("server", "workers") {
            cfg.workers =
                v.as_usize().ok_or_else(|| Error::Config("workers must be int".into()))?;
        }
        if let Some(v) = doc.get("server", "batch_wait_ms") {
            cfg.batch_wait_ms =
                v.as_i64().ok_or_else(|| Error::Config("batch_wait_ms must be int".into()))?
                    as u64;
        }
        if let Some(v) = doc.get("server", "preview_every") {
            cfg.preview_every = v
                .as_usize()
                .ok_or_else(|| Error::Config("preview_every must be int >= 0".into()))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// `[telemetry]` section: the metrics registry + trace-span layer
/// (DESIGN.md §12). Enabled by default — telemetry is observation-only
/// and near-zero-cost, so opting *out* is the explicit act.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// Master switch. When false the serving stack attaches no sinks at
    /// all and `{"op":"metrics"}`/`{"op":"trace"}` answer an error.
    pub enabled: bool,
    /// Trace ring-buffer capacity (spans kept for `{"op":"trace"}`).
    pub trace_capacity: usize,
    /// Optional plain-HTTP Prometheus scrape bind (`host:port`) —
    /// `serve --metrics-addr` overrides it.
    pub metrics_addr: Option<String>,
    /// Optional path: retained trace spans are exported as JSONL when
    /// the server shuts down.
    pub trace_jsonl: Option<String>,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            enabled: true,
            trace_capacity: crate::telemetry::DEFAULT_TRACE_CAPACITY,
            metrics_addr: None,
            trace_jsonl: None,
        }
    }
}

impl TelemetryConfig {
    pub fn validate(&self) -> Result<()> {
        if self.enabled && self.trace_capacity == 0 {
            return Err(Error::Config("telemetry trace_capacity must be >= 1".into()));
        }
        Ok(())
    }

    /// Build from the `[telemetry]` TOML section (missing keys keep
    /// defaults). Knobs under `enabled = false` are an operator error,
    /// not a silent no-op (mirroring the `[qos]`/`[guidance]` rule).
    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = TelemetryConfig::default();
        if let Some(v) = doc.get("telemetry", "enabled") {
            cfg.enabled = v
                .as_bool()
                .ok_or_else(|| Error::Config("telemetry enabled must be bool".into()))?;
        }
        let knobs = ["trace_capacity", "metrics_addr", "trace_jsonl"];
        if !cfg.enabled {
            if let Some(orphan) = knobs.iter().find(|&&k| doc.get("telemetry", k).is_some()) {
                return Err(Error::Config(format!(
                    "telemetry {orphan} requires enabled = true"
                )));
            }
            return Ok(cfg);
        }
        if let Some(v) = doc.get("telemetry", "trace_capacity") {
            cfg.trace_capacity = v
                .as_usize()
                .ok_or_else(|| Error::Config("trace_capacity must be int".into()))?;
        }
        if let Some(v) = doc.get("telemetry", "metrics_addr") {
            cfg.metrics_addr = Some(
                v.as_str()
                    .ok_or_else(|| Error::Config("metrics_addr must be string".into()))?
                    .to_string(),
            );
        }
        if let Some(v) = doc.get("telemetry", "trace_jsonl") {
            cfg.trace_jsonl = Some(
                v.as_str()
                    .ok_or_else(|| Error::Config("trace_jsonl must be string".into()))?
                    .to_string(),
            );
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// The telemetry hub this config describes: `Some(enabled hub)` or
    /// `None` — layers given no hub attach no sinks and pay nothing.
    pub fn build(&self) -> Option<std::sync::Arc<crate::telemetry::Telemetry>> {
        if !self.enabled {
            return None;
        }
        Some(crate::telemetry::Telemetry::with_clock(
            self.trace_capacity,
            crate::telemetry::Clock::wall(),
        ))
    }
}

/// `[cost]` section: the measured-cost plan model (DESIGN.md §15).
/// Off by default — without a cost source every layer keeps pricing in
/// analytic UNet-eval units.
#[derive(Debug, Clone, PartialEq)]
pub struct CostConfig {
    /// Path to a sealed cost manifest (`sgd-serve calibrate --out …`).
    /// Validated against the loaded runtime at startup: backend, preset,
    /// model fingerprint and resolution must all match.
    pub table_path: Option<String>,
    /// Calibrate the loaded runtime at startup instead of loading a
    /// manifest (the fast grid; mutually exclusive with `table_path`).
    pub calibrate_on_start: bool,
    /// Continuous-batcher admission budget in measured milliseconds per
    /// iteration. 0 keeps the `slot_budget` unit currency.
    pub budget_ms: f64,
    /// What an uncovered (batch, mode) lookup does: price analytically
    /// and count, or refuse the table at startup.
    pub fallback: FallbackPolicy,
}

impl Default for CostConfig {
    fn default() -> Self {
        CostConfig {
            table_path: None,
            calibrate_on_start: false,
            budget_ms: 0.0,
            fallback: FallbackPolicy::Analytic,
        }
    }
}

impl CostConfig {
    /// Is any cost source configured?
    pub fn enabled(&self) -> bool {
        self.table_path.is_some() || self.calibrate_on_start
    }

    pub fn validate(&self) -> Result<()> {
        if self.table_path.is_some() && self.calibrate_on_start {
            return Err(Error::Config(
                "cost table_path and calibrate_on_start are mutually exclusive — \
                 configure exactly one table source"
                    .into(),
            ));
        }
        if !self.budget_ms.is_finite() || self.budget_ms < 0.0 {
            return Err(Error::Config(format!(
                "cost budget_ms {} must be finite and >= 0",
                self.budget_ms
            )));
        }
        if self.budget_ms > 0.0 && !self.enabled() {
            return Err(Error::Config(
                "cost budget_ms requires a table source (table_path or calibrate_on_start)"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Build from the `[cost]` TOML section. Knobs without a table
    /// source are an operator error, not a silent no-op (mirroring the
    /// `[qos]`/`[telemetry]` rule).
    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = CostConfig::default();
        if let Some(v) = doc.get("cost", "table_path") {
            cfg.table_path = Some(
                v.as_str()
                    .ok_or_else(|| Error::Config("cost table_path must be string".into()))?
                    .to_string(),
            );
        }
        if let Some(v) = doc.get("cost", "calibrate_on_start") {
            cfg.calibrate_on_start = v
                .as_bool()
                .ok_or_else(|| Error::Config("cost calibrate_on_start must be bool".into()))?;
        }
        let knobs = ["budget_ms", "fallback"];
        if !cfg.enabled() {
            if let Some(orphan) = knobs.iter().find(|&&k| doc.get("cost", k).is_some()) {
                return Err(Error::Config(format!(
                    "cost {orphan} requires a table source (table_path or calibrate_on_start)"
                )));
            }
            return Ok(cfg);
        }
        if let Some(v) = doc.get("cost", "budget_ms") {
            cfg.budget_ms = v
                .as_f64()
                .ok_or_else(|| Error::Config("cost budget_ms must be a number".into()))?;
        }
        if let Some(v) = doc.get("cost", "fallback") {
            cfg.fallback = FallbackPolicy::parse(
                v.as_str()
                    .ok_or_else(|| Error::Config("cost fallback must be string".into()))?,
            )?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// `[planner]` section: the deadline-optimal frontier plan search
/// (DESIGN.md §16). Off by default — without a frontier source the QoS
/// actuator keeps the legacy analytic widening.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PlannerConfig {
    /// Path to a sealed frontier manifest (`sgd-serve tune --out …`).
    /// Validated against the loaded runtime at startup: backend, preset,
    /// model fingerprint and resolution must all match.
    pub frontier_path: Option<String>,
    /// Tune the loaded runtime at startup instead of loading a manifest
    /// (mutually exclusive with `frontier_path`). Needs a `[cost]` table
    /// source: the sweep prices candidates in measured milliseconds.
    pub tune_on_start: bool,
    /// Use the reduced fast sweep when tuning on start.
    pub fast: bool,
}

impl PlannerConfig {
    /// Is any frontier source configured?
    pub fn enabled(&self) -> bool {
        self.frontier_path.is_some() || self.tune_on_start
    }

    pub fn validate(&self) -> Result<()> {
        if self.frontier_path.is_some() && self.tune_on_start {
            return Err(Error::Config(
                "planner frontier_path and tune_on_start are mutually exclusive — \
                 configure exactly one frontier source"
                    .into(),
            ));
        }
        if self.fast && !self.tune_on_start {
            return Err(Error::Config(
                "planner fast requires tune_on_start = true (a loaded manifest carries \
                 its own sweep)"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Build from the `[planner]` TOML section. Knobs without a frontier
    /// source are an operator error, not a silent no-op (mirroring the
    /// `[cost]`/`[telemetry]` rule).
    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = PlannerConfig::default();
        if let Some(v) = doc.get("planner", "frontier_path") {
            cfg.frontier_path = Some(
                v.as_str()
                    .ok_or_else(|| Error::Config("planner frontier_path must be string".into()))?
                    .to_string(),
            );
        }
        if let Some(v) = doc.get("planner", "tune_on_start") {
            cfg.tune_on_start = v
                .as_bool()
                .ok_or_else(|| Error::Config("planner tune_on_start must be bool".into()))?;
        }
        let knobs = ["fast"];
        if !cfg.enabled() {
            if let Some(orphan) = knobs.iter().find(|&&k| doc.get("planner", k).is_some()) {
                return Err(Error::Config(format!(
                    "planner {orphan} requires a frontier source (frontier_path or \
                     tune_on_start)"
                )));
            }
            return Ok(cfg);
        }
        if let Some(v) = doc.get("planner", "fast") {
            cfg.fast =
                v.as_bool().ok_or_else(|| Error::Config("planner fast must be bool".into()))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Complete deployment configuration (engine + server + qos + cluster +
/// telemetry + artifacts).
#[derive(Debug, Clone, Default)]
pub struct RunConfig {
    pub artifacts_dir: Option<String>,
    pub engine: EngineConfig,
    pub server: ServerConfig,
    /// `[qos]` section — disabled by default (see `qos::QosConfig`).
    pub qos: QosConfig,
    /// `[cluster]` section — absent by default (single coordinator); see
    /// `cluster::ClusterConfig`. Replicas default to the `[server]`
    /// shape, overridden per replica by `[cluster.replica.N]` sections.
    pub cluster: Option<crate::cluster::ClusterConfig>,
    /// `[telemetry]` section — enabled by default (see
    /// [`TelemetryConfig`]).
    pub telemetry: TelemetryConfig,
    /// `[cache]` section — all tiers off by default (see
    /// `cache::CacheConfig`): exact-match request cache, in-flight
    /// dedup, and the cross-request shared uncond tier.
    pub cache: crate::cache::CacheConfig,
    /// `[cost]` section — off by default (see [`CostConfig`]): the
    /// measured-cost table source, ms admission budget and fallback
    /// policy.
    pub cost: CostConfig,
    /// `[planner]` section — off by default (see [`PlannerConfig`]): the
    /// Pareto frontier source for deadline-optimal plan search at
    /// admission (DESIGN.md §16).
    pub planner: PlannerConfig,
    /// `[workload]` section — absent by default. A deployment file can
    /// carry its evaluation traffic shape (arrival process, img2img
    /// strength, variation fan-out, popularity skew) next to the
    /// serving config; see [`crate::workload::WorkloadSpec::from_toml`].
    pub workload: Option<crate::workload::WorkloadSpec>,
}

impl RunConfig {
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(format!("reading {}", path.display()), e))?;
        Self::from_str(&text)
    }

    pub fn from_str(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let artifacts_dir = doc
            .get("model", "artifacts")
            .and_then(|v| v.as_str().map(String::from));
        let server = ServerConfig::from_toml(&doc)?;
        let cluster = crate::cluster::ClusterConfig::from_toml(&doc, &server)?;
        let engine = EngineConfig::from_toml(&doc)?;
        let workload = crate::workload::WorkloadSpec::from_toml(&doc, &engine)?;
        let cost = CostConfig::from_toml(&doc)?;
        let planner = PlannerConfig::from_toml(&doc)?;
        if planner.tune_on_start && !cost.enabled() {
            return Err(Error::Config(
                "planner tune_on_start requires a [cost] table source (table_path or \
                 calibrate_on_start) to price the sweep in milliseconds"
                    .into(),
            ));
        }
        Ok(RunConfig {
            artifacts_dir,
            engine,
            server,
            qos: QosConfig::from_toml(&doc)?,
            cluster,
            telemetry: TelemetryConfig::from_toml(&doc)?,
            cache: crate::cache::CacheConfig::from_toml(&doc)?,
            cost,
            planner,
            workload,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guidance::WindowSpec;

    const SAMPLE: &str = r#"
# sample deployment config
[model]
artifacts = "artifacts/tiny"

[engine]
steps = 50
scheduler = "ddim"
guidance_scale = 7.5
window_fraction = 0.2
window_position = "last"
decode_images = true
seed = 42

[server]
bind = "0.0.0.0:9000"
max_batch = 4
workers = 2
batch_wait_ms = 5

[qos]
enabled = true
max_queue_depth = 32
floor_fraction = 0.4
ramp_low = 1
ramp_high = 8
default_deadline_ms = 2500.0
ewma_alpha = 0.3
"#;

    #[test]
    fn parse_full_config() {
        let cfg = RunConfig::from_str(SAMPLE).unwrap();
        assert_eq!(cfg.artifacts_dir.as_deref(), Some("artifacts/tiny"));
        assert_eq!(cfg.engine.steps, 50);
        assert_eq!(cfg.engine.scheduler, SchedulerKind::Ddim);
        assert_eq!(cfg.engine.schedule, GuidanceSchedule::Window(WindowSpec::last(0.2)));
        assert_eq!(cfg.engine.seed, 42);
        assert_eq!(cfg.server.bind, "0.0.0.0:9000");
        assert_eq!(cfg.server.workers, 2);
        assert!(cfg.qos.enabled);
        assert_eq!(cfg.qos.max_queue_depth, 32);
        assert!((cfg.qos.floor_fraction - 0.4).abs() < 1e-12);
        assert_eq!(cfg.qos.ramp_low, 1);
        assert_eq!(cfg.qos.ramp_high, 8);
        assert!((cfg.qos.default_deadline_ms - 2500.0).abs() < 1e-12);
        assert!((cfg.qos.ewma_alpha - 0.3).abs() < 1e-12);
    }

    #[test]
    fn defaults_when_sections_missing() {
        let cfg = RunConfig::from_str("").unwrap();
        assert_eq!(cfg.engine.steps, 50);
        assert_eq!(cfg.engine.scheduler, SchedulerKind::Pndm);
        assert_eq!(cfg.engine.schedule, GuidanceSchedule::none());
        assert_eq!(cfg.engine.adaptive, None);
        assert_eq!(cfg.server.max_batch, 4);
        assert!(!cfg.qos.enabled);
        assert_eq!(cfg.qos, QosConfig::default());
        assert!(cfg.cluster.is_none());
    }

    #[test]
    fn invalid_qos_section_rejected() {
        assert!(RunConfig::from_str("[qos]\nmax_queue_depth = 0\n").is_err());
        assert!(RunConfig::from_str("[qos]\nfloor_fraction = 1.5\n").is_err());
        assert!(RunConfig::from_str("[qos]\nramp_low = 9\nramp_high = 3\n").is_err());
        assert!(RunConfig::from_str("[qos]\newma_alpha = 0.0\n").is_err());
        assert!(RunConfig::from_str("[qos]\nenabled = \"yes\"\n").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(RunConfig::from_str("[engine]\nsteps = 0\n").is_err());
        assert!(RunConfig::from_str("[engine]\nscheduler = \"bogus\"\n").is_err());
        assert!(RunConfig::from_str("[engine]\nwindow_fraction = 1.5\n").is_err());
        assert!(RunConfig::from_str("[server]\nworkers = 0\n").is_err());
        assert!(RunConfig::from_str("[engine]\nwindow_fraction = 0.2\nwindow_position = \"bogus\"\n").is_err());
    }

    #[test]
    fn batch_mode_parse() {
        // default: the classic fixed batcher
        let cfg = RunConfig::from_str("").unwrap();
        assert_eq!(cfg.server.mode, BatchMode::Fixed);
        assert_eq!(cfg.server.slot_budget, 8);
        let cfg = RunConfig::from_str("[server]\nmode = \"continuous\"\nslot_budget = 12\n")
            .unwrap();
        assert_eq!(cfg.server.mode, BatchMode::Continuous);
        assert_eq!(cfg.server.slot_budget, 12);
        assert!(RunConfig::from_str("[server]\nmode = \"bogus\"\n").is_err());
        // a slot budget below one dual step can never admit CFG traffic —
        // but the bound only applies when continuous mode will read it
        assert!(
            RunConfig::from_str("[server]\nmode = \"continuous\"\nslot_budget = 1\n").is_err()
        );
        assert!(RunConfig::from_str("[server]\nslot_budget = 1\n").is_ok());
        assert!(RunConfig::from_str("[server]\nslot_budget = \"many\"\n").is_err());
    }

    #[test]
    fn guidance_strategy_parse() {
        use crate::guidance::ReuseKind;
        let cfg = RunConfig::from_str(
            "[engine]\nguidance_strategy = \"hold\"\nrefresh_every = 4\n",
        )
        .unwrap();
        assert_eq!(
            cfg.engine.guidance_strategy,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 }
        );
        let cfg = RunConfig::from_str("[engine]\nguidance_strategy = \"extrapolate\"\n").unwrap();
        assert_eq!(
            cfg.engine.guidance_strategy,
            GuidanceStrategy::Reuse { kind: ReuseKind::Extrapolate, refresh_every: 0 }
        );
        // default: the paper's drop-guidance optimization
        let cfg = RunConfig::from_str("").unwrap();
        assert_eq!(cfg.engine.guidance_strategy, GuidanceStrategy::CondOnly);
        assert!(RunConfig::from_str("[engine]\nguidance_strategy = \"bogus\"\n").is_err());
        assert!(RunConfig::from_str(
            "[engine]\nguidance_strategy = \"hold\"\nrefresh_every = -2\n"
        )
        .is_err());
        // a cadence without a strategy is an error, not a silent no-op
        assert!(RunConfig::from_str("[engine]\nrefresh_every = 4\n").is_err());
    }

    #[test]
    fn guidance_schedule_section() {
        use crate::guidance::Segment;
        let cfg = RunConfig::from_str("[guidance]\ninterval = \"0.25-0.75\"\n").unwrap();
        assert_eq!(cfg.engine.schedule, GuidanceSchedule::Interval { lo: 0.25, hi: 0.75 });
        let cfg = RunConfig::from_str("[guidance]\ncadence = 4\n").unwrap();
        assert_eq!(cfg.engine.schedule, GuidanceSchedule::Cadence { every: 4 });
        let cfg =
            RunConfig::from_str("[guidance]\nsegments = \"0.0-0.2,!0.4-0.6,0.8-1.0\"\n").unwrap();
        assert_eq!(
            cfg.engine.schedule,
            GuidanceSchedule::Segments(vec![
                Segment::optimized(0.0, 0.2),
                Segment::dual(0.4, 0.6),
                Segment::optimized(0.8, 1.0),
            ])
        );
        // schedules are mutually exclusive — across sections too
        assert!(RunConfig::from_str("[guidance]\ninterval = \"0.2-0.8\"\ncadence = 4\n").is_err());
        assert!(RunConfig::from_str(
            "[engine]\nwindow_fraction = 0.2\n[guidance]\ncadence = 4\n"
        )
        .is_err());
        // invalid values are structured config errors
        assert!(RunConfig::from_str("[guidance]\ncadence = 0\n").is_err());
        assert!(RunConfig::from_str("[guidance]\ninterval = \"0.8-0.2\"\n").is_err());
        assert!(RunConfig::from_str("[guidance]\nsegments = \"nope\"\n").is_err());
        // window_position alone is validated, not silently dropped
        assert!(RunConfig::from_str("[engine]\nwindow_position = \"bogus\"\n").is_err());
        let cfg = RunConfig::from_str("[engine]\nwindow_position = \"first\"\n").unwrap();
        assert_eq!(cfg.engine.schedule, GuidanceSchedule::Window(WindowSpec::first(0.0)));
        assert!(RunConfig::from_str(
            "[engine]\nwindow_position = \"first\"\n[guidance]\ncadence = 4\n"
        )
        .is_err());
    }

    #[test]
    fn window_position_offset_round_trips_through_config() {
        use crate::guidance::WindowPosition;
        let cfg = RunConfig::from_str(
            "[engine]\nwindow_fraction = 0.25\nwindow_position = \"offset(0.25)\"\n",
        )
        .unwrap();
        assert_eq!(
            cfg.engine.schedule,
            GuidanceSchedule::Window(WindowSpec::at_offset(0.25, 0.25))
        );
        // name() output parses back — the round trip the ISSUE requires
        let name = WindowPosition::Offset(0.25).name();
        let toml = format!("[engine]\nwindow_fraction = 0.2\nwindow_position = \"{name}\"\n");
        let cfg = RunConfig::from_str(&toml).unwrap();
        assert_eq!(
            cfg.engine.schedule,
            GuidanceSchedule::Window(WindowSpec::at_offset(0.25, 0.2))
        );
        // out-of-range offsets are rejected with a structured error
        assert!(RunConfig::from_str(
            "[engine]\nwindow_fraction = 0.2\nwindow_position = \"offset(1.5)\"\n"
        )
        .is_err());
    }

    #[test]
    fn adaptive_guidance_section() {
        let cfg = RunConfig::from_str("[guidance]\nadaptive = true\n").unwrap();
        assert_eq!(cfg.engine.adaptive, Some(AdaptiveConfig::default()));
        let cfg = RunConfig::from_str(
            "[guidance]\nadaptive = true\nadaptive_threshold = 0.1\nadaptive_patience = 3\n\
             adaptive_min_dual_fraction = 0.4\nadaptive_probe_every = 6\n",
        )
        .unwrap();
        assert_eq!(
            cfg.engine.adaptive,
            Some(AdaptiveConfig {
                threshold: 0.1,
                patience: 3,
                min_dual_fraction: 0.4,
                probe_every: 6
            })
        );
        // explicit off
        let cfg = RunConfig::from_str("[guidance]\nadaptive = false\n").unwrap();
        assert_eq!(cfg.engine.adaptive, None);
        // orphan knobs are an operator error, not a silent no-op
        assert!(RunConfig::from_str("[guidance]\nadaptive_threshold = 0.1\n").is_err());
        // adaptive + a static schedule is a conflict, not a precedence rule
        assert!(RunConfig::from_str("[guidance]\nadaptive = true\ncadence = 4\n").is_err());
        assert!(RunConfig::from_str(
            "[engine]\nwindow_fraction = 0.2\n[guidance]\nadaptive = true\n"
        )
        .is_err());
        // invalid knob values are rejected
        assert!(RunConfig::from_str(
            "[guidance]\nadaptive = true\nadaptive_min_dual_fraction = 1.5\n"
        )
        .is_err());
        assert!(
            RunConfig::from_str("[guidance]\nadaptive = true\nadaptive_threshold = -1.0\n")
                .is_err()
        );
    }

    #[test]
    fn telemetry_section() {
        // default: enabled, default capacity, no scrape endpoint
        let cfg = RunConfig::from_str("").unwrap();
        assert!(cfg.telemetry.enabled);
        assert_eq!(cfg.telemetry.trace_capacity, crate::telemetry::DEFAULT_TRACE_CAPACITY);
        assert_eq!(cfg.telemetry.metrics_addr, None);
        assert!(cfg.telemetry.build().is_some());
        let cfg = RunConfig::from_str(
            "[telemetry]\ntrace_capacity = 64\nmetrics_addr = \"127.0.0.1:9090\"\n\
             trace_jsonl = \"spans.jsonl\"\n",
        )
        .unwrap();
        assert_eq!(cfg.telemetry.trace_capacity, 64);
        assert_eq!(cfg.telemetry.metrics_addr.as_deref(), Some("127.0.0.1:9090"));
        assert_eq!(cfg.telemetry.trace_jsonl.as_deref(), Some("spans.jsonl"));
        // explicit off builds no hub
        let cfg = RunConfig::from_str("[telemetry]\nenabled = false\n").unwrap();
        assert!(!cfg.telemetry.enabled);
        assert!(cfg.telemetry.build().is_none());
        // orphan knobs under a disabled switch are an operator error
        assert!(RunConfig::from_str(
            "[telemetry]\nenabled = false\ntrace_capacity = 64\n"
        )
        .is_err());
        assert!(RunConfig::from_str(
            "[telemetry]\nenabled = false\nmetrics_addr = \"127.0.0.1:9090\"\n"
        )
        .is_err());
        // invalid values are structured config errors
        assert!(RunConfig::from_str("[telemetry]\ntrace_capacity = 0\n").is_err());
        assert!(RunConfig::from_str("[telemetry]\nenabled = \"yes\"\n").is_err());
        assert!(RunConfig::from_str("[telemetry]\nmetrics_addr = 9090\n").is_err());
    }

    #[test]
    fn cost_section() {
        // default: no source, unit currency everywhere
        let cfg = RunConfig::from_str("").unwrap();
        assert_eq!(cfg.cost, CostConfig::default());
        assert!(!cfg.cost.enabled());
        let cfg = RunConfig::from_str(
            "[cost]\ntable_path = \"cost.json\"\nbudget_ms = 12.5\nfallback = \"reject\"\n",
        )
        .unwrap();
        assert_eq!(cfg.cost.table_path.as_deref(), Some("cost.json"));
        assert_eq!(cfg.cost.budget_ms, 12.5);
        assert_eq!(cfg.cost.fallback, FallbackPolicy::Reject);
        assert!(cfg.cost.enabled());
        let cfg = RunConfig::from_str("[cost]\ncalibrate_on_start = true\n").unwrap();
        assert!(cfg.cost.calibrate_on_start && cfg.cost.enabled());
        // orphan knobs without a table source are an operator error
        assert!(RunConfig::from_str("[cost]\nbudget_ms = 10.0\n").is_err());
        assert!(RunConfig::from_str("[cost]\nfallback = \"analytic\"\n").is_err());
        // exactly one source
        assert!(RunConfig::from_str(
            "[cost]\ntable_path = \"cost.json\"\ncalibrate_on_start = true\n"
        )
        .is_err());
        // invalid values are structured config errors
        assert!(RunConfig::from_str(
            "[cost]\ntable_path = \"cost.json\"\nbudget_ms = -1.0\n"
        )
        .is_err());
        assert!(RunConfig::from_str(
            "[cost]\ntable_path = \"cost.json\"\nfallback = \"panic\"\n"
        )
        .is_err());
        assert!(RunConfig::from_str("[cost]\ntable_path = 3\n").is_err());
    }

    #[test]
    fn planner_section() {
        // default: no frontier source, legacy actuator everywhere
        let cfg = RunConfig::from_str("").unwrap();
        assert_eq!(cfg.planner, PlannerConfig::default());
        assert!(!cfg.planner.enabled());
        let cfg =
            RunConfig::from_str("[planner]\nfrontier_path = \"frontier.json\"\n").unwrap();
        assert_eq!(cfg.planner.frontier_path.as_deref(), Some("frontier.json"));
        assert!(cfg.planner.enabled() && !cfg.planner.fast);
        // tuning on start needs a cost source to price the sweep
        assert!(RunConfig::from_str("[planner]\ntune_on_start = true\n").is_err());
        let cfg = RunConfig::from_str(
            "[cost]\ncalibrate_on_start = true\n[planner]\ntune_on_start = true\nfast = true\n",
        )
        .unwrap();
        assert!(cfg.planner.tune_on_start && cfg.planner.fast && cfg.planner.enabled());
        // orphan knobs without a frontier source are an operator error
        assert!(RunConfig::from_str("[planner]\nfast = true\n").is_err());
        // exactly one frontier source
        assert!(RunConfig::from_str(
            "[cost]\ncalibrate_on_start = true\n[planner]\nfrontier_path = \"f.json\"\ntune_on_start = true\n"
        )
        .is_err());
        // fast only modifies a startup tune, not a loaded manifest
        assert!(RunConfig::from_str(
            "[planner]\nfrontier_path = \"f.json\"\nfast = true\n"
        )
        .is_err());
        // invalid values are structured config errors
        assert!(RunConfig::from_str("[planner]\nfrontier_path = 3\n").is_err());
        assert!(RunConfig::from_str("[planner]\ntune_on_start = \"yes\"\n").is_err());
    }

    #[test]
    fn cache_section() {
        // default: every tier off, nothing keyed
        let cfg = RunConfig::from_str("").unwrap();
        assert_eq!(cfg.cache, crate::cache::CacheConfig::default());
        assert!(!cfg.cache.enabled());
        let cfg = RunConfig::from_str(
            "[cache]\nrequest_cache = true\nrequest_capacity = 64\ndedup = true\n",
        )
        .unwrap();
        assert!(cfg.cache.request_cache && cfg.cache.dedup && !cfg.cache.shared_uncond);
        assert_eq!(cfg.cache.request_capacity, 64);
        assert!(cfg.cache.keyed());
        // orphan knobs under a disabled switch are operator errors
        assert!(RunConfig::from_str("[cache]\nrequest_capacity = 64\n").is_err());
        assert!(RunConfig::from_str("[cache]\nshared_tolerance = 0.5\n").is_err());
        // invalid values are structured config errors
        assert!(RunConfig::from_str(
            "[cache]\nrequest_cache = true\nrequest_capacity = 0\n"
        )
        .is_err());
        assert!(RunConfig::from_str("[cache]\ndedup = \"yes\"\n").is_err());
    }

    #[test]
    fn server_preview_cadence() {
        // default: progress events only, no preview decodes
        let cfg = RunConfig::from_str("").unwrap();
        assert_eq!(cfg.server.preview_every, 0);
        let cfg = RunConfig::from_str("[server]\npreview_every = 5\n").unwrap();
        assert_eq!(cfg.server.preview_every, 5);
        assert!(RunConfig::from_str("[server]\npreview_every = -1\n").is_err());
        assert!(RunConfig::from_str("[server]\npreview_every = \"often\"\n").is_err());
    }

    #[test]
    fn workload_section_rides_run_config() {
        use crate::workload::ArrivalProcess;
        // absent by default
        let cfg = RunConfig::from_str(SAMPLE).unwrap();
        assert!(cfg.workload.is_none());
        // present: traffic shape parsed, guidance policy inherited from
        // the resolved [engine] section of the same file
        let cfg = RunConfig::from_str(
            "[engine]\nsteps = 24\n[workload]\narrival = \"uniform\"\nrate_per_s = 8.0\n\
             requests = 6\nstrength = 0.5\nvariations = 2\n",
        )
        .unwrap();
        let spec = cfg.workload.expect("workload section");
        assert_eq!(spec.arrivals, ArrivalProcess::Uniform { rate_per_s: 8.0 });
        assert_eq!(spec.steps, 24);
        assert_eq!(spec.strength, Some(0.5));
        assert_eq!(spec.variations, 2);
        let trace = spec.synthesize();
        assert_eq!(trace.len(), 12); // 6 arrivals x 2 variations
        assert!(trace.iter().all(|e| e.request.executed_steps() == 12));
        // a bad workload section fails the whole config load
        assert!(RunConfig::from_str("[workload]\nvariations = 0\n").is_err());
    }

    #[test]
    fn seed_validation_rejects_negatives() {
        assert_eq!(seed_from_i64(0), Ok(0));
        assert_eq!(seed_from_i64(i64::MAX), Ok(i64::MAX as u64));
        assert!(seed_from_i64(-1).unwrap_err().contains("-1"));
        // the TOML surface routes through the same check: a negative
        // seed is a structured error, not a silent two's-complement wrap
        assert!(RunConfig::from_str("[engine]\nseed = -42\n").is_err());
        let cfg = RunConfig::from_str("[engine]\nseed = 42\n").unwrap();
        assert_eq!(cfg.engine.seed, 42);
    }

    #[test]
    fn dual_strategy_parse() {
        assert_eq!(DualStrategy::parse("two-b1").unwrap(), DualStrategy::TwoB1);
        assert_eq!(DualStrategy::parse("fused").unwrap(), DualStrategy::FusedB2);
        assert!(DualStrategy::parse("bogus").is_err());
        let cfg =
            RunConfig::from_str("[engine]\ndual_strategy = \"fused-b2\"\n").unwrap();
        assert_eq!(cfg.engine.dual_strategy, DualStrategy::FusedB2);
    }

    #[test]
    fn engine_validate_bounds() {
        let mut cfg = EngineConfig::default();
        cfg.steps = 1001;
        assert!(cfg.validate().is_err());
        cfg.steps = 50;
        cfg.guidance_scale = f32::INFINITY;
        assert!(cfg.validate().is_err());
    }
}
