//! TOML-subset parser: `[section]`, `key = value`, `#` comments.
//! Values: quoted strings, booleans, integers, floats.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: section -> key -> value. Keys outside any section go
/// into the "" section.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim();
                if name.is_empty() {
                    return Err(Error::Config(format!("line {}: empty section", lineno + 1)));
                }
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(Error::Config(format!(
                    "line {}: expected `key = value`, got {raw:?}",
                    lineno + 1
                )));
            };
            let key = key.trim();
            if key.is_empty() {
                return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
            }
            let value = parse_value(value.trim())
                .map_err(|e| Error::Config(format!("line {}: {e}", lineno + 1)))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn section(&self, name: &str) -> Option<&BTreeMap<String, TomlValue>> {
        self.sections.get(name)
    }

    /// All section names present in the document (sorted) — lets config
    /// readers reject orphan sections instead of silently ignoring them.
    pub fn section_names(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    if let Some(inner) = s.strip_prefix('"') {
        let Some(inner) = inner.strip_suffix('"') else {
            return Err(format!("unterminated string {s:?}"));
        };
        if inner.contains('"') {
            return Err(format!("embedded quote in {s:?}"));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        if f.is_finite() {
            return Ok(TomlValue::Float(f));
        }
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_scalar_kinds() {
        let doc = TomlDoc::parse(
            "a = 1\nb = 2.5\nc = true\nd = \"hi\"\n[s]\ne = -3\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "a"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("", "b"), Some(&TomlValue::Float(2.5)));
        assert_eq!(doc.get("", "c"), Some(&TomlValue::Bool(true)));
        assert_eq!(doc.get("", "d"), Some(&TomlValue::Str("hi".into())));
        assert_eq!(doc.get("s", "e"), Some(&TomlValue::Int(-3)));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = TomlDoc::parse("# top\n\na = 1 # trailing\nb = \"x # not comment\"\n").unwrap();
        assert_eq!(doc.get("", "a"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("", "b"), Some(&TomlValue::Str("x # not comment".into())));
    }

    #[test]
    fn bad_lines_error_with_lineno() {
        let err = TomlDoc::parse("a = 1\nbogus line\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        assert!(TomlDoc::parse("[]\n").is_err());
        assert!(TomlDoc::parse("= 3\n").is_err());
        assert!(TomlDoc::parse("x = \"unterminated\n").is_err());
        assert!(TomlDoc::parse("x = nan\n").is_err());
    }

    #[test]
    fn value_accessors() {
        assert_eq!(TomlValue::Int(5).as_f64(), Some(5.0));
        assert_eq!(TomlValue::Int(5).as_usize(), Some(5));
        assert_eq!(TomlValue::Int(-5).as_usize(), None);
        assert_eq!(TomlValue::Float(1.5).as_i64(), None);
        assert_eq!(TomlValue::Bool(true).as_str(), None);
    }

    #[test]
    fn later_values_override() {
        let doc = TomlDoc::parse("[s]\na = 1\na = 2\n").unwrap();
        assert_eq!(doc.get("s", "a"), Some(&TomlValue::Int(2)));
    }
}
