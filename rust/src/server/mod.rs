//! TCP front-end: JSON-lines protocol + blocking client library.
//!
//! One JSON object per line in each direction, served by a **single
//! nonblocking multiplexer thread** — no thread per client. The loop
//! polls the listener and every connection's socket, buffers partial
//! frames until their newline arrives, and drains in-flight generation
//! tickets between socket polls, so hundreds of concurrent (and
//! streaming) connections cost one thread.
//!
//! Two wire versions share the parser ([`parse_frame`], DESIGN.md §14):
//!
//! * **v1** (no `"v"` field — every legacy client): one request line,
//!   one response line, byte-identical to the historical shapes;
//! * **v2** (`{"v":2,"op":...}`): adds `cancel` and the streaming
//!   generate (`stream`, `preview_every`, `strength`/`init_latent`,
//!   `variations`). A streamed generate answers with typed event
//!   frames — `queued`/`progress`/`preview`/`done`/`error` — pushed as
//!   the sample denoises; `cancel` aborts it mid-cohort and frees its
//!   reserved slots as admission headroom.
//!
//! Operations:
//!
//! * `{"op":"generate", "prompt":..., ...}` → generation result (metrics
//!   and, when `return_image` is true, the PNG as base64). Optional QoS
//!   fields: `deadline_ms` (number) and `priority`
//!   (`interactive|standard|batch`); a shed request answers
//!   `{"ok":false,"rejected":true,"code":429|503,...}` and a
//!   queue-expired one `{"ok":false,"deadline_exceeded":true,"code":504}`;
//! * `{"op":"ping"}` → `{"ok":true,"pong":true}`;
//! * `{"op":"stats"}` → serving stats snapshot (incl. `rejected`,
//!   `deadline_missed`, `queue_depth_max`, `actuator_fraction`). When
//!   the server fronts a [`crate::cluster::ReplicaSet`] the snapshot is
//!   the **aggregate** (cluster-owned latency percentiles, merged
//!   counters, `requeued`/`ejected`) plus a `replicas` array with the
//!   per-replica breakdown;
//! * `{"op":"metrics"}` → the Prometheus text exposition (DESIGN.md
//!   §12) wrapped in JSON: `{"ok":true,"content_type":"text/plain;
//!   version=0.0.4","body":...}`. Errors when the backend runs with
//!   telemetry disabled;
//! * `{"op":"trace"}` → recent trace ids (`recent`, `evicted`);
//!   `{"op":"trace","trace":N}` → that request's span as structured
//!   JSON (`span.events[]` with `event`/`at_ms` + event fields). The
//!   span key is `trace`, never `id` — [`Client`] reserves `id` for
//!   request/response correlation;
//! * `{"v":2,"op":"cancel","target":N}` → abort the streamed generates
//!   whose frame `id` was `N` on this connection;
//! * `{"op":"shutdown"}` → acks and stops the listener.
//!
//! No HTTP stack exists in the offline registry snapshot; JSON-over-TCP
//! keeps the wire format inspectable (`nc localhost 7878`). The one
//! exception is [`MetricsScrape`] (`serve --metrics-addr`): Prometheus
//! speaks plain HTTP, so that listener hand-rolls the two lines of
//! HTTP/1.1 a scraper needs.

mod base64;
mod protocol;

pub use base64::{b64decode, b64encode};
pub use protocol::{
    event_done, event_error, event_preview, event_progress, event_queued, parse_frame,
    parse_request, parse_request_versioned, render_failure, render_output, Frame, ServerOp,
    ServerRequest,
};

use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

use crate::cache::CacheOutcome;
use crate::cluster::ReplicaSet;
use crate::config::EngineConfig;
use crate::coordinator::{Coordinator, Ticket, WatchOptions, Watched};
use crate::error::{Error, Result};
use crate::guidance::{AdaptiveConfig, GuidanceSchedule, GuidanceStrategy};
use crate::json::{self, Value};
use crate::qos::QosMeta;
use crate::telemetry::{Counter, Telemetry, PROMETHEUS_CONTENT_TYPE};

/// What the server fronts: a single coordinator or a replica cluster.
/// Every wire operation behaves identically against both — only the
/// `stats` payload differs (the cluster adds the per-replica breakdown).
pub enum Backend {
    Single(Arc<Coordinator>),
    Cluster(Arc<ReplicaSet>),
}

impl Backend {
    fn submit_qos(&self, req: crate::engine::GenerationRequest, meta: QosMeta) -> Result<Ticket> {
        match self {
            Backend::Single(c) => c.submit_qos(req, meta),
            Backend::Cluster(s) => s.submit_qos(req, meta),
        }
    }

    fn submit_watched(
        &self,
        req: crate::engine::GenerationRequest,
        meta: QosMeta,
        watch: WatchOptions,
    ) -> Result<Watched> {
        match self {
            Backend::Single(c) => c.submit_watched(req, meta, watch),
            Backend::Cluster(s) => s.submit_watched(req, meta, watch),
        }
    }

    /// The telemetry hub the backend was started with, if any.
    fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        match self {
            Backend::Single(c) => c.telemetry(),
            Backend::Cluster(s) => s.telemetry(),
        }
    }

    fn stats_value(&self, id: Option<i64>) -> Value {
        match self {
            Backend::Single(c) => {
                let s = c.stats();
                let rc = c.request_cache_stats();
                ok_base(id)
                    .with("cluster", false)
                    .with("mode", s.mode.name())
                    .with("submitted", s.submitted as i64)
                    .with("completed", s.completed as i64)
                    .with("failed", s.failed as i64)
                    .with("rejected", s.rejected as i64)
                    .with("deadline_missed", s.deadline_missed as i64)
                    .with("cancelled", s.cancelled as i64)
                    .with("drain_shed", s.drain_shed as i64)
                    .with("cache_hits", s.cache_hits as i64)
                    .with("dedup_coalesced", s.dedup_coalesced as i64)
                    .with("cache_entries", rc.entries as i64)
                    .with("cache_evictions", rc.evictions as i64)
                    .with("cache_bytes", rc.bytes as i64)
                    .with("batches", s.batches as i64)
                    .with("batched_requests", s.batched_requests as i64)
                    .with("slot_budget", s.slot_budget as i64)
                    .with("iterations", s.iterations as i64)
                    .with("joins", s.joins as i64)
                    .with("retires", s.retires as i64)
                    .with("cohort_max", s.cohort_max as i64)
                    .with("cohort_last", s.cohort_last as i64)
                    .with("slot_utilization", s.slot_utilization)
                    .with("queue_depth", s.queue_depth as i64)
                    .with("queue_depth_max", s.queue_depth_max as i64)
                    .with("actuator_fraction", s.actuator_fraction)
                    .with("latency_ms_mean", s.latency_ms_mean)
                    .with("latency_ms_p50", s.latency_ms_p50)
                    .with("latency_ms_p90", s.latency_ms_p90)
                    .with(
                        "cost",
                        Value::obj()
                            .with("priced", s.cost_model_ratio > 0.0)
                            .with("budget_ms", s.cost_budget_ms)
                            .with("fallbacks", s.cost_fallbacks as i64)
                            .with("model_ratio", s.cost_model_ratio)
                            .with("shed_ratio", s.cost_shed_ratio),
                    )
                    .with(
                        "planner",
                        Value::obj()
                            .with("attached", s.planner_attached)
                            .with("searches", s.planner_searches as i64)
                            .with("frontier_hits", s.planner_frontier_hits as i64)
                            .with("fallbacks", s.planner_fallbacks as i64)
                            .with("floor_clamps", s.planner_floor_clamps as i64),
                    )
            }
            Backend::Cluster(set) => {
                let s = set.stats();
                let replicas: Vec<Value> = s
                    .replicas
                    .iter()
                    .map(|r| {
                        Value::obj()
                            .with("id", r.id as i64)
                            .with("healthy", r.healthy)
                            .with("routed", r.routed as i64)
                            .with("outstanding_evals", r.outstanding_evals as i64)
                            .with("capacity_weight", r.capacity_weight)
                            .with("route_weight", r.route_weight)
                            .with("mode", r.coordinator.mode.name())
                            .with("slot_budget", r.coordinator.slot_budget as i64)
                            .with("completed", r.coordinator.completed as i64)
                            .with("failed", r.coordinator.failed as i64)
                            .with("drain_shed", r.coordinator.drain_shed as i64)
                            .with("batches", r.coordinator.batches as i64)
                            .with("iterations", r.coordinator.iterations as i64)
                            .with("queue_depth", r.coordinator.queue_depth as i64)
                            .with("slot_utilization", r.coordinator.slot_utilization)
                    })
                    .collect();
                ok_base(id)
                    .with("cluster", true)
                    .with("route", s.route.name())
                    .with("healthy_replicas", s.healthy_replicas as i64)
                    .with("submitted", s.submitted as i64)
                    .with("completed", s.completed as i64)
                    .with("failed", s.failed as i64)
                    .with("rejected", s.rejected as i64)
                    .with("deadline_missed", s.deadline_missed as i64)
                    .with("cancelled", s.cancelled as i64)
                    .with("requeued", s.requeued as i64)
                    .with("ejected", s.ejected as i64)
                    .with("drain_shed", s.drain_shed as i64)
                    .with("cache_hits", s.cache_hits as i64)
                    .with("dedup_coalesced", s.dedup_coalesced as i64)
                    .with("batches", s.batches as i64)
                    .with("iterations", s.iterations as i64)
                    .with("joins", s.joins as i64)
                    .with("retires", s.retires as i64)
                    .with("queue_depth", s.queue_depth as i64)
                    .with("queue_depth_max", s.queue_depth_max as i64)
                    .with("outstanding_evals", s.outstanding_evals as i64)
                    .with("actuator_fraction", s.actuator_fraction)
                    .with("latency_ms_mean", s.latency_ms_mean)
                    .with("latency_ms_p50", s.latency_ms_p50)
                    .with("latency_ms_p90", s.latency_ms_p90)
                    .with(
                        "cost",
                        Value::obj()
                            .with("priced", s.cost_priced)
                            .with("fallbacks", s.cost_fallbacks as i64),
                    )
                    .with(
                        "planner",
                        Value::obj()
                            .with("attached", s.planner_attached)
                            .with("searches", s.planner_searches as i64)
                            .with("frontier_hits", s.planner_frontier_hits as i64)
                            .with("fallbacks", s.planner_fallbacks as i64)
                            .with("floor_clamps", s.planner_floor_clamps as i64),
                    )
                    .with("replicas", Value::Arr(replicas))
            }
        }
    }
}

/// Server-side guidance defaults (from the `[engine]`/`[guidance]`
/// config and the `serve` CLI) applied to requests that carry no
/// guidance fields of their own. The triple is applied wholesale —
/// schedule, strategy and adaptive interact, so a request that sets
/// *any* of them keeps exactly what it asked for.
#[derive(Debug, Clone, Default)]
pub struct GuidanceDefaults {
    pub schedule: GuidanceSchedule,
    pub strategy: GuidanceStrategy,
    pub adaptive: Option<AdaptiveConfig>,
    /// Preview cadence applied to streamed requests that don't set
    /// their own `preview_every` (`[server] preview_every` /
    /// `serve --preview-every`). 0 = progress events only.
    pub preview_every: usize,
}

impl GuidanceDefaults {
    /// The serving defaults a validated engine config implies.
    pub fn from_engine(cfg: &EngineConfig) -> GuidanceDefaults {
        GuidanceDefaults {
            schedule: cfg.schedule.clone(),
            strategy: cfg.guidance_strategy,
            adaptive: cfg.adaptive,
            preview_every: 0,
        }
    }

    /// Set the default preview cadence for streamed requests.
    pub fn with_preview_every(mut self, every: usize) -> GuidanceDefaults {
        self.preview_every = every;
        self
    }
}

/// A running server: one multiplexer thread serving every connection.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve in a background multiplexer thread.
    pub fn start(coordinator: Arc<Coordinator>, bind: &str) -> Result<Server> {
        Self::start_with_defaults(coordinator, bind, GuidanceDefaults::default())
    }

    /// Bind and serve with server-side guidance defaults: requests whose
    /// payload carries none of the guidance fields (schedule, strategy,
    /// adaptive) run the configured default triple — the `[engine]` /
    /// `[guidance]` TOML and `serve --adaptive`/schedule-flag surface.
    /// A request that sets any of those fields keeps them untouched.
    pub fn start_with_defaults(
        coordinator: Arc<Coordinator>,
        bind: &str,
        defaults: GuidanceDefaults,
    ) -> Result<Server> {
        Self::start_backend(Backend::Single(coordinator), bind, defaults)
    }

    /// Bind and serve in front of a replica cluster (`serve --replicas`).
    pub fn start_cluster(
        set: Arc<ReplicaSet>,
        bind: &str,
        defaults: GuidanceDefaults,
    ) -> Result<Server> {
        Self::start_backend(Backend::Cluster(set), bind, defaults)
    }

    /// Bind and serve any [`Backend`].
    pub fn start_backend(
        backend: Backend,
        bind: &str,
        defaults: GuidanceDefaults,
    ) -> Result<Server> {
        let backend = Arc::new(backend);
        let listener = TcpListener::bind(bind)
            .map_err(|e| Error::io(format!("binding {bind}"), e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::io("local_addr", e))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let defaults = Arc::new(defaults);
        let handle = std::thread::spawn(move || {
            multiplex_loop(listener, backend, stop2, defaults);
        });
        Ok(Server { addr, stop, handle: Some(handle) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Whether a `shutdown` op (or [`Server::stop`]) has stopped the
    /// multiplexer — what the `serve` command polls to exit cleanly.
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Request the multiplexer to stop (it notices within one poll tick).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---------------------------------------------------------------------
// The multiplexer: one thread, every connection, nonblocking sockets.
// ---------------------------------------------------------------------

/// One client connection's poll-loop state: the nonblocking socket plus
/// a read buffer (bytes up to the next newline — a frame split across
/// TCP segments stays here until complete) and a write buffer (frames
/// not yet accepted by the socket — a partial write keeps the rest).
struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    closed: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn { stream, rbuf: Vec::new(), wbuf: Vec::new(), closed: false }
    }

    /// Nonblocking read: drain the socket into `rbuf`. Returns whether
    /// any bytes arrived.
    fn fill(&mut self) -> bool {
        let mut any = false;
        let mut tmp = [0u8; 8192];
        loop {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    self.closed = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&tmp[..n]);
                    any = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.closed = true;
                    break;
                }
            }
        }
        any
    }

    /// Every complete newline-terminated frame buffered so far. A
    /// partial trailing frame (no newline yet) stays in `rbuf` — the
    /// fix for the historical partial-read hazard where a frame split
    /// across reads would be parsed as two broken ones.
    fn take_lines(&mut self) -> Vec<String> {
        let mut out = Vec::new();
        while let Some(pos) = self.rbuf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = self.rbuf.drain(..=pos).collect();
            let s = String::from_utf8_lossy(&line);
            let s = s.trim();
            if !s.is_empty() {
                out.push(s.to_string());
            }
        }
        out
    }

    /// Queue one frame for writing.
    fn push(&mut self, v: Value) {
        self.wbuf.extend_from_slice(v.to_string().as_bytes());
        self.wbuf.push(b'\n');
    }

    /// Nonblocking flush: write as much of `wbuf` as the socket takes.
    /// Returns whether any bytes moved.
    fn flush_some(&mut self) -> bool {
        let mut any = false;
        while !self.wbuf.is_empty() {
            match self.stream.write(&self.wbuf) {
                Ok(0) => {
                    self.closed = true;
                    break;
                }
                Ok(n) => {
                    self.wbuf.drain(..n);
                    any = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.closed = true;
                    break;
                }
            }
        }
        any
    }
}

/// A non-streamed generate in flight: respond with one line when the
/// ticket resolves. `variation` is the fan-out index when the frame
/// asked for `variations > 1` (one response line per variation).
struct PlainJob {
    conn: u64,
    frame_id: Option<i64>,
    variation: Option<usize>,
    sr: ServerRequest,
    ticket: Ticket,
    outcome: Arc<OnceLock<CacheOutcome>>,
}

/// A streamed (v2) generate in flight: progress/preview events are
/// relayed as they arrive; `done`/`error` closes the stream. The cancel
/// handle is flipped by a `cancel` op targeting this frame id (or by
/// the connection disappearing), which aborts the sample mid-cohort
/// and returns its slots to admission headroom.
struct StreamJob {
    conn: u64,
    frame_id: Option<i64>,
    variation: Option<usize>,
    sr: ServerRequest,
    watched: Watched,
}

/// Per-version wire-frame counters (`sg_protocol_requests_total`).
struct ProtoCounters {
    v1: Counter,
    v2: Counter,
}

fn multiplex_loop(
    listener: TcpListener,
    backend: Arc<Backend>,
    stop: Arc<AtomicBool>,
    defaults: Arc<GuidanceDefaults>,
) {
    let _ = listener.set_nonblocking(true);
    let proto = backend.telemetry().map(|t| {
        let help = "Wire frames received by protocol version";
        ProtoCounters {
            v1: t.registry().counter("sg_protocol_requests_total", help, &[("version", "1")]),
            v2: t.registry().counter("sg_protocol_requests_total", help, &[("version", "2")]),
        }
    });
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_conn: u64 = 0;
    let mut plain: Vec<PlainJob> = Vec::new();
    let mut streams: Vec<StreamJob> = Vec::new();

    while !stop.load(Ordering::SeqCst) {
        let mut activity = false;

        // 1. accept — every waiting connection, no thread spawned
        loop {
            match listener.accept() {
                Ok((s, _)) => {
                    if s.set_nonblocking(true).is_ok() {
                        conns.insert(next_conn, Conn::new(s));
                        next_conn += 1;
                    }
                    activity = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }

        // 2. read + parse + dispatch per connection
        let ids: Vec<u64> = conns.keys().copied().collect();
        for cid in ids {
            let Some(conn) = conns.get_mut(&cid) else { continue };
            activity |= conn.fill();
            for line in conn.take_lines() {
                handle_line(
                    &line, cid, conn, &backend, &stop, &defaults, &mut plain, &mut streams,
                    proto.as_ref(),
                );
                activity = true;
            }
        }

        // 3. relay progress/preview events and resolved tickets
        streams.retain_mut(|j| {
            let Some(conn) = conns.get_mut(&j.conn) else {
                // subscriber gone: abort the sample so its slots return
                // to admission headroom instead of denoising for nobody
                j.watched.cancel.cancel();
                return false;
            };
            activity |= drain_progress(&j.watched, j.frame_id, j.variation, conn);
            match j.watched.ticket.try_wait_timed() {
                None => true,
                Some((res, _)) => {
                    // events the worker sent before resolving
                    drain_progress(&j.watched, j.frame_id, j.variation, conn);
                    let frame = match res {
                        Ok(out) => tag_var(event_done(j.frame_id, &j.sr, &out), j.variation),
                        Err(e) => tag_var(event_error(j.frame_id, &e), j.variation),
                    };
                    conn.push(frame);
                    activity = true;
                    false
                }
            }
        });
        plain.retain_mut(|j| {
            if !conns.contains_key(&j.conn) {
                return false; // response has no reader; drop the ticket
            }
            match j.ticket.try_wait_timed() {
                None => true,
                Some((res, _)) => {
                    let conn = conns.get_mut(&j.conn).expect("checked above");
                    let frame = match res {
                        Ok(out) => {
                            let mut v = render_output(j.frame_id, &j.sr, &out);
                            // echoed only when a cache layer keyed the
                            // admission — absent field == caches off,
                            // exactly the v1 wire shape
                            if let Some(o) = j.outcome.get() {
                                v = v.with("cache", o.label());
                            }
                            tag_var(v, j.variation)
                        }
                        Err(e) => tag_var(render_failure(j.frame_id, &e), j.variation),
                    };
                    conn.push(frame);
                    activity = true;
                    false
                }
            }
        });

        // 4. flush write buffers (partial writes keep their remainder)
        for conn in conns.values_mut() {
            activity |= conn.flush_some();
        }

        // 5. sweep closed connections
        conns.retain(|_, c| !c.closed);

        if !activity {
            std::thread::sleep(std::time::Duration::from_micros(500));
        }
    }

    // best-effort final flush so a `shutdown` ack reaches its client
    for (_, mut c) in conns {
        let _ = c.stream.set_nonblocking(false);
        let _ = c.stream.write_all(&c.wbuf);
    }
}

/// Relay every queued progress/preview event of one watched job to its
/// connection. Returns whether any event moved.
fn drain_progress(
    watched: &Watched,
    frame_id: Option<i64>,
    variation: Option<usize>,
    conn: &mut Conn,
) -> bool {
    let mut any = false;
    while let Ok(ev) = watched.progress.try_recv() {
        conn.push(tag_var(event_progress(frame_id, ev.step, ev.steps), variation));
        if let Some(img) = &ev.preview {
            if let Ok(f) = event_preview(frame_id, ev.step, img) {
                conn.push(tag_var(f, variation));
            }
        }
        any = true;
    }
    any
}

/// Tag a frame with its variations fan-out index (absent for plain,
/// single-sample generates — exactly the pre-fan-out wire shape).
fn tag_var(v: Value, variation: Option<usize>) -> Value {
    match variation {
        Some(i) => v.with("variation", i as i64),
        None => v,
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_line(
    line: &str,
    cid: u64,
    conn: &mut Conn,
    backend: &Backend,
    stop: &AtomicBool,
    defaults: &GuidanceDefaults,
    plain: &mut Vec<PlainJob>,
    streams: &mut Vec<StreamJob>,
    proto: Option<&ProtoCounters>,
) {
    let parsed = match json::from_str(line) {
        Ok(v) => v,
        Err(e) => return conn.push(err_response(None, &format!("bad json: {e}"))),
    };
    let id = parsed.get("id").and_then(Value::as_i64);
    let frame = match parse_frame(&parsed) {
        Ok(f) => f,
        Err(e) => return conn.push(err_response(id, &e.to_string())),
    };
    if let Some(p) = proto {
        match frame.version {
            2 => p.v2.inc(),
            _ => p.v1.inc(),
        }
    }
    match frame.op {
        ServerOp::Ping => conn.push(ok_base(id).with("pong", true)),
        ServerOp::Stats => conn.push(backend.stats_value(id)),
        ServerOp::Shutdown => {
            stop.store(true, Ordering::SeqCst);
            conn.push(ok_base(id).with("stopping", true));
        }
        ServerOp::Metrics => match backend.telemetry() {
            Some(t) => conn.push(
                ok_base(id)
                    .with("content_type", PROMETHEUS_CONTENT_TYPE)
                    .with("body", t.render_prometheus().as_str()),
            ),
            None => conn.push(err_response(id, "telemetry disabled")),
        },
        ServerOp::Trace { trace } => match backend.telemetry() {
            Some(t) => match trace {
                Some(tid) => match t.traces().span(tid as u64) {
                    Some(span) => conn.push(ok_base(id).with("span", span.to_json())),
                    None => conn.push(err_response(id, &format!("unknown trace id {tid}"))),
                },
                None => {
                    let recent: Vec<Value> =
                        t.traces().recent(64).iter().map(|&i| Value::int(i as i64)).collect();
                    conn.push(
                        ok_base(id)
                            .with("recent", Value::Arr(recent))
                            .with("evicted", t.traces().evicted() as i64),
                    );
                }
            },
            None => conn.push(err_response(id, "telemetry disabled")),
        },
        ServerOp::Cancel { target } => {
            // scoped to this connection: one client cannot cancel
            // another's streams by guessing frame ids
            let mut n = 0i64;
            for j in streams.iter().filter(|j| j.conn == cid && j.frame_id == Some(target)) {
                j.watched.cancel.cancel();
                n += 1;
            }
            if n > 0 {
                conn.push(ok_base(id).with("cancelled", n));
            } else {
                conn.push(err_response(id, &format!("cancel: unknown target {target}")));
            }
        }
        ServerOp::Generate(sr) => {
            handle_generate(*sr, id, cid, conn, backend, defaults, plain, streams)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_generate(
    mut sr: ServerRequest,
    id: Option<i64>,
    cid: u64,
    conn: &mut Conn,
    backend: &Backend,
    defaults: &GuidanceDefaults,
    plain: &mut Vec<PlainJob>,
    streams: &mut Vec<StreamJob>,
) {
    // server-side guidance defaults: applied wholesale, and only when
    // the client set none of the guidance fields — a request that
    // picked any schedule/strategy/adaptive field keeps exactly what
    // it asked for
    if !sr.schedule_set && !sr.strategy_set && !sr.adaptive_set {
        sr.request.schedule = defaults.schedule.clone();
        sr.request.strategy = defaults.strategy;
        sr.request.adaptive = defaults.adaptive;
    }
    // variations fan-out: N seeds share ONE compiled guidance plan;
    // each variation is its own sample (own ticket, own event frames,
    // `variation` tag for correlation)
    let reqs: Vec<(Option<usize>, crate::engine::GenerationRequest)> = if sr.variations > 1 {
        match sr.request.variations(sr.variations) {
            Ok(rs) => rs.into_iter().enumerate().map(|(i, r)| (Some(i), r)).collect(),
            Err(e) => {
                let f = if sr.stream { event_error(id, &e) } else { render_failure(id, &e) };
                return conn.push(f);
            }
        }
    } else {
        vec![(None, sr.request.clone())]
    };
    for (variation, req) in reqs {
        if sr.stream {
            // the server default cadence fills in only when the request
            // didn't pick one (per-request knob wins)
            let preview_every = if sr.preview_every > 0 {
                sr.preview_every
            } else {
                defaults.preview_every
            };
            let watch = WatchOptions { preview_every };
            match backend.submit_watched(req, sr.meta, watch) {
                Ok(watched) => {
                    conn.push(tag_var(event_queued(id), variation));
                    streams.push(StreamJob {
                        conn: cid,
                        frame_id: id,
                        variation,
                        sr: sr.clone(),
                        watched,
                    });
                }
                Err(e) => conn.push(tag_var(event_error(id, &e), variation)),
            }
        } else {
            // submit through the QoS path: a shed request comes back as
            // a structured 429/503 response, a queue-expired one as 504
            match backend.submit_qos(req, sr.meta) {
                Ok(ticket) => {
                    // the admission's cache outcome: hit/dedup are
                    // decided synchronously at submit, so the cell is
                    // settled by the time the ticket resolves
                    let outcome = ticket.outcome_cell();
                    plain.push(PlainJob {
                        conn: cid,
                        frame_id: id,
                        variation,
                        sr: sr.clone(),
                        ticket,
                        outcome,
                    });
                }
                Err(e) => conn.push(tag_var(render_failure(id, &e), variation)),
            }
        }
    }
}

/// Plain-HTTP Prometheus scrape endpoint (`serve --metrics-addr`, or
/// `[telemetry] metrics_addr` in config).
///
/// Prometheus only speaks HTTP, and no HTTP stack exists in the offline
/// registry snapshot — but a scraper needs exactly one thing: `GET`
/// anything, get the exposition back. So this listener hand-rolls that
/// sliver of HTTP/1.1: read the request head, answer `200 OK` with
/// `Content-Type: text/plain; version=0.0.4` and the current registry
/// render, close. One connection per scrape, no keep-alive.
pub struct MetricsScrape {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsScrape {
    /// Bind `bind` and serve scrapes of `telemetry` until dropped.
    pub fn start(telemetry: Arc<Telemetry>, bind: &str) -> Result<MetricsScrape> {
        let listener = TcpListener::bind(bind)
            .map_err(|e| Error::io(format!("binding metrics endpoint {bind}"), e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::io("local_addr", e))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let _ = serve_scrape(s, &telemetry);
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(MetricsScrape { addr, stop, handle: Some(handle) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop the scrape listener (it wakes on the next connection).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsScrape {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_scrape(stream: TcpStream, telemetry: &Arc<Telemetry>) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let head = line.trim().to_string();
    // drain the request headers; any path scrapes the one registry
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 || line.trim().is_empty() {
            break;
        }
    }
    let (status, body) = if head.starts_with("GET ") || head.starts_with("HEAD ") {
        ("200 OK", telemetry.render_prometheus())
    } else {
        ("405 Method Not Allowed", String::new())
    };
    let payload = if head.starts_with("HEAD ") { "" } else { body.as_str() };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {PROMETHEUS_CONTENT_TYPE}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{payload}",
        body.len()
    );
    writer.write_all(response.as_bytes())?;
    writer.flush()
}

fn ok_base(id: Option<i64>) -> Value {
    let v = Value::obj().with("ok", true);
    match id {
        Some(id) => v.with("id", id),
        None => v,
    }
}

fn err_response(id: Option<i64>, msg: &str) -> Value {
    let v = Value::obj().with("ok", false).with("error", msg);
    match id {
        Some(id) => v.with("id", id),
        None => v,
    }
}

/// Blocking client for the JSON-lines protocol (v1 and v2).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: i64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::io(format!("connecting {addr}"), e))?;
        let reader = BufReader::new(stream.try_clone().map_err(|e| Error::io("clone", e))?);
        Ok(Client { reader, writer: stream, next_id: 1 })
    }

    /// Send one op object (the `id` field is added automatically) and
    /// block for its response.
    pub fn call(&mut self, payload: Value) -> Result<Value> {
        let id = self.send(payload)?;
        let v = self.read_frame()?;
        match v.get("id").and_then(Value::as_i64) {
            Some(rid) if rid == id => Ok(v),
            Some(rid) => Err(Error::Protocol(format!("response id {rid} != request id {id}"))),
            None => Ok(v), // error responses may lack an id
        }
    }

    /// Send one op object without waiting for its response (the `id`
    /// field is added automatically; returned for correlation) — the
    /// v2 streaming primitive: follow with [`Client::read_frame`] until
    /// the `done`/`error` event arrives.
    pub fn send(&mut self, mut payload: Value) -> Result<i64> {
        let id = self.next_id;
        self.next_id += 1;
        if let Value::Obj(m) = &mut payload {
            m.insert("id".into(), Value::int(id));
        }
        let line = payload.to_string();
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .and_then(|_| self.writer.flush())
            .map_err(|e| Error::io("sending request", e))?;
        Ok(id)
    }

    /// Block for the next frame from the server — a response line or,
    /// on a streamed generate, the next event frame.
    pub fn read_frame(&mut self) -> Result<Value> {
        let mut resp = String::new();
        self.reader
            .read_line(&mut resp)
            .map_err(|e| Error::io("reading response", e))?;
        if resp.is_empty() {
            return Err(Error::Protocol("server closed connection".into()));
        }
        json::from_str(&resp)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let v = self.call(Value::obj().with("op", "ping"))?;
        Ok(v.get("pong").and_then(Value::as_bool).unwrap_or(false))
    }

    pub fn stats(&mut self) -> Result<Value> {
        self.call(Value::obj().with("op", "stats"))
    }

    pub fn shutdown_server(&mut self) -> Result<()> {
        self.call(Value::obj().with("op", "shutdown"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_helpers() {
        let ok = ok_base(Some(3)).with("x", 1i64);
        assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(ok.get("id").unwrap().as_i64(), Some(3));
        let err = err_response(None, "boom");
        assert_eq!(err.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(err.get("error").unwrap().as_str(), Some("boom"));
    }

    #[test]
    fn variation_tag_only_on_fanout() {
        let v = tag_var(ok_base(Some(1)), None);
        assert!(v.get("variation").is_none());
        let v = tag_var(ok_base(Some(1)), Some(2));
        assert_eq!(v.get("variation").unwrap().as_i64(), Some(2));
    }

    // `take_lines` is the partial-frame fix: frames split across TCP
    // segments must buffer until their newline, and multiple frames in
    // one segment must all come out.
    #[test]
    fn take_lines_buffers_partial_frames() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let peer = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (sock, _) = listener.accept().unwrap();
        drop(peer);
        let mut c = Conn::new(sock);

        c.rbuf.extend_from_slice(b"{\"op\":");
        assert!(c.take_lines().is_empty(), "partial frame must stay buffered");
        c.rbuf.extend_from_slice(b"\"ping\"}\n{\"op\":\"stats\"}\n{\"op\":");
        let lines = c.take_lines();
        assert_eq!(lines, vec![r#"{"op":"ping"}"#, r#"{"op":"stats"}"#]);
        assert_eq!(c.rbuf, b"{\"op\":");
        c.rbuf.extend_from_slice(b"\"x\"}\r\n\n");
        // CRLF endings and blank lines are tolerated, not frames
        assert_eq!(c.take_lines(), vec![r#"{"op":"x"}"#]);
        assert!(c.take_lines().is_empty());
    }
}
