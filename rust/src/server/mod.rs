//! TCP front-end: JSON-lines protocol + blocking client library.
//!
//! One JSON object per line in each direction. Operations:
//!
//! * `{"op":"generate", "prompt":..., ...}` → generation result (metrics
//!   and, when `return_image` is true, the PNG as base64). Optional QoS
//!   fields: `deadline_ms` (number) and `priority`
//!   (`interactive|standard|batch`); a shed request answers
//!   `{"ok":false,"rejected":true,"code":429|503,...}` and a
//!   queue-expired one `{"ok":false,"deadline_exceeded":true,"code":504}`;
//! * `{"op":"ping"}` → `{"ok":true,"pong":true}`;
//! * `{"op":"stats"}` → serving stats snapshot (incl. `rejected`,
//!   `deadline_missed`, `queue_depth_max`, `actuator_fraction`). When
//!   the server fronts a [`crate::cluster::ReplicaSet`] the snapshot is
//!   the **aggregate** (cluster-owned latency percentiles, merged
//!   counters, `requeued`/`ejected`) plus a `replicas` array with the
//!   per-replica breakdown;
//! * `{"op":"metrics"}` → the Prometheus text exposition (DESIGN.md
//!   §12) wrapped in JSON: `{"ok":true,"content_type":"text/plain;
//!   version=0.0.4","body":...}`. Errors when the backend runs with
//!   telemetry disabled;
//! * `{"op":"trace"}` → recent trace ids (`recent`, `evicted`);
//!   `{"op":"trace","trace":N}` → that request's span as structured
//!   JSON (`span.events[]` with `event`/`at_ms` + event fields). The
//!   span key is `trace`, never `id` — [`Client`] reserves `id` for
//!   request/response correlation;
//! * `{"op":"shutdown"}` → acks and stops the listener.
//!
//! No HTTP stack exists in the offline registry snapshot; JSON-over-TCP
//! keeps the wire format inspectable (`nc localhost 7878`). The one
//! exception is [`MetricsScrape`] (`serve --metrics-addr`): Prometheus
//! speaks plain HTTP, so that listener hand-rolls the two lines of
//! HTTP/1.1 a scraper needs.

mod base64;
mod protocol;

pub use base64::{b64decode, b64encode};
pub use protocol::{parse_request, render_failure, render_output, ServerRequest};

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::cluster::ReplicaSet;
use crate::config::EngineConfig;
use crate::coordinator::{Coordinator, Ticket};
use crate::error::{Error, Result};
use crate::guidance::{AdaptiveConfig, GuidanceSchedule, GuidanceStrategy};
use crate::json::{self, Value};
use crate::qos::QosMeta;
use crate::telemetry::{Telemetry, PROMETHEUS_CONTENT_TYPE};

/// What the server fronts: a single coordinator or a replica cluster.
/// Every wire operation behaves identically against both — only the
/// `stats` payload differs (the cluster adds the per-replica breakdown).
pub enum Backend {
    Single(Arc<Coordinator>),
    Cluster(Arc<ReplicaSet>),
}

impl Backend {
    fn submit_qos(&self, req: crate::engine::GenerationRequest, meta: QosMeta) -> Result<Ticket> {
        match self {
            Backend::Single(c) => c.submit_qos(req, meta),
            Backend::Cluster(s) => s.submit_qos(req, meta),
        }
    }

    /// The telemetry hub the backend was started with, if any.
    fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        match self {
            Backend::Single(c) => c.telemetry(),
            Backend::Cluster(s) => s.telemetry(),
        }
    }

    fn stats_value(&self, id: Option<i64>) -> Value {
        match self {
            Backend::Single(c) => {
                let s = c.stats();
                let rc = c.request_cache_stats();
                ok_base(id)
                    .with("cluster", false)
                    .with("mode", s.mode.name())
                    .with("submitted", s.submitted as i64)
                    .with("completed", s.completed as i64)
                    .with("failed", s.failed as i64)
                    .with("rejected", s.rejected as i64)
                    .with("deadline_missed", s.deadline_missed as i64)
                    .with("drain_shed", s.drain_shed as i64)
                    .with("cache_hits", s.cache_hits as i64)
                    .with("dedup_coalesced", s.dedup_coalesced as i64)
                    .with("cache_entries", rc.entries as i64)
                    .with("cache_evictions", rc.evictions as i64)
                    .with("cache_bytes", rc.bytes as i64)
                    .with("batches", s.batches as i64)
                    .with("batched_requests", s.batched_requests as i64)
                    .with("slot_budget", s.slot_budget as i64)
                    .with("iterations", s.iterations as i64)
                    .with("joins", s.joins as i64)
                    .with("retires", s.retires as i64)
                    .with("cohort_max", s.cohort_max as i64)
                    .with("cohort_last", s.cohort_last as i64)
                    .with("slot_utilization", s.slot_utilization)
                    .with("queue_depth", s.queue_depth as i64)
                    .with("queue_depth_max", s.queue_depth_max as i64)
                    .with("actuator_fraction", s.actuator_fraction)
                    .with("latency_ms_mean", s.latency_ms_mean)
                    .with("latency_ms_p50", s.latency_ms_p50)
                    .with("latency_ms_p90", s.latency_ms_p90)
            }
            Backend::Cluster(set) => {
                let s = set.stats();
                let replicas: Vec<Value> = s
                    .replicas
                    .iter()
                    .map(|r| {
                        Value::obj()
                            .with("id", r.id as i64)
                            .with("healthy", r.healthy)
                            .with("routed", r.routed as i64)
                            .with("outstanding_evals", r.outstanding_evals as i64)
                            .with("capacity_weight", r.capacity_weight)
                            .with("mode", r.coordinator.mode.name())
                            .with("slot_budget", r.coordinator.slot_budget as i64)
                            .with("completed", r.coordinator.completed as i64)
                            .with("failed", r.coordinator.failed as i64)
                            .with("drain_shed", r.coordinator.drain_shed as i64)
                            .with("batches", r.coordinator.batches as i64)
                            .with("iterations", r.coordinator.iterations as i64)
                            .with("queue_depth", r.coordinator.queue_depth as i64)
                            .with("slot_utilization", r.coordinator.slot_utilization)
                    })
                    .collect();
                ok_base(id)
                    .with("cluster", true)
                    .with("route", s.route.name())
                    .with("healthy_replicas", s.healthy_replicas as i64)
                    .with("submitted", s.submitted as i64)
                    .with("completed", s.completed as i64)
                    .with("failed", s.failed as i64)
                    .with("rejected", s.rejected as i64)
                    .with("deadline_missed", s.deadline_missed as i64)
                    .with("requeued", s.requeued as i64)
                    .with("ejected", s.ejected as i64)
                    .with("drain_shed", s.drain_shed as i64)
                    .with("cache_hits", s.cache_hits as i64)
                    .with("dedup_coalesced", s.dedup_coalesced as i64)
                    .with("batches", s.batches as i64)
                    .with("iterations", s.iterations as i64)
                    .with("joins", s.joins as i64)
                    .with("retires", s.retires as i64)
                    .with("queue_depth", s.queue_depth as i64)
                    .with("queue_depth_max", s.queue_depth_max as i64)
                    .with("outstanding_evals", s.outstanding_evals as i64)
                    .with("actuator_fraction", s.actuator_fraction)
                    .with("latency_ms_mean", s.latency_ms_mean)
                    .with("latency_ms_p50", s.latency_ms_p50)
                    .with("latency_ms_p90", s.latency_ms_p90)
                    .with("replicas", Value::Arr(replicas))
            }
        }
    }
}

/// Server-side guidance defaults (from the `[engine]`/`[guidance]`
/// config and the `serve` CLI) applied to requests that carry no
/// guidance fields of their own. The triple is applied wholesale —
/// schedule, strategy and adaptive interact, so a request that sets
/// *any* of them keeps exactly what it asked for.
#[derive(Debug, Clone, Default)]
pub struct GuidanceDefaults {
    pub schedule: GuidanceSchedule,
    pub strategy: GuidanceStrategy,
    pub adaptive: Option<AdaptiveConfig>,
}

impl GuidanceDefaults {
    /// The serving defaults a validated engine config implies.
    pub fn from_engine(cfg: &EngineConfig) -> GuidanceDefaults {
        GuidanceDefaults {
            schedule: cfg.schedule.clone(),
            strategy: cfg.guidance_strategy,
            adaptive: cfg.adaptive,
        }
    }
}

/// A running server (listener thread + per-connection threads).
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve in background threads.
    pub fn start(coordinator: Arc<Coordinator>, bind: &str) -> Result<Server> {
        Self::start_with_defaults(coordinator, bind, GuidanceDefaults::default())
    }

    /// Bind and serve with server-side guidance defaults: requests whose
    /// payload carries none of the guidance fields (schedule, strategy,
    /// adaptive) run the configured default triple — the `[engine]` /
    /// `[guidance]` TOML and `serve --adaptive`/schedule-flag surface.
    /// A request that sets any of those fields keeps them untouched.
    pub fn start_with_defaults(
        coordinator: Arc<Coordinator>,
        bind: &str,
        defaults: GuidanceDefaults,
    ) -> Result<Server> {
        Self::start_backend(Backend::Single(coordinator), bind, defaults)
    }

    /// Bind and serve in front of a replica cluster (`serve --replicas`).
    pub fn start_cluster(
        set: Arc<ReplicaSet>,
        bind: &str,
        defaults: GuidanceDefaults,
    ) -> Result<Server> {
        Self::start_backend(Backend::Cluster(set), bind, defaults)
    }

    /// Bind and serve any [`Backend`].
    pub fn start_backend(
        backend: Backend,
        bind: &str,
        defaults: GuidanceDefaults,
    ) -> Result<Server> {
        let backend = Arc::new(backend);
        let listener = TcpListener::bind(bind)
            .map_err(|e| Error::io(format!("binding {bind}"), e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::io("local_addr", e))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let defaults = Arc::new(defaults);
        let handle = std::thread::spawn(move || {
            listener.set_nonblocking(false).ok();
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let backend = Arc::clone(&backend);
                        let stop3 = Arc::clone(&stop2);
                        let defaults = Arc::clone(&defaults);
                        std::thread::spawn(move || {
                            let _ = handle_connection(s, backend, stop3, defaults);
                        });
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server { addr, stop, handle: Some(handle) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Whether a `shutdown` op (or [`Server::stop`]) has stopped the
    /// listener — what the `serve` command polls to exit cleanly.
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Request the listener to stop (it wakes on the next connection).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the listener so `incoming()` yields once more
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Plain-HTTP Prometheus scrape endpoint (`serve --metrics-addr`, or
/// `[telemetry] metrics_addr` in config).
///
/// Prometheus only speaks HTTP, and no HTTP stack exists in the offline
/// registry snapshot — but a scraper needs exactly one thing: `GET`
/// anything, get the exposition back. So this listener hand-rolls that
/// sliver of HTTP/1.1: read the request head, answer `200 OK` with
/// `Content-Type: text/plain; version=0.0.4` and the current registry
/// render, close. One connection per scrape, no keep-alive.
pub struct MetricsScrape {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsScrape {
    /// Bind `bind` and serve scrapes of `telemetry` until dropped.
    pub fn start(telemetry: Arc<Telemetry>, bind: &str) -> Result<MetricsScrape> {
        let listener = TcpListener::bind(bind)
            .map_err(|e| Error::io(format!("binding metrics endpoint {bind}"), e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::io("local_addr", e))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let _ = serve_scrape(s, &telemetry);
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(MetricsScrape { addr, stop, handle: Some(handle) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop the scrape listener (it wakes on the next connection).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsScrape {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_scrape(stream: TcpStream, telemetry: &Arc<Telemetry>) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let head = line.trim().to_string();
    // drain the request headers; any path scrapes the one registry
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 || line.trim().is_empty() {
            break;
        }
    }
    let (status, body) = if head.starts_with("GET ") || head.starts_with("HEAD ") {
        ("200 OK", telemetry.render_prometheus())
    } else {
        ("405 Method Not Allowed", String::new())
    };
    let payload = if head.starts_with("HEAD ") { "" } else { body.as_str() };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {PROMETHEUS_CONTENT_TYPE}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{payload}",
        body.len()
    );
    writer.write_all(response.as_bytes())?;
    writer.flush()
}

fn handle_connection(
    stream: TcpStream,
    backend: Arc<Backend>,
    stop: Arc<AtomicBool>,
    defaults: Arc<GuidanceDefaults>,
) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let response = dispatch(&line, &backend, &stop, &defaults);
        writer.write_all(response.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if stop.load(Ordering::SeqCst) {
            let _ = peer;
            return Ok(());
        }
    }
}

fn dispatch(
    line: &str,
    backend: &Arc<Backend>,
    stop: &Arc<AtomicBool>,
    defaults: &GuidanceDefaults,
) -> Value {
    let parsed = match json::from_str(line) {
        Ok(v) => v,
        Err(e) => return err_response(None, &format!("bad json: {e}")),
    };
    let id = parsed.get("id").and_then(Value::as_i64);
    match parsed.get("op").and_then(Value::as_str) {
        Some("ping") => ok_base(id).with("pong", true),
        Some("stats") => backend.stats_value(id),
        Some("shutdown") => {
            stop.store(true, Ordering::SeqCst);
            ok_base(id).with("stopping", true)
        }
        Some("metrics") => match backend.telemetry() {
            Some(t) => ok_base(id)
                .with("content_type", PROMETHEUS_CONTENT_TYPE)
                .with("body", t.render_prometheus().as_str()),
            None => err_response(id, "telemetry disabled"),
        },
        Some("trace") => match backend.telemetry() {
            Some(t) => {
                // `trace` names the span — never `id`, which the
                // [`Client`] injects on every call for correlation
                match parsed.get("trace").and_then(Value::as_i64) {
                    Some(tid) => match t.traces().span(tid as u64) {
                        Some(span) => ok_base(id).with("span", span.to_json()),
                        None => err_response(id, &format!("unknown trace id {tid}")),
                    },
                    None => {
                        let recent: Vec<Value> =
                            t.traces().recent(64).iter().map(|&i| Value::int(i as i64)).collect();
                        ok_base(id)
                            .with("recent", Value::Arr(recent))
                            .with("evicted", t.traces().evicted() as i64)
                    }
                }
            }
            None => err_response(id, "telemetry disabled"),
        },
        Some("generate") => match parse_request(&parsed) {
            // submit through the QoS path: a shed request comes back as
            // a structured 429/503 response, a queue-expired one as 504
            Ok(mut sr) => {
                // server-side guidance defaults: applied wholesale, and
                // only when the client set none of the guidance fields —
                // a request that picked any schedule/strategy/adaptive
                // field keeps exactly what it asked for
                if !sr.schedule_set && !sr.strategy_set && !sr.adaptive_set {
                    sr.request.schedule = defaults.schedule.clone();
                    sr.request.strategy = defaults.strategy;
                    sr.request.adaptive = defaults.adaptive;
                }
                match backend.submit_qos(sr.request.clone(), sr.meta) {
                    Ok(ticket) => {
                        // read the admission's cache outcome after the
                        // wait: hit/dedup are decided synchronously at
                        // submit, so the cell is already settled
                        let outcome = ticket.outcome_cell();
                        match ticket.wait() {
                            Ok(out) => {
                                let mut v = render_output(id, &sr, &out);
                                // echoed only when a cache layer keyed
                                // the admission — absent field == caches
                                // off, exactly today's wire shape
                                if let Some(o) = outcome.get() {
                                    v = v.with("cache", o.label());
                                }
                                v
                            }
                            Err(e) => render_failure(id, &e),
                        }
                    }
                    Err(e) => render_failure(id, &e),
                }
            }
            Err(e) => err_response(id, &e.to_string()),
        },
        Some(other) => err_response(id, &format!("unknown op {other:?}")),
        None => err_response(id, "missing op"),
    }
}

fn ok_base(id: Option<i64>) -> Value {
    let v = Value::obj().with("ok", true);
    match id {
        Some(id) => v.with("id", id),
        None => v,
    }
}

fn err_response(id: Option<i64>, msg: &str) -> Value {
    let v = Value::obj().with("ok", false).with("error", msg);
    match id {
        Some(id) => v.with("id", id),
        None => v,
    }
}

/// Blocking client for the JSON-lines protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: i64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::io(format!("connecting {addr}"), e))?;
        let reader = BufReader::new(stream.try_clone().map_err(|e| Error::io("clone", e))?);
        Ok(Client { reader, writer: stream, next_id: 1 })
    }

    /// Send one op object (the `id` field is added automatically) and
    /// block for its response.
    pub fn call(&mut self, mut payload: Value) -> Result<Value> {
        let id = self.next_id;
        self.next_id += 1;
        if let Value::Obj(m) = &mut payload {
            m.insert("id".into(), Value::int(id));
        }
        let line = payload.to_string();
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .and_then(|_| self.writer.flush())
            .map_err(|e| Error::io("sending request", e))?;
        let mut resp = String::new();
        self.reader
            .read_line(&mut resp)
            .map_err(|e| Error::io("reading response", e))?;
        if resp.is_empty() {
            return Err(Error::Protocol("server closed connection".into()));
        }
        let v = json::from_str(&resp)?;
        match v.get("id").and_then(Value::as_i64) {
            Some(rid) if rid == id => Ok(v),
            Some(rid) => Err(Error::Protocol(format!("response id {rid} != request id {id}"))),
            None => Ok(v), // error responses may lack an id
        }
    }

    pub fn ping(&mut self) -> Result<bool> {
        let v = self.call(Value::obj().with("op", "ping"))?;
        Ok(v.get("pong").and_then(Value::as_bool).unwrap_or(false))
    }

    pub fn stats(&mut self) -> Result<Value> {
        self.call(Value::obj().with("op", "stats"))
    }

    pub fn shutdown_server(&mut self) -> Result<()> {
        self.call(Value::obj().with("op", "shutdown"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_helpers() {
        let ok = ok_base(Some(3)).with("x", 1i64);
        assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(ok.get("id").unwrap().as_i64(), Some(3));
        let err = err_response(None, "boom");
        assert_eq!(err.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(err.get("error").unwrap().as_str(), Some("boom"));
    }
}
