//! Standard base64 (RFC 4648, with padding) — used to ship PNG bytes
//! over the JSON-lines protocol.

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes to base64.
pub fn b64encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = *chunk.get(1).unwrap_or(&0) as u32;
        let b2 = *chunk.get(2).unwrap_or(&0) as u32;
        let n = (b0 << 16) | (b1 << 8) | b2;
        out.push(ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { ALPHABET[(n >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { ALPHABET[n as usize & 63] as char } else { '=' });
    }
    out
}

fn decode_char(c: u8) -> Option<u32> {
    match c {
        b'A'..=b'Z' => Some((c - b'A') as u32),
        b'a'..=b'z' => Some((c - b'a' + 26) as u32),
        b'0'..=b'9' => Some((c - b'0' + 52) as u32),
        b'+' => Some(62),
        b'/' => Some(63),
        _ => None,
    }
}

/// Decode base64 (padded). Returns None on malformed input.
pub fn b64decode(s: &str) -> Option<Vec<u8>> {
    let bytes = s.as_bytes();
    if bytes.len() % 4 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for chunk in bytes.chunks(4) {
        let pad = chunk.iter().rev().take_while(|&&c| c == b'=').count();
        if pad > 2 {
            return None;
        }
        let mut n: u32 = 0;
        for (i, &c) in chunk.iter().enumerate() {
            let v = if c == b'=' {
                if i < 4 - pad {
                    return None; // '=' only at the end
                }
                0
            } else {
                decode_char(c)?
            };
            n = (n << 6) | v;
        }
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        assert_eq!(b64encode(b""), "");
        assert_eq!(b64encode(b"f"), "Zg==");
        assert_eq!(b64encode(b"fo"), "Zm8=");
        assert_eq!(b64encode(b"foo"), "Zm9v");
        assert_eq!(b64encode(b"foob"), "Zm9vYg==");
        assert_eq!(b64encode(b"fooba"), "Zm9vYmE=");
        assert_eq!(b64encode(b"foobar"), "Zm9vYmFy");
    }

    #[test]
    fn decode_vectors() {
        assert_eq!(b64decode("Zm9vYmFy").unwrap(), b"foobar");
        assert_eq!(b64decode("Zg==").unwrap(), b"f");
        assert_eq!(b64decode("").unwrap(), b"");
    }

    #[test]
    fn round_trip_binary() {
        let mut rng = crate::rng::Rng::new(1);
        for len in [0usize, 1, 2, 3, 4, 57, 256, 1000] {
            let data: Vec<u8> = (0..len).map(|_| rng.next_below(256) as u8).collect();
            assert_eq!(b64decode(&b64encode(&data)).unwrap(), data, "len {len}");
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(b64decode("a").is_none()); // bad length
        assert!(b64decode("====").is_none());
        assert!(b64decode("Zm9v!b==").is_none());
        assert!(b64decode("Z=9v").is_none()); // '=' in the middle
    }
}
